"""Continuous-batching engine tests.

The two load-bearing properties:

* **parity** — greedy continuous-batching output is identical per
  request to lock-step decode of the same prompt, across all four model
  families (decoder, ssm, moe, encdec), under staggered arrivals,
  ragged prompt/generation lengths, chunked prefill and slot reuse;
* **isolation** — a reused slot carries nothing over from its previous
  occupant (KV rows are fenced by causal masking, SSM/conv state is
  zeroed on admission).

Plus scheduler/cache-manager unit behaviour and the headline
throughput claim (fewer steps than the lock-step baseline on a
staggered heterogeneous workload).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import model as lm
from repro.serve import (
    ContinuousBatchingEngine,
    Request,
    Scheduler,
    ServeConfig,
    SlotCacheManager,
    generate_lockstep,
    generate_reference,
    lockstep_waves,
    poisson_workload,
)

FAMILY_ARCHS = {
    "decoder": "qwen2.5-3b",
    "ssm": "mamba2-1.3b",
    "moe": "kimi-k2-1t-a32b",
    "encdec": "whisper-large-v3",
}
MAX_SEQ = 24


def _setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _run_engine(cfg, params, reqs, *, slots=2, chunk=4, budget=0):
    eng = ContinuousBatchingEngine(
        cfg,
        params,
        ServeConfig(
            max_slots=slots, max_seq=MAX_SEQ, prefill_chunk=chunk,
            token_budget=budget,
        ),
    )
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    return eng, out


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_continuous_matches_lockstep_per_request(family):
    """6 staggered ragged requests through 2 slots (forces slot reuse
    and prefill/decode interleaving) == per-request lock-step decode."""
    cfg, params = _setup(FAMILY_ARCHS[family])
    reqs = poisson_workload(
        cfg, n_requests=6, arrival_rate=0.7, prompt_len=(3, 7),
        gen_len=(3, 9), seed=42,
    )
    eng, out = _run_engine(cfg, params, reqs)
    assert len(out) == len(reqs)
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens,
            max_seq=MAX_SEQ, frames=r.frames,
        )
        np.testing.assert_array_equal(
            out[r.rid], ref, err_msg=f"{family} rid={r.rid}"
        )


def test_slot_reuse_does_not_leak_state():
    """SSM state is positionless — a leaked slot would corrupt the next
    occupant's tokens. Serve 3 sequential waves through ONE slot and
    check each against its own fresh reference."""
    cfg, params = _setup(FAMILY_ARCHS["ssm"])
    reqs = poisson_workload(
        cfg, n_requests=3, arrival_rate=1e9, prompt_len=(4, 6),
        gen_len=(5, 8), seed=7,
    )
    eng, out = _run_engine(cfg, params, reqs, slots=1)
    for r in reqs:
        ref = generate_reference(cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ)
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"rid={r.rid}")


def test_reset_slots_zeroes_only_freed_rows():
    cfg, _ = _setup(FAMILY_ARCHS["ssm"])
    mgr = SlotCacheManager(cfg, 3, 8)
    dirty = jax.tree.map(lambda a: jnp.ones_like(a), mgr.cache)
    mgr.cache = dirty
    mgr.reset([1])
    for leaf in jax.tree.leaves(mgr.cache):
        assert float(jnp.abs(leaf[:, 1]).max()) == 0.0
        assert float(jnp.abs(leaf[:, 0]).min()) == 1.0
        assert float(jnp.abs(leaf[:, 2]).min()) == 1.0


def test_cache_manager_alloc_free():
    cfg, _ = _setup(FAMILY_ARCHS["decoder"])
    mgr = SlotCacheManager(cfg, 2, 8)
    a, b = mgr.alloc(), mgr.alloc()
    assert {a, b} == {0, 1} and mgr.n_free == 0
    with pytest.raises(RuntimeError):
        mgr.alloc()
    mgr.pos[a] = 5
    mgr.free(a)
    assert mgr.n_free == 1 and mgr.pos[a] == 0
    assert mgr.alloc() == a
    mgr.free(b)  # valid free
    with pytest.raises(ValueError):
        mgr.free(b)  # double free rejected


def test_serve_config_rejects_negative_budget():
    with pytest.raises(ValueError):
        ServeConfig(max_slots=2, max_seq=32, token_budget=-1)


def test_scheduler_budget_and_fifo():
    cfg = ServeConfig(max_slots=4, max_seq=64, prefill_chunk=8, token_budget=6)
    sched = Scheduler(cfg)
    mk = lambda rid, p, filled, arrival: Request(
        rid=rid, prompt=np.zeros(p, np.int32), max_new_tokens=4, arrival=arrival
    )
    # slots 0,1 decoding; slots 2,3 prefilling (arrivals 5 and 2)
    by_slot = {}
    for s, (p, filled, arr) in {
        0: (4, 4, 0), 1: (4, 4, 0), 2: (20, 0, 5), 3: (20, 0, 2)
    }.items():
        r = mk(s, p, filled, arr)
        r.prefilled = filled
        if filled:
            r.generated = [1]
        by_slot[s] = r
    plan = sched.plan(by_slot)
    # decodes first (1+1), remaining 4 tokens to the OLDER prefill (slot 3)
    assert plan[0] == 1 and plan[1] == 1
    assert plan[3] == 4 and 2 not in plan
    assert sum(plan.values()) <= cfg.budget
    # admission: FIFO and arrival-gated
    waiting = [mk(9, 4, 0, 0), mk(10, 4, 0, 3)]
    assert [r.rid for r in sched.admit(waiting, 2, clock=0)] == [9]
    assert [r.rid for r in sched.admit(waiting, 2, clock=3)] == [9, 10]
    assert [r.rid for r in sched.admit(waiting, 1, clock=3)] == [9]


def test_scheduler_rotates_decode_under_tight_budget():
    """budget < decoding slots must round-robin, not starve high ids."""
    cfg = ServeConfig(max_slots=3, max_seq=64, prefill_chunk=4, token_budget=1)
    sched = Scheduler(cfg)
    by_slot = {}
    for s in range(3):
        r = Request(rid=s, prompt=np.zeros(2, np.int32), max_new_tokens=50)
        r.prefilled = 2
        r.generated = [1]
        by_slot[s] = r
    served = [next(iter(sched.plan(by_slot))) for _ in range(6)]
    assert set(served) == {0, 1, 2}, served  # everyone gets a turn


def test_continuous_beats_lockstep_on_staggered_workload():
    """The acceptance criterion: fewer compute steps (higher generated
    tokens/step) than the static lock-step waves at equal capacity."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    capacity = 3
    reqs = poisson_workload(
        cfg, n_requests=9, arrival_rate=2.0, prompt_len=6,
        gen_len=(3, 14), seed=3, uniform_prompts=True,
    )
    eng, out = _run_engine(cfg, params, reqs, slots=capacity, chunk=6)
    engine_steps = eng.stats()["compute_steps"]

    lockstep_steps = 0
    for wave in lockstep_waves(reqs, capacity):
        res = generate_lockstep(
            cfg, params,
            np.stack([r.prompt for r in wave]),
            [r.max_new_tokens for r in wave],
            max_seq=MAX_SEQ,
        )
        lockstep_steps += res["steps"]
        for r, toks in zip(wave, res["tokens"]):
            np.testing.assert_array_equal(out[r.rid], toks, err_msg=f"rid={r.rid}")

    assert engine_steps < lockstep_steps, (engine_steps, lockstep_steps)
    gen_total = sum(len(v) for v in out.values())
    assert gen_total / engine_steps > gen_total / lockstep_steps


def test_engine_respects_arrivals_and_capacity():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    reqs = [
        Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new_tokens=3),
        Request(rid=1, prompt=np.arange(4, dtype=np.int32), max_new_tokens=3,
                arrival=50),
    ]
    eng, out = _run_engine(cfg, params, reqs, slots=2)
    assert eng.idle_steps > 0  # waited for rid=1's arrival
    r1 = eng.finished[1]
    assert r1.first_token_step >= 50
    assert len(out[0]) == 3 and len(out[1]) == 3


def test_submit_rejects_oversized_request():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    eng = ContinuousBatchingEngine(
        cfg, params, ServeConfig(max_slots=1, max_seq=8)
    )
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(6, np.int32), max_new_tokens=4))
