"""Continuous-batching engine tests.

The load-bearing properties:

* **parity** — greedy AND sampled output is identical per request to
  lock-step decode of the same prompt, across all four model families
  (decoder, ssm, moe, encdec) and BOTH cache layouts (contiguous slots
  and the paged/block pool), under staggered arrivals, ragged
  prompt/generation lengths, chunked prefill, slot reuse and — paged —
  preemption (recompute for greedy, host swap for sampled, including
  victims evicted mid-PREFILL);
* **isolation** — a reused slot carries nothing over from its previous
  occupant (KV rows are fenced by causal masking, SSM/conv state is
  zeroed on admission), and a reused *page* reads back zero before its
  next occupant writes it;
* **allocator soundness** — the block allocator never double-allocates,
  conserves the pool, and rejects double-free (randomized-ops property
  test).

Plus scheduler/cache-manager unit behaviour and the headline
throughput claim (fewer steps than the lock-step baseline on a
staggered heterogeneous workload).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import model as lm
from repro.serve import (
    PREFILL,
    WAITING,
    BlockAllocator,
    ContinuousBatchingEngine,
    NoFreeBlocks,
    PagedCacheManager,
    Request,
    SamplingParams,
    Scheduler,
    ServeConfig,
    SlotCacheManager,
    TokenEvent,
    generate_lockstep,
    generate_reference,
    lockstep_waves,
    longtail_workload,
    poisson_workload,
)

FAMILY_ARCHS = {
    "decoder": "qwen2.5-3b",
    "ssm": "mamba2-1.3b",
    "moe": "kimi-k2-1t-a32b",
    "encdec": "whisper-large-v3",
}
MAX_SEQ = 24

# paged grid point: 4-token pages, pool ~2/3 of worst case so block
# dynamics (lazy growth, reuse) actually exercise under MAX_SEQ=24
PAGED_KW = dict(block_size=4, n_blocks=8)

# engine variants for the parity grids: the contiguous cache, the paged
# cache with the gather path, and the paged cache attending in place
# via the Pallas kernel (attn_kernel is a no-op for pure-SSM families,
# which still must pass through the same config unharmed)
ENGINE_KW = {
    "contiguous": {},
    "paged": PAGED_KW,
    "paged_kernel": dict(PAGED_KW, attn_kernel=True),
}


def _setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _run_engine(cfg, params, reqs, *, slots=2, chunk=4, budget=0, **kw):
    eng = ContinuousBatchingEngine(
        cfg,
        params,
        ServeConfig(
            max_slots=slots, max_seq=MAX_SEQ, prefill_chunk=chunk,
            token_budget=budget, **kw,
        ),
    )
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    return eng, out


@pytest.mark.parametrize("engine", sorted(ENGINE_KW))
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_engine_matches_lockstep_per_request(family, engine):
    """The parity grid: 6 staggered ragged requests through 2 slots
    (forces slot reuse and prefill/decode interleaving) == per-request
    lock-step decode — for the contiguous cache, the paged gather,
    and the in-place paged-attention kernel."""
    cfg, params = _setup(FAMILY_ARCHS[family])
    reqs = poisson_workload(
        cfg, n_requests=6, arrival_rate=0.7, prompt_len=(3, 7),
        gen_len=(3, 9), seed=42,
    )
    kw = ENGINE_KW[engine]
    eng, out = _run_engine(cfg, params, reqs, **kw)
    assert len(out) == len(reqs)
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens,
            max_seq=MAX_SEQ, frames=r.frames,
        )
        np.testing.assert_array_equal(
            out[r.rid], ref, err_msg=f"{family}/{engine} rid={r.rid}"
        )


def test_paged_preemption_keeps_greedy_parity():
    """A pool too small for the working set forces preempt-to-WAITING;
    recompute-on-readmission must keep every output bit-exact."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    reqs = poisson_workload(
        cfg, n_requests=6, arrival_rate=2.0, prompt_len=(3, 7),
        gen_len=(6, 12), seed=5,
    )
    eng, out = _run_engine(
        cfg, params, reqs, slots=3, block_size=4, n_blocks=7,
    )
    assert eng.preemptions > 0  # the point of this pool size
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ,
        )
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"rid={r.rid}")


def test_slot_reuse_does_not_leak_state():
    """SSM state is positionless — a leaked slot would corrupt the next
    occupant's tokens. Serve 3 sequential waves through ONE slot and
    check each against its own fresh reference."""
    cfg, params = _setup(FAMILY_ARCHS["ssm"])
    reqs = poisson_workload(
        cfg, n_requests=3, arrival_rate=1e9, prompt_len=(4, 6),
        gen_len=(5, 8), seed=7,
    )
    eng, out = _run_engine(cfg, params, reqs, slots=1)
    for r in reqs:
        ref = generate_reference(cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ)
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"rid={r.rid}")


def test_reset_slots_zeroes_only_freed_rows():
    cfg, _ = _setup(FAMILY_ARCHS["ssm"])
    mgr = SlotCacheManager(cfg, 3, 8)
    dirty = jax.tree.map(lambda a: jnp.ones_like(a), mgr.cache)
    mgr.cache = dirty
    mgr.reset([1])
    for leaf in jax.tree.leaves(mgr.cache):
        assert float(jnp.abs(leaf[:, 1]).max()) == 0.0
        assert float(jnp.abs(leaf[:, 0]).min()) == 1.0
        assert float(jnp.abs(leaf[:, 2]).min()) == 1.0


def test_cache_manager_alloc_free():
    cfg, _ = _setup(FAMILY_ARCHS["decoder"])
    mgr = SlotCacheManager(cfg, 2, 8)
    a, b = mgr.alloc(), mgr.alloc()
    assert {a, b} == {0, 1} and mgr.n_free == 0
    with pytest.raises(RuntimeError):
        mgr.alloc()
    mgr.pos[a] = 5
    mgr.free(a)
    assert mgr.n_free == 1 and mgr.pos[a] == 0
    assert mgr.alloc() == a
    mgr.free(b)  # valid free
    with pytest.raises(ValueError):
        mgr.free(b)  # double free rejected


def test_block_allocator_properties():
    """Randomized-ops property test over the page free list: no page is
    ever held twice, free + held always conserves the pool, double-free
    raises, and exhaustion raises without corrupting the pool."""
    rng = np.random.default_rng(123)
    n_blocks = 13
    alloc = BlockAllocator(n_blocks)
    held = []  # pages we believe we own
    for _ in range(500):
        op = rng.random()
        if op < 0.5:  # alloc a random burst
            n = int(rng.integers(0, 4))
            if n > alloc.n_free:
                with pytest.raises(NoFreeBlocks):
                    alloc.alloc(n)
            else:
                got = alloc.alloc(n)
                assert len(got) == n
                assert not (set(got) & set(held)), "double allocation"
                held.extend(got)
        elif op < 0.9 and held:  # free a random subset
            k = int(rng.integers(1, len(held) + 1))
            idx = rng.choice(len(held), size=k, replace=False)
            out = [held[i] for i in idx]
            alloc.free(out)
            held = [p for i, p in enumerate(held) if i not in set(idx)]
        elif held:  # double-free rejected, pool untouched
            page = held[int(rng.integers(len(held)))]
            before = alloc.n_free
            with pytest.raises(ValueError):
                alloc.free([page, page])  # duplicate ids in one call
            assert alloc.n_free == before
            alloc.free([page])
            with pytest.raises(ValueError):
                alloc.free([page])  # already back in the pool
            assert alloc.n_free == before + 1
            held.remove(page)
        # conservation invariant after every op
        assert alloc.n_free + len(held) == n_blocks
        assert len(set(held)) == len(held)


def test_paged_freed_pages_read_back_zero():
    """Zero-on-free, extended to the KV pool: dirty a slot's pages via
    real writes, free the slot, and read the pages back as zeros from
    the device before any reuse."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    mgr = PagedCacheManager(cfg, 2, 16, block_size=4, n_blocks=6)
    slot = mgr.alloc()
    assert mgr.ensure(slot, 7)  # 2 pages
    pages = mgr.block_tables[slot, :2].tolist()
    # scatter real k/v into the slot's pages through the model step
    toks = jnp.asarray(np.arange(7, dtype=np.int32)[None].repeat(2, 0))
    _, mgr.cache = lm.decode_slots(
        cfg, params, toks, mgr.cache,
        jnp.zeros((2,), jnp.int32),
        jnp.asarray(np.array([7, 0], np.int32)),
        block_tables=jnp.asarray(mgr.block_tables),
    )
    assert any(
        float(np.abs(leaf).max()) > 0 for p in pages for leaf in mgr.page_view(p)
    ), "writes never landed — test is vacuous"
    mgr.free(slot)
    for p in pages:
        for leaf in mgr.page_view(p):
            assert float(np.abs(leaf).max()) == 0.0, f"page {p} not zeroed"
    # and the freed pages are immediately reusable
    slot2 = mgr.alloc()
    assert mgr.ensure(slot2, 16)
    assert mgr.n_free_blocks == 2


def test_scheduler_admission_gated_on_free_blocks():
    """Paged admission: FIFO prefix limited by the free-page count; a
    head-of-line shortfall blocks later (even smaller) requests."""
    cfg = ServeConfig(max_slots=4, max_seq=32, block_size=4)
    sched = Scheduler(cfg)

    def mk(rid, p):
        return Request(rid=rid, prompt=np.zeros(p, np.int32), max_new_tokens=4)

    waiting = [mk(0, 8), mk(1, 8), mk(2, 4)]  # 2 + 2 + 1 pages
    got = sched.admit(waiting, 4, clock=0, n_free_blocks=5)
    assert [r.rid for r in got] == [0, 1, 2]
    got = sched.admit(waiting, 4, clock=0, n_free_blocks=3)
    assert [r.rid for r in got] == [0]  # rid=1 shortfall blocks rid=2 too
    got = sched.admit(waiting, 4, clock=0, n_free_blocks=1)
    assert got == []


def test_decode_width_ladder_picks_smallest_fit():
    """Mixed steps stop padding to prefill_chunk: the engine compiles
    widths {1, 4, chunk} and picks the smallest that fits the plan."""
    cfg = ServeConfig(max_slots=2, max_seq=32, prefill_chunk=8)
    assert cfg.widths == (1, 4, 8)
    eng = ContinuousBatchingEngine.__new__(ContinuousBatchingEngine)
    eng.serve_cfg = cfg
    assert eng._pick_width({0: 1, 1: 1}) == 1
    assert eng._pick_width({0: 1, 1: 3}) == 4
    assert eng._pick_width({0: 4, 1: 1}) == 4
    assert eng._pick_width({0: 5}) == 8
    legacy = ServeConfig(max_slots=2, max_seq=32, prefill_chunk=8,
                         decode_widths=(1,))
    assert legacy.widths == (1, 8)


def test_serve_config_rejects_negative_budget():
    with pytest.raises(ValueError):
        ServeConfig(max_slots=2, max_seq=32, token_budget=-1)


def test_serve_config_paged_validation():
    with pytest.raises(ValueError):
        ServeConfig(max_slots=2, max_seq=32, n_blocks=4)  # needs block_size
    with pytest.raises(ValueError):
        ServeConfig(max_slots=2, max_seq=32, block_size=-1)
    cfg = ServeConfig(max_slots=3, max_seq=24, block_size=4)
    assert cfg.paged and cfg.blocks_per_slot == 6 and cfg.total_blocks == 18
    assert not ServeConfig(max_slots=3, max_seq=24).paged


def test_paged_engine_rejects_request_larger_than_pool():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    eng = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=2, max_seq=MAX_SEQ, block_size=4, n_blocks=4),
    )
    with pytest.raises(ValueError):  # 20 tokens -> 5 pages > 4-page pool
        eng.submit(Request(rid=0, prompt=np.zeros(10, np.int32),
                           max_new_tokens=11))


def test_scheduler_budget_and_fifo():
    cfg = ServeConfig(max_slots=4, max_seq=64, prefill_chunk=8, token_budget=6)
    sched = Scheduler(cfg)
    def mk(rid, p, filled, arrival):
        return Request(
            rid=rid, prompt=np.zeros(p, np.int32), max_new_tokens=4,
            arrival=arrival,
        )
    # slots 0,1 decoding; slots 2,3 prefilling (arrivals 5 and 2)
    by_slot = {}
    for s, (p, filled, arr) in {
        0: (4, 4, 0), 1: (4, 4, 0), 2: (20, 0, 5), 3: (20, 0, 2)
    }.items():
        r = mk(s, p, filled, arr)
        r.prefilled = filled
        if filled:
            r.generated = [1]
        by_slot[s] = r
    plan = sched.plan(by_slot)
    # decodes first (1+1), remaining 4 tokens to the OLDER prefill (slot 3)
    assert plan[0] == 1 and plan[1] == 1
    assert plan[3] == 4 and 2 not in plan
    assert sum(plan.values()) <= cfg.budget
    # admission: FIFO and arrival-gated
    waiting = [mk(9, 4, 0, 0), mk(10, 4, 0, 3)]
    assert [r.rid for r in sched.admit(waiting, 2, clock=0)] == [9]
    assert [r.rid for r in sched.admit(waiting, 2, clock=3)] == [9, 10]
    assert [r.rid for r in sched.admit(waiting, 1, clock=3)] == [9]


def test_scheduler_rotates_decode_under_tight_budget():
    """budget < decoding slots must round-robin, not starve high ids."""
    cfg = ServeConfig(max_slots=3, max_seq=64, prefill_chunk=4, token_budget=1)
    sched = Scheduler(cfg)
    by_slot = {}
    for s in range(3):
        r = Request(rid=s, prompt=np.zeros(2, np.int32), max_new_tokens=50)
        r.prefilled = 2
        r.generated = [1]
        by_slot[s] = r
    served = [next(iter(sched.plan(by_slot))) for _ in range(6)]
    assert set(served) == {0, 1, 2}, served  # everyone gets a turn


def test_paged_admits_more_concurrency_at_equal_memory():
    """The paging claim, in miniature: at identical cache memory
    (3 slots × 24 rows == 18 pages × 4 tokens) a long-tail workload
    admits strictly more concurrent requests through the paged engine
    — concurrency is bounded by actual use, not worst case — with
    identical greedy outputs."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    def wl():
        return longtail_workload(
            cfg, n_requests=10, arrival_rate=3.0, prompt_len=(3, 6),
            gen_short=(3, 5), gen_long=(14, 18), tail_frac=0.2, seed=9,
        )
    cont_eng, cont_out = _run_engine(cfg, params, wl(), slots=3)
    paged_eng, paged_out = _run_engine(
        cfg, params, wl(), slots=6, block_size=4, n_blocks=18,
    )
    assert paged_eng.peak_concurrency > cont_eng.peak_concurrency
    for rid in cont_out:
        np.testing.assert_array_equal(
            paged_out[rid], cont_out[rid], err_msg=f"rid={rid}"
        )


def test_continuous_beats_lockstep_on_staggered_workload():
    """The acceptance criterion: fewer compute steps (higher generated
    tokens/step) than the static lock-step waves at equal capacity."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    capacity = 3
    reqs = poisson_workload(
        cfg, n_requests=9, arrival_rate=2.0, prompt_len=6,
        gen_len=(3, 14), seed=3, uniform_prompts=True,
    )
    eng, out = _run_engine(cfg, params, reqs, slots=capacity, chunk=6)
    engine_steps = eng.stats()["compute_steps"]

    lockstep_steps = 0
    for wave in lockstep_waves(reqs, capacity):
        res = generate_lockstep(
            cfg, params,
            np.stack([r.prompt for r in wave]),
            [r.max_new_tokens for r in wave],
            max_seq=MAX_SEQ,
        )
        lockstep_steps += res["steps"]
        for r, toks in zip(wave, res["tokens"], strict=True):
            np.testing.assert_array_equal(out[r.rid], toks, err_msg=f"rid={r.rid}")

    assert engine_steps < lockstep_steps, (engine_steps, lockstep_steps)
    gen_total = sum(len(v) for v in out.values())
    assert gen_total / engine_steps > gen_total / lockstep_steps


def test_engine_respects_arrivals_and_capacity():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    reqs = [
        Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new_tokens=3),
        Request(rid=1, prompt=np.arange(4, dtype=np.int32), max_new_tokens=3,
                arrival=50),
    ]
    eng, out = _run_engine(cfg, params, reqs, slots=2)
    assert eng.idle_steps > 0  # waited for rid=1's arrival
    r1 = eng.finished[1]
    assert r1.first_token_step >= 50
    assert len(out[0]) == 3 and len(out[1]) == 3


def test_submit_rejects_oversized_request():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    eng = ContinuousBatchingEngine(
        cfg, params, ServeConfig(max_slots=1, max_seq=8)
    )
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(6, np.int32), max_new_tokens=4))


# ----------------------------------------------------------------------
# sampling
# ----------------------------------------------------------------------


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_sampling_key_data_matches_prngkey():
    """The raw uint32[2] lane the engine ships in step state must be the
    same key PRNGKey(seed) would build — that identity is what lets the
    host-side numpy path and jax.random.fold_in agree on every draw."""
    for seed in (0, 1, 42, 123456789):
        np.testing.assert_array_equal(
            SamplingParams(temperature=1.0, seed=seed).key_data(),
            jax.random.key_data(jax.random.PRNGKey(seed)),
        )


def test_request_preempt_raises_for_sampled():
    """The latent recompute-assumes-greedy bug, now a checked invariant:
    recompute preemption of a sampled request must refuse loudly."""
    req = Request(
        rid=0, prompt=np.zeros(3, np.int32), max_new_tokens=2,
        sampling=SamplingParams(temperature=1.0, seed=7),
    )
    with pytest.raises(RuntimeError, match="swap"):
        req.preempt()
    assert req.preemptions == 0  # refused, not half-applied
    req.preempt_swap(object())  # the swap path accepts any request
    assert req.preemptions == 1 and req.state == WAITING


@pytest.mark.parametrize("engine", sorted(ENGINE_KW))
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_engine_matches_lockstep_sampled(family, engine):
    """Sampled parity grid: per-request temperature/top-k/top-p with
    per-request seeds through the continuous engine == the sampled
    lock-step oracle, token-for-token. The streaming callback events
    are checked against the same outputs for free."""
    cfg, params = _setup(FAMILY_ARCHS[family])
    reqs = poisson_workload(
        cfg, n_requests=5, arrival_rate=0.8, prompt_len=(3, 7),
        gen_len=(3, 8), seed=13, temperature=0.8, top_k=12, top_p=0.9,
    )
    assert all(not r.sampling.greedy for r in reqs)
    assert len({r.sampling.seed for r in reqs}) == len(reqs)
    kw = ENGINE_KW[engine]
    eng = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=2, max_seq=MAX_SEQ, prefill_chunk=4, **kw),
    )
    for r in reqs:
        eng.submit(r)
    events = []
    out = eng.run(on_token=events.append)
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ,
            frames=r.frames, sampling=r.sampling,
        )
        np.testing.assert_array_equal(
            out[r.rid], ref, err_msg=f"{family}/{engine} rid={r.rid}"
        )
    # the event stream IS the outputs, with is_last exactly once per rid
    per = {}
    for ev in events:
        per.setdefault(ev.rid, []).append(ev.token)
    for r in reqs:
        np.testing.assert_array_equal(
            np.asarray(per[r.rid], np.int32), out[r.rid]
        )
    assert sum(1 for ev in events if ev.is_last) == len(reqs)


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_sampled_determinism_under_forced_preemption(family):
    """The headline bugfix claim: a seeded sampled workload through a
    pool too small for the working set (forced swap evictions) is
    bit-identical to the same workload through a pressure-free pool."""
    cfg, params = _setup(FAMILY_ARCHS[family])

    def wl():
        return poisson_workload(
            cfg, n_requests=6, arrival_rate=2.0, prompt_len=(3, 7),
            gen_len=(6, 12), seed=5, temperature=0.7, top_k=12,
        )

    forced_eng, forced_out = _run_engine(
        cfg, params, wl(), slots=3, block_size=4, n_blocks=7,
    )
    assert forced_eng.swap_preemptions > 0, "pool never pressured — vacuous"
    assert forced_eng.recompute_preemptions == 0  # auto never recomputes sampled
    free_eng, free_out = _run_engine(
        cfg, params, wl(), slots=3, block_size=4, n_blocks=18,
    )
    assert free_eng.preemptions == 0, "reference run was pressured — vacuous"
    for rid in free_out:
        np.testing.assert_array_equal(
            forced_out[rid], free_out[rid], err_msg=f"{family} rid={rid}"
        )


def test_greedy_swap_and_recompute_agree():
    """Same greedy workload under forced preemption, both policies:
    identical outputs, and swap finishes in no more engine steps (it
    re-prefills nothing)."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])

    def wl():
        return poisson_workload(
            cfg, n_requests=6, arrival_rate=2.0, prompt_len=(3, 7),
            gen_len=(6, 12), seed=5,
        )

    swap_eng, swap_out = _run_engine(
        cfg, params, wl(), slots=3, block_size=4, n_blocks=7, preempt="swap",
    )
    rec_eng, rec_out = _run_engine(
        cfg, params, wl(), slots=3, block_size=4, n_blocks=7,
        preempt="recompute",
    )
    assert swap_eng.swap_preemptions > 0
    assert rec_eng.recompute_preemptions > 0
    for rid in rec_out:
        np.testing.assert_array_equal(
            swap_out[rid], rec_out[rid], err_msg=f"rid={rid}"
        )
    assert (
        swap_eng.stats()["compute_steps"] <= rec_eng.stats()["compute_steps"]
    )


@pytest.mark.parametrize("mode", ["swap", "recompute"])
def test_mid_prefill_preemption_keeps_parity(mode):
    """A victim evicted while STILL PREFILLING its original prompt: the
    swap path must resume the chunked prefill where it stopped, the
    recompute path must restart the context from zero (empty-generated
    branch) — both ending bit-exact vs the lock-step oracle."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    sp = (
        SamplingParams(temperature=0.7, top_k=8, seed=123)
        if mode == "swap"
        else SamplingParams()
    )
    req = Request(
        rid=0, prompt=(np.arange(12, dtype=np.int32) % cfg.vocab),
        max_new_tokens=8, sampling=sp,
    )
    eng = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=2, max_seq=MAX_SEQ, prefill_chunk=4,
                    block_size=4, preempt=mode),
    )
    eng.submit(req)
    eng.step()  # admit + first prefill chunk
    assert req.state == PREFILL and 0 < req.prefilled < req.prompt_len
    eng._preempt(req.slot)
    assert req.preemptions == 1 and req.state == WAITING
    if mode == "swap":
        assert req.swap is not None and req.prefilled == 4  # resumes mid-way
    else:
        assert req.swap is None and req.prefilled == 0  # restarts
        assert req.context_len == req.prompt_len  # nothing generated yet
    out = eng.run()
    ref = generate_reference(
        cfg, params, req.prompt, req.max_new_tokens, max_seq=MAX_SEQ,
        sampling=sp if mode == "swap" else None,
    )
    np.testing.assert_array_equal(out[0], ref)


def test_second_preemption_during_reprefill_keeps_parity():
    """A recompute victim evicted AGAIN while re-prefilling its resumed
    context (prompt + generated tokens): prefill progress through the
    recompute context must restart cleanly a second time."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    req = Request(
        rid=0, prompt=(np.arange(6, dtype=np.int32) % cfg.vocab),
        max_new_tokens=8,
    )
    eng = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=2, max_seq=MAX_SEQ, prefill_chunk=4,
                    block_size=4, preempt="recompute"),
    )
    eng.submit(req)
    guard = 0
    while len(req.generated) < 3:
        eng.step()
        guard += 1
        assert guard < 50
    eng._preempt(req.slot)
    # resumed context = prompt + generated[:-1] (newest re-fed, not cached)
    assert req.preemptions == 1
    assert req.context_len == req.prompt_len + len(req.generated) - 1
    while not (req.state == PREFILL and 0 < req.prefilled < req.context_len):
        eng.step()
        guard += 1
        assert guard < 50
    eng._preempt(req.slot)  # mid-RE-prefill this time
    assert req.preemptions == 2 and req.prefilled == 0
    out = eng.run()
    ref = generate_reference(
        cfg, params, req.prompt, req.max_new_tokens, max_seq=MAX_SEQ,
    )
    np.testing.assert_array_equal(out[0], ref)


def test_swap_roundtrip_restores_device_state():
    """Unit swap cycle: dirty a slot via real model writes, swap out
    (slot + pages freed, pages zeroed), swap back into a fresh slot —
    the staged bundle must land bit-identical at the new pages."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    mgr = PagedCacheManager(cfg, 2, 16, block_size=4, n_blocks=6)
    slot = mgr.alloc()
    assert mgr.ensure(slot, 7)  # 2 pages
    pages = mgr.block_tables[slot, :2].tolist()
    toks = jnp.asarray(np.arange(7, dtype=np.int32)[None].repeat(2, 0))
    _, mgr.cache = lm.decode_slots(
        cfg, params, toks, mgr.cache,
        jnp.zeros((2,), jnp.int32),
        jnp.asarray(np.array([7, 0], np.int32)),
        block_tables=jnp.asarray(mgr.block_tables),
    )
    mgr.pos[slot] = 7
    swapped = mgr.swap_out(slot)
    assert swapped.pos == 7 and swapped.n_pages == 2
    assert swapped.nbytes > 0
    assert mgr.n_free == 2  # slot freed by the swap-out
    for p in pages:  # zero-on-free still holds for swapped-out pages
        for leaf in mgr.page_view(p):
            assert float(np.abs(leaf).max()) == 0.0
    with pytest.raises(ValueError):
        mgr.swap_out(slot)  # free slot has nothing to stage

    slot2 = mgr.alloc()
    assert mgr.swap_in(slot2, swapped)
    assert int(mgr.pos[slot2]) == 7
    new_pages = mgr.block_tables[slot2, :2].tolist()
    restored = lm.swap_out_slot(mgr.cache, slot2, new_pages)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(swapped.data), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_swap_in_fails_cleanly_when_pool_full():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    mgr = PagedCacheManager(cfg, 3, 16, block_size=4, n_blocks=4)
    slot = mgr.alloc()
    assert mgr.ensure(slot, 8)  # 2 pages
    mgr.pos[slot] = 8
    swapped = mgr.swap_out(slot)
    hog = mgr.alloc()
    assert mgr.ensure(hog, 13)  # 4 pages — whole pool
    back = mgr.alloc()
    assert not mgr.swap_in(back, swapped)  # no pages: report, don't raise
    assert int(mgr.pos[back]) == 0  # nothing half-restored


def test_streaming_iterator_matches_finished_outputs():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    reqs = poisson_workload(
        cfg, n_requests=4, arrival_rate=1.0, prompt_len=(3, 6),
        gen_len=(3, 7), seed=21, temperature=0.9, top_p=0.9,
    )
    eng = ContinuousBatchingEngine(
        cfg, params, ServeConfig(max_slots=2, max_seq=MAX_SEQ, prefill_chunk=4)
    )
    for r in reqs:
        eng.submit(r)
    events = list(eng.stream())
    assert all(isinstance(ev, TokenEvent) for ev in events)
    out = {rid: r.tokens() for rid, r in eng.finished.items()}
    per, last = {}, {}
    for ev in events:
        per.setdefault(ev.rid, []).append(ev.token)
        last[ev.rid] = ev.is_last
    for r in reqs:
        np.testing.assert_array_equal(
            np.asarray(per[r.rid], np.int32), out[r.rid]
        )
        assert last[r.rid]  # final event per rid carries is_last
    assert sum(1 for ev in events if ev.is_last) == len(reqs)


# ----------------------------------------------------------------------
# satellite fixes: percentile, config validation, duplicate rids
# ----------------------------------------------------------------------


def test_stats_percentile_nearest_rank():
    """Golden nearest-rank values: p50 of 2 samples is the SMALLER one
    (the old int(p/100*n) index returned the max), p50 of 10 is the
    5th smallest, p99 of 10 is the max, and 1 sample is its own p50."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    eng = ContinuousBatchingEngine(
        cfg, params, ServeConfig(max_slots=1, max_seq=MAX_SEQ)
    )

    def fake(rid, lats):
        r = Request(rid=rid, prompt=np.zeros(1, np.int32),
                    max_new_tokens=len(lats))
        r.token_latencies = list(lats)
        r.generated = [0] * len(lats)
        return r

    eng.finished = {0: fake(0, [0.002, 0.001])}
    assert eng.stats()["p50_token_latency_s"] == 0.001
    eng.finished = {0: fake(0, [i / 1000.0 for i in range(1, 11)])}
    assert eng.stats()["p50_token_latency_s"] == 0.005
    assert eng.stats()["p99_token_latency_s"] == 0.010
    eng.finished = {0: fake(0, [0.004])}
    assert eng.stats()["p50_token_latency_s"] == 0.004
    eng.finished = {}
    assert eng.stats()["p50_token_latency_s"] == 0.0


def test_serve_config_rejects_bad_decode_widths():
    with pytest.raises(ValueError, match="duplicates"):
        ServeConfig(max_slots=2, max_seq=32, prefill_chunk=8,
                    decode_widths=(1, 4, 4))
    with pytest.raises(ValueError, match="exceed prefill_chunk"):
        ServeConfig(max_slots=2, max_seq=32, prefill_chunk=4,
                    decode_widths=(1, 8))


def test_serve_config_rejects_bad_preempt():
    with pytest.raises(ValueError, match="preemption policy"):
        ServeConfig(max_slots=2, max_seq=32, preempt="drop")
    for mode in ("auto", "swap", "recompute"):
        assert ServeConfig(max_slots=2, max_seq=32, preempt=mode).preempt == mode


def test_submit_rejects_duplicate_rid():
    """A duplicate rid would silently overwrite the first request's
    output in ``finished`` — reject across waiting/running/finished."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    eng = ContinuousBatchingEngine(
        cfg, params, ServeConfig(max_slots=2, max_seq=MAX_SEQ)
    )

    def mk(rid):
        return Request(rid=rid, prompt=np.zeros(3, np.int32), max_new_tokens=2)

    eng.submit(mk(7))
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(mk(7))  # still waiting
    eng.run()
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(mk(7))  # finished rids stay reserved
    eng.submit(mk(8))  # fresh rid is fine
    assert len(eng.run()) == 2


# ----------------------------------------------------------------------
# speculative decoding: parity, preemption, rollback, drafter contract
# ----------------------------------------------------------------------

# the rollback-heavy grid point: a drafter with the SAME architecture but
# DIFFERENT weights proposes tokens the target mostly rejects, exercising
# per-step acceptance, KV fencing past the accepted prefix, SSM state
# selection and (paged) page trim on nearly every tick
SPEC_ENGINE_KW = {
    "contiguous": {},
    "paged": dict(block_size=4, n_blocks=12),
}


def _run_spec_engine(cfg, params, reqs, *, slots=2, chunk=4, spec_k=3,
                     draft_cfg=None, draft_params=None, **kw):
    eng = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=slots, max_seq=MAX_SEQ, prefill_chunk=chunk,
                    spec_k=spec_k, **kw),
        draft_cfg=draft_cfg, draft_params=draft_params,
    )
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    return eng, out


@pytest.mark.parametrize("engine", sorted(SPEC_ENGINE_KW))
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_speculative_matches_lockstep_greedy(family, engine):
    """Speculative parity grid, greedy: the spec_k=3 engine with a
    mismatched drafter (same config, different weights — near-zero
    acceptance, so rollback runs constantly) must emit token-for-token
    what the per-request lock-step oracle emits."""
    cfg, params = _setup(FAMILY_ARCHS[family])
    draft_params = lm.init_params(cfg, jax.random.PRNGKey(99))
    reqs = poisson_workload(
        cfg, n_requests=6, arrival_rate=0.7, prompt_len=(3, 7),
        gen_len=(3, 9), seed=42,
    )
    eng, out = _run_spec_engine(
        cfg, params, reqs, draft_params=draft_params,
        **SPEC_ENGINE_KW[engine],
    )
    assert eng.spec_proposed > 0  # speculation actually engaged
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ,
            frames=r.frames,
        )
        np.testing.assert_array_equal(
            out[r.rid], ref, err_msg=f"{family}/{engine} rid={r.rid}"
        )


@pytest.mark.parametrize("engine", sorted(SPEC_ENGINE_KW))
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_speculative_matches_lockstep_sampled(family, engine):
    """Speculative parity grid, sampled: per-request temperature/top-k/
    top-p streams are a pure function of (seed, position), so the
    accepted-prefix emission must reproduce the lock-step oracle exactly
    — same folds, fewer steps. Mismatched drafter keeps rollback hot."""
    cfg, params = _setup(FAMILY_ARCHS[family])
    draft_params = lm.init_params(cfg, jax.random.PRNGKey(99))
    reqs = poisson_workload(
        cfg, n_requests=5, arrival_rate=0.8, prompt_len=(3, 7),
        gen_len=(3, 8), seed=13, temperature=0.8, top_k=12, top_p=0.9,
    )
    eng, out = _run_spec_engine(
        cfg, params, reqs, draft_params=draft_params,
        **SPEC_ENGINE_KW[engine],
    )
    assert eng.spec_proposed > 0
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ,
            frames=r.frames, sampling=r.sampling,
        )
        np.testing.assert_array_equal(
            out[r.rid], ref, err_msg=f"{family}/{engine} rid={r.rid}"
        )


def test_speculative_self_draft_full_acceptance():
    """Drafter == target: every proposal must be accepted (the drafter
    samples the same logits at the same folds), so acceptance is exactly
    1.0 and the engine takes strictly fewer verify steps than spec_k=0
    on the identical workload — while emitting identical tokens."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])

    def wl():
        return poisson_workload(
            cfg, n_requests=5, arrival_rate=0.7, prompt_len=(3, 6),
            gen_len=(6, 12), seed=11,
        )

    spec_eng, spec_out = _run_spec_engine(cfg, params, wl())  # self-draft
    base_eng, base_out = _run_engine(cfg, params, wl())
    for rid in base_out:
        np.testing.assert_array_equal(spec_out[rid], base_out[rid])
    st = spec_eng.stats()
    assert st["spec_proposed"] > 0
    assert st["acceptance_rate"] == 1.0
    assert st["draft_steps"] > 0
    assert st["compute_steps"] < base_eng.stats()["compute_steps"]


def test_speculative_sampled_self_draft_full_acceptance():
    """Self-draft under sampling: the drafter folds the request's own
    PRNG lane at the same absolute positions the target will fold, so
    acceptance stays exactly 1.0 even for stochastic streams."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    reqs = poisson_workload(
        cfg, n_requests=4, arrival_rate=0.9, prompt_len=(3, 6),
        gen_len=(5, 10), seed=23, temperature=0.8, top_k=16, top_p=0.9,
    )
    eng, out = _run_spec_engine(cfg, params, reqs)
    st = eng.stats()
    assert st["spec_proposed"] > 0 and st["acceptance_rate"] == 1.0
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ,
            sampling=r.sampling,
        )
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"rid={r.rid}")


def test_speculative_swap_preemption_determinism():
    """Forced swap evictions with speculation on: drafter state is
    advisory (dropped with the slot, rebuilt by catch-up on resume), so
    the sampled stream through a pressured pool must stay bit-identical
    to the pressure-free run of the same workload."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    draft_params = lm.init_params(cfg, jax.random.PRNGKey(99))

    def wl():
        return poisson_workload(
            cfg, n_requests=6, arrival_rate=2.0, prompt_len=(3, 7),
            gen_len=(6, 12), seed=5, temperature=0.7, top_k=12,
        )

    forced_eng, forced_out = _run_spec_engine(
        cfg, params, wl(), slots=3, draft_params=draft_params,
        block_size=4, n_blocks=7,
    )
    assert forced_eng.swap_preemptions > 0, "pool never pressured — vacuous"
    free_eng, free_out = _run_spec_engine(
        cfg, params, wl(), slots=3, draft_params=draft_params,
        block_size=4, n_blocks=18,
    )
    assert free_eng.preemptions == 0
    for rid in free_out:
        np.testing.assert_array_equal(
            forced_out[rid], free_out[rid], err_msg=f"rid={rid}"
        )


def test_speculative_recompute_preemption_parity():
    """Forced recompute evictions with speculation on: the victim's
    re-prefilled context and re-synced drafter must land back on the
    oracle stream (greedy — recompute's contract)."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    draft_params = lm.init_params(cfg, jax.random.PRNGKey(99))
    reqs = poisson_workload(
        cfg, n_requests=6, arrival_rate=2.0, prompt_len=(3, 7),
        gen_len=(6, 12), seed=5,
    )
    eng, out = _run_spec_engine(
        cfg, params, reqs, slots=3, draft_params=draft_params,
        block_size=4, n_blocks=7, preempt="recompute",
    )
    assert eng.recompute_preemptions > 0, "pool never pressured — vacuous"
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ,
        )
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"rid={r.rid}")


def test_speculative_width_ladder_and_no_spec_optout():
    """spec_k+1 added to decode_widths gives verify chunks their own
    compiled width; a no_spec request rides the same engine one token
    per step — both must stay on the oracle stream, and the opted-out
    request must never contribute proposals."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    reqs = poisson_workload(
        cfg, n_requests=4, arrival_rate=1.0, prompt_len=(3, 6),
        gen_len=(4, 9), seed=31,
    )
    reqs[0].no_spec = True
    eng, out = _run_spec_engine(
        cfg, params, reqs, spec_k=2, decode_widths=(1, 3),
    )
    assert eng.spec_proposed > 0  # the other requests still speculate
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ,
        )
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"rid={r.rid}")


def test_serve_config_rejects_oversized_spec_k():
    with pytest.raises(ValueError, match="spec_k"):
        ServeConfig(max_slots=2, max_seq=32, prefill_chunk=4, spec_k=4)
    with pytest.raises(ValueError, match="spec_k"):
        ServeConfig(max_slots=2, max_seq=32, spec_k=-1)
    assert ServeConfig(max_slots=2, max_seq=32, prefill_chunk=4,
                       spec_k=3).spec_k == 3


def test_sampling_params_rejects_top_k_above_cap():
    """lax.top_k in the jitted step uses a static bound; a request
    asking for a larger k must be refused at construction, not silently
    truncated on device."""
    from repro.launch.steps import TOP_K_CAP

    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=TOP_K_CAP + 1)
    assert SamplingParams(top_k=TOP_K_CAP).top_k == TOP_K_CAP


def test_paged_trim_releases_and_zeroes_pages():
    """Rolling a slot back past rejected draft tokens must return the
    now-unreferenced pages to the pool zeroed (the zero-on-free
    invariant the isolation tests rely on)."""
    cfg, _ = _setup(FAMILY_ARCHS["decoder"])
    mgr = PagedCacheManager(cfg, 2, 16, block_size=4, n_blocks=6)
    slot = mgr.alloc()
    assert mgr.ensure(slot, 11)  # 3 pages
    dropped = mgr.block_tables[slot, 2]
    mgr.cache = jax.tree.map(lambda a: jnp.ones_like(a), mgr.cache)
    mgr.trim(slot, 6)  # keep 2 pages
    assert int(mgr.n_table_blocks[slot]) == 2
    assert mgr.n_free_blocks == 4
    view = mgr.page_view(int(dropped))
    assert view is not None
    for leaf in view:
        assert float(np.abs(leaf).max()) == 0.0
    mgr.trim(slot, 8)  # keep >= have: no-op
    assert int(mgr.n_table_blocks[slot]) == 2
