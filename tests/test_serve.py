"""Continuous-batching engine tests.

The load-bearing properties:

* **parity** — greedy output is identical per request to lock-step
  decode of the same prompt, across all four model families (decoder,
  ssm, moe, encdec) and BOTH cache layouts (contiguous slots and the
  paged/block pool), under staggered arrivals, ragged prompt/generation
  lengths, chunked prefill, slot reuse and — paged — preemption;
* **isolation** — a reused slot carries nothing over from its previous
  occupant (KV rows are fenced by causal masking, SSM/conv state is
  zeroed on admission), and a reused *page* reads back zero before its
  next occupant writes it;
* **allocator soundness** — the block allocator never double-allocates,
  conserves the pool, and rejects double-free (randomized-ops property
  test).

Plus scheduler/cache-manager unit behaviour and the headline
throughput claim (fewer steps than the lock-step baseline on a
staggered heterogeneous workload).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import model as lm
from repro.serve import (
    BlockAllocator,
    ContinuousBatchingEngine,
    NoFreeBlocks,
    PagedCacheManager,
    Request,
    Scheduler,
    ServeConfig,
    SlotCacheManager,
    generate_lockstep,
    generate_reference,
    lockstep_waves,
    longtail_workload,
    poisson_workload,
)

FAMILY_ARCHS = {
    "decoder": "qwen2.5-3b",
    "ssm": "mamba2-1.3b",
    "moe": "kimi-k2-1t-a32b",
    "encdec": "whisper-large-v3",
}
MAX_SEQ = 24

# paged grid point: 4-token pages, pool ~2/3 of worst case so block
# dynamics (lazy growth, reuse) actually exercise under MAX_SEQ=24
PAGED_KW = dict(block_size=4, n_blocks=8)


def _setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _run_engine(cfg, params, reqs, *, slots=2, chunk=4, budget=0, **kw):
    eng = ContinuousBatchingEngine(
        cfg,
        params,
        ServeConfig(
            max_slots=slots, max_seq=MAX_SEQ, prefill_chunk=chunk,
            token_budget=budget, **kw,
        ),
    )
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    return eng, out


@pytest.mark.parametrize("engine", ["contiguous", "paged"])
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_engine_matches_lockstep_per_request(family, engine):
    """The parity grid: 6 staggered ragged requests through 2 slots
    (forces slot reuse and prefill/decode interleaving) == per-request
    lock-step decode — for the contiguous AND the paged cache."""
    cfg, params = _setup(FAMILY_ARCHS[family])
    reqs = poisson_workload(
        cfg, n_requests=6, arrival_rate=0.7, prompt_len=(3, 7),
        gen_len=(3, 9), seed=42,
    )
    kw = PAGED_KW if engine == "paged" else {}
    eng, out = _run_engine(cfg, params, reqs, **kw)
    assert len(out) == len(reqs)
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens,
            max_seq=MAX_SEQ, frames=r.frames,
        )
        np.testing.assert_array_equal(
            out[r.rid], ref, err_msg=f"{family}/{engine} rid={r.rid}"
        )


def test_paged_preemption_keeps_greedy_parity():
    """A pool too small for the working set forces preempt-to-WAITING;
    recompute-on-readmission must keep every output bit-exact."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    reqs = poisson_workload(
        cfg, n_requests=6, arrival_rate=2.0, prompt_len=(3, 7),
        gen_len=(6, 12), seed=5,
    )
    eng, out = _run_engine(
        cfg, params, reqs, slots=3, block_size=4, n_blocks=7,
    )
    assert eng.preemptions > 0  # the point of this pool size
    for r in reqs:
        ref = generate_reference(
            cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ,
        )
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"rid={r.rid}")


def test_slot_reuse_does_not_leak_state():
    """SSM state is positionless — a leaked slot would corrupt the next
    occupant's tokens. Serve 3 sequential waves through ONE slot and
    check each against its own fresh reference."""
    cfg, params = _setup(FAMILY_ARCHS["ssm"])
    reqs = poisson_workload(
        cfg, n_requests=3, arrival_rate=1e9, prompt_len=(4, 6),
        gen_len=(5, 8), seed=7,
    )
    eng, out = _run_engine(cfg, params, reqs, slots=1)
    for r in reqs:
        ref = generate_reference(cfg, params, r.prompt, r.max_new_tokens, max_seq=MAX_SEQ)
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"rid={r.rid}")


def test_reset_slots_zeroes_only_freed_rows():
    cfg, _ = _setup(FAMILY_ARCHS["ssm"])
    mgr = SlotCacheManager(cfg, 3, 8)
    dirty = jax.tree.map(lambda a: jnp.ones_like(a), mgr.cache)
    mgr.cache = dirty
    mgr.reset([1])
    for leaf in jax.tree.leaves(mgr.cache):
        assert float(jnp.abs(leaf[:, 1]).max()) == 0.0
        assert float(jnp.abs(leaf[:, 0]).min()) == 1.0
        assert float(jnp.abs(leaf[:, 2]).min()) == 1.0


def test_cache_manager_alloc_free():
    cfg, _ = _setup(FAMILY_ARCHS["decoder"])
    mgr = SlotCacheManager(cfg, 2, 8)
    a, b = mgr.alloc(), mgr.alloc()
    assert {a, b} == {0, 1} and mgr.n_free == 0
    with pytest.raises(RuntimeError):
        mgr.alloc()
    mgr.pos[a] = 5
    mgr.free(a)
    assert mgr.n_free == 1 and mgr.pos[a] == 0
    assert mgr.alloc() == a
    mgr.free(b)  # valid free
    with pytest.raises(ValueError):
        mgr.free(b)  # double free rejected


def test_block_allocator_properties():
    """Randomized-ops property test over the page free list: no page is
    ever held twice, free + held always conserves the pool, double-free
    raises, and exhaustion raises without corrupting the pool."""
    rng = np.random.default_rng(123)
    n_blocks = 13
    alloc = BlockAllocator(n_blocks)
    held = []  # pages we believe we own
    for _ in range(500):
        op = rng.random()
        if op < 0.5:  # alloc a random burst
            n = int(rng.integers(0, 4))
            if n > alloc.n_free:
                with pytest.raises(NoFreeBlocks):
                    alloc.alloc(n)
            else:
                got = alloc.alloc(n)
                assert len(got) == n
                assert not (set(got) & set(held)), "double allocation"
                held.extend(got)
        elif op < 0.9 and held:  # free a random subset
            k = int(rng.integers(1, len(held) + 1))
            idx = rng.choice(len(held), size=k, replace=False)
            out = [held[i] for i in idx]
            alloc.free(out)
            held = [p for i, p in enumerate(held) if i not in set(idx)]
        elif held:  # double-free rejected, pool untouched
            page = held[int(rng.integers(len(held)))]
            before = alloc.n_free
            with pytest.raises(ValueError):
                alloc.free([page, page])  # duplicate ids in one call
            assert alloc.n_free == before
            alloc.free([page])
            with pytest.raises(ValueError):
                alloc.free([page])  # already back in the pool
            assert alloc.n_free == before + 1
            held.remove(page)
        # conservation invariant after every op
        assert alloc.n_free + len(held) == n_blocks
        assert len(set(held)) == len(held)


def test_paged_freed_pages_read_back_zero():
    """Zero-on-free, extended to the KV pool: dirty a slot's pages via
    real writes, free the slot, and read the pages back as zeros from
    the device before any reuse."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    mgr = PagedCacheManager(cfg, 2, 16, block_size=4, n_blocks=6)
    slot = mgr.alloc()
    assert mgr.ensure(slot, 7)  # 2 pages
    pages = mgr.block_tables[slot, :2].tolist()
    # scatter real k/v into the slot's pages through the model step
    toks = jnp.asarray(np.arange(7, dtype=np.int32)[None].repeat(2, 0))
    _, mgr.cache = lm.decode_slots(
        cfg, params, toks, mgr.cache,
        jnp.zeros((2,), jnp.int32),
        jnp.asarray(np.array([7, 0], np.int32)),
        block_tables=jnp.asarray(mgr.block_tables),
    )
    assert any(
        float(np.abs(leaf).max()) > 0 for p in pages for leaf in mgr.page_view(p)
    ), "writes never landed — test is vacuous"
    mgr.free(slot)
    for p in pages:
        for leaf in mgr.page_view(p):
            assert float(np.abs(leaf).max()) == 0.0, f"page {p} not zeroed"
    # and the freed pages are immediately reusable
    slot2 = mgr.alloc()
    assert mgr.ensure(slot2, 16)
    assert mgr.n_free_blocks == 2


def test_scheduler_admission_gated_on_free_blocks():
    """Paged admission: FIFO prefix limited by the free-page count; a
    head-of-line shortfall blocks later (even smaller) requests."""
    cfg = ServeConfig(max_slots=4, max_seq=32, block_size=4)
    sched = Scheduler(cfg)

    def mk(rid, p):
        return Request(rid=rid, prompt=np.zeros(p, np.int32), max_new_tokens=4)

    waiting = [mk(0, 8), mk(1, 8), mk(2, 4)]  # 2 + 2 + 1 pages
    got = sched.admit(waiting, 4, clock=0, n_free_blocks=5)
    assert [r.rid for r in got] == [0, 1, 2]
    got = sched.admit(waiting, 4, clock=0, n_free_blocks=3)
    assert [r.rid for r in got] == [0]  # rid=1 shortfall blocks rid=2 too
    got = sched.admit(waiting, 4, clock=0, n_free_blocks=1)
    assert got == []


def test_decode_width_ladder_picks_smallest_fit():
    """Mixed steps stop padding to prefill_chunk: the engine compiles
    widths {1, 4, chunk} and picks the smallest that fits the plan."""
    cfg = ServeConfig(max_slots=2, max_seq=32, prefill_chunk=8)
    assert cfg.widths == (1, 4, 8)
    eng = ContinuousBatchingEngine.__new__(ContinuousBatchingEngine)
    eng.serve_cfg = cfg
    assert eng._pick_width({0: 1, 1: 1}) == 1
    assert eng._pick_width({0: 1, 1: 3}) == 4
    assert eng._pick_width({0: 4, 1: 1}) == 4
    assert eng._pick_width({0: 5}) == 8
    legacy = ServeConfig(max_slots=2, max_seq=32, prefill_chunk=8,
                         decode_widths=(1,))
    assert legacy.widths == (1, 8)


def test_serve_config_rejects_negative_budget():
    with pytest.raises(ValueError):
        ServeConfig(max_slots=2, max_seq=32, token_budget=-1)


def test_serve_config_paged_validation():
    with pytest.raises(ValueError):
        ServeConfig(max_slots=2, max_seq=32, n_blocks=4)  # needs block_size
    with pytest.raises(ValueError):
        ServeConfig(max_slots=2, max_seq=32, block_size=-1)
    cfg = ServeConfig(max_slots=3, max_seq=24, block_size=4)
    assert cfg.paged and cfg.blocks_per_slot == 6 and cfg.total_blocks == 18
    assert not ServeConfig(max_slots=3, max_seq=24).paged


def test_paged_engine_rejects_request_larger_than_pool():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    eng = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=2, max_seq=MAX_SEQ, block_size=4, n_blocks=4),
    )
    with pytest.raises(ValueError):  # 20 tokens -> 5 pages > 4-page pool
        eng.submit(Request(rid=0, prompt=np.zeros(10, np.int32),
                           max_new_tokens=11))


def test_scheduler_budget_and_fifo():
    cfg = ServeConfig(max_slots=4, max_seq=64, prefill_chunk=8, token_budget=6)
    sched = Scheduler(cfg)
    def mk(rid, p, filled, arrival):
        return Request(
            rid=rid, prompt=np.zeros(p, np.int32), max_new_tokens=4,
            arrival=arrival,
        )
    # slots 0,1 decoding; slots 2,3 prefilling (arrivals 5 and 2)
    by_slot = {}
    for s, (p, filled, arr) in {
        0: (4, 4, 0), 1: (4, 4, 0), 2: (20, 0, 5), 3: (20, 0, 2)
    }.items():
        r = mk(s, p, filled, arr)
        r.prefilled = filled
        if filled:
            r.generated = [1]
        by_slot[s] = r
    plan = sched.plan(by_slot)
    # decodes first (1+1), remaining 4 tokens to the OLDER prefill (slot 3)
    assert plan[0] == 1 and plan[1] == 1
    assert plan[3] == 4 and 2 not in plan
    assert sum(plan.values()) <= cfg.budget
    # admission: FIFO and arrival-gated
    waiting = [mk(9, 4, 0, 0), mk(10, 4, 0, 3)]
    assert [r.rid for r in sched.admit(waiting, 2, clock=0)] == [9]
    assert [r.rid for r in sched.admit(waiting, 2, clock=3)] == [9, 10]
    assert [r.rid for r in sched.admit(waiting, 1, clock=3)] == [9]


def test_scheduler_rotates_decode_under_tight_budget():
    """budget < decoding slots must round-robin, not starve high ids."""
    cfg = ServeConfig(max_slots=3, max_seq=64, prefill_chunk=4, token_budget=1)
    sched = Scheduler(cfg)
    by_slot = {}
    for s in range(3):
        r = Request(rid=s, prompt=np.zeros(2, np.int32), max_new_tokens=50)
        r.prefilled = 2
        r.generated = [1]
        by_slot[s] = r
    served = [next(iter(sched.plan(by_slot))) for _ in range(6)]
    assert set(served) == {0, 1, 2}, served  # everyone gets a turn


def test_paged_admits_more_concurrency_at_equal_memory():
    """The paging claim, in miniature: at identical cache memory
    (3 slots × 24 rows == 18 pages × 4 tokens) a long-tail workload
    admits strictly more concurrent requests through the paged engine
    — concurrency is bounded by actual use, not worst case — with
    identical greedy outputs."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    def wl():
        return longtail_workload(
            cfg, n_requests=10, arrival_rate=3.0, prompt_len=(3, 6),
            gen_short=(3, 5), gen_long=(14, 18), tail_frac=0.2, seed=9,
        )
    cont_eng, cont_out = _run_engine(cfg, params, wl(), slots=3)
    paged_eng, paged_out = _run_engine(
        cfg, params, wl(), slots=6, block_size=4, n_blocks=18,
    )
    assert paged_eng.peak_concurrency > cont_eng.peak_concurrency
    for rid in cont_out:
        np.testing.assert_array_equal(
            paged_out[rid], cont_out[rid], err_msg=f"rid={rid}"
        )


def test_continuous_beats_lockstep_on_staggered_workload():
    """The acceptance criterion: fewer compute steps (higher generated
    tokens/step) than the static lock-step waves at equal capacity."""
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    capacity = 3
    reqs = poisson_workload(
        cfg, n_requests=9, arrival_rate=2.0, prompt_len=6,
        gen_len=(3, 14), seed=3, uniform_prompts=True,
    )
    eng, out = _run_engine(cfg, params, reqs, slots=capacity, chunk=6)
    engine_steps = eng.stats()["compute_steps"]

    lockstep_steps = 0
    for wave in lockstep_waves(reqs, capacity):
        res = generate_lockstep(
            cfg, params,
            np.stack([r.prompt for r in wave]),
            [r.max_new_tokens for r in wave],
            max_seq=MAX_SEQ,
        )
        lockstep_steps += res["steps"]
        for r, toks in zip(wave, res["tokens"]):
            np.testing.assert_array_equal(out[r.rid], toks, err_msg=f"rid={r.rid}")

    assert engine_steps < lockstep_steps, (engine_steps, lockstep_steps)
    gen_total = sum(len(v) for v in out.values())
    assert gen_total / engine_steps > gen_total / lockstep_steps


def test_engine_respects_arrivals_and_capacity():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    reqs = [
        Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new_tokens=3),
        Request(rid=1, prompt=np.arange(4, dtype=np.int32), max_new_tokens=3,
                arrival=50),
    ]
    eng, out = _run_engine(cfg, params, reqs, slots=2)
    assert eng.idle_steps > 0  # waited for rid=1's arrival
    r1 = eng.finished[1]
    assert r1.first_token_step >= 50
    assert len(out[0]) == 3 and len(out[1]) == 3


def test_submit_rejects_oversized_request():
    cfg, params = _setup(FAMILY_ARCHS["decoder"])
    eng = ContinuousBatchingEngine(
        cfg, params, ServeConfig(max_slots=1, max_seq=8)
    )
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(6, np.int32), max_new_tokens=4))
