"""Conv/dense backward parity through the unified engine.

The engine's core guarantee (``repro.core.backward``): mask mode and
gather mode share one selection per call, so gather-mode gradients equal
the mask-mode oracle to accumulation tolerance — across geometry
(stride × padding × dilation × groups), granularity, ``bwd_dtype``, TP
sharding, and the Pallas block path (interpret mode on CPU). Plus the
ragged-tail regression the old per-op implementations failed:
``C % block_size != 0`` must not double-count or overwrite the last
channel.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparse_conv2d, sparse_dense, sparsity
from repro.core.policy import SsPropPolicy, tpu_default


def _pol(granularity, bwd_dtype, *, mask=False, block_size=8, rate=0.5, **kw):
    return SsPropPolicy(
        rate,
        granularity=granularity,
        block_size=block_size,
        mask_mode=mask,
        bwd_dtype=bwd_dtype,
        **kw,
    )


def _tols(bwd_dtype):
    if bwd_dtype == "bfloat16":
        return dict(rtol=3e-2, atol=3e-2)
    return dict(rtol=2e-4, atol=1e-5)


def _conv_grads(pol, stride, padding, dilation, groups, c_out=16):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 8, 8))
    w = jax.random.normal(
        jax.random.PRNGKey(1), (c_out, 6 // groups, 3, 3)
    ) * 0.2
    b = jax.random.normal(jax.random.PRNGKey(2), (c_out,))

    def loss(x, w, b):
        y = sparse_conv2d(
            x, w, b,
            stride=stride, padding=padding, dilation=dilation, groups=groups,
            policy=pol,
        )
        return 0.5 * (y ** 2).mean()

    return jax.grad(loss, argnums=(0, 1, 2))(x, w, b)


def _dense_grads(pol, d_out=32):
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 24))
    w = jax.random.normal(jax.random.PRNGKey(4), (24, d_out)) * 0.2
    b = jax.random.normal(jax.random.PRNGKey(5), (d_out,))

    def loss(x, w, b):
        return 0.5 * (sparse_dense(x, w, b, policy=pol) ** 2).mean()

    return jax.grad(loss, argnums=(0, 1, 2))(x, w, b)


# geometry: full stride×padding cross, dilation/groups folded in
GEOMS = [
    # (stride, padding, dilation, groups)
    (1, 1, 1, 1),
    (2, 1, 1, 1),
    (1, 0, 1, 1),
    (2, 0, 1, 1),
    (1, 1, 2, 1),
    (2, 0, 2, 1),
    (1, 1, 1, 2),
    (2, 1, 2, 2),
]
CFGS = [
    ("channel", ""),
    ("block", ""),
    ("channel", "bfloat16"),
    ("block", "bfloat16"),
]


class TestConvParityGrid:
    @pytest.mark.parametrize("granularity,bwd_dtype", CFGS)
    @pytest.mark.parametrize("stride,padding,dilation,groups", GEOMS)
    def test_gather_equals_mask_oracle(
        self, stride, padding, dilation, groups, granularity, bwd_dtype
    ):
        g_gather = _conv_grads(
            _pol(granularity, bwd_dtype), stride, padding, dilation, groups
        )
        g_mask = _conv_grads(
            _pol(granularity, bwd_dtype, mask=True), stride, padding, dilation, groups
        )
        for name, a, r in zip(("dx", "dw", "db"), g_gather, g_mask, strict=True):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), err_msg=name, **_tols(bwd_dtype)
            )

    @pytest.mark.parametrize("granularity,bwd_dtype", CFGS)
    def test_conv_tp_shards_gather_equals_mask(self, granularity, bwd_dtype):
        g1 = _conv_grads(_pol(granularity, bwd_dtype, tp_shards=4), 1, 1, 1, 1)
        g2 = _conv_grads(
            _pol(granularity, bwd_dtype, mask=True, tp_shards=4), 1, 1, 1, 1
        )
        for name, a, r in zip(("dx", "dw", "db"), g1, g2, strict=True):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), err_msg=name, **_tols(bwd_dtype)
            )

    def test_conv_tp_shards_balanced(self):
        # 4 shards of 4 channels at rate 0.5 -> 2 kept per shard
        _, dw, _ = _conv_grads(_pol("channel", "", tp_shards=4), 1, 1, 1, 1)
        kept = (np.abs(np.asarray(dw)).sum((1, 2, 3)) != 0).reshape(4, 4).sum(1)
        assert (kept == kept[0]).all()


class TestDenseParityGrid:
    @pytest.mark.parametrize("granularity,bwd_dtype", CFGS)
    @pytest.mark.parametrize("tp_shards", [0, 4])
    def test_gather_equals_mask_oracle(self, granularity, bwd_dtype, tp_shards):
        g1 = _dense_grads(_pol(granularity, bwd_dtype, tp_shards=tp_shards))
        g2 = _dense_grads(_pol(granularity, bwd_dtype, mask=True, tp_shards=tp_shards))
        for name, a, r in zip(("dx", "dw", "db"), g1, g2, strict=True):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), err_msg=name, **_tols(bwd_dtype)
            )


class TestPallasParity:
    """The acceptance-criterion paths: block granularity through the
    Pallas gathered kernels, interpret mode on CPU, fp32 tolerance."""

    @pytest.mark.parametrize(
        "stride,padding,dilation", [(1, 1, 1), (2, 0, 1), (1, 1, 2)]
    )
    def test_conv_pallas_block_vs_mask(self, stride, padding, dilation):
        pol = _pol("block", "", block_size=8, use_pallas=True)
        ref = _pol("block", "", block_size=8, mask=True)
        g1 = _conv_grads(pol, stride, padding, dilation, 1)
        g2 = _conv_grads(ref, stride, padding, dilation, 1)
        for name, a, r in zip(("dx", "dw", "db"), g1, g2, strict=True):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-4, err_msg=name
            )

    def test_conv_pallas_bf16(self):
        pol = _pol("block", "bfloat16", block_size=8, use_pallas=True)
        ref = _pol("block", "bfloat16", block_size=8, mask=True)
        g1 = _conv_grads(pol, 1, 1, 1, 1)
        g2 = _conv_grads(ref, 1, 1, 1, 1)
        for name, a, r in zip(("dx", "dw", "db"), g1, g2, strict=True):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), err_msg=name, **_tols("bfloat16")
            )

    def _spy(self, monkeypatch, names):
        from repro.kernels import ops as kops

        calls = dict.fromkeys(names, 0)
        for name in names:
            real = getattr(kops, name)

            def spy(*a, _name=name, _real=real, **kw):
                calls[_name] += 1
                return _real(*a, **kw)

            monkeypatch.setattr(kops, name, spy)
        return calls

    def test_conv_pallas_path_actually_routes_through_kernels(self, monkeypatch):
        # fuse_im2col is on by default: the fused kernels take the call,
        # the materializing canonical kernels are never touched.
        calls = self._spy(
            monkeypatch,
            ("conv_dx_fused", "conv_dw_fused_scatter",
             "dx_gathered", "dw_gathered_scatter"),
        )
        _conv_grads(_pol("block", "", block_size=8, use_pallas=True), 1, 1, 1, 1)
        assert calls["conv_dx_fused"] == 1 and calls["conv_dw_fused_scatter"] == 1
        assert calls["dx_gathered"] == 0 and calls["dw_gathered_scatter"] == 0

    def test_conv_pallas_fuse_off_routes_materializing(self, monkeypatch):
        calls = self._spy(
            monkeypatch,
            ("conv_dx_fused", "conv_dw_fused_scatter",
             "dx_gathered", "dw_gathered_scatter"),
        )
        _conv_grads(
            _pol("block", "", block_size=8, use_pallas=True, fuse_im2col=False),
            1, 1, 1, 1,
        )
        assert calls["dx_gathered"] == 1 and calls["dw_gathered_scatter"] == 1
        assert calls["conv_dx_fused"] == 0 and calls["conv_dw_fused_scatter"] == 0

    @pytest.mark.parametrize("stride,padding,dilation,groups", GEOMS)
    def test_conv_fused_equals_materialized(
        self, stride, padding, dilation, groups
    ):
        # the tentpole contract: the fused index-map kernels compute the
        # same backward as the materializing canonical path, across the
        # full geometry grid (selection is identical — only the lowering
        # differs).
        pol = _pol("block", "", block_size=4, use_pallas=True)
        ref = dataclasses.replace(pol, fuse_im2col=False)
        g1 = _conv_grads(pol, stride, padding, dilation, groups)
        g2 = _conv_grads(ref, stride, padding, dilation, groups)
        for name, a, r in zip(("dx", "dw", "db"), g1, g2, strict=True):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-4, err_msg=name
            )

    def test_conv_pallas_grouped_routes_fused_block_diagonal(self, monkeypatch):
        # grouped convs route onto the SAME fused kernels via the
        # block-diagonal canonical form (whole blocks per group) — the
        # old framework-VJP fallback is only for indivisible shapes.
        calls = self._spy(monkeypatch, ("conv_dx_fused", "conv_dw_fused_scatter"))
        pol = _pol("block", "", block_size=4, use_pallas=True)
        ref = _pol("block", "", block_size=4, mask=True)
        g1 = _conv_grads(pol, 1, 1, 1, 2)
        g2 = _conv_grads(ref, 1, 1, 1, 2)
        assert calls["conv_dx_fused"] == 1 and calls["conv_dw_fused_scatter"] == 1
        for a, r in zip(g1, g2, strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-4)

    def test_conv_pallas_grouped_indivisible_falls_back(self, monkeypatch):
        # c_out=16 with groups=2 needs whole 8-channel blocks per group;
        # block_size=16 can't split block-diagonally -> framework VJP,
        # still exact.
        calls = self._spy(monkeypatch, ("conv_dx_fused", "conv_dw_fused_scatter"))
        pol = _pol("block", "", block_size=16, use_pallas=True)
        ref = _pol("block", "", block_size=16, mask=True)
        g1 = _conv_grads(pol, 1, 1, 1, 2)
        g2 = _conv_grads(ref, 1, 1, 1, 2)
        assert calls["conv_dx_fused"] == 0 and calls["conv_dw_fused_scatter"] == 0
        for a, r in zip(g1, g2, strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=2e-4, atol=1e-5)


class TestRaggedTailRegression:
    """C=130 with block_size=128: the tail block's clamped phantom
    indices used to double-count dX and overwrite dW/db of channel 129."""

    def _dense(self, pol):
        return _dense_grads(pol, d_out=130)

    def _make_tail_kept_policy(self, **kw):
        # rate 0.5 over 2 blocks keeps exactly 1; seeds below make the
        # tail block win often enough that both cases are exercised by
        # the pair of d_out values.
        return dataclasses.replace(tpu_default(0.5), block_size=128, **kw)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_dense_c130_gather_equals_mask(self, use_pallas):
        pol = self._make_tail_kept_policy(use_pallas=use_pallas)
        ref = self._make_tail_kept_policy(mask_mode=True)
        g1 = self._dense(pol)
        g2 = self._dense(ref)
        for name, a, r in zip(("dx", "dw", "db"), g1, g2, strict=True):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-4, err_msg=name
            )

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_conv_c130_gather_equals_mask(self, use_pallas):
        pol = self._make_tail_kept_policy(use_pallas=use_pallas)
        ref = self._make_tail_kept_policy(mask_mode=True)
        g1 = _conv_grads(pol, 1, 1, 1, 1, c_out=130)
        g2 = _conv_grads(ref, 1, 1, 1, 1, c_out=130)
        for name, a, r in zip(("dx", "dw", "db"), g1, g2, strict=True):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-4, err_msg=name
            )

    def test_select_marks_phantom_slots(self):
        # force the tail block to win: channels 128..129 carry the mass
        dy = jnp.zeros((4, 130)).at[:, 128:].set(10.0)
        pol = dataclasses.replace(tpu_default(0.5), block_size=128)
        sel = sparsity.select(dy, pol, channel_axis=-1)
        assert sel.k == 128
        assert sel.valid is not None
        assert int(np.asarray(sel.valid).sum()) == 2  # only 128, 129 real
        assert int(np.asarray(sel.idx).max()) == 129  # clamped in range
        # block 1 was selected
        assert np.asarray(sel.block_idx).tolist() == [1]

    def test_scatter_add_ignores_phantom_duplicates(self):
        # 3 slots all pointing at channel 1, only slot 0 valid
        from repro.core import backward

        compact = jnp.array([[1.0, 0.0, 0.0]])
        idx = jnp.array([1, 1, 1])
        out = backward.scatter_channels(compact, idx, 4, axis=1)
        np.testing.assert_array_equal(
            np.asarray(out), np.array([[0.0, 1.0, 0.0, 0.0]])
        )


class TestSparsifyFlags:
    """``sparsify_dx`` / ``sparsify_dw`` select WHICH gradient shrinks.

    The un-sparsified side must reproduce the dense gradient *exactly*
    (same full-size contraction, not an approximation), in both gather
    and mask mode, for dense and conv ops.
    """

    DENSE = SsPropPolicy(0.0)

    @pytest.mark.parametrize("granularity", ["channel", "block"])
    @pytest.mark.parametrize("mask", [False, True])
    def test_dense_dx_off_is_exactly_dense(self, granularity, mask):
        pol = _pol(granularity, "", mask=mask, sparsify_dx=False)
        dx, dw, _ = _dense_grads(pol)
        dx_ref, dw_ref, _ = _dense_grads(self.DENSE)
        np.testing.assert_array_equal(np.asarray(dx), np.asarray(dx_ref))
        # dw still sparsified: dropped channels are exact zeros
        assert (np.asarray(dw) == 0).all(0).sum() > (np.asarray(dw_ref) == 0).all(0).sum()

    @pytest.mark.parametrize("granularity", ["channel", "block"])
    @pytest.mark.parametrize("mask", [False, True])
    def test_dense_dw_off_is_exactly_dense(self, granularity, mask):
        pol = _pol(granularity, "", mask=mask, sparsify_dw=False)
        _, dw, db = _dense_grads(pol)
        _, dw_ref, db_ref = _dense_grads(self.DENSE)
        np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))
        np.testing.assert_array_equal(np.asarray(db), np.asarray(db_ref))

    @pytest.mark.parametrize("mask", [False, True])
    def test_conv_dx_off_is_exactly_dense(self, mask):
        pol = _pol("channel", "", mask=mask, sparsify_dx=False)
        dx, dw, _ = _conv_grads(pol, 1, 1, 1, 1)
        dx_ref, dw_ref, _ = _conv_grads(self.DENSE, 1, 1, 1, 1)
        np.testing.assert_array_equal(np.asarray(dx), np.asarray(dx_ref))
        assert (np.abs(np.asarray(dw)).sum((1, 2, 3)) == 0).sum() > 0

    def test_both_off_is_dense_path(self):
        pol = _pol("channel", "", sparsify_dx=False, sparsify_dw=False)
        for a, r in zip(_dense_grads(pol), _dense_grads(self.DENSE), strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(r))

    def test_pallas_block_respects_flags(self):
        pol = _pol("block", "", sparsify_dx=False, use_pallas=True)
        dx, dw, _ = _dense_grads(pol)
        dx_ref, _, _ = _dense_grads(self.DENSE)
        np.testing.assert_allclose(
            np.asarray(dx), np.asarray(dx_ref), rtol=1e-5, atol=1e-6
        )
        assert (np.asarray(dw) == 0).all(0).sum() > 0

    def test_flops_flags_monotone(self):
        from repro.core import flops

        base = SsPropPolicy(0.8)
        both = flops.dense_backward_flops_policy(128, 256, 512, base)
        dx_only = flops.dense_backward_flops_policy(
            128, 256, 512, dataclasses.replace(base, sparsify_dw=False)
        )
        off = flops.dense_backward_flops_policy(
            128, 256, 512, dataclasses.replace(base, sparsify_dx=False, sparsify_dw=False)
        )
        dense = flops.dense_backward_flops(128, 256, 512)
        assert both < dx_only < off == dense
        cb = flops.conv_backward_flops_policy(8, 16, 16, 64, 128, 3, base)
        cd = flops.conv_backward_flops_policy(
            8, 16, 16, 64, 128, 3, dataclasses.replace(base, sparsify_dx=False)
        )
        assert cb < cd < flops.conv_backward_flops(8, 16, 16, 64, 128, 3)
