"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import SsPropPolicy, flops, sparse_dense, sparsity
from repro.core import schedulers
from repro.core.policy import paper_default

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


@given(
    c_in=st.integers(1, 512),
    k=st.integers(1, 7),
    bt=st.integers(1, 64),
    hw=st.integers(1, 32),
    c_out=st.integers(1, 256),
    rate=st.floats(0.05, 0.95),
)
def test_eq9_savings_iff_above_lower_bound(c_in, k, bt, hw, c_out, rate):
    """ssProp saves FLOPs exactly when D > 1/(4*C_in*K^2+1) (Eq. 10)."""
    dense = flops.conv_backward_flops(bt, hw, hw, c_in, c_out, k)
    sp = flops.conv_backward_flops_ssprop(bt, hw, hw, c_in, c_out, k, rate)
    bound = flops.drop_rate_lower_bound(c_in, k)
    if rate > bound + 1e-9:
        assert sp < dense
    elif rate < bound - 1e-9:
        assert sp >= dense


@given(
    target=st.floats(0.0, 0.95),
    total=st.integers(2, 200),
    spe=st.integers(1, 50),
    name=st.sampled_from(["constant", "linear", "cosine", "bar", "epoch_bar"]),
)
def test_scheduler_rates_bounded(target, total, spe, name):
    """Every scheduler stays within [0, target] at every step."""
    for s in range(0, total, max(total // 17, 1)):
        r = schedulers.drop_rate_for_step(
            name, step=s, steps_per_epoch=spe, total_steps=total, target=target
        )
        assert -1e-12 <= r <= target + 1e-12


@given(
    c=st.integers(2, 200),
    rate=st.floats(0.0, 0.95),
)
def test_keep_count_bounds(c, rate):
    pol = SsPropPolicy(rate)
    k = pol.keep_count(c)
    assert 1 <= k <= c
    # keep fraction tracks 1-rate within rounding
    assert abs(k - (1 - rate) * c) <= 0.5 + 1e-9


@given(
    m=st.integers(1, 12),
    d_in=st.integers(1, 24),
    d_out=st.integers(4, 48),
    rate=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**20),
)
def test_sparse_dense_grad_subset_property(m, d_in, d_out, rate, seed):
    """dW columns form a subset: kept ones equal the dense dW exactly,
    dropped ones are zero — the defining invariant of ssProp."""
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (m, d_in))
    w = jax.random.normal(jax.random.fold_in(k, 1), (d_in, d_out))

    def loss(w, pol):
        return (sparse_dense(x, w, policy=pol) ** 2).sum()

    dw_dense = jax.grad(loss)(w, SsPropPolicy(0.0))
    dw_sp = jax.grad(loss)(w, paper_default(rate))
    dw_sp = np.asarray(dw_sp)
    dw_dense = np.asarray(dw_dense)
    kept_cols = np.abs(dw_sp).sum(0) != 0
    np.testing.assert_allclose(
        dw_sp[:, kept_cols], dw_dense[:, kept_cols], rtol=1e-4, atol=1e-4
    )
    assert np.all(dw_sp[:, ~kept_cols] == 0)
    assert kept_cols.sum() == paper_default(rate).keep_count(d_out)


@given(
    shape=st.sampled_from([(4, 6), (2, 3, 5), (2, 2, 2, 7)]),
    axis=st.integers(-1, 0),
    seed=st.integers(0, 1000),
)
def test_importance_permutation_equivariance(shape, axis, seed):
    """Permuting channels permutes importance identically."""
    dy = jax.random.normal(jax.random.PRNGKey(seed), shape)
    c = shape[axis]
    perm = np.random.RandomState(seed).permutation(c)
    imp = np.asarray(sparsity.channel_importance(dy, axis))
    dy_p = jnp.take(dy, jnp.asarray(perm), axis=axis)
    imp_p = np.asarray(sparsity.channel_importance(dy_p, axis))
    np.testing.assert_allclose(imp_p, imp[perm], rtol=1e-6)


@given(rate=st.floats(0.0, 0.9), c=st.integers(1, 64))
def test_mask_idempotent(rate, c):
    """Masking twice == masking once (selection is deterministic)."""
    dy = jax.random.normal(jax.random.PRNGKey(0), (8, c))
    pol = SsPropPolicy(rate)
    m1 = sparsity.mask_grad(dy, pol)
    m2 = sparsity.mask_grad(m1, pol)
    np.testing.assert_allclose(m1, m2, rtol=1e-6)


@given(
    ratio=st.floats(0.01, 0.5),
    seed=st.integers(0, 100),
)
def test_compression_error_feedback_conserves_mass(ratio, seed):
    """grad == compressed + residual exactly (error feedback invariant)."""
    from repro.optim.compression import compress_tree, init_residual

    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (64, 64))}
    res = init_residual(g)
    cg, new_res = compress_tree(g, res, ratio=ratio, min_size=16)
    np.testing.assert_allclose(
        np.asarray(cg["a"], np.float32) + np.asarray(new_res["a"]),
        np.asarray(g["a"], np.float32),
        rtol=1e-6,
        atol=1e-6,
    )


# ----------------------------------------------------------------------
# sharded checkpoints: placement and round-trip invariants
# ----------------------------------------------------------------------
import functools
import os
import tempfile

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.registry import get_config
from repro.dist import sharding as shd
from repro.launch import steps as steps_lib

_FAMILIES = ("qwen2.5-3b", "mistral-large-123b", "mamba2-1.3b")


@functools.lru_cache(maxsize=None)
def _family_items_and_specs(arch):
    """Abstract (no-allocation) param tree + aligned spec list for one
    model family; cached so hypothesis examples don't re-trace."""
    cfg = get_config(arch).reduced()
    a_params, _ = steps_lib.abstract_state(cfg)
    items, _ = ckpt_lib._flatten(a_params)
    spec_items, _ = ckpt_lib._flatten(shd.param_specs(a_params))
    assert [k for k, _ in items] == [k for k, _ in spec_items]
    return items, [s for _, s in spec_items]


@given(arch=st.sampled_from(_FAMILIES), world=st.integers(1, 8))
def test_fsdp_plan_partitions_every_key(arch, world):
    """make_shard_plan covers every param exactly once (no gap, no
    overlap) for every family at any fleet size, and never assigns a
    piece to a rank outside the fleet."""
    items, _ = _family_items_and_specs(arch)
    ranks = list(range(world))
    plan = ckpt_lib.make_shard_plan(items, ranks)
    shapes = {k: tuple(v.shape) for k, v in items}
    assert set(plan) == set(shapes)
    ckpt_lib.validate_plan(plan, shapes)
    owners = {p.shard for pieces in plan.values() for p in pieces}
    assert owners <= set(ranks)


@given(
    arch=st.sampled_from(_FAMILIES),
    data=st.sampled_from([1, 2, 4]),
    model=st.sampled_from([1, 2, 4, 8]),
    host_split=st.integers(0, 3),
)
def test_spec_plan_partitions_for_arbitrary_meshes(
    arch, data, model, host_split
):
    """plan_from_specs (addressable-shards addressing) partitions every
    key for arbitrary mesh shapes × host counts dividing the device
    count — replicated blocks get exactly one deterministic owner."""
    items, specs = _family_items_and_specs(arch)
    n_dev = data * model
    max_split = n_dev.bit_length() - 1  # n_dev is a power of two here
    n_hosts = 2 ** min(host_split, max_split)
    ranks = list(range(n_hosts))
    plan = ckpt_lib.plan_from_specs(
        items, specs, {"data": data, "model": model}, ranks
    )
    shapes = {k: tuple(v.shape) for k, v in items}
    assert set(plan) == set(shapes)
    ckpt_lib.validate_plan(plan, shapes)
    owners = {p.shard for pieces in plan.values() for p in pieces}
    assert owners <= set(ranks)


_TREE_SPECS = st.dictionaries(
    keys=st.sampled_from(["w", "b", "scale", "table", "gamma"]),
    values=st.tuples(
        st.lists(st.integers(1, 6), min_size=0, max_size=3),
        st.sampled_from(["float32", "int32", "float16"]),
    ),
    min_size=1,
    max_size=5,
)


@given(
    spec=_TREE_SPECS,
    world=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_sharded_roundtrip_matches_monolithic(spec, world, seed):
    """A sharded save (per-rank shards + manifest + commit) restores
    bit-exactly equal to a monolithic save of the same tree, including
    0-d scalars and non-float dtypes; single-key partial reads match
    too."""
    rng = np.random.default_rng(seed)
    tree = {}
    for k, (shape, dtype) in spec.items():
        if dtype == "int32":
            tree[k] = rng.integers(-100, 100, size=shape, dtype=np.int32)
        else:
            tree[k] = rng.standard_normal(shape).astype(dtype)
    ranks = list(range(world))
    items, _ = ckpt_lib._flatten(tree)
    host_items = [(k, np.asarray(v)) for k, v in items]
    plan = ckpt_lib.make_shard_plan(host_items, ranks)
    with tempfile.TemporaryDirectory() as d:
        mono = os.path.join(d, "mono")
        shard_d = os.path.join(d, "shard")
        ckpt_lib.save(mono, 1, tree)
        for r in ranks:
            ckpt_lib.write_shard(shard_d, 1, host_items, rank=r, plan=plan)
        ckpt_lib.write_sharded_manifest(
            shard_d, 1, host_items, plan=plan, ranks=ranks
        )
        ckpt_lib.commit_sharded(shard_d, 1, timeout_s=5.0)
        like = jax.tree.map(np.zeros_like, tree)
        got_m = ckpt_lib.restore(mono, 1, like)
        got_s = ckpt_lib.restore(shard_d, 1, like)
        for k, want in tree.items():
            a = np.asarray(got_s[k])
            assert a.dtype == want.dtype and a.shape == want.shape
            assert np.array_equal(a, np.asarray(got_m[k]))
            assert np.array_equal(a, want)
        k0 = sorted(tree)[0]
        got_p = ckpt_lib.restore(shard_d, 1, {k0: like[k0]})
        assert np.array_equal(np.asarray(got_p[k0]), np.asarray(tree[k0]))
