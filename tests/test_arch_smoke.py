"""Per-architecture smoke tests: reduced config, one forward + train step
on CPU, asserting output shapes and no NaNs — for all 10 assigned archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.policy import SsPropPolicy, paper_default
from repro.launch import steps as steps_lib
from repro.models import model as lm
from repro.optim import adam


def _batch(cfg, b=2, s=16, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    tok = jax.random.randint(rng, (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "targets": tok}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (b, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (b, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = lm.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab]).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_improves_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adam.init(params)
    step = jax.jit(
        steps_lib.make_train_step(
            cfg, paper_default(0.5), adam.AdamConfig(lr=1e-3, clip_norm=1.0)
        )
    )
    batch = _batch(cfg)
    losses = []
    for _ in range(3):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(x) for x in losses)
    assert losses[-1] < losses[0]  # same batch -> must descend


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "kimi-k2-1t-a32b", "jamba-1.5-large-398b", "mamba2-1.3b"])
def test_train_step_with_accumulation(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adam.init(params)
    step = jax.jit(
        steps_lib.make_train_step(
            cfg, SsPropPolicy(0.0), adam.AdamConfig(lr=1e-3), accum=2
        )
    )
    batch = _batch(cfg, b=4)
    params, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    cache = lm.init_cache(cfg, b, s, dtype=jnp.float32)
    enc_out = None
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(1), (b, cfg.enc_seq, cfg.d_model))
        enc_out = lm.encode(cfg, params, frames.astype(jnp.dtype(cfg.dtype)))
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, cache2 = lm.decode_step(cfg, params, tok, cache, jnp.int32(0), enc_out=enc_out)
    assert logits.shape == (b, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[:, : cfg.vocab]).all())
    # cache structure preserved
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_exact_table_constants():
    """Configs carry the exact assigned constants."""
    rows = {
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }
    for arch, (L, d, h, kv, ff, v) in rows.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab) == (
            L, d, h, kv, ff, v
        ), arch


def test_moe_metadata():
    k = get_config("kimi-k2-1t-a32b")
    assert (k.n_experts, k.moe_topk) == (384, 8)
    l4 = get_config("llama4-maverick-400b-a17b")
    assert (l4.n_experts, l4.moe_topk) == (128, 1)
    j = get_config("jamba-1.5-large-398b")
    assert (j.n_experts, j.moe_topk, j.attn_every) == (16, 2, 8)
    assert get_config("mamba2-1.3b").ssm_state == 128


def test_param_counts_in_range():
    """Sanity: derived parameter counts sit near the advertised sizes."""
    expect = {
        "mistral-large-123b": (100e9, 140e9),
        "nemotron-4-15b": (12e9, 18e9),
        "whisper-large-v3": (1.2e9, 1.8e9),
        "deepseek-67b": (55e9, 75e9),
        "qwen2.5-3b": (2.5e9, 4.5e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.2e12),
        "llama4-maverick-400b-a17b": (3.5e11, 4.5e11),
        "jamba-1.5-large-398b": (3.0e11, 4.6e11),
        "mamba2-1.3b": (0.9e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e}"


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok, why = cfg.supports_shape(long)
        if arch in ("mamba2-1.3b", "jamba-1.5-large-398b"):
            assert ok
        else:
            assert not ok and "full-attention" in why
