"""Tests for repro.analysis: the jaxpr walker vs the analytic FLOPs
tables (exact, over a policy × groups × dtype grid), the seeded
regressions each lint must catch (planted f32 upcast, planted host
callback, out-of-bounds index map), retrace budgets, the Pallas traffic
cross-check, and the docs/CLI static checker."""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import jaxpr_walk, pallas_check, retrace, savings
from repro.analysis.lints import lint_step_counts
from repro.analysis.report import ERROR, INFO, Report
from repro.core import backward
from repro.core import flops as ftab
from repro.core.policy import (
    DENSE,
    PolicyProgram,
    PolicyRules,
    paper_default,
    tpu_default,
)
from repro.core.schedulers import make_schedule
from repro.configs.registry import get_config

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.abspath(_ROOT))


def _policies():
    block = tpu_default(0.8)
    return [
        ("dense", DENSE),
        ("channel", paper_default(0.8)),
        ("block", block),
        ("block_pallas", dataclasses.replace(block, use_pallas=True)),
        (
            "block_pallas_32",
            dataclasses.replace(block, use_pallas=True, block_size=32),
        ),
    ]


@pytest.fixture(autouse=True)
def _fresh_trace_cache():
    savings.clear_cache()
    yield
    savings.clear_cache()


# ----------------------------------------------------------------------
# walker == analytic tables, exactly
# ----------------------------------------------------------------------


class TestConvAuditGrid:
    @pytest.mark.parametrize("pname,policy", _policies())
    @pytest.mark.parametrize("groups", [1, 2])
    @pytest.mark.parametrize("bwd_dtype", ["", "bfloat16"])
    def test_measured_equals_bounds(self, pname, policy, groups, bwd_dtype):
        policy = dataclasses.replace(policy, bwd_dtype=bwd_dtype)
        rep = Report("t")
        savings.audit_conv_site(
            rep, "site", 2, 8, 8, 16, 32, 3, policy, groups=groups
        )
        assert not rep.errors(), [f.message for f in rep.errors()]

    def test_strided_site_audits_via_stride1_twin(self):
        # the probe is stride-1 by construction; the tables carry no
        # stride, so the same (h_out, w_out) geometry must stay exact
        rep = Report("t")
        counts = savings.audit_conv_site(
            rep, "site", 2, 4, 4, 16, 32, 3, tpu_default(0.8)
        )
        lo, hi = ftab.conv_backward_contraction_bounds(
            2, 4, 4, 16, 32, 3, tpu_default(0.8), h_pad=4 + 3 - 1
        )
        assert (counts.flops_lo, counts.flops_hi) == (lo, hi)
        assert not rep.errors()


class TestDenseAuditGrid:
    @pytest.mark.parametrize("pname,policy", _policies())
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_measured_equals_bounds(self, pname, policy, dtype):
        rep = Report("t")
        counts = savings.audit_dense_site(
            rep, "site", 64, 128, 256, policy, dtype=dtype
        )
        assert not rep.errors(), [f.message for f in rep.errors()]
        # dense bounds are a point interval on every route
        assert counts.flops_lo == counts.flops_hi

    def test_tp_fast_path(self):
        policy = dataclasses.replace(tpu_default(0.8), tp_shards=2)
        rep = Report("t")
        savings.audit_dense_site(rep, "site", 64, 128, 256, policy)
        assert not rep.errors(), [f.message for f in rep.errors()]


class TestLmAudit:
    def test_reduced_decoder_no_errors(self):
        cfg = get_config("qwen2.5-3b").reduced()
        rep = savings.audit_lm(cfg, tpu_default(0.8), batch=2, seq=16)
        assert not rep.errors(), [f.message for f in rep.errors()]

    def test_iter_dense_shapes_families(self):
        from repro.models import transformer

        moe = get_config("kimi-k2-1t-a32b").reduced()
        sites = {s for s, *_ in transformer.iter_dense_shapes(moe, 2, 16)}
        assert any("moe/gate" in s for s in sites)
        assert any("moe/shared/up" in s for s in sites)
        encdec = get_config("whisper-large-v3").reduced()
        sites = {s for s, *_ in transformer.iter_dense_shapes(encdec, 2, 16)}
        assert any(s.startswith("enc/") for s in sites)
        assert any("/cross/" in s for s in sites)

    def test_lm_site_flops_rows(self):
        cfg = get_config("qwen2.5-3b").reduced()
        rows = savings.lm_site_flops(cfg, tpu_default(0.8), batch=2, seq=16)
        assert rows
        m = 2 * 16
        for site, count, fwd, lo, hi in rows:
            assert count >= 1 and lo <= hi
            if site.endswith("attn/q"):
                d = cfg.d_model
                assert fwd == 2 * m * d * (cfg.n_heads * cfg.head_dim)


# ----------------------------------------------------------------------
# seeded regressions: each lint must catch its plant
# ----------------------------------------------------------------------


class TestSeededRegressions:
    def test_planted_f32_upcast_is_caught(self, monkeypatch):
        policy = dataclasses.replace(tpu_default(0.8), bwd_dtype="bfloat16")
        rep = Report("clean")
        savings.audit_dense_site(
            rep, "site", 64, 128, 256, policy, dtype="bfloat16"
        )
        assert not rep.errors()

        monkeypatch.setattr(backward, "_acc_dtype", lambda p: jnp.float32)
        savings.clear_cache()
        rep = Report("seeded")
        savings.audit_dense_site(
            rep, "site", 64, 128, 256, policy, dtype="bfloat16"
        )
        assert any(f.check == "dtype" for f in rep.errors())

    def test_planted_host_callback_is_caught(self):
        def fn(x):
            jax.debug.callback(lambda a: None, x)
            return x * 2

        closed = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((4,), "float32"))
        counts = jaxpr_walk.count(closed, name="t")
        rep = Report("t")
        lint_step_counts(rep, "t", counts)
        assert any(f.check == "transfer" for f in rep.errors())

    def test_clean_step_has_no_callback_errors(self):
        closed = jax.make_jaxpr(lambda x: x * 2)(
            jax.ShapeDtypeStruct((4,), "float32")
        )
        counts = jaxpr_walk.count(closed, name="t")
        rep = Report("t")
        lint_step_counts(rep, "t", counts)
        assert not rep.errors()

    def test_oob_index_map_is_caught(self):
        dw_spec, _, idx = pallas_check.conv_fused_site_specs(
            2, 8, 8, 32, 64, 3,
            dataclasses.replace(
                tpu_default(0.8), use_pallas=True, block_size=32
            ),
        )
        info = dw_spec.in_specs[0]
        bad = dataclasses.replace(
            info, index_map=lambda *a: tuple(10**6 for _ in info.block_shape)
        )
        bad_spec = dataclasses.replace(
            dw_spec, in_specs=(bad,) + dw_spec.in_specs[1:]
        )
        rep = Report("t")
        pallas_check.check_in_bounds(rep, bad_spec, prefetch_candidates=(idx,))
        assert any(f.check == "pallas" for f in rep.errors())

    def test_ragged_operand_is_caught(self):
        info = pallas_check.BlockSpecInfo(
            "x", (100,), (64,), lambda i: (i,)
        )
        spec = pallas_check.KernelSpec("k", (2,), (info,), ())
        rep = Report("t")
        pallas_check.check_divisibility(rep, spec)
        assert rep.errors()

    def test_vmem_over_budget_is_caught(self):
        big = pallas_check.BlockSpecInfo(
            "x", (4096, 4096), (4096, 4096), lambda i: (0, 0)
        )
        spec = pallas_check.KernelSpec("k", (1,), (big,), ())
        rep = Report("t")
        pallas_check.check_vmem(rep, spec, platform="tpu")
        assert rep.errors()


# ----------------------------------------------------------------------
# Pallas traffic cross-check on a real fused site
# ----------------------------------------------------------------------


class TestPallasTraffic:
    def test_fused_conv_traffic_matches_bytes_model(self):
        pol = dataclasses.replace(
            tpu_default(0.8), use_pallas=True, block_size=32
        )
        assert ftab._conv_fused_route(2, 8, 8, 32, 64, 3, pol, 1)
        rep = Report("t")
        pallas_check.check_conv_fused_site(rep, "site", 2, 8, 8, 32, 64, 3, pol)
        assert not rep.errors(), [f.message for f in rep.errors()]

    def test_paged_attention_geometry(self):
        rep = Report("t")
        pallas_check.check_paged_attention_site(
            rep, b=2, s=8, h=4, d=16, n_pages=8, bs_pg=16, kvh=2, nb=4
        )
        assert not rep.errors(), [f.message for f in rep.errors()]


# ----------------------------------------------------------------------
# retrace budgets
# ----------------------------------------------------------------------


class TestRetrace:
    def _program(self):
        return PolicyProgram(
            rules=PolicyRules.single(tpu_default(0.8)),
            schedule=make_schedule("epoch_bar", target=0.8),
        )

    def test_train_within_budget(self):
        program = self._program()
        sites = ["layer_0/mlp/up", "layer_0/mlp/down"]
        tables = retrace.train_tables(program, sites)
        assert len(tables) <= len(program.schedule.rate_buckets)
        rep = Report("t")
        retrace.check_train_retrace(rep, program, sites)
        assert not rep.errors()

    def test_train_over_budget_fails(self):
        rep = Report("t")
        retrace.check_train_retrace(
            rep, self._program(), ["layer_0/mlp/up"], budget=0
        )
        assert rep.errors()

    def test_serve_executables_and_budget(self):
        from repro.serve.scheduler import ServeConfig

        cfg = get_config("qwen2.5-3b").reduced()
        serve_cfg = ServeConfig(
            max_slots=2, max_seq=64, prefill_chunk=8, spec_k=2
        )
        per_fn = retrace.serve_executables(cfg, serve_cfg)
        assert per_fn["_step_fn"] == len(serve_cfg.widths)
        assert per_fn["_draft_step_fn"] == 2  # catch-up + width-1 propose
        rep = Report("t")
        retrace.check_serve_retrace(rep, cfg, serve_cfg)
        assert not rep.errors()
        rep = Report("t")
        retrace.check_serve_retrace(rep, cfg, serve_cfg, budget=1)
        assert rep.errors()

    def test_serve_encdec_adds_encode_planes(self):
        from repro.serve.scheduler import ServeConfig

        cfg = get_config("whisper-large-v3").reduced()
        per_fn = retrace.serve_executables(
            cfg, ServeConfig(max_slots=2, max_seq=64, prefill_chunk=8,
                             spec_k=2)
        )
        assert per_fn["_encode"] == 1 and per_fn["_draft_encode"] == 1


# ----------------------------------------------------------------------
# the analyze CLI end to end (reports, exit code)
# ----------------------------------------------------------------------


class TestAnalyzeCli:
    def test_conv_model_clean(self, tmp_path):
        from repro.launch import analyze

        out = tmp_path / "r.json"
        rc = analyze.main([
            "--model", "resnet18", "--image", "3,8,8", "--batch", "2",
            "--use-pallas", "--block-size", "32", "--json", str(out),
        ])
        assert rc == 0
        assert out.exists()

    def test_lm_arch_clean(self):
        from repro.launch import analyze

        rc = analyze.main([
            "--arch", "qwen2.5-3b", "--reduced", "--seq-len", "16",
            "--global-batch", "2",
        ])
        assert rc == 0


# ----------------------------------------------------------------------
# docs / CLI static checker
# ----------------------------------------------------------------------


class TestCheckDocs:
    @pytest.fixture()
    def cd(self):
        sys.path.insert(0, os.path.join(_ROOT, "tools"))
        import check_docs

        yield check_docs
        sys.path.pop(0)

    def test_real_docs_are_clean(self, cd):
        assert cd.main() == 0

    def test_unknown_flag_fails(self, cd):
        bad = "Run `python -m repro.launch.train --arch x --no-such-flag`"
        fails = cd.check_cli_flags(bad, "t.md")
        assert fails and "--no-such-flag" in fails[0]

    def test_continuation_lines_are_joined(self, cd):
        bad = (
            "```\npython -m repro.launch.serve --arch q \\\n"
            "  --bogus-flag 3\n```"
        )
        fails = cd.check_cli_flags(bad, "t.md")
        assert fails and "--bogus-flag" in fails[0]

    def test_known_flags_pass(self, cd):
        ok = (
            "`python -m repro.launch.serve --arch qwen2.5-3b --spec-k 2 "
            "--stream`"
        )
        assert cd.check_cli_flags(ok, "t.md") == []

    def test_missing_script_fails(self, cd):
        fails = cd.check_cli_flags(
            "`python -m repro.launch.nonexistent --x`", "t.md"
        )
        assert fails

    def test_out_of_repo_commands_ignored(self, cd):
        assert cd.check_cli_flags("`python -m pytest -x --tb=short`", "t.md") == []


# ----------------------------------------------------------------------
# roofline --lm-sites rows
# ----------------------------------------------------------------------


class TestRooflineLmSites:
    def test_rows_and_total(self):
        from benchmarks import roofline

        rows = roofline.lm_site_rows("qwen2.5-3b", "train_tight")
        assert rows[-1]["kind"] == "lm_site_total"
        total = rows[-1]
        per_site = [r for r in rows if r["kind"] == "lm_site"]
        assert per_site
        assert total["fwd_flops"] == sum(
            r["count"] * r["fwd_flops"] for r in per_site
        )
        assert total["bwd_flops_lo"] <= total["bwd_flops_hi"]
        assert 0 < total["ratio_vs_6nd"] < 1.5
