"""Policy programs: Schedule objects, per-site rules, end-to-end parity.

Covers the redesigned control surface:

* golden values for every ``Schedule.rate`` / ``average_rate`` (the
  epoch bar must average to target/2 — the paper's ~40% saving claim);
* the legacy string shim (``drop_rate_for_step``) stays consistent with
  the objects, and bad scheduler names fail at policy construction;
* rule-pattern grammar (globs, brace sets, negative indices, ranges),
  first-match-wins resolution and table scoping;
* the jit-cache property: a program never produces more distinct
  per-step site tables than ``len(rate_buckets)``;
* the trivial one-rule program is bit-exact with the global-policy
  path, and genuinely per-site programs train end-to-end on two model
  families with FLOPs accounted over the resolved site table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import flops, schedulers
from repro.core.policy import (
    DENSE,
    PolicyProgram,
    PolicyRules,
    SitePolicies,
    SsPropPolicy,
    expand_pattern,
    paper_default,
    pattern_matches,
    policy_for,
)
from repro.core.schedulers import (
    Bar,
    Constant,
    Cosine,
    EpochBar,
    Linear,
    PeriodicBar,
    make_schedule,
)
from repro.data.pipeline import ImagePipeline, ImagePipelineConfig
from repro.launch import steps as steps_lib
from repro.models import model as lm
from repro.models import resnet
from repro.optim import adam


# ----------------------------------------------------------------------
# schedules
# ----------------------------------------------------------------------


class TestScheduleGolden:
    def test_constant(self):
        s = Constant(target=0.8)
        assert [s.rate(i) for i in (0, 7, 99)] == [0.8, 0.8, 0.8]
        assert s.average_rate(10) == 0.8
        assert s.average_rate(0) == 0.0

    def test_linear(self):
        s = Linear(target=0.8, total_steps=5)
        np.testing.assert_allclose(
            [s.rate(i) for i in range(5)], [0.0, 0.2, 0.4, 0.6, 0.8]
        )
        np.testing.assert_allclose(s.average_rate(5), 0.4)

    def test_cosine(self):
        s = Cosine(target=0.8, total_steps=3)
        np.testing.assert_allclose(
            [s.rate(i) for i in range(3)], [0.0, 0.4, 0.8], atol=1e-12
        )

    def test_bar(self):
        s = Bar(target=0.6, total_steps=10)
        assert [s.rate(i) for i in range(10)] == [0.0] * 5 + [0.6] * 5
        np.testing.assert_allclose(s.average_rate(10), 0.3)

    def test_epoch_bar(self):
        s = EpochBar(target=0.8, steps_per_epoch=3)
        assert [s.rate(i) for i in range(9)] == [0.0] * 3 + [0.8] * 3 + [0.0] * 3
        # the paper's "nearly 40% computation saved" at the 0.8 target
        assert s.average_rate(96) == 0.4  # whole 2-epoch periods
        # partial runs report the true mean, not the closed form: a
        # 1-epoch run trains entirely dense
        assert EpochBar(target=0.8, steps_per_epoch=10).average_rate(10) == 0.0
        np.testing.assert_allclose(
            EpochBar(target=0.8, steps_per_epoch=20).average_rate(30), 0.8 / 3
        )

    def test_periodic_bar(self):
        s = PeriodicBar(target=0.8, period=4)
        assert [s.rate(i) for i in range(8)] == [0.0, 0.0, 0.8, 0.8] * 2
        np.testing.assert_allclose(s.average_rate(8), 0.4)
        # odd period: 3 of 5 steps sparse
        np.testing.assert_allclose(
            PeriodicBar(target=0.8, period=5).average_rate(10), 0.48
        )
        with pytest.raises(ValueError):
            PeriodicBar(target=0.8, period=0)

    def test_bucketed_rate_and_scale(self):
        s = Linear(target=0.8, total_steps=100)
        assert s.bucketed_rate(99) == 0.8
        assert s.bucketed_rate(0) == 0.0
        assert s.scale(99) == 1.0
        assert s.scale(0) == 0.0
        assert Constant(target=0.0).scale(5) == 0.0


class TestLegacyShim:
    @pytest.mark.parametrize(
        "name", ["constant", "linear", "cosine", "bar", "epoch_bar", "periodic_bar"]
    )
    def test_drop_rate_for_step_matches_objects(self, name):
        sched = make_schedule(
            name, target=0.7, total_steps=40, steps_per_epoch=5, period=8
        )
        for step in range(40):
            legacy = schedulers.drop_rate_for_step(
                name, step=step, steps_per_epoch=5, total_steps=40,
                target=0.7, period=8,
            )
            assert legacy == sched.rate(step)

    def test_periodic_bar_legacy_string_is_valid_policy(self):
        # The satellite regression: "periodic_bar" used to pass the
        # dataclass but be missing from the scheduler registry.
        pol = SsPropPolicy(scheduler="periodic_bar")
        assert pol.scheduler in schedulers.SCHEDULE_NAMES

    def test_unknown_scheduler_fails_at_construction(self):
        with pytest.raises(ValueError, match="unknown scheduler"):
            SsPropPolicy(scheduler="cosine_bar")
        with pytest.raises(ValueError, match="unknown scheduler"):
            make_schedule("nope", target=0.8)

    def test_average_rate_shim(self):
        avg = schedulers.average_rate(
            "epoch_bar", total_steps=100, steps_per_epoch=10, target=0.8
        )
        assert abs(avg - 0.4) < 1e-9


# ----------------------------------------------------------------------
# rule patterns + resolution
# ----------------------------------------------------------------------


class TestRules:
    def test_expand_negative_and_range(self):
        assert expand_pattern("layer_{0,-1}/*", 12) == ("layer_0/*", "layer_11/*")
        assert expand_pattern("layer_{2..4}/mlp", 12) == (
            "layer_2/mlp", "layer_3/mlp", "layer_4/mlp",
        )
        assert expand_pattern("block_{0..-3}/x", 4) == ("block_0/x", "block_1/x")
        assert expand_pattern("{a,b}/{c,d}") == ("a/c", "a/d", "b/c", "b/d")

    def test_negative_without_depth_raises(self):
        with pytest.raises(ValueError, match="negative index"):
            expand_pattern("layer_{-1}/*", None)

    def test_pattern_matches(self):
        assert pattern_matches("*/attn/*", "layer_3/attn/q")
        assert pattern_matches("conv*", "conv1")
        assert not pattern_matches("layer_{0,-1}/*", "layer_1/attn/q", 4)
        assert pattern_matches("layer_{0,-1}/*", "layer_3/attn/q", 4)

    def test_first_match_wins_and_default(self):
        base = paper_default(0.8)
        rules = PolicyRules.of(
            ("layer_0/*", 0.0), ("*/attn/*", 0.5), base=base
        )
        tab = rules.resolve(
            ["layer_0/attn/q", "layer_1/attn/q", "layer_1/mlp/up"], depth=2
        )
        assert tab["layer_0/attn/q"].target_rate == 0.0  # rule 1 beats rule 2
        assert tab["layer_1/attn/q"].target_rate == 0.5
        assert tab["layer_1/mlp/up"].target_rate == 0.0  # default: dense
        assert tab["not/a/site"] == tab.default

    def test_parse_grammar(self):
        rules = PolicyRules.parse(
            "layer_{0,-1}/*=dense; */attn/*=0.5; *=0.8", base=paper_default(0.8)
        )
        assert [p.target_rate for _, p in rules.rules] == [0.0, 0.5, 0.8]
        with pytest.raises(ValueError):
            PolicyRules.parse("justapattern", base=paper_default(0.8))

    def test_scoped_and_uniform(self):
        tab = SitePolicies(
            (("layer_0/attn/q", DENSE), ("layer_0/mlp/up", paper_default(0.8))),
        )
        sub = tab.scoped("layer_0")
        assert sub["attn/q"] == DENSE
        assert sub["mlp/up"].drop_rate == 0.8
        assert tab.uniform() is None
        uni = SitePolicies((("a", DENSE), ("b", DENSE)), default=DENSE)
        assert uni.uniform() == DENSE

    def test_policy_for_plain_passthrough(self):
        pol = paper_default(0.5)
        assert policy_for(pol, "anything") is pol


# ----------------------------------------------------------------------
# programs
# ----------------------------------------------------------------------


def _resnet_program(schedule):
    rules = PolicyRules.of(
        ("stem", 0.0), ("block_{0,-1}/*", 0.0), ("*", 0.8),
        base=paper_default(0.8),
    )
    sites, depth = resnet.site_names("resnet18")
    return PolicyProgram(rules=rules, schedule=schedule).resolve(sites, depth=depth)


class TestProgram:
    def test_trivial_program_is_identity(self):
        pol = paper_default(0.8)
        res = PolicyProgram.single(pol).resolve(["a/b", "c"], depth=None)
        tab = res.policies_for_step(123)
        assert tab["a/b"] == pol
        assert tab["c"] == pol

    def test_single_off_bucket_rate_stays_exact(self):
        # 0.6 is not in the default rate_buckets; the trivial program
        # must still run exactly 0.6, not quantize it to 0.5
        res = PolicyProgram.single(paper_default(0.6)).resolve(["x"])
        assert res.peak()["x"].drop_rate == 0.6
        assert res.policies_for_step(7)["x"].drop_rate == 0.6

    def test_single_dense_stays_dense(self):
        # SsPropPolicy(0.0) carries the legacy target_rate=0.8 default;
        # the trivial program must still never schedule it sparse.
        res = PolicyProgram.single(SsPropPolicy(0.0)).resolve(["x"])
        assert res.peak()["x"].drop_rate == 0.0

    def test_epoch_bar_program_flips_all_sites(self):
        res = _resnet_program(EpochBar(target=0.8, steps_per_epoch=2))
        dense_tab = res.policies_for_step(0)
        sparse_tab = res.policies_for_step(2)
        assert all(p.drop_rate == 0.0 for _, p in dense_tab.entries)
        assert sparse_tab["block_1/conv1"].drop_rate == 0.8
        assert sparse_tab["block_0/conv1"].drop_rate == 0.0  # pinned dense
        assert sparse_tab["stem"].drop_rate == 0.0

    @pytest.mark.parametrize(
        "schedule",
        [
            Linear(target=0.8, total_steps=97),
            Cosine(target=0.8, total_steps=97),
            EpochBar(target=0.8, steps_per_epoch=7),
            PeriodicBar(target=0.8, period=13),
            Constant(target=0.8),
        ],
    )
    def test_jit_cache_bound_property(self, schedule):
        """Bucket quantization bounds the number of distinct compiled
        step tables by len(rate_buckets), whatever the schedule."""
        res = _resnet_program(schedule)
        tables = {res.policies_for_step(s) for s in range(97)}
        assert len(tables) <= len(schedule.rate_buckets)

    def test_average_rates_per_site(self):
        res = _resnet_program(EpochBar(target=0.8, steps_per_epoch=10))
        rates = res.average_rates(100)
        assert rates["stem"] == 0.0
        np.testing.assert_allclose(rates["block_3/conv1"], 0.4)


# ----------------------------------------------------------------------
# end-to-end: bit-exact parity + per-site training on two families
# ----------------------------------------------------------------------


def _train_resnet(policy_at_step, steps=4, seed=0):
    """Tiny resnet18 loop; returns (losses, params)."""
    pipe = ImagePipeline(ImagePipelineConfig((3, 16, 16), 10, 16, seed=3), n_train=64)
    params = resnet.init_params("resnet18", jax.random.PRNGKey(seed), num_classes=10)
    opt = adam.init(params)
    ocfg = adam.AdamConfig(lr=1e-3)
    cache = {}

    def get_step(pol):
        if pol not in cache:
            def loss_fn(p, x, y):
                logits = resnet.forward("resnet18", p, x, pol)
                return -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y].mean()

            @jax.jit
            def step(p, o, x, y):
                lv, g = jax.value_and_grad(loss_fn)(p, x, y)
                p2, o2, _ = adam.apply_updates(ocfg, p, g, o)
                return p2, o2, lv

            cache[pol] = step
        return cache[pol]

    losses = []
    for i in range(steps):
        b = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        params, opt, lv = get_step(policy_at_step(i))(
            params, opt, b["images"], b["labels"]
        )
        losses.append(float(lv))
    return losses, params


class TestBitExactParity:
    def test_one_rule_program_matches_global_policy_path(self):
        """The pre-redesign path (bucketed(drop_rate_for_step(...)) on a
        global policy) and the one-rule program produce bit-identical
        training trajectories."""
        base = paper_default(0.8)
        sched = EpochBar(target=0.8, steps_per_epoch=2)

        def legacy(i):
            rate = schedulers.drop_rate_for_step(
                "epoch_bar", step=i, steps_per_epoch=2, total_steps=4, target=0.8
            )
            return base.bucketed(rate)

        sites, depth = resnet.site_names("resnet18")
        res = PolicyProgram(
            rules=PolicyRules.single(base), schedule=sched
        ).resolve(sites, depth=depth)

        l1, p1 = _train_resnet(legacy)
        l2, p2 = _train_resnet(res.policies_for_step)
        assert l1 == l2  # bit-exact, not approximately equal
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2), strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPerSiteEndToEnd:
    def test_resnet_per_site_trains_and_differs_from_global(self):
        res = _resnet_program(EpochBar(target=0.8, steps_per_epoch=1))
        losses, _ = _train_resnet(res.policies_for_step)
        assert all(np.isfinite(losses))
        # sparse steps genuinely differ from the global-0.8 trajectory
        g_losses, _ = _train_resnet(lambda i: paper_default(0.8).bucketed(
            EpochBar(target=0.8, steps_per_epoch=1).rate(i)
        ))
        assert losses != g_losses

    def test_resnet_flops_match_resolved_site_table(self):
        """FLOPs are summed over the site table: the per-site count must
        equal the global-0.8 count plus exactly the delta of the sites
        pinned dense (counted at their own shapes)."""
        res = _resnet_program(Constant(target=0.8))
        peak = res.peak()
        batch, image = 8, (3, 32, 32)
        _, site_f = resnet.flops_per_iter("resnet18", batch, image, policy=peak)
        _, global_f = resnet.flops_per_iter(
            "resnet18", batch, image, policy=paper_default(0.8)
        )
        # dense-pinned sites: stem + block_0 (2 convs) + block_7 (2 convs)
        pinned = [
            (3, 64, 3, 32, 32),     # stem
            (64, 64, 3, 32, 32),    # block_0/conv1
            (64, 64, 3, 32, 32),    # block_0/conv2
            (512, 512, 3, 4, 4),    # block_7/conv1
            (512, 512, 3, 4, 4),    # block_7/conv2
        ]
        delta = 0
        for c_in, c_out, k, h, w in pinned:
            delta += flops.conv_backward_flops_policy(
                batch, h, w, c_in, c_out, k, DENSE
            ) - flops.conv_backward_flops_policy(
                batch, h, w, c_in, c_out, k, paper_default(0.8)
            )
        assert site_f == global_f + delta

    def test_uniform_site_table_equals_global_count(self):
        pol = paper_default(0.8)
        sites, depth = resnet.site_names("resnet18")
        uni = PolicyProgram.single(pol).resolve(sites, depth=depth).peak()
        a = resnet.flops_per_iter("resnet18", 8, (3, 32, 32), policy=uni)
        b = resnet.flops_per_iter("resnet18", 8, (3, 32, 32), policy=pol)
        assert a == b

    def test_transformer_per_site_trains_end_to_end(self):
        """Second model family: reduced LM, first/last layer dense, MLP
        at 0.8, attention at 0.5, trained through make_train_step."""
        cfg = get_config("qwen2.5-3b").reduced(n_layers=4, scan_layers=False)
        sites, depth = lm.site_names(cfg)
        rules = PolicyRules.of(
            ("layer_{0,-1}/*", 0.0),
            ("*/attn/*", 0.5),
            ("*/mlp/*", 0.8),
            base=paper_default(0.8),
        )
        res = PolicyProgram(
            rules=rules, schedule=EpochBar(target=0.8, steps_per_epoch=1)
        ).resolve(sites, depth=depth)
        tab = res.policies_for_step(1)  # sparse epoch
        assert tab["layer_0/attn/q"].drop_rate == 0.0
        assert tab["layer_3/mlp/up"].drop_rate == 0.0
        assert tab["layer_1/attn/q"].drop_rate == 0.5
        assert tab["layer_2/mlp/up"].drop_rate == 0.8

        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = adam.init(params)
        batch = {
            "tokens": jnp.ones((2, 16), jnp.int32),
            "targets": jnp.ones((2, 16), jnp.int32),
        }
        step = jax.jit(
            steps_lib.make_train_step(cfg, tab, adam.AdamConfig(lr=1e-3))
        )
        for _ in range(2):
            params, opt, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))

        # per-site FLOPs for the LM: summed over the resolved table,
        # each projection at its own keep count — not one global rate.
        d, ff, m = cfg.d_model, cfg.d_ff, 2 * 16
        hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
        shapes = {"attn/q": (d, nh * hd), "attn/k": (d, nkv * hd),
                  "attn/v": (d, nkv * hd), "attn/o": (nh * hd, d),
                  "mlp/up": (d, ff), "mlp/gate": (d, ff), "mlp/down": (ff, d)}
        site_total = sum(
            flops.dense_backward_flops_site(m, *shapes[s.split("/", 1)[1]], tab, s,
                                            bias=False)
            for s in sites
        )
        global_total = sum(
            flops.dense_backward_flops_policy(m, *shapes[s.split("/", 1)[1]],
                                              paper_default(0.8), bias=False)
            for s in sites
        )
        assert site_total > global_total  # dense/0.5 sites cost more than all-0.8

    def test_scan_layers_rejects_depth_varying_program(self):
        cfg = get_config("qwen2.5-3b").reduced(n_layers=4)  # scan_layers=True
        sites, depth = lm.site_names(cfg)
        rules = PolicyRules.of(
            ("layer_{0,-1}/*", 0.0), ("*", 0.8), base=paper_default(0.8)
        )
        tab = PolicyProgram(
            rules=rules, schedule=Constant(target=0.8)
        ).resolve(sites, depth=depth).peak()
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        batch = {
            "tokens": jnp.ones((2, 8), jnp.int32),
            "targets": jnp.ones((2, 8), jnp.int32),
        }
        with pytest.raises(ValueError, match="scan_layers"):
            lm.loss_fn(cfg, params, batch, tab)


# ----------------------------------------------------------------------
# config satellite: active-param counts
# ----------------------------------------------------------------------


class TestActiveParamCounts:
    """Pins the MoE active-param accounting (the dead hybrid clause in
    ``active_param_count`` was removed; these totals must not move)."""

    PINNED = {
        "jamba-1.5-large-398b": (397_704_429_568, 93_298_622_464),
        "kimi-k2-1t-a32b": (1_043_852_558_336, 33_746_714_624),
        "llama4-maverick-400b-a17b": (397_691_453_440, 14_164_295_680),
    }

    @pytest.mark.parametrize("arch", sorted(PINNED))
    def test_moe_counts_pinned(self, arch):
        cfg = get_config(arch)
        total, active = self.PINNED[arch]
        assert cfg.param_count() == total
        assert cfg.active_param_count() == active
        assert active < total

    def test_dense_active_equals_total(self):
        cfg = get_config("deepseek-67b")
        assert not cfg.is_moe
        assert cfg.active_param_count() == cfg.param_count()


def test_model_site_names_cover_all_families():
    """Every family enumerates sites; encdec includes encoder + cross."""
    for arch in ("qwen2.5-3b", "mamba2-1.3b", "jamba-1.5-large-398b",
                 "whisper-large-v3", "kimi-k2-1t-a32b"):
        cfg = get_config(arch).reduced()
        sites, depth = lm.site_names(cfg)
        assert depth == cfg.n_layers
        assert len(sites) == len(set(sites))
        if cfg.family == "encdec":
            assert any(s.startswith("enc/") for s in sites)
            assert any("/cross/" in s for s in sites)
        if cfg.family == "ssm":
            assert all("/ssm/" in s for s in sites)
        if cfg.is_moe:
            assert any("/moe/" in s for s in sites)
