"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gathered_matmul as gm
from repro.kernels import ops, ref

SHAPES_DX = [
    # (M, N, D_in, kept_blocks)
    (128, 256, 128, [0]),
    (256, 512, 384, [0, 2, 3]),
    (200, 512, 130, [1, 3]),  # non-multiples exercise padding
    (64, 128, 64, [0]),
]


@pytest.mark.parametrize("m,n,d,blocks", SHAPES_DX)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dx_gathered(m, n, d, blocks, dtype):
    k = jax.random.PRNGKey(0)
    dy = jax.random.normal(k, (m, n), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d, n), dtype)
    bidx = jnp.asarray(blocks, jnp.int32)
    out = ops.dx_gathered(dy, w, bidx)
    expect = ref.dx_gathered_ref(dy, w, bidx, 128)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("m,n,d,blocks", SHAPES_DX)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dw_gathered_scatter(m, n, d, blocks, dtype):
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (m, d), dtype)
    dy = jax.random.normal(jax.random.PRNGKey(3), (m, n), dtype)
    bidx = jnp.asarray(blocks, jnp.int32)
    out = ops.dw_gathered_scatter(x, dy, bidx, n)
    cols = ref.expand_block_idx(bidx, 128)
    expect = (
        jnp.zeros((d, n), jnp.float32)
        .at[:, cols]
        .set(ref.dw_gathered_ref(x, dy, bidx, 128))
    )
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * 10)
    # dropped blocks must be exactly zero
    dropped = sorted(set(range(n // 128)) - set(blocks))
    for b in dropped:
        assert np.abs(np.asarray(out)[:, b * 128 : (b + 1) * 128]).sum() == 0


@pytest.mark.parametrize("m,n", [(256, 128), (300, 130), (512, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_importance_kernel(m, n, dtype):
    dy = jax.random.normal(jax.random.PRNGKey(4), (m, n), dtype)
    out = ops.importance(dy)
    expect = ref.importance_ref(dy)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (200, 384, 130), (64, 256, 512)])
def test_matmul_kernel(m, k, n):
    a = jax.random.normal(jax.random.PRNGKey(5), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(6), (k, n))
    np.testing.assert_allclose(
        ops.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-3
    )


def test_kernel_blockspec_grid_shapes():
    """Direct (unpadded) kernel invocation at several block sizes."""
    for bm, bn in [(128, 128), (256, 128)]:
        m, n, d = 512, 512, 512
        dy = jax.random.normal(jax.random.PRNGKey(7), (m, n))
        w = jax.random.normal(jax.random.PRNGKey(8), (d, n))
        bidx = jnp.asarray([0, 3], jnp.int32)
        out = gm.dx_gathered(dy, w, bidx, bm=bm, bn=bn, interpret=True)
        np.testing.assert_allclose(
            out, ref.dx_gathered_ref(dy, w, bidx, 128), rtol=1e-5, atol=1e-3
        )


# --- fused-im2col conv kernels vs the framework conv VJP -------------

_DN = ("NCHW", "OIHW", "NCHW")
_FUSED_GEOMS = [
    # (stride, padding, dilation, groups)
    (1, 1, 1, 1),
    (2, 1, 1, 1),
    (1, 0, 2, 1),
    (1, 1, 1, 2),
    (2, 1, 2, 2),
]


def _conv_fused_case(stride, padding, dilation, groups, c_out=16, bs=4):
    c_in, k = 6, 3
    x = jax.random.normal(jax.random.PRNGKey(10), (2, c_in, 8, 8))
    w = jax.random.normal(
        jax.random.PRNGKey(11), (c_out, c_in // groups, k, k)
    ) * 0.2

    def fwd(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), ((padding, padding), (padding, padding)),
            rhs_dilation=(dilation, dilation), feature_group_count=groups,
            dimension_numbers=_DN,
        )

    y, vjp = jax.vjp(fwd, x, w)
    dy = jax.random.normal(jax.random.PRNGKey(12), y.shape)
    # one kept block per group where grouped (idx must be sorted and
    # spread block-diagonally); a ragged pair otherwise
    nb = c_out // bs
    blocks = (
        jnp.asarray([g * (nb // groups) for g in range(groups)], jnp.int32)
        if groups > 1
        else jnp.asarray([0, 2], jnp.int32)
    )
    chan = jnp.zeros((c_out,), bool)
    for b in np.asarray(blocks):
        chan = chan.at[b * bs : (b + 1) * bs].set(True)
    dy_masked = jnp.where(chan[None, :, None, None], dy, 0.0)
    dx_ref, dw_ref = vjp(dy_masked)
    common = dict(
        stride=(stride, stride), padding=((padding, padding), (padding, padding)),
        dilation=(dilation, dilation), groups=groups, block_size=bs,
    )
    return x, w, dy, blocks, dx_ref, dw_ref, common


@pytest.mark.parametrize("stride,padding,dilation,groups", _FUSED_GEOMS)
def test_conv_dx_fused_vs_vjp(stride, padding, dilation, groups):
    x, w, dy, blocks, dx_ref, _, common = _conv_fused_case(
        stride, padding, dilation, groups
    )
    dx = ops.conv_dx_fused(dy, w, blocks, hw=x.shape[2:], **common)
    np.testing.assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,padding,dilation,groups", _FUSED_GEOMS)
def test_conv_dw_fused_vs_vjp(stride, padding, dilation, groups):
    x, w, dy, blocks, _, dw_ref, common = _conv_fused_case(
        stride, padding, dilation, groups
    )
    kh, kw = w.shape[2:]
    dw2 = ops.conv_dw_fused_scatter(x, dy, blocks, kh=kh, kw=kw, **common)
    # [Cg*Kh*Kw, C_out] rows in (c, kh, kw) order -> OIHW
    expect = dw_ref.transpose(1, 2, 3, 0).reshape(-1, w.shape[0])
    np.testing.assert_allclose(dw2, expect, rtol=1e-4, atol=1e-4)


def test_conv_dw_fused_ragged_c_out():
    # c_out=10 with block_size=4: the phantom tail channels must stay
    # out of the scattered result
    x, w, dy, blocks, _, dw_ref, common = _conv_fused_case(
        1, 1, 1, 1, c_out=10, bs=4
    )
    dw2 = ops.conv_dw_fused_scatter(x, dy, blocks, kh=3, kw=3, **common)
    expect = dw_ref.transpose(1, 2, 3, 0).reshape(-1, 10)
    np.testing.assert_allclose(dw2, expect, rtol=1e-4, atol=1e-4)


# --- paged attention vs the gather + masked-attention oracle ---------


def _paged_attn_ref(q, k_pool, v_pool, tables, qpos):
    b, s, h, d = q.shape
    n_pages, bs_pg, kv, _ = k_pool.shape
    nb = tables.shape[1]
    tables = jnp.clip(tables, 0, n_pages - 1)
    g = h // kv
    kk = jnp.repeat(k_pool[tables].reshape(b, nb * bs_pg, kv, d), g, axis=2)
    vv = jnp.repeat(v_pool[tables].reshape(b, nb * bs_pg, kv, d), g, axis=2)
    t = jnp.arange(nb * bs_pg)
    mask = t[None, None, :] <= qpos[:, :, None]
    scores = jnp.einsum("bshd,bthd->bhst", q, kk).astype(jnp.float32)
    scores = scores / np.sqrt(d)
    scores = jnp.where(mask[:, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum(
        "bhst,bthd->bshd", p, vv.astype(jnp.float32)
    ).astype(q.dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,kv,s", [(4, 2, 2), (4, 4, 1)])
def test_paged_attention_vs_gather(dtype, h, kv, s):
    b, d, n_pages, bs_pg, nb = 3, 8, 10, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(20), 4)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k_pool = jax.random.normal(ks[1], (n_pages, bs_pg, kv, d), dtype)
    v_pool = jax.random.normal(ks[2], (n_pages, bs_pg, kv, d), dtype)
    tables = jax.random.randint(ks[3], (b, nb), 0, n_pages)
    # heterogeneous positions: slots mid-page, page-boundary, deep
    qpos = jnp.stack([jnp.arange(s) + off for off in (1, 4, 7)]).astype(jnp.int32)
    out = ops.paged_attention(q, k_pool, v_pool, tables, qpos)
    expect = _paged_attn_ref(q, k_pool, v_pool, tables, qpos)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, jnp.float32), np.asarray(expect, jnp.float32),
        rtol=tol, atol=tol,
    )


def test_paged_attention_ignores_unassigned_pages():
    """Table entries past the causal horizon may be stale or garbage —
    the per-token fence (t_pos <= qpos) must keep them out, and
    out-of-range page ids must not fault (they are clipped, then
    masked)."""
    b, s, h, kv, d = 2, 1, 4, 2, 8
    n_pages, bs_pg, nb = 6, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(21), 4)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k_pool = jax.random.normal(ks[1], (n_pages, bs_pg, kv, d))
    v_pool = jax.random.normal(ks[2], (n_pages, bs_pg, kv, d))
    qpos = jnp.asarray([[2], [5]], jnp.int32)  # pages 2+ never reached
    good = jnp.asarray([[0, 1, 2], [3, 4, 2]], jnp.int32)
    bad = good.at[:, 2].set(jnp.asarray([999, -7]))
    out_good = ops.paged_attention(q, k_pool, v_pool, good, qpos)
    out_bad = ops.paged_attention(q, k_pool, v_pool, bad, qpos)
    np.testing.assert_allclose(out_good, out_bad, rtol=1e-6, atol=1e-6)
