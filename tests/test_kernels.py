"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gathered_matmul as gm
from repro.kernels import ops, ref

SHAPES_DX = [
    # (M, N, D_in, kept_blocks)
    (128, 256, 128, [0]),
    (256, 512, 384, [0, 2, 3]),
    (200, 512, 130, [1, 3]),  # non-multiples exercise padding
    (64, 128, 64, [0]),
]


@pytest.mark.parametrize("m,n,d,blocks", SHAPES_DX)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dx_gathered(m, n, d, blocks, dtype):
    k = jax.random.PRNGKey(0)
    dy = jax.random.normal(k, (m, n), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d, n), dtype)
    bidx = jnp.asarray(blocks, jnp.int32)
    out = ops.dx_gathered(dy, w, bidx)
    expect = ref.dx_gathered_ref(dy, w, bidx, 128)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("m,n,d,blocks", SHAPES_DX)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dw_gathered_scatter(m, n, d, blocks, dtype):
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (m, d), dtype)
    dy = jax.random.normal(jax.random.PRNGKey(3), (m, n), dtype)
    bidx = jnp.asarray(blocks, jnp.int32)
    out = ops.dw_gathered_scatter(x, dy, bidx, n)
    cols = ref.expand_block_idx(bidx, 128)
    expect = (
        jnp.zeros((d, n), jnp.float32)
        .at[:, cols]
        .set(ref.dw_gathered_ref(x, dy, bidx, 128))
    )
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * 10)
    # dropped blocks must be exactly zero
    dropped = sorted(set(range(n // 128)) - set(blocks))
    for b in dropped:
        assert np.abs(np.asarray(out)[:, b * 128 : (b + 1) * 128]).sum() == 0


@pytest.mark.parametrize("m,n", [(256, 128), (300, 130), (512, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_importance_kernel(m, n, dtype):
    dy = jax.random.normal(jax.random.PRNGKey(4), (m, n), dtype)
    out = ops.importance(dy)
    expect = ref.importance_ref(dy)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (200, 384, 130), (64, 256, 512)])
def test_matmul_kernel(m, k, n):
    a = jax.random.normal(jax.random.PRNGKey(5), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(6), (k, n))
    np.testing.assert_allclose(
        ops.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-3
    )


def test_kernel_blockspec_grid_shapes():
    """Direct (unpadded) kernel invocation at several block sizes."""
    for bm, bn in [(128, 128), (256, 128)]:
        m, n, d = 512, 512, 512
        dy = jax.random.normal(jax.random.PRNGKey(7), (m, n))
        w = jax.random.normal(jax.random.PRNGKey(8), (d, n))
        bidx = jnp.asarray([0, 3], jnp.int32)
        out = gm.dx_gathered(dy, w, bidx, bm=bm, bn=bn, interpret=True)
        np.testing.assert_allclose(
            out, ref.dx_gathered_ref(dy, w, bidx, 128), rtol=1e-5, atol=1e-3
        )
