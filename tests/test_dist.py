"""Unit tests for repro.dist: fit_spec, the spec rule table, fault
tolerance edge cases, and the checkpoint paths test_system.py only
exercises indirectly (partial shardings restore, async-save flush)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.registry import get_config
from repro.dist import compat as dist_compat
from repro.dist import sharding as shd
from repro.dist.fault import (
    FleetSupervisor,
    Heartbeat,
    HeartbeatMonitor,
    HeartbeatThread,
    Membership,
    MembershipChanged,
    MembershipView,
    RestartPolicy,
    StragglerEvicted,
    StragglerSupervisor,
    StragglerTracker,
)
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


# ----------------------------------------------------------------------
# fit_spec
# ----------------------------------------------------------------------


class TestFitSpec:
    def test_legal_spec_passes_through(self):
        mesh = FakeMesh(model=4, data=2)
        sp = shd.fit_spec(P("data", None, "model"), (8, 3, 16), mesh)
        assert sp == P("data", None, "model")

    def test_relocates_to_nearest_divisible_dim(self):
        mesh = FakeMesh(model=16)
        # 16-way model on dim of size 8: both neighbours legal, later wins
        sp = shd.fit_spec(P(None, "model", None), (32, 8, 32), mesh)
        assert sp == P(None, None, "model")
        # only the earlier neighbour is legal
        sp = shd.fit_spec(P(None, "model", None), (32, 8, 3), mesh)
        assert sp == P("model", None, None)

    def test_no_legal_dim_falls_back_to_replicated(self):
        mesh = FakeMesh(model=16)
        sp = shd.fit_spec(P("model", None), (3, 5), mesh)
        assert sp == P(None, None)

    def test_tuple_axis_splits_jointly(self):
        mesh = FakeMesh(pod=2, data=16)
        # ('pod','data') = 32-way on batch 8: pod (2 | 8) stays on the
        # batch dim, data (16 | 64) relocates to the seq dim — the
        # tuple is split, not moved whole
        sp = shd.fit_spec(P(("pod", "data"), None), (8, 64), mesh)
        assert sp == P("pod", "data")

    def test_tuple_axis_keeps_largest_divisible_subtuple(self):
        mesh = FakeMesh(pod=2, data=16, model=4)
        # batch 16: data (16) wins the batch dim, pod moves to seq
        sp = shd.fit_spec(P(("pod", "data"), None), (16, 4096), mesh)
        assert sp == P("data", "pod")
        # batch 1 decode: nothing divides batch, both relocate; only
        # one free dim remains so the larger axis priority is moot —
        # relocation is per-axis, first-come
        sp = shd.fit_spec(P(("pod", "data"), None), (1, 524288), mesh)
        assert sp == P(None, "pod")

    def test_tuple_axis_whole_tuple_stays_when_divisible(self):
        mesh = FakeMesh(pod=2, data=16)
        sp = shd.fit_spec(P(("pod", "data"), None), (64, 64), mesh)
        assert sp == P(("pod", "data"), None)

    def test_short_spec_is_padded(self):
        mesh = FakeMesh(data=2)
        sp = shd.fit_spec(P("data"), (4, 8, 3), mesh)
        assert sp == P("data", None, None)

    def test_spec_longer_than_shape_is_truncated(self):
        mesh = FakeMesh(model=4)
        sp = shd.fit_spec(P(None, None, "model"), (8, 16), mesh)
        assert sp == P(None, None)

    def test_size_one_axis_always_legal(self):
        mesh = FakeMesh(model=1)
        sp = shd.fit_spec(P("model", None), (3, 5), mesh)
        assert sp == P("model", None)


# ----------------------------------------------------------------------
# param_specs rule table
# ----------------------------------------------------------------------


def _specs_by_path(arch):
    cfg = get_config(arch)
    a_params, _ = steps_lib.abstract_state(cfg)
    specs = shd.param_specs(a_params)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    return {jax.tree_util.keystr(k): v for k, v in flat}


class TestParamSpecs:
    def test_dense_arch_rules(self):
        by_path = _specs_by_path("mistral-large-123b")
        for proj in ("q", "k", "v"):
            vs = [v for k, v in by_path.items() if f"['attn']['{proj}']['w']" in k]
            assert vs and all(v[-1] == "model" for v in vs)
        ow = [v for k, v in by_path.items() if "['attn']['o']['w']" in k]
        assert ow and all(v[-2] == "model" for v in ow)
        up = [v for k, v in by_path.items() if "['mlp']['up']['w']" in k]
        assert up and all(v[-1] == "model" for v in up)
        dn = [v for k, v in by_path.items() if "['mlp']['down']['w']" in k]
        assert dn and all(v[-2] == "model" for v in dn)
        norms = [v for k, v in by_path.items() if "norm" in k]
        assert norms and all(all(e is None for e in v) for v in norms)

    def test_moe_arch_rules(self):
        by_path = _specs_by_path("kimi-k2-1t-a32b")
        for t in ("gate", "up", "down"):
            vs = [v for k, v in by_path.items() if f"['moe']['{t}']" in k and "shared" not in k]
            assert vs and all(v[1] == "model" for v in vs)
        router = [v for k, v in by_path.items() if "router" in k]
        assert router and all(all(e is None for e in v) for v in router)

    def test_ssm_arch_rules(self):
        by_path = _specs_by_path("mamba2-1.3b")
        inp = [v for k, v in by_path.items() if "['in_proj']['w']" in k]
        assert inp and all(v[-1] == "model" for v in inp)
        outp = [v for k, v in by_path.items() if "['out_proj']['w']" in k]
        assert outp and all(v[-2] == "model" for v in outp)
        conv = [v for k, v in by_path.items() if "conv" in k]
        assert conv and all(all(e is None for e in v) for v in conv)

    def test_embed_sharded_on_vocab(self):
        for arch in ("mistral-large-123b", "kimi-k2-1t-a32b", "mamba2-1.3b"):
            by_path = _specs_by_path(arch)
            emb = [v for k, v in by_path.items() if "embed" in k]
            assert emb and emb[0][0] == "model"

    def test_replicate_kv_option(self):
        cfg = get_config("qwen2.5-3b")
        a_params, _ = steps_lib.abstract_state(cfg)
        specs = shd.param_specs(a_params, replicate_kv=True)
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        by_path = {jax.tree_util.keystr(k): v for k, v in flat}
        for proj, expect_model in (("k", False), ("v", False), ("q", True)):
            vs = [v for k, v in by_path.items() if f"['attn']['{proj}']['w']" in k]
            assert vs
            for v in vs:
                assert (v[-1] == "model") == expect_model

    def test_param_shardings_all_legal_on_host_mesh(self):
        mesh = make_host_mesh(1, 1)
        cfg = get_config("qwen2.5-3b").reduced()
        a_params, _ = steps_lib.abstract_state(cfg)
        shardings = shd.param_shardings(mesh, a_params)
        leaves = jax.tree.leaves(shardings)
        assert leaves and all(
            isinstance(s, jax.sharding.NamedSharding) for s in leaves
        )


# ----------------------------------------------------------------------
# fault tolerance edge cases
# ----------------------------------------------------------------------


class TestHeartbeat:
    def test_empty_dir_no_dead_ranks(self, tmp_path):
        mon = HeartbeatMonitor(str(tmp_path), timeout_s=0.0)
        assert mon.dead_ranks() == []
        # a directory that doesn't exist yet is also fine
        mon = HeartbeatMonitor(str(tmp_path / "missing"), timeout_s=0.0)
        assert mon.dead_ranks() == []

    def test_single_rank_alive_then_dead(self, tmp_path):
        d = str(tmp_path)
        hb = Heartbeat(d, rank=0, interval_s=0.0)
        hb.beat(force=True)
        assert HeartbeatMonitor(d, timeout_s=3600.0).dead_ranks() == []
        assert HeartbeatMonitor(d, timeout_s=-1.0).dead_ranks() == [0]

    def test_interval_throttles_beats(self, tmp_path):
        hb = Heartbeat(str(tmp_path), rank=1, interval_s=3600.0)
        assert hb.beat() is True
        assert hb.beat() is False  # throttled
        assert hb.beat(force=True) is True

    def test_foreign_files_ignored(self, tmp_path):
        d = str(tmp_path)
        (tmp_path / "rank_notanumber").write_text("x")
        (tmp_path / "unrelated.txt").write_text("x")
        Heartbeat(d, rank=2, interval_s=0.0).beat(force=True)
        assert HeartbeatMonitor(d, timeout_s=-1.0).dead_ranks() == [2]


class TestStragglerTracker:
    def test_single_rank_never_straggles(self):
        t = StragglerTracker(slack=2.0)
        for _ in range(10):
            t.record(0, 100.0)
        assert t.stragglers() == []

    def test_warmup_records_not_judged(self):
        t = StragglerTracker(slack=2.0, min_records=3)
        t.record(0, 1.0)
        t.record(1, 50.0)
        assert t.stragglers() == []

    def test_slack_boundary(self):
        # EWMA exactly at slack x median is NOT a straggler; above is.
        t = StragglerTracker(slack=2.0, alpha=1.0, min_records=1)
        for r in (0, 1, 2):
            t.record(r, 1.0)
        t.record(3, 2.0)
        assert t.stragglers() == []  # 2.0 == 2.0 * median(1.0)
        t.record(3, 2.0 + 1e-6)
        assert t.stragglers() == [3]

    def test_two_rank_fleet_flags_the_slow_rank(self):
        # leave-one-out baseline: the slow rank must not shift the
        # median it is judged against
        t = StragglerTracker(slack=2.0, alpha=1.0, min_records=1)
        t.record(0, 1.0)
        t.record(1, 1000.0)
        assert t.stragglers() == [1]

    def test_recovered_rank_drops_off(self):
        t = StragglerTracker(slack=2.0, alpha=1.0, min_records=1)
        for r in range(4):
            t.record(r, 1.0)
        t.record(3, 10.0)
        assert t.stragglers() == [3]
        t.record(3, 1.0)  # alpha=1.0 -> instant recovery
        assert t.stragglers() == []


class TestStragglerEviction:
    """ROADMAP "Straggler response": detection wired to RestartPolicy
    through an excluded-rank list."""

    @staticmethod
    def _sup(patience=3):
        return StragglerSupervisor(
            StragglerTracker(slack=2.0, alpha=1.0, min_records=1),
            patience=patience,
        )

    def _feed(self, sup, slow_rank=3, slow=10.0, ranks=4):
        for r in range(ranks):
            sup.record(r, slow if r == slow_rank else 1.0)

    def test_patience_gates_eviction(self):
        sup = self._sup(patience=3)
        for _ in range(2):
            self._feed(sup)
            sup.check()  # streaks 1, 2: no eviction yet
        self._feed(sup)
        with pytest.raises(StragglerEvicted) as ei:
            sup.check()
        assert ei.value.rank == 3
        assert ei.value.ewma_s > ei.value.baseline_s

    def test_transient_slowness_resets_streak(self):
        sup = self._sup(patience=2)
        self._feed(sup)
        sup.check()
        self._feed(sup, slow=1.0)  # alpha=1.0: instant recovery
        sup.check()  # streak cleared
        self._feed(sup)
        sup.check()  # streak back to 1 — still no eviction
        self._feed(sup)
        with pytest.raises(StragglerEvicted):
            sup.check()

    def test_excluded_rank_never_re_evicted(self):
        sup = self._sup(patience=1)
        for _ in range(5):
            self._feed(sup)
            sup.check(excluded=[3])  # must not raise

    def test_restart_policy_records_rank_and_reshards(self):
        pol = RestartPolicy(max_restarts=0, backoff_s=0.0)
        seen = []

        def attempt(i):
            seen.append(tuple(pol.excluded_ranks))
            if not pol.excluded_ranks:
                raise StragglerEvicted(3, 10.0, 1.0)
            return "ok"

        evicted = []
        assert pol.run(attempt, on_evict=lambda r, e: evicted.append(r)) == "ok"
        assert pol.excluded_ranks == [3]
        assert evicted == [3]
        assert seen == [(), (3,)]  # second attempt saw the eviction

    def test_eviction_does_not_consume_restart_budget(self):
        pol = RestartPolicy(max_restarts=1, backoff_s=0.0)
        calls = []

        def attempt(i):
            calls.append(i)
            if len(calls) == 1:
                raise StragglerEvicted(1, 5.0, 1.0)
            if len(calls) == 2:
                raise RuntimeError("boom")
            return "ok"

        # one eviction + one crash still succeeds on a budget of 1
        assert pol.run(attempt) == "ok"
        assert len(calls) == 3

    def test_double_eviction_degrades_to_bounded_restart(self):
        pol = RestartPolicy(max_restarts=0, backoff_s=0.0)

        def attempt(i):
            raise StragglerEvicted(2, 9.0, 1.0)

        with pytest.raises(StragglerEvicted):
            pol.run(attempt)
        assert pol.excluded_ranks == [2]  # added once, then budget-bounded

    def test_evicted_rank_ewma_does_not_mask_survivors(self):
        # rank 2 evicted at EWMA 10.0; its stale entry must not inflate
        # the baseline rank 1 is judged against afterwards
        sup = self._sup(patience=1)
        sup.record(0, 1.0)
        sup.record(1, 1.0)
        sup.record(2, 10.0)
        with pytest.raises(StragglerEvicted) as ei:
            sup.check()
        assert ei.value.rank == 2
        sup.record(0, 1.0)
        sup.record(1, 3.9)  # straggler vs median 1.0 — but not vs 5.5
        with pytest.raises(StragglerEvicted) as ei:
            sup.check(excluded=[2])
        assert ei.value.rank == 1

    def test_eviction_storm_is_bounded(self):
        # never-repeating rank ids must not grant unlimited free restarts
        pol = RestartPolicy(max_restarts=0, backoff_s=0.0, max_evictions=3)
        seen = {"n": 0}

        def attempt(i):
            seen["n"] += 1
            raise StragglerEvicted(seen["n"], 9.0, 1.0)

        with pytest.raises(StragglerEvicted):
            pol.run(attempt)
        # 3 budgeted evictions + the one that degraded to a bounded restart
        assert len(pol.excluded_ranks) == 4

    def test_eviction_path_end_to_end(self):
        sup = self._sup(patience=2)
        pol = RestartPolicy(max_restarts=0, backoff_s=0.0)

        def attempt(i):
            ranks = [r for r in range(4) if r not in pol.excluded_ranks]
            for _ in range(3):
                for r in ranks:
                    sup.record(r, 10.0 if r == 2 else 1.0)
                sup.check(excluded=pol.excluded_ranks)
            return ranks

        assert pol.run(attempt) == [0, 1, 3]
        assert pol.excluded_ranks == [2]


class TestRestartPolicy:
    def test_retries_then_succeeds(self):
        calls = []

        def attempt(i):
            calls.append(i)
            if i < 2:
                raise RuntimeError("boom")
            return "ok"

        pol = RestartPolicy(max_restarts=3, backoff_s=0.0)
        restarts = []
        out = pol.run(attempt, on_restart=lambda i, e: restarts.append(i))
        assert out == "ok"
        assert calls == [0, 1, 2]
        assert restarts == [0, 1]

    def test_exhausted_restarts_reraise(self):
        pol = RestartPolicy(max_restarts=1, backoff_s=0.0)
        with pytest.raises(RuntimeError, match="always"):
            pol.run(lambda i: (_ for _ in ()).throw(RuntimeError("always")))


# ----------------------------------------------------------------------
# checkpoint: partial shardings restore + async-save flush
# ----------------------------------------------------------------------


class TestCkptPaths:
    def test_restore_with_partial_shardings(self, tmp_path):
        d = str(tmp_path)
        params = {"w": jnp.arange(8.0).reshape(2, 4)}
        m = {"w": jnp.ones((2, 4))}
        v = {"w": jnp.full((2, 4), 2.0)}
        ckpt_lib.save(d, 3, {"params": params, "m": m, "v": v})

        mesh = make_host_mesh(1, 1)
        sh = jax.sharding.NamedSharding(mesh, P(None, None))
        like = {"params": params, "m": m, "v": v}
        # partial: only params carries a sharding; m/v restore unsharded
        r = ckpt_lib.restore(d, 3, like, shardings={"params": {"w": sh}})
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]), params["w"])
        np.testing.assert_array_equal(np.asarray(r["m"]["w"]), m["w"])
        np.testing.assert_array_equal(np.asarray(r["v"]["w"]), v["w"])
        assert r["params"]["w"].sharding.is_equivalent_to(sh, 2)

    def test_restore_rejects_unmatched_shardings_keys(self, tmp_path):
        d = str(tmp_path)
        ckpt_lib.save(d, 1, {"params": {"w": jnp.ones(4)}})
        mesh = make_host_mesh(1, 1)
        sh = jax.sharding.NamedSharding(mesh, P(None))
        with pytest.raises(ValueError, match="match no checkpoint leaf"):
            ckpt_lib.restore(
                d, 1, {"params": {"w": jnp.ones(4)}},
                shardings={"param": {"w": sh}},  # typo'd key
            )

    def test_restore_with_single_sharding_broadcast(self, tmp_path):
        d = str(tmp_path)
        tree = {"a": jnp.ones(4), "b": jnp.zeros((2, 2))}
        ckpt_lib.save(d, 1, tree)
        mesh = make_host_mesh(1, 1)
        sh = jax.sharding.NamedSharding(mesh, P())
        r = ckpt_lib.restore(d, 1, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(r["a"]), tree["a"])
        np.testing.assert_array_equal(np.asarray(r["b"]), tree["b"])

    def test_saver_wait_flushes_last_async_save(self, tmp_path):
        d = str(tmp_path)
        saver = ckpt_lib.Saver(d, keep=10)
        for s in (1, 2, 3):
            saver.save(s, {"x": jnp.full((4,), float(s))})
        saver.wait()
        assert saver.last_path is not None
        assert ckpt_lib.list_steps(d) == [1, 2, 3]
        r = ckpt_lib.restore(d, 3, {"x": jnp.zeros(4)})
        np.testing.assert_array_equal(np.asarray(r["x"]), np.full((4,), 3.0))

    def test_saver_wait_idempotent_and_safe_before_save(self, tmp_path):
        saver = ckpt_lib.Saver(str(tmp_path))
        saver.wait()  # no save in flight: must not raise
        saver.save(1, {"x": jnp.ones(2)})
        saver.wait()
        saver.wait()
        assert ckpt_lib.latest_step(str(tmp_path)) == 1


# ----------------------------------------------------------------------
# clock skew: heartbeat mtimes vs the monitor's wall clock
# ----------------------------------------------------------------------


class TestMonitorClockSkew:
    def test_skewed_monitor_clock_does_not_evict_live_ranks(
        self, tmp_path, monkeypatch
    ):
        """Regression: ``dead_ranks()`` used to compare file mtimes
        against the monitor host's ``time.time()``; a monitor running
        ahead of the file server's clock falsely evicted live ranks.
        The default ``now`` is a sentinel-file mtime from the SAME
        filesystem clock, so process-clock skew is invisible."""
        import time as _time

        hb = Heartbeat(str(tmp_path), rank=0, interval_s=0.0)
        hb.beat(force=True)
        mon = HeartbeatMonitor(str(tmp_path), timeout_s=5.0)

        real = _time.time
        monkeypatch.setattr(_time, "time", lambda: real() + 10_000.0)
        assert mon.dead_ranks() == []

    def test_skewed_monitor_clock_behind_still_detects_dead(
        self, tmp_path, monkeypatch
    ):
        """The converse skew (monitor clock behind the file server)
        must not mask a genuinely stale heartbeat."""
        import os as _os
        import time as _time

        hb = Heartbeat(str(tmp_path), rank=0, interval_s=0.0)
        hb.beat(force=True)
        # fake a rank that stopped beating 100s ago (skewed mtimes)
        past = _os.path.getmtime(hb.path) - 100.0
        _os.utime(hb.path, (past, past))
        mon = HeartbeatMonitor(str(tmp_path), timeout_s=5.0)

        real = _time.time
        monkeypatch.setattr(_time, "time", lambda: real() - 10_000.0)
        assert mon.dead_ranks() == [0]

    def test_explicit_now_overrides_sentinel(self, tmp_path):
        import os as _os

        hb = Heartbeat(str(tmp_path), rank=3, interval_s=0.0)
        hb.beat(force=True)
        mon = HeartbeatMonitor(str(tmp_path), timeout_s=5.0)
        mtime = _os.path.getmtime(hb.path)
        assert mon.dead_ranks(now=mtime + 1.0) == []
        assert mon.dead_ranks(now=mtime + 100.0) == [3]


class TestHeartbeatThread:
    def test_background_beater_keeps_beating_through_main_stall(
        self, tmp_path
    ):
        """The beater thread models a rank whose MAIN thread is stuck
        in a long XLA compile: the heartbeat must stay fresh anyway
        (process liveness, not step progress)."""
        import os as _os
        import time as _time

        hb = Heartbeat(str(tmp_path), rank=0, interval_s=0.05)
        t = HeartbeatThread(hb).start()
        try:
            first = _os.path.getmtime(hb.path)
            deadline = _time.monotonic() + 5.0
            while _os.path.getmtime(hb.path) <= first:
                assert _time.monotonic() < deadline, "beater never beat again"
                _time.sleep(0.05)  # the "stalled" main thread
        finally:
            t.stop()

    def test_stop_is_graceful_and_idempotent(self, tmp_path):
        hb = Heartbeat(str(tmp_path), rank=1, interval_s=0.05)
        t = HeartbeatThread(hb).start()
        t.stop()
        t.stop()
        assert not t._thread.is_alive()


# ----------------------------------------------------------------------
# membership epochs: evict / un-evict / leader failover
# ----------------------------------------------------------------------


class TestMembership:
    def test_evict_bumps_epoch_and_moves_rank(self):
        m = Membership(0, (0, 1, 2, 3), ())
        m2 = m.evict([2])
        assert (m2.epoch, m2.active, m2.evicted) == (1, (0, 1, 3), (2,))

    def test_evict_noop_for_inactive_rank_keeps_epoch(self):
        m = Membership(0, (0, 1), (2,))
        assert m.evict([2]) is m
        assert m.evict([7]) is m

    def test_unevict_restores_rank_and_bumps_epoch(self):
        m = Membership(1, (0, 1, 3), (2,))
        m2 = m.unevict([2])
        assert (m2.epoch, m2.active, m2.evicted) == (2, (0, 1, 2, 3), ())

    def test_leader_fails_over_deterministically(self):
        m = Membership(0, (0, 1, 2), ())
        assert m.leader == 0
        assert m.evict([0]).leader == 1
        assert m.evict([0, 1]).leader == 2
        assert m.evict([0, 1, 2]).leader == -1

    def test_view_roundtrip_and_initial(self, tmp_path):
        view = MembershipView(str(tmp_path), 4)
        assert view.read() == view.initial() == Membership(0, (0, 1, 2, 3), ())
        m = view.initial().evict([1])
        view.write(m)
        assert view.read() == m


class TestFleetSupervisor:
    def _beat_all(self, coord, ranks):
        for r in ranks:
            Heartbeat(str(coord / "hb"), rank=r, interval_s=0.0).beat(force=True)

    def _stale(self, coord, rank, ago=100.0):
        import os as _os

        path = str(coord / "hb" / f"rank_{rank:05d}")
        past = _os.path.getmtime(path) - ago
        _os.utime(path, (past, past))

    def test_poll_evicts_stale_rank(self, tmp_path):
        self._beat_all(tmp_path, range(3))
        sup = FleetSupervisor(str(tmp_path), 3, timeout_s=5.0)
        self._stale(tmp_path, 2)
        m = sup.poll()
        assert (m.epoch, m.active, m.evicted) == (1, (0, 1), (2,))

    def test_poll_evicts_rank_that_never_beat(self, tmp_path):
        self._beat_all(tmp_path, [0, 2])
        sup = FleetSupervisor(str(tmp_path), 3, timeout_s=5.0)
        m = sup.poll()
        assert m.evicted == (1,)

    def test_rejoin_needs_request_and_fresh_beat(self, tmp_path):
        self._beat_all(tmp_path, range(2))
        sup = FleetSupervisor(str(tmp_path), 2, timeout_s=5.0)
        self._stale(tmp_path, 1)
        assert sup.poll().evicted == (1,)

        # a rejoin request alone (beat still stale) is not enough: a
        # stale request file from a rank that died again must not flap
        sup.request_rejoin(1)
        assert sup.poll().evicted == (1,)

        # fresh beat + request ⇒ un-evicted, epoch bumped again
        self._beat_all(tmp_path, [1])
        m = sup.poll()
        assert (m.epoch, m.active, m.evicted) == (2, (0, 1), ())
        # the request was consumed: the next poll is a no-op
        assert sup.poll().epoch == 2

    def test_completed_rank_is_never_evicted(self, tmp_path):
        """Orderly leave: a rank that wrote its done marker stops
        heartbeating on purpose — silence is completion, not death."""
        self._beat_all(tmp_path, range(2))
        (tmp_path / "done").mkdir()
        (tmp_path / "done" / "rank_00001.json").write_text("{}")
        sup = FleetSupervisor(str(tmp_path), 2, timeout_s=5.0)
        self._stale(tmp_path, 1)
        m = sup.poll()
        assert (m.epoch, m.active, m.evicted) == (0, (0, 1), ())
        assert sup.completed_ranks() == [1]

    def test_check_epoch_raises_on_drift(self, tmp_path):
        self._beat_all(tmp_path, range(2))
        sup = FleetSupervisor(str(tmp_path), 2, timeout_s=5.0)
        assert sup.check_epoch(0).epoch == 0
        self._stale(tmp_path, 1)
        sup.poll()
        with pytest.raises(MembershipChanged) as exc:
            sup.check_epoch(0)
        assert exc.value.membership.epoch == 1

    def test_should_poll_leader_and_failover(self, tmp_path):
        self._beat_all(tmp_path, range(3))
        sup = FleetSupervisor(str(tmp_path), 3, timeout_s=5.0)
        assert sup.should_poll(0)
        assert not sup.should_poll(1)
        assert not sup.should_poll(2)
        # leader heartbeat goes stale: the NEXT rank inherits the seat
        # (exactly one standby — rank 2 still defers)
        self._stale(tmp_path, 0)
        assert sup.should_poll(1)
        assert not sup.should_poll(2)

    def test_should_poll_skips_completed_leader(self, tmp_path):
        self._beat_all(tmp_path, range(3))
        (tmp_path / "done").mkdir()
        (tmp_path / "done" / "rank_00000.json").write_text("{}")
        sup = FleetSupervisor(str(tmp_path), 3, timeout_s=5.0)
        # rank 0 finished: the lowest still-running rank is the leader
        assert not sup.should_poll(0)
        assert sup.should_poll(1)
        assert not sup.should_poll(2)

    def test_wait_active_times_out_with_actionable_error(self, tmp_path):
        self._beat_all(tmp_path, range(2))
        sup = FleetSupervisor(str(tmp_path), 2, timeout_s=5.0)
        self._stale(tmp_path, 1)
        sup.poll()
        with pytest.raises(TimeoutError, match="rank 1 never re-admitted"):
            sup.wait_active(1, timeout_s=0.1)


class TestRestartPolicyUnexclude:
    def test_unexclude_readmits_and_reports(self):
        p = RestartPolicy(max_restarts=0)
        p.excluded_ranks.append(3)
        assert p.unexclude(3) is True
        assert p.excluded_ranks == []
        assert p.unexclude(3) is False

    def test_unexcluded_rank_is_evictable_afresh(self):
        """The rejoin half of the protocol: after unexclude, a repeat
        eviction of the same rank must again restart budget-free."""
        p = RestartPolicy(max_restarts=0, backoff_s=0.0)
        calls = []

        def attempt(i):
            calls.append(i)
            if len(calls) == 1:
                raise StragglerEvicted(3, 1.0, 0.1)
            if len(calls) == 2:
                p.unexclude(3)
                raise StragglerEvicted(3, 1.0, 0.1)
            return "ok"

        assert p.run(attempt) == "ok"
        assert len(calls) == 3


# ----------------------------------------------------------------------
# ProcessGroup: filesystem-backed control-plane collectives
# ----------------------------------------------------------------------


class TestProcessGroup:
    def _group(self, tmp_path, world=2, **kw):
        return [
            dist_compat.ProcessGroup(str(tmp_path), r, world, **kw)
            for r in range(world)
        ]

    def test_put_get_roundtrip(self, tmp_path):
        a, b = self._group(tmp_path)
        a.put("x.0", {"v": 1})
        assert b.get("x.0", 0, timeout_s=1.0) == {"v": 1}
        assert b.try_get("x.0", 1) is None

    def test_gather_returns_every_participant(self, tmp_path):
        a, b = self._group(tmp_path)
        a.put("g.0", "from0")
        got = b.gather("g.0", "from1", timeout_s=1.0)
        assert got == {0: "from0", 1: "from1"}

    def test_collectives_among_survivor_subset(self, tmp_path):
        """After an eviction the survivors pass ``ranks=`` and never
        wait on the dead rank."""
        pgs = self._group(tmp_path, world=3)
        pgs[0].put("s.0", 0)
        got = pgs[2].gather("s.0", 2, ranks=[0, 2], timeout_s=1.0)
        assert got == {0: 0, 2: 2}
        pgs[0].put("bar.b.0", None)
        pgs[2].barrier("b.0", ranks=[0, 2], timeout_s=1.0)

    def test_broadcast_from_src(self, tmp_path):
        a, b = self._group(tmp_path)
        a.broadcast("cfg.0", {"seed": 7})
        assert b.broadcast("cfg.0", src=0, timeout_s=1.0) == {"seed": 7}

    def test_missing_peer_times_out_not_hangs(self, tmp_path):
        (a,) = self._group(tmp_path, world=1)
        pg = dist_compat.ProcessGroup(str(tmp_path), 0, 2)
        with pytest.raises(dist_compat.ProcessGroupTimeout, match="rank 1"):
            pg.get("never.0", 1, timeout_s=0.05)

    def test_rank_outside_world_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="outside world"):
            dist_compat.ProcessGroup(str(tmp_path), 5, 2)

    def test_initialize_registers_and_unblocks(self, tmp_path):
        """initialize blocks until every peer registers, so the two
        ranks must initialize concurrently (as real processes would)."""
        import threading

        d = str(tmp_path)
        pgs = {}

        def init(r):
            pgs[r] = dist_compat.initialize(
                d, process_id=r, num_processes=2, timeout_s=10.0
            )

        threads = [threading.Thread(target=init, args=(r,)) for r in (0, 1)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=15.0)
        assert sorted(pgs) == [0, 1]
        assert dist_compat.registered_ranks(d) == [0, 1]
        pgs[0].put("hello.0", "hi")
        assert pgs[1].get("hello.0", 0, timeout_s=1.0) == "hi"

    def test_initialize_times_out_on_missing_peer(self, tmp_path):
        with pytest.raises(
            dist_compat.ProcessGroupTimeout, match="never registered"
        ):
            dist_compat.initialize(
                str(tmp_path), process_id=0, num_processes=2, timeout_s=0.1
            )
