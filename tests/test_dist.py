"""Unit tests for repro.dist: fit_spec, the spec rule table, fault
tolerance edge cases, and the checkpoint paths test_system.py only
exercises indirectly (partial shardings restore, async-save flush)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.registry import get_config
from repro.dist import sharding as shd
from repro.dist.fault import (
    Heartbeat,
    HeartbeatMonitor,
    RestartPolicy,
    StragglerEvicted,
    StragglerSupervisor,
    StragglerTracker,
)
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


# ----------------------------------------------------------------------
# fit_spec
# ----------------------------------------------------------------------


class TestFitSpec:
    def test_legal_spec_passes_through(self):
        mesh = FakeMesh(model=4, data=2)
        sp = shd.fit_spec(P("data", None, "model"), (8, 3, 16), mesh)
        assert sp == P("data", None, "model")

    def test_relocates_to_nearest_divisible_dim(self):
        mesh = FakeMesh(model=16)
        # 16-way model on dim of size 8: both neighbours legal, later wins
        sp = shd.fit_spec(P(None, "model", None), (32, 8, 32), mesh)
        assert sp == P(None, None, "model")
        # only the earlier neighbour is legal
        sp = shd.fit_spec(P(None, "model", None), (32, 8, 3), mesh)
        assert sp == P("model", None, None)

    def test_no_legal_dim_falls_back_to_replicated(self):
        mesh = FakeMesh(model=16)
        sp = shd.fit_spec(P("model", None), (3, 5), mesh)
        assert sp == P(None, None)

    def test_tuple_axis_uses_product_size(self):
        mesh = FakeMesh(pod=2, data=16)
        # ('pod','data') = 32-way on batch 8 -> moves to the seq dim
        sp = shd.fit_spec(P(("pod", "data"), None), (8, 64), mesh)
        assert sp == P(None, ("pod", "data"))

    def test_short_spec_is_padded(self):
        mesh = FakeMesh(data=2)
        sp = shd.fit_spec(P("data"), (4, 8, 3), mesh)
        assert sp == P("data", None, None)

    def test_spec_longer_than_shape_is_truncated(self):
        mesh = FakeMesh(model=4)
        sp = shd.fit_spec(P(None, None, "model"), (8, 16), mesh)
        assert sp == P(None, None)

    def test_size_one_axis_always_legal(self):
        mesh = FakeMesh(model=1)
        sp = shd.fit_spec(P("model", None), (3, 5), mesh)
        assert sp == P("model", None)


# ----------------------------------------------------------------------
# param_specs rule table
# ----------------------------------------------------------------------


def _specs_by_path(arch):
    cfg = get_config(arch)
    a_params, _ = steps_lib.abstract_state(cfg)
    specs = shd.param_specs(a_params)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    return {jax.tree_util.keystr(k): v for k, v in flat}


class TestParamSpecs:
    def test_dense_arch_rules(self):
        by_path = _specs_by_path("mistral-large-123b")
        for proj in ("q", "k", "v"):
            vs = [v for k, v in by_path.items() if f"['attn']['{proj}']['w']" in k]
            assert vs and all(v[-1] == "model" for v in vs)
        ow = [v for k, v in by_path.items() if "['attn']['o']['w']" in k]
        assert ow and all(v[-2] == "model" for v in ow)
        up = [v for k, v in by_path.items() if "['mlp']['up']['w']" in k]
        assert up and all(v[-1] == "model" for v in up)
        dn = [v for k, v in by_path.items() if "['mlp']['down']['w']" in k]
        assert dn and all(v[-2] == "model" for v in dn)
        norms = [v for k, v in by_path.items() if "norm" in k]
        assert norms and all(all(e is None for e in v) for v in norms)

    def test_moe_arch_rules(self):
        by_path = _specs_by_path("kimi-k2-1t-a32b")
        for t in ("gate", "up", "down"):
            vs = [v for k, v in by_path.items() if f"['moe']['{t}']" in k and "shared" not in k]
            assert vs and all(v[1] == "model" for v in vs)
        router = [v for k, v in by_path.items() if "router" in k]
        assert router and all(all(e is None for e in v) for v in router)

    def test_ssm_arch_rules(self):
        by_path = _specs_by_path("mamba2-1.3b")
        inp = [v for k, v in by_path.items() if "['in_proj']['w']" in k]
        assert inp and all(v[-1] == "model" for v in inp)
        outp = [v for k, v in by_path.items() if "['out_proj']['w']" in k]
        assert outp and all(v[-2] == "model" for v in outp)
        conv = [v for k, v in by_path.items() if "conv" in k]
        assert conv and all(all(e is None for e in v) for v in conv)

    def test_embed_sharded_on_vocab(self):
        for arch in ("mistral-large-123b", "kimi-k2-1t-a32b", "mamba2-1.3b"):
            by_path = _specs_by_path(arch)
            emb = [v for k, v in by_path.items() if "embed" in k]
            assert emb and emb[0][0] == "model"

    def test_replicate_kv_option(self):
        cfg = get_config("qwen2.5-3b")
        a_params, _ = steps_lib.abstract_state(cfg)
        specs = shd.param_specs(a_params, replicate_kv=True)
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        by_path = {jax.tree_util.keystr(k): v for k, v in flat}
        for proj, expect_model in (("k", False), ("v", False), ("q", True)):
            vs = [v for k, v in by_path.items() if f"['attn']['{proj}']['w']" in k]
            assert vs
            for v in vs:
                assert (v[-1] == "model") == expect_model

    def test_param_shardings_all_legal_on_host_mesh(self):
        mesh = make_host_mesh(1, 1)
        cfg = get_config("qwen2.5-3b").reduced()
        a_params, _ = steps_lib.abstract_state(cfg)
        shardings = shd.param_shardings(mesh, a_params)
        leaves = jax.tree.leaves(shardings)
        assert leaves and all(
            isinstance(s, jax.sharding.NamedSharding) for s in leaves
        )


# ----------------------------------------------------------------------
# fault tolerance edge cases
# ----------------------------------------------------------------------


class TestHeartbeat:
    def test_empty_dir_no_dead_ranks(self, tmp_path):
        mon = HeartbeatMonitor(str(tmp_path), timeout_s=0.0)
        assert mon.dead_ranks() == []
        # a directory that doesn't exist yet is also fine
        mon = HeartbeatMonitor(str(tmp_path / "missing"), timeout_s=0.0)
        assert mon.dead_ranks() == []

    def test_single_rank_alive_then_dead(self, tmp_path):
        d = str(tmp_path)
        hb = Heartbeat(d, rank=0, interval_s=0.0)
        hb.beat(force=True)
        assert HeartbeatMonitor(d, timeout_s=3600.0).dead_ranks() == []
        assert HeartbeatMonitor(d, timeout_s=-1.0).dead_ranks() == [0]

    def test_interval_throttles_beats(self, tmp_path):
        hb = Heartbeat(str(tmp_path), rank=1, interval_s=3600.0)
        assert hb.beat() is True
        assert hb.beat() is False  # throttled
        assert hb.beat(force=True) is True

    def test_foreign_files_ignored(self, tmp_path):
        d = str(tmp_path)
        (tmp_path / "rank_notanumber").write_text("x")
        (tmp_path / "unrelated.txt").write_text("x")
        Heartbeat(d, rank=2, interval_s=0.0).beat(force=True)
        assert HeartbeatMonitor(d, timeout_s=-1.0).dead_ranks() == [2]


class TestStragglerTracker:
    def test_single_rank_never_straggles(self):
        t = StragglerTracker(slack=2.0)
        for _ in range(10):
            t.record(0, 100.0)
        assert t.stragglers() == []

    def test_warmup_records_not_judged(self):
        t = StragglerTracker(slack=2.0, min_records=3)
        t.record(0, 1.0)
        t.record(1, 50.0)
        assert t.stragglers() == []

    def test_slack_boundary(self):
        # EWMA exactly at slack x median is NOT a straggler; above is.
        t = StragglerTracker(slack=2.0, alpha=1.0, min_records=1)
        for r in (0, 1, 2):
            t.record(r, 1.0)
        t.record(3, 2.0)
        assert t.stragglers() == []  # 2.0 == 2.0 * median(1.0)
        t.record(3, 2.0 + 1e-6)
        assert t.stragglers() == [3]

    def test_two_rank_fleet_flags_the_slow_rank(self):
        # leave-one-out baseline: the slow rank must not shift the
        # median it is judged against
        t = StragglerTracker(slack=2.0, alpha=1.0, min_records=1)
        t.record(0, 1.0)
        t.record(1, 1000.0)
        assert t.stragglers() == [1]

    def test_recovered_rank_drops_off(self):
        t = StragglerTracker(slack=2.0, alpha=1.0, min_records=1)
        for r in range(4):
            t.record(r, 1.0)
        t.record(3, 10.0)
        assert t.stragglers() == [3]
        t.record(3, 1.0)  # alpha=1.0 -> instant recovery
        assert t.stragglers() == []


class TestStragglerEviction:
    """ROADMAP "Straggler response": detection wired to RestartPolicy
    through an excluded-rank list."""

    @staticmethod
    def _sup(patience=3):
        return StragglerSupervisor(
            StragglerTracker(slack=2.0, alpha=1.0, min_records=1),
            patience=patience,
        )

    def _feed(self, sup, slow_rank=3, slow=10.0, ranks=4):
        for r in range(ranks):
            sup.record(r, slow if r == slow_rank else 1.0)

    def test_patience_gates_eviction(self):
        sup = self._sup(patience=3)
        for _ in range(2):
            self._feed(sup)
            sup.check()  # streaks 1, 2: no eviction yet
        self._feed(sup)
        with pytest.raises(StragglerEvicted) as ei:
            sup.check()
        assert ei.value.rank == 3
        assert ei.value.ewma_s > ei.value.baseline_s

    def test_transient_slowness_resets_streak(self):
        sup = self._sup(patience=2)
        self._feed(sup)
        sup.check()
        self._feed(sup, slow=1.0)  # alpha=1.0: instant recovery
        sup.check()  # streak cleared
        self._feed(sup)
        sup.check()  # streak back to 1 — still no eviction
        self._feed(sup)
        with pytest.raises(StragglerEvicted):
            sup.check()

    def test_excluded_rank_never_re_evicted(self):
        sup = self._sup(patience=1)
        for _ in range(5):
            self._feed(sup)
            sup.check(excluded=[3])  # must not raise

    def test_restart_policy_records_rank_and_reshards(self):
        pol = RestartPolicy(max_restarts=0, backoff_s=0.0)
        seen = []

        def attempt(i):
            seen.append(tuple(pol.excluded_ranks))
            if not pol.excluded_ranks:
                raise StragglerEvicted(3, 10.0, 1.0)
            return "ok"

        evicted = []
        assert pol.run(attempt, on_evict=lambda r, e: evicted.append(r)) == "ok"
        assert pol.excluded_ranks == [3]
        assert evicted == [3]
        assert seen == [(), (3,)]  # second attempt saw the eviction

    def test_eviction_does_not_consume_restart_budget(self):
        pol = RestartPolicy(max_restarts=1, backoff_s=0.0)
        calls = []

        def attempt(i):
            calls.append(i)
            if len(calls) == 1:
                raise StragglerEvicted(1, 5.0, 1.0)
            if len(calls) == 2:
                raise RuntimeError("boom")
            return "ok"

        # one eviction + one crash still succeeds on a budget of 1
        assert pol.run(attempt) == "ok"
        assert len(calls) == 3

    def test_double_eviction_degrades_to_bounded_restart(self):
        pol = RestartPolicy(max_restarts=0, backoff_s=0.0)

        def attempt(i):
            raise StragglerEvicted(2, 9.0, 1.0)

        with pytest.raises(StragglerEvicted):
            pol.run(attempt)
        assert pol.excluded_ranks == [2]  # added once, then budget-bounded

    def test_evicted_rank_ewma_does_not_mask_survivors(self):
        # rank 2 evicted at EWMA 10.0; its stale entry must not inflate
        # the baseline rank 1 is judged against afterwards
        sup = self._sup(patience=1)
        sup.record(0, 1.0)
        sup.record(1, 1.0)
        sup.record(2, 10.0)
        with pytest.raises(StragglerEvicted) as ei:
            sup.check()
        assert ei.value.rank == 2
        sup.record(0, 1.0)
        sup.record(1, 3.9)  # straggler vs median 1.0 — but not vs 5.5
        with pytest.raises(StragglerEvicted) as ei:
            sup.check(excluded=[2])
        assert ei.value.rank == 1

    def test_eviction_storm_is_bounded(self):
        # never-repeating rank ids must not grant unlimited free restarts
        pol = RestartPolicy(max_restarts=0, backoff_s=0.0, max_evictions=3)
        seen = {"n": 0}

        def attempt(i):
            seen["n"] += 1
            raise StragglerEvicted(seen["n"], 9.0, 1.0)

        with pytest.raises(StragglerEvicted):
            pol.run(attempt)
        # 3 budgeted evictions + the one that degraded to a bounded restart
        assert len(pol.excluded_ranks) == 4

    def test_eviction_path_end_to_end(self):
        sup = self._sup(patience=2)
        pol = RestartPolicy(max_restarts=0, backoff_s=0.0)

        def attempt(i):
            ranks = [r for r in range(4) if r not in pol.excluded_ranks]
            for _ in range(3):
                for r in ranks:
                    sup.record(r, 10.0 if r == 2 else 1.0)
                sup.check(excluded=pol.excluded_ranks)
            return ranks

        assert pol.run(attempt) == [0, 1, 3]
        assert pol.excluded_ranks == [2]


class TestRestartPolicy:
    def test_retries_then_succeeds(self):
        calls = []

        def attempt(i):
            calls.append(i)
            if i < 2:
                raise RuntimeError("boom")
            return "ok"

        pol = RestartPolicy(max_restarts=3, backoff_s=0.0)
        restarts = []
        out = pol.run(attempt, on_restart=lambda i, e: restarts.append(i))
        assert out == "ok"
        assert calls == [0, 1, 2]
        assert restarts == [0, 1]

    def test_exhausted_restarts_reraise(self):
        pol = RestartPolicy(max_restarts=1, backoff_s=0.0)
        with pytest.raises(RuntimeError, match="always"):
            pol.run(lambda i: (_ for _ in ()).throw(RuntimeError("always")))


# ----------------------------------------------------------------------
# checkpoint: partial shardings restore + async-save flush
# ----------------------------------------------------------------------


class TestCkptPaths:
    def test_restore_with_partial_shardings(self, tmp_path):
        d = str(tmp_path)
        params = {"w": jnp.arange(8.0).reshape(2, 4)}
        m = {"w": jnp.ones((2, 4))}
        v = {"w": jnp.full((2, 4), 2.0)}
        ckpt_lib.save(d, 3, {"params": params, "m": m, "v": v})

        mesh = make_host_mesh(1, 1)
        sh = jax.sharding.NamedSharding(mesh, P(None, None))
        like = {"params": params, "m": m, "v": v}
        # partial: only params carries a sharding; m/v restore unsharded
        r = ckpt_lib.restore(d, 3, like, shardings={"params": {"w": sh}})
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]), params["w"])
        np.testing.assert_array_equal(np.asarray(r["m"]["w"]), m["w"])
        np.testing.assert_array_equal(np.asarray(r["v"]["w"]), v["w"])
        assert r["params"]["w"].sharding.is_equivalent_to(sh, 2)

    def test_restore_rejects_unmatched_shardings_keys(self, tmp_path):
        d = str(tmp_path)
        ckpt_lib.save(d, 1, {"params": {"w": jnp.ones(4)}})
        mesh = make_host_mesh(1, 1)
        sh = jax.sharding.NamedSharding(mesh, P(None))
        with pytest.raises(ValueError, match="match no checkpoint leaf"):
            ckpt_lib.restore(
                d, 1, {"params": {"w": jnp.ones(4)}},
                shardings={"param": {"w": sh}},  # typo'd key
            )

    def test_restore_with_single_sharding_broadcast(self, tmp_path):
        d = str(tmp_path)
        tree = {"a": jnp.ones(4), "b": jnp.zeros((2, 2))}
        ckpt_lib.save(d, 1, tree)
        mesh = make_host_mesh(1, 1)
        sh = jax.sharding.NamedSharding(mesh, P())
        r = ckpt_lib.restore(d, 1, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(r["a"]), tree["a"])
        np.testing.assert_array_equal(np.asarray(r["b"]), tree["b"])

    def test_saver_wait_flushes_last_async_save(self, tmp_path):
        d = str(tmp_path)
        saver = ckpt_lib.Saver(d, keep=10)
        for s in (1, 2, 3):
            saver.save(s, {"x": jnp.full((4,), float(s))})
        saver.wait()
        assert saver.last_path is not None
        assert ckpt_lib.list_steps(d) == [1, 2, 3]
        r = ckpt_lib.restore(d, 3, {"x": jnp.zeros(4)})
        np.testing.assert_array_equal(np.asarray(r["x"]), np.full((4,), 3.0))

    def test_saver_wait_idempotent_and_safe_before_save(self, tmp_path):
        saver = ckpt_lib.Saver(str(tmp_path))
        saver.wait()  # no save in flight: must not raise
        saver.save(1, {"x": jnp.ones(2)})
        saver.wait()
        saver.wait()
        assert ckpt_lib.latest_step(str(tmp_path)) == 1
