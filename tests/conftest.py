import os
import sys

# src/ layout without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)

# The two multi-minute system tests (full CPU train runs); deselect with
# `-m "not slow"` for the fast CI lane.
_SLOW = {
    "test_ssprop_trains_comparably_to_dense",
    "test_train_cli_crash_resume",
}

# The speculative parity grids are 16 cells at ~1 CPU-minute each (the
# mismatched drafter rejects nearly everything, so every tick runs the
# drafter AND the rollback path). The decoder cells stay in the fast
# lane as the representative; the other families ride the full lane.
_SLOW_GRID_PREFIXES = (
    "test_speculative_matches_lockstep_greedy[",
    "test_speculative_matches_lockstep_sampled[",
)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute end-to-end test (fast lane skips these)"
    )
    config.addinivalue_line(
        "markers",
        "dist: multi-process fault-tolerance harness (spawns real rank "
        "subprocesses; CI runs these in their own lane)",
    )


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.name in _SLOW:
            item.add_marker(pytest.mark.slow)
        elif (
            item.name.startswith(_SLOW_GRID_PREFIXES)
            and "decoder" not in item.name
        ):
            item.add_marker(pytest.mark.slow)
        # every test in the multi-process harness is dist (and slow:
        # the fast lane must not pay for subprocess fleets)
        if "test_multiprocess" in str(item.fspath):
            item.add_marker(pytest.mark.dist)
            item.add_marker(pytest.mark.slow)
