"""End-to-end behaviour tests: training convergence with ssProp, the
paper's headline claims on synthetic data, checkpoint/restart, elastic
resharding, serving, and distributed lowering on a local mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.registry import get_config
from repro.core.policy import SsPropPolicy, paper_default
from repro.core.schedulers import drop_rate_for_step
from repro.data.pipeline import (
    ImagePipeline,
    ImagePipelineConfig,
    TokenPipeline,
    TokenPipelineConfig,
)
from repro.dist import sharding as shd
from repro.dist.fault import HeartbeatMonitor, Heartbeat, StragglerTracker
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import model as lm, resnet
from repro.optim import adam


def _train_resnet(policy_fn, steps=30, seed=0, name="resnet18", lr=1e-3):
    """Tiny ResNet on the synthetic image task; returns loss history."""
    pipe = ImagePipeline(ImagePipelineConfig((3, 16, 16), 10, 32, seed=1), n_train=256)
    params = resnet.init_params(name, jax.random.PRNGKey(seed), num_classes=10)
    opt_state = adam.init(params)
    opt_cfg = adam.AdamConfig(lr=lr)

    def loss_fn(params, batch, pol):
        logits = resnet.forward(name, params, batch["images"], pol)
        logp = jax.nn.log_softmax(logits)
        return -logp[jnp.arange(logits.shape[0]), batch["labels"]].mean()

    @jax.jit
    def step_dense(params, opt_state, batch):
        lv, g = jax.value_and_grad(loss_fn)(params, batch, SsPropPolicy(0.0))
        p, s, _ = adam.apply_updates(opt_cfg, params, g, opt_state)
        return p, s, lv

    @jax.jit
    def step_sparse(params, opt_state, batch):
        lv, g = jax.value_and_grad(loss_fn)(params, batch, paper_default(0.8))
        p, s, _ = adam.apply_updates(opt_cfg, params, g, opt_state)
        return p, s, lv

    hist = []
    for i in range(steps):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        rate = policy_fn(i)
        fn = step_sparse if rate > 0 else step_dense
        params, opt_state, lv = fn(params, opt_state, batch)
        hist.append(float(lv))
    return hist


class TestPaperClaims:
    def test_ssprop_trains_comparably_to_dense(self):
        """Headline claim: ~40% backward FLOPs saved with comparable loss."""
        dense = _train_resnet(lambda i: 0.0, steps=30)
        bar = _train_resnet(
            lambda i: drop_rate_for_step(
                "epoch_bar", step=i, steps_per_epoch=5, total_steps=30, target=0.8
            ),
            steps=30,
        )
        assert dense[-1] < dense[0] * 0.8  # training works at all
        assert bar[-1] < bar[0] * 0.85  # sparse training converges too
        # comparable: within 50% relative on this tiny task
        assert bar[-1] < dense[-1] * 1.5 + 0.3

    def test_lm_ssprop_trains(self):
        cfg = get_config("qwen2.5-3b").reduced()
        pipe = TokenPipeline(TokenPipelineConfig(cfg.vocab, 32, 8, seed=0))
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adam.init(params)
        step = jax.jit(
            steps_lib.make_train_step(
                cfg, paper_default(0.8), adam.AdamConfig(lr=1e-3)
            )
        )
        hist = []
        for i in range(20):
            batch = jax.tree.map(jnp.asarray, pipe.batch_at(i))
            params, opt_state, m = step(params, opt_state, batch)
            hist.append(float(m["loss"]))
        assert hist[-1] < hist[0]
        assert np.isfinite(hist).all()


class TestCheckpointRestart:
    def test_roundtrip_preserves_training_state(self, tmp_path):
        d = str(tmp_path)
        params = {"w": jnp.arange(12.0).reshape(3, 4)}
        st = adam.init(params)
        ckpt_lib.save(d, 5, {"params": params, "m": st.m, "v": st.v})
        like = {"params": params, "m": st.m, "v": st.v}
        r = ckpt_lib.restore(d, 5, like)
        np.testing.assert_array_equal(r["params"]["w"], params["w"])

    def test_commit_marker_hides_partial(self, tmp_path):
        d = str(tmp_path)
        os.makedirs(os.path.join(d, "step_00000007"))
        assert ckpt_lib.list_steps(d) == []
        ckpt_lib.save(d, 9, {"x": jnp.ones(3)})
        assert ckpt_lib.list_steps(d) == [9]

    def test_gc_keeps_latest(self, tmp_path):
        d = str(tmp_path)
        for s in (1, 2, 3, 4, 5):
            ckpt_lib.save(d, s, {"x": jnp.ones(2)}, keep=2)
        assert ckpt_lib.list_steps(d) == [4, 5]

    def test_elastic_reshard_across_meshes(self, tmp_path):
        """Save, then restore under an explicit (different) sharding."""
        d = str(tmp_path)
        w = jnp.arange(64.0).reshape(8, 8)
        ckpt_lib.save(d, 1, {"w": w})
        mesh = make_host_mesh(1, 1)
        sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(None, None))
        r = ckpt_lib.restore(d, 1, {"w": w}, shardings={"w": sh})
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(w))

    def test_train_cli_crash_resume(self, tmp_path):
        """Full driver: injected crash, auto-restart, bit-exact replay."""
        from repro.launch.train import build_parser, run

        args = build_parser().parse_args(
            [
                "--arch", "qwen2.5-3b", "--reduced", "--steps", "12",
                "--steps-per-epoch", "4", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "4", "--fail-at-step", "6",
                "--global-batch", "4", "--seq-len", "32", "--log-every", "100",
            ]
        )
        out = run(args)
        assert out["final_loss"] is not None and np.isfinite(out["final_loss"])
        assert ckpt_lib.latest_step(str(tmp_path)) == 12


class TestFaultTolerance:
    def test_heartbeat_monitor(self, tmp_path):
        d = str(tmp_path)
        hb = Heartbeat(d, rank=3, interval_s=0.0)
        hb.beat(force=True)
        mon = HeartbeatMonitor(d, timeout_s=60.0)
        assert mon.dead_ranks() == []
        mon_strict = HeartbeatMonitor(d, timeout_s=-1.0)
        assert mon_strict.dead_ranks() == [3]

    def test_straggler_tracker(self):
        t = StragglerTracker(slack=2.0)
        for r in range(8):
            for _ in range(5):
                t.record(r, 1.0)
        for _ in range(5):
            t.record(7, 10.0)
        assert t.stragglers() == [7]


class TestDistributedLowering:
    """pjit on a local 1x1 mesh with the production sharding rules."""

    def test_sharded_train_step_runs(self):
        cfg = get_config("qwen2.5-3b").reduced()
        mesh = make_host_mesh(1, 1)
        a_params, _ = steps_lib.abstract_state(cfg)
        p_sh = shd.param_shardings(mesh, a_params)
        with jax.set_mesh(mesh):
            params = jax.jit(lambda r: lm.init_params(cfg, r), out_shardings=p_sh)(
                jax.random.PRNGKey(0)
            )
            opt_state = adam.AdamState(
                jnp.zeros((), jnp.int32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            )
            step = jax.jit(
                steps_lib.make_train_step(cfg, paper_default(0.8), adam.AdamConfig())
            )
            tok = jnp.zeros((2, 16), jnp.int32)
            params, opt_state, m = step(params, opt_state, {"tokens": tok, "targets": tok})
            assert np.isfinite(float(m["loss"]))

    def test_spec_rules(self):
        """Production rules pick the intended axes."""
        cfg = get_config("mistral-large-123b")
        a_params, _ = steps_lib.abstract_state(cfg)
        specs = shd.param_specs(a_params)
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        by_path = {jax.tree_util.keystr(k): v for k, v in flat}
        qw = [v for k, v in by_path.items() if "['attn']['q']['w']" in k]
        assert qw and all(v[-1] == "model" for v in qw)
        ow = [v for k, v in by_path.items() if "['attn']['o']['w']" in k]
        assert ow and all(v[-2] == "model" for v in ow)
        emb = [v for k, v in by_path.items() if "embed" in k]
        assert emb and emb[0][0] == "model"

    def test_moe_expert_parallel_spec(self):
        cfg = get_config("kimi-k2-1t-a32b")
        a_params, _ = steps_lib.abstract_state(cfg)
        specs = shd.param_specs(a_params)
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        exp = [v for k, v in flat if "['moe']['up']" in jax.tree_util.keystr(k)]
        # expert tensors are stacked [np, E, d, ff] -> expert axis = model
        assert exp and all(v[1] == "model" for v in exp)

    def test_fit_spec_relocates_illegal_axis(self):
        from jax.sharding import PartitionSpec as P

        class FakeMesh:
            shape = {"model": 16, "data": 16}

        # kv-head dim 8 can't take 16 -> relocated to head_dim 128
        sp = shd.fit_spec(P(None, None, None, "model", None), (9, 128, 32768, 8, 128), FakeMesh())
        assert sp == P(None, None, None, None, "model")
        # batch=1 decode -> relocated to seq dim
        sp = shd.fit_spec(P(None, "data", None, None, "model"), (9, 1, 524288, 8, 128), FakeMesh())
        assert sp == P(None, None, "data", None, "model")


class TestServing:
    def test_serve_driver(self):
        from repro.launch.serve import build_parser, run

        args = build_parser().parse_args(
            ["--arch", "mamba2-1.3b", "--reduced", "--batch", "2",
             "--prompt-len", "4", "--gen", "4"]
        )
        out = run(args)
        assert out["generated"].shape == (2, 4)
        assert out["tokens_per_s"] > 0
