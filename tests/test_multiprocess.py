"""Multi-process chaos harness: N real ranks as OS subprocesses.

Every test here spawns genuine concurrent processes sharing a tmpdir
filesystem (the same substrate a multi-host fleet shares over NFS) and
exercises the rank-complete fault protocol end to end:

* a 4-rank fleet trains, SIGKILL takes a live rank down mid-step, the
  supervisor evicts it within the heartbeat timeout, the survivors
  restart resharded from the last committed checkpoint, a relaunched
  rank rejoins through the un-evict protocol — and the loss trajectory
  is bit-identical to an uninterrupted single-process reference run
  (compute is replicated across ranks, so fleet size never changes the
  math — see the train driver docstring);
* a checkpoint writer killed between its shard write and ``COMMITTED``
  leaves a torn step that restart discovery skips, and a restore that
  needs a missing ``shard_<r>.msgpack`` fails with an actionable error;
* per-host sharded save + partial-read restore onto a *reshaped* mesh
  (different axis split over 8 ``--xla_force_host_platform_device_count``
  devices) is bit-exact vs the monolithic restore path;
* the 512-chip dry-run lowering path lands the joint ``fit_spec``
  placement (``("pod","data")`` split across batch and seq at
  ``batch < dp_size``).

The test process legitimately runs ``FleetSupervisor.poll()`` in its
wait loops: the decision procedure is a pure function of the shared
files, so an extra (external) supervisor converges with the leader's —
and keeps the rejoin handshake from racing survivors that finish early.

Marked ``dist`` (and ``slow``): CI runs these in their own lane.
"""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.dist.fault import FleetSupervisor

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# generous single-core CI slack on top of the protocol timeout: four jax
# processes compete for the CPU, so wall-clock detection latency is
# timeout_s + (scheduler noise + supervisor poll cadence), not timeout_s
HB_TIMEOUT_S = 3.0
DETECT_SLACK_S = 25.0


def _env(**extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # identical device topology in every proc
    env.update(extra)
    return env


def _train_cmd(coord, rank, *, steps, world, step_delay=0.0):
    return [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "qwen2.5-3b", "--reduced",
        "--steps", str(steps), "--seq-len", "32", "--global-batch", "2",
        "--steps-per-epoch", "4",
        "--ckpt-dir", os.path.join(coord, "ckpt"), "--ckpt-every", "5",
        "--coord-dir", coord, "--world-size", str(world), "--rank", str(rank),
        "--hb-interval", "0.2", "--hb-timeout", str(HB_TIMEOUT_S),
        # a rejoined rank recompiles while its peers are already
        # stepping: the leader's commit must tolerate that skew
        "--commit-timeout", "30", "--rejoin-timeout", "300",
        "--step-delay", str(step_delay),
    ]


def _spawn(cmd, log_path):
    with open(log_path, "w") as log:
        return subprocess.Popen(
            cmd, env=_env(), stdout=log, stderr=subprocess.STDOUT
        )


def _tail(log_path, n=2000):
    try:
        with open(log_path) as f:
            return f.read()[-n:]
    except OSError:
        return "<no log>"


def _read_losses(path):
    """step → loss from an append-only jsonl log; steps replayed after a
    restart appear twice and the LAST occurrence wins."""
    out = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rec = json.loads(line)
                out[rec["step"]] = rec["loss"]
    return out


def _wait_for(cond, timeout_s, what, poll_s=0.25, on_poll=None):
    deadline = time.monotonic() + timeout_s
    while True:
        if cond():
            return
        if time.monotonic() > deadline:
            raise AssertionError(f"timed out waiting for {what}")
        if on_poll is not None:
            on_poll()
        time.sleep(poll_s)


def _membership(coord):
    try:
        with open(os.path.join(coord, "membership.json")) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _loss_lines(coord, rank):
    path = os.path.join(coord, "loss", f"rank_{rank:05d}.jsonl")
    if not os.path.exists(path):
        return 0
    with open(path) as f:
        return sum(1 for _ in f)


def test_chaos_kill_evict_rejoin_loss_parity(tmp_path):
    """SIGKILL a live rank mid-step: eviction within the heartbeat
    timeout, survivors restart resharded from the last committed
    checkpoint, a relaunched rank rejoins via un-evict, and every
    rank's final trajectory matches an uninterrupted run — exactly."""
    steps, world, victim = 40, 4, 2
    coord = str(tmp_path / "fleet")
    ref = str(tmp_path / "ref")
    os.makedirs(coord)
    os.makedirs(ref)

    # uninterrupted reference first (alone on the machine: fast, and its
    # losses are what the chaotic fleet must reproduce bit-for-bit)
    ref_log = str(tmp_path / "ref.log")
    rc = _spawn(_train_cmd(ref, 0, steps=steps, world=1), ref_log).wait(
        timeout=600
    )
    assert rc == 0, _tail(ref_log)
    ref_losses = _read_losses(os.path.join(ref, "loss", "rank_00000.jsonl"))
    assert sorted(ref_losses) == list(range(steps))

    procs = {
        r: _spawn(
            _train_cmd(coord, r, steps=steps, world=world, step_delay=0.2),
            str(tmp_path / f"rank{r}.log"),
        )
        for r in range(world)
    }
    sup = FleetSupervisor(coord, world, timeout_s=HB_TIMEOUT_S)
    admitted = {}
    try:
        # let the fleet get past its first committed checkpoint so the
        # survivors have something to restart from, then strike
        _wait_for(
            lambda: _loss_lines(coord, victim) >= 8
            and ckpt_lib.list_steps(os.path.join(coord, "ckpt")),
            timeout_s=300,
            what="fleet progress past the first committed checkpoint",
        )
        procs[victim].send_signal(signal.SIGKILL)
        procs[victim].wait(timeout=30)
        t_kill = time.monotonic()

        _wait_for(
            lambda: victim in _membership(coord).get("evicted", []),
            timeout_s=HB_TIMEOUT_S + DETECT_SLACK_S,
            what=f"supervisor evicting rank {victim}",
            on_poll=sup.poll,
        )
        detect_s = time.monotonic() - t_kill
        assert detect_s <= HB_TIMEOUT_S + DETECT_SLACK_S

        # relaunch the dead rank: same command, fresh process. It finds
        # itself evicted, files a rejoin request, and waits for the
        # supervisor to re-admit it.
        procs[victim] = _spawn(
            _train_cmd(coord, victim, steps=steps, world=world, step_delay=0.2),
            str(tmp_path / f"rank{victim}_re.log"),
        )
        _wait_for(
            lambda: victim in _membership(coord).get("active", []),
            timeout_s=300,
            what=f"rank {victim} re-admitted",
            on_poll=sup.poll,
        )
        admitted = _membership(coord)

        for r, p in procs.items():
            log = tmp_path / (f"rank{r}_re.log" if r == victim else f"rank{r}.log")
            assert p.wait(timeout=600) == 0, f"rank {r}: " + _tail(str(log))
    finally:
        for p in procs.values():
            if p.poll() is None:
                p.kill()

    # the rejoin handshake bumped the epoch twice (evict, un-evict) and
    # re-admitted the relaunched rank into the active set; completed
    # ranks are exempt from eviction (orderly leave), so the final view
    # is the full fleet again
    assert admitted.get("epoch", 0) >= 2
    assert victim in admitted.get("active", [])
    final_view = _membership(coord)
    assert sorted(final_view["active"]) == list(range(world))
    assert final_view["evicted"] == []

    # every rank — the relaunched victim included — reports completion
    # at the same final loss
    finals = []
    for r in range(world):
        with open(os.path.join(coord, "done", f"rank_{r:05d}.json")) as f:
            done = json.load(f)
        assert done["steps"] == steps
        finals.append(done["final_loss"])
    assert len(set(finals)) == 1

    # loss parity: every rank's trajectory (kill, shrink, rejoin and
    # all) equals the uninterrupted reference, step for step, bit for
    # bit — including the relaunched victim's
    for r in range(world):
        losses = _read_losses(
            os.path.join(coord, "loss", f"rank_{r:05d}.jsonl")
        )
        assert sorted(losses) == list(range(steps)), f"rank {r} gap"
        assert losses == ref_losses, f"rank {r} trajectory diverged"

    # the last committed checkpoint is per-host sharded across the FULL
    # post-rejoin fleet: every rank owns pieces again
    last = ckpt_lib.latest_step(os.path.join(coord, "ckpt"))
    step_dir = os.path.join(coord, "ckpt", f"step_{last:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "sharded"
    written = {
        p["shard"]
        for meta in manifest["keys"].values()
        for p in meta["pieces"]
    }
    assert written == set(range(world))
    for r in written:
        assert os.path.exists(os.path.join(step_dir, f"shard_{r}.msgpack"))


def _torn_tree():
    # (6,4) and (6,) split 3 ways across shards 0/1/2; the scalar is
    # whole-owned by shard 0 (crc32 pick) — the one key a partial
    # restore can still serve after shard 2 is lost
    return {
        "w": np.arange(24, dtype=np.float32).reshape(6, 4),
        "b": np.arange(6, dtype=np.float32),
        "scale": np.float32(2.5),
    }


_TORN_WRITER = """
import os, signal, sys
import numpy as np
sys.path.insert(0, {src!r})
from repro.checkpoint import ckpt

tree = {{
    "w": np.arange(24, dtype=np.float32).reshape(6, 4),
    "b": np.arange(6, dtype=np.float32),
    "scale": np.float32(2.5),
}}
items, _ = ckpt._flatten(tree)
items = [(k, np.asarray(v)) for k, v in items]
ranks = [0, 1, 2]
plan = ckpt.make_shard_plan(items, ranks)
# shards 0 and 1 land; the manifest lands; then the process dies
# before shard 2 and before COMMITTED — a torn step
ckpt.write_shard({ckpt_dir!r}, 7, items, rank=0, plan=plan)
ckpt.write_shard({ckpt_dir!r}, 7, items, rank=1, plan=plan)
ckpt.write_sharded_manifest({ckpt_dir!r}, 7, items, plan=plan, ranks=ranks)
print("WROTE", flush=True)
os.kill(os.getpid(), signal.SIGKILL)
"""


def test_checkpoint_crash_atomicity(tmp_path):
    """A writer killed between shard write and COMMITTED leaves a step
    that restart discovery skips; a restore that needs the missing
    shard is an actionable hard error, not a silently partial tree."""
    ckpt_dir = str(tmp_path / "ckpt")
    tree = _torn_tree()
    like = {k: np.zeros_like(v) for k, v in tree.items()}

    # a prior committed step the fleet can fall back to
    ckpt_lib.save(ckpt_dir, 5, like)

    script = _TORN_WRITER.format(src=SRC, ckpt_dir=ckpt_dir)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=_env(),
        capture_output=True, text=True, timeout=300,
    )
    assert "WROTE" in proc.stdout, proc.stdout + proc.stderr
    assert proc.returncode == -signal.SIGKILL

    step_dir = os.path.join(ckpt_dir, "step_00000007")
    assert os.path.isdir(step_dir), "the torn step must exist on disk"
    assert not os.path.exists(os.path.join(step_dir, "COMMITTED"))
    # restart discovery skips the torn step and falls back
    assert ckpt_lib.list_steps(ckpt_dir) == [5]
    assert ckpt_lib.latest_step(ckpt_dir) == 5

    # the leader's commit cannot complete either: shard 2 never landed
    with pytest.raises(TimeoutError, match="missing shards"):
        ckpt_lib.commit_sharded(ckpt_dir, 7, timeout_s=0.5)

    # forcing a restore of the torn step: needing the missing shard is
    # a hard, actionable error naming the lost file
    with pytest.raises(ckpt_lib.MissingShardError, match="shard_2.msgpack"):
        ckpt_lib.restore(ckpt_dir, 7, like)

    # ...but keys whose pieces avoid the dead shard partial-restore fine
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    safe = [
        k for k, meta in manifest["keys"].items()
        if all(p["shard"] != 2 for p in meta["pieces"])
    ]
    assert safe == ["['scale']"]
    partial = ckpt_lib.restore(ckpt_dir, 7, {"scale": like["scale"]})
    np.testing.assert_array_equal(np.asarray(partial["scale"]), tree["scale"])

    # the repaired save (shard 2 written, commit retried) becomes
    # visible to discovery and restores in full
    items, _ = ckpt_lib._flatten(tree)
    items = [(k, np.asarray(v)) for k, v in items]
    plan = ckpt_lib.make_shard_plan(items, [0, 1, 2])
    ckpt_lib.write_shard(ckpt_dir, 7, items, rank=2, plan=plan)
    ckpt_lib.commit_sharded(ckpt_dir, 7, timeout_s=5)
    assert ckpt_lib.latest_step(ckpt_dir) == 7
    full = ckpt_lib.restore(ckpt_dir, 7, like)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(full[k]), tree[k])


_RESHAPE_SCRIPT = """
import json, os, sys
sys.path.insert(0, {src!r})
import jax
import numpy as np
from repro.checkpoint import ckpt
from repro.configs.registry import get_config
from repro.dist import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import model as lm

out = {{}}
cfg = get_config("qwen2.5-3b").reduced()
assert jax.device_count() == 8, jax.device_count()

# ---- writer: params live on a (data=2, model=4) mesh; 4 "hosts" of 2
# devices each write their own shard under the addressable-shards plan
mesh_w = make_host_mesh(2, 4)
a_params, _ = steps_lib.abstract_state(cfg)
p_sh_w = shd.param_shardings(mesh_w, a_params)
with jax.set_mesh(mesh_w):
    params = jax.jit(lambda r: lm.init_params(cfg, r), out_shardings=p_sh_w)(
        jax.random.PRNGKey(0)
    )

flat, _ = jax.tree_util.tree_flatten_with_path(params)
items = [
    (jax.tree_util.keystr(k), np.asarray(jax.device_get(v))) for k, v in flat
]
sflat, _ = jax.tree_util.tree_flatten_with_path(shd.param_specs(a_params))
specs = [v for _, v in sflat]
ranks = [0, 1, 2, 3]
plan = ckpt.plan_from_specs(items, specs, dict(mesh_w.shape), ranks)
ckpt.validate_plan(plan, {{k: v.shape for k, v in items}})

sharded_dir = {sharded_dir!r}
mono_dir = {mono_dir!r}
for r in ranks:
    ckpt.write_shard(sharded_dir, 3, items, rank=r, plan=plan)
ckpt.write_sharded_manifest(sharded_dir, 3, items, plan=plan, ranks=ranks)
ckpt.commit_sharded(sharded_dir, 3, timeout_s=5)
ckpt.save(mono_dir, 3, params)

manifest = json.load(
    open(os.path.join(sharded_dir, "step_00000003", "manifest.json"))
)
out["shards_used"] = sorted(
    {{p["shard"] for m in manifest["keys"].values() for p in m["pieces"]}}
)

# ---- reader: a RESHAPED mesh (data=4, model=2) — different axis split,
# different per-device slices; restore must be bit-exact anyway
mesh_r = make_host_mesh(4, 2)
p_sh_r = shd.param_shardings(mesh_r, a_params)
like = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), a_params)
got_sharded = ckpt.restore(sharded_dir, 3, like, shardings=p_sh_r)
got_mono = ckpt.restore(mono_dir, 3, like, shardings=p_sh_r)

def same(a, b):
    return bool(
        np.array_equal(
            np.asarray(jax.device_get(a)), np.asarray(jax.device_get(b))
        )
    )

out["bit_exact"] = all(
    jax.tree.leaves(jax.tree.map(same, got_sharded, got_mono))
)
out["reader_sharding_ok"] = all(
    jax.tree.leaves(
        jax.tree.map(lambda g, s: g.sharding == s, got_sharded, p_sh_r)
    )
)

# ---- partial read: restore one top-level subtree whose pieces span a
# strict subset of the shards, with an UNNEEDED shard file hidden —
# proving only the covering shards are read
by_head = {{}}
for key, meta in manifest["keys"].items():
    head = key.split("]")[0] + "]"
    by_head.setdefault(head, set()).update(p["shard"] for p in meta["pieces"])
head, needed = min(
    ((h, s) for h, s in by_head.items() if len(s) < len(ranks)),
    key=lambda kv: len(kv[1]),
)
sub_key = head[2:-2]  # "['embed']" -> "embed"
unneeded = sorted(set(ranks) - needed)[0]
victim = os.path.join(
    sharded_dir, "step_00000003", f"shard_{{unneeded}}.msgpack"
)
os.rename(victim, victim + ".hidden")
sub = ckpt.restore(
    sharded_dir, 3, {{sub_key: like[sub_key]}},
    shardings={{sub_key: p_sh_r[sub_key]}},
)
out["partial_subtree"] = sub_key
out["partial_bit_exact"] = all(
    jax.tree.leaves(jax.tree.map(same, sub[sub_key], got_mono[sub_key]))
)
# the FULL restore does need the hidden shard: actionable hard error
try:
    ckpt.restore(sharded_dir, 3, like, shardings=p_sh_r)
    out["missing_shard_detected"] = False
except ckpt.MissingShardError:
    out["missing_shard_detected"] = True
print("RESULT " + json.dumps(out))
"""


def test_sharded_restore_reshaped_mesh_bit_exact(tmp_path):
    """Per-host sharded save on a (2,4) mesh, partial-read restore onto
    a reshaped (4,2) mesh: bit-exact vs the monolithic path, asserted
    inside a real 8-device subprocess."""
    script = _RESHAPE_SCRIPT.format(
        src=SRC,
        sharded_dir=str(tmp_path / "sharded"),
        mono_dir=str(tmp_path / "mono"),
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        env=_env(XLA_FLAGS="--xla_force_host_platform_device_count=8"),
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    line = next(
        ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT ")
    )
    out = json.loads(line[len("RESULT "):])
    assert out["shards_used"] == [0, 1, 2, 3]
    assert out["bit_exact"]
    assert out["reader_sharding_ok"]
    assert out["partial_bit_exact"], out
    assert out["missing_shard_detected"]


def test_dryrun_joint_fit_spec_placement():
    """The 512-chip multi-pod lowering path lands the JOINT batch split
    for the tight-batch train cell: batch 8 < dp_size 32, so ``pod``
    (2 | 8) stays on the batch dim and ``data`` (16) relocates to seq."""
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "qwen2.5-3b", "--shape", "train_tight",
            "--mesh", "multi", "--placements-only",
        ],
        env=_env(), capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    payload = json.loads(
        [ln for ln in proc.stdout.splitlines() if ln.startswith("{")][-1]
    )
    assert payload["inputs"]["['tokens']"] == "PartitionSpec('pod', 'data')"
    assert payload["inputs"]["['targets']"] == "PartitionSpec('pod', 'data')"
