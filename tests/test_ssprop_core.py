"""Core ssProp behaviour: selection, gradients, schedulers, FLOPs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SsPropPolicy,
    sparse_dense,
    sparse_conv2d,
    channel_importance,
    select_topk_channels,
    flops,
)
from repro.core import schedulers, sparsity
from repro.core.policy import paper_default, tpu_default


def _dense_grads(x, w, b, pol):
    def loss(x, w, b):
        return (sparse_dense(x, w, b, policy=pol) ** 2).sum()

    return jax.grad(loss, argnums=(0, 1, 2))(x, w, b)


@pytest.fixture(scope="module")
def xwb():
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (16, 48))
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 96))
    b = jax.random.normal(jax.random.PRNGKey(2), (96,))
    return x, w, b


class TestSelection:
    def test_importance_matches_definition(self):
        dy = jax.random.normal(jax.random.PRNGKey(3), (4, 7, 9))
        imp = channel_importance(dy, channel_axis=1)
        ref = jnp.abs(dy).mean(axis=(0, 2))
        np.testing.assert_allclose(imp, ref, rtol=1e-6)

    def test_topk_keeps_largest(self):
        imp = jnp.array([0.1, 5.0, 0.2, 3.0, 0.01])
        idx = select_topk_channels(imp, 2)
        assert set(np.asarray(idx).tolist()) == {1, 3}
        assert np.all(np.diff(np.asarray(idx)) > 0)  # sorted

    def test_block_selection_alignment(self):
        imp = jnp.arange(256.0)
        bidx = sparsity.select_topk_blocks(imp, 128, 1)
        assert np.asarray(bidx).tolist() == [1]  # second block has larger mean

    def test_keep_count(self):
        pol = SsPropPolicy(0.8)
        assert pol.keep_count(64) == 13
        polb = tpu_default(0.5)
        assert polb.keep_count(256) == 1  # 2 blocks -> keep 1


class TestDenseGrad:
    def test_dense_policy_equals_autodiff(self, xwb):
        x, w, b = xwb
        g = _dense_grads(x, w, b, SsPropPolicy(0.0))
        gp = jax.grad(lambda x, w, b: ((x @ w + b) ** 2).sum(), argnums=(0, 1, 2))(
            x, w, b
        )
        for a, r in zip(g, gp, strict=True):
            np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("rate", [0.25, 0.5, 0.8])
    def test_gather_equals_mask_oracle(self, xwb, rate):
        x, w, b = xwb
        g_gather = _dense_grads(x, w, b, paper_default(rate))
        g_mask = _dense_grads(x, w, b, SsPropPolicy(rate, mask_mode=True))
        for a, r in zip(g_gather, g_mask, strict=True):
            np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)

    def test_dropped_channels_zero_grad(self, xwb):
        x, w, b = xwb
        pol = paper_default(0.5)
        _, dw, db = _dense_grads(x, w, b, pol)
        zero_cols = int((np.abs(np.asarray(dw)).sum(0) == 0).sum())
        assert zero_cols == 96 - pol.keep_count(96)
        assert int((np.asarray(db) == 0).sum()) >= zero_cols

    def test_kept_channels_are_most_important(self, xwb):
        x, w, b = xwb
        pol = paper_default(0.5)

        def loss(x, w, b):
            return (sparse_dense(x, w, b, policy=pol) ** 2).sum()

        # recover dy at output: dL/dy = 2y
        y = x @ w + b
        imp = np.asarray(jnp.abs(2 * y).mean(0))
        _, dw, _ = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
        kept = np.abs(np.asarray(dw)).sum(0) != 0
        k = pol.keep_count(96)
        topk = set(np.argsort(-imp)[:k].tolist())
        assert set(np.where(kept)[0].tolist()) == topk

    def test_random_selection_differs_from_topk(self, xwb):
        x, w, b = xwb
        pol = SsPropPolicy(0.5, selection="random")
        key = jax.random.PRNGKey(7)

        def loss(x, w, b):
            return (sparse_dense(x, w, b, policy=pol, key=key) ** 2).sum()

        _, dw_r, _ = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
        _, dw_t, _ = _dense_grads(x, w, b, paper_default(0.5))
        assert not np.allclose(dw_r, dw_t)

    def test_forward_unchanged_by_policy(self, xwb):
        x, w, b = xwb
        y0 = sparse_dense(x, w, b, policy=SsPropPolicy(0.0))
        y1 = sparse_dense(x, w, b, policy=paper_default(0.95))
        np.testing.assert_allclose(y0, y1, rtol=1e-6)

    def test_block_granularity_pallas_path(self, xwb):
        x, w, b = xwb
        # pad to block-size-friendly dims
        x = jnp.pad(x, ((0, 0), (0, 80)))  # 128 in
        w = jnp.pad(w, ((0, 80), (0, 160)))  # 128 -> 256
        b = jnp.pad(b, (0, 160))
        pol = dataclasses.replace(tpu_default(0.5), use_pallas=True)
        ref = dataclasses.replace(tpu_default(0.5), mask_mode=True)
        g1 = _dense_grads(x, w, b, pol)
        g2 = _dense_grads(x, w, b, ref)
        for a, r in zip(g1, g2, strict=True):
            np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-3)


class TestConvGrad:
    @pytest.mark.parametrize("stride,padding", [(1, 1), (2, 1), (1, 0)])
    def test_gather_equals_mask_oracle(self, stride, padding):
        k = jax.random.PRNGKey(0)
        x = jax.random.normal(k, (2, 3, 12, 12))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 3, 3, 3))
        b = jnp.zeros((16,))

        def loss(x, w, b, pol):
            y = sparse_conv2d(x, w, b, stride=stride, padding=padding, policy=pol)
            return (y**2).sum()

        g1 = jax.grad(loss, argnums=(0, 1, 2))(x, w, b, paper_default(0.5))
        g2 = jax.grad(loss, argnums=(0, 1, 2))(x, w, b, SsPropPolicy(0.5, mask_mode=True))
        for a, r in zip(g1, g2, strict=True):
            np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)

    def test_groups_supported(self):
        k = jax.random.PRNGKey(0)
        x = jax.random.normal(k, (2, 8, 8, 8))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 4, 3, 3))  # groups=2

        def loss(x, w):
            return (
                sparse_conv2d(x, w, stride=1, padding=1, groups=2, policy=paper_default(0.5)) ** 2
            ).sum()

        g = jax.grad(loss, argnums=(0, 1))(x, w)
        assert all(np.isfinite(np.asarray(t)).all() for t in g)


class TestSchedulers:
    def test_epoch_bar_parity(self):
        rates = [schedulers.epoch_bar_schedule(e, 0.8) for e in range(6)]
        assert rates == [0.0, 0.8, 0.0, 0.8, 0.0, 0.8]

    def test_average_rate_epoch_bar_is_half_target(self):
        avg = schedulers.average_rate(
            "epoch_bar", total_steps=100, steps_per_epoch=10, target=0.8
        )
        assert abs(avg - 0.4) < 1e-9  # the paper's "~40% saved"

    def test_linear_cosine_monotone(self):
        for name in ("linear", "cosine"):
            vals = [
                schedulers.drop_rate_for_step(
                    name, step=s, steps_per_epoch=10, total_steps=50, target=0.8
                )
                for s in range(50)
            ]
            assert vals[0] == 0.0
            assert abs(vals[-1] - 0.8) < 1e-9
            assert all(b >= a - 1e-12 for a, b in zip(vals, vals[1:], strict=False))

    def test_bar_is_step_function(self):
        vals = [
            schedulers.drop_rate_for_step(
                "bar", step=s, steps_per_epoch=10, total_steps=100, target=0.6
            )
            for s in range(100)
        ]
        assert vals[:50] == [0.0] * 50
        assert vals[50:] == [0.6] * 50

    def test_periodic_bar(self):
        vals = [schedulers.periodic_bar_schedule(s, 30, 0.8) for s in range(60)]
        assert vals[:15] == [0.0] * 15
        assert vals[15:30] == [0.8] * 15
        assert vals[30:45] == [0.0] * 15

    def test_bucketing(self):
        pol = SsPropPolicy(0.0)
        assert pol.bucketed(0.79).drop_rate == 0.8
        assert pol.bucketed(0.05).drop_rate == 0.0


class TestFlops:
    def test_eq6_example(self):
        # hand-computed: M=2*4*4=32, N=3*9=27 -> 32*(4*27+1)*8
        assert flops.conv_backward_flops(2, 4, 4, 3, 8, 3) == 32 * 109 * 8

    def test_eq9_reduces_to_eq6_at_zero(self):
        d = flops.conv_backward_flops(4, 8, 8, 16, 32, 3)
        s = flops.conv_backward_flops_ssprop(4, 8, 8, 16, 32, 3, 0.0)
        # drop 0 still pays the importance reduction: +M per channel
        assert s == d + 4 * 8 * 8 * 32

    def test_lower_bound_eq10(self):
        assert abs(flops.drop_rate_lower_bound(1, 3) - 1 / 37) < 1e-12
        assert flops.drop_rate_lower_bound(1, 3) <= 0.0271

    def test_paper_resnet_numbers(self):
        """Table 4: CIFAR ResNet-18 285.32B, ResNet-50 669.75B (±0.5%)."""
        from repro.models import resnet

        d18, _ = resnet.flops_per_iter("resnet18", 128, (3, 32, 32))
        d50, _ = resnet.flops_per_iter("resnet50", 128, (3, 32, 32))
        assert abs(d18 / 1e9 - 285.32) / 285.32 < 0.005
        assert abs(d50 / 1e9 - 669.75) / 669.75 < 0.005

    def test_ssprop_40pct_saving_at_bar_08(self):
        """Eq. 9 at the schedule-average rate 0.4 ≈ 40% saved."""
        d = flops.conv_backward_flops(128, 16, 16, 64, 128, 3)
        s = flops.conv_backward_flops_ssprop(128, 16, 16, 64, 128, 3, 0.4)
        assert 0.38 < flops.savings_fraction(d, s) < 0.41

    def test_policy_counts_channel_matches_nominal(self):
        """Channel granularity: policy-aware == nominal Eq. 9 at the
        keep_count-realized rate, for conv and dense."""
        pol = paper_default(0.8)
        kept = flops.kept_channels(128, pol)
        assert kept == pol.keep_count(128)
        eff = flops.effective_drop_rate(128, pol)
        c = flops.conv_backward_flops_policy(4, 8, 8, 16, 128, 3, pol)
        assert c == flops.conv_backward_flops_ssprop(4, 8, 8, 16, 128, 3, eff)
        d = flops.dense_backward_flops_policy(32, 64, 128, pol)
        m, d_in = 32, 64
        assert d == int(4 * m * d_in * kept + m * kept + m * 128)

    def test_policy_counts_block_rounding(self):
        """Block granularity rounds to whole blocks: 64 channels in one
        128-block cannot drop anything; the realized rate is 0."""
        pol = tpu_default(0.8)
        assert flops.kept_channels(64, pol) == 64
        assert flops.effective_drop_rate(64, pol) == 0.0
        # 256 channels = 2 blocks, keep_count(2)=max(1,round(0.2*2))=1
        assert flops.kept_channels(256, pol) == 128
        assert flops.effective_drop_rate(256, pol) == 0.5

    def test_policy_counts_pallas_padding(self):
        """The Pallas path pays for 128-aligned tiles: misaligned M and
        D_in count at padded sizes, so the dense path is never cheaper
        than the count claims."""
        import dataclasses as _dc

        pol = _dc.replace(tpu_default(0.5), use_pallas=True)
        plain = _dc.replace(pol, use_pallas=False)
        # m=100, d_in=130 both misaligned; d_out=256 -> keep 1 block
        assert flops.dense_backward_flops_policy(
            100, 130, 256, pol
        ) >= flops.dense_backward_flops_policy(100, 130, 256, plain)
        assert flops.conv_backward_flops_policy(
            2, 5, 5, 3, 256, 3, pol
        ) >= flops.conv_backward_flops_policy(2, 5, 5, 3, 256, 3, plain)

    def test_policy_counts_inactive_equals_dense(self):
        pol = SsPropPolicy(0.0)
        assert flops.conv_backward_flops_policy(
            4, 8, 8, 16, 32, 3, pol
        ) == flops.conv_backward_flops(4, 8, 8, 16, 32, 3)
        assert flops.dense_backward_flops_policy(
            32, 64, 128, pol
        ) == flops.dense_backward_flops(32, 64, 128)


class TestTPLocalSelection:
    """§Perf iteration 1: TP-local per-shard top-k (comm-free gather)."""

    def test_balanced_and_subset_of_dense(self, xwb):
        x, _, _ = xwb
        w = jax.random.normal(jax.random.PRNGKey(9), (48, 128))
        b = jax.random.normal(jax.random.PRNGKey(10), (128,))
        pol = dataclasses.replace(paper_default(0.5), tp_shards=4)
        _, dw, _ = _dense_grads(x, w, b, pol)
        kept = (np.abs(np.asarray(dw)).sum(0) != 0).reshape(4, 32).sum(1)
        assert (kept == kept[0]).all()  # balanced across shards
        dwd = _dense_grads(x, w, b, SsPropPolicy(0.0))[1]
        mask = np.abs(np.asarray(dw)).sum(0) != 0
        np.testing.assert_allclose(
            np.asarray(dw)[:, mask], np.asarray(dwd)[:, mask], rtol=1e-4, atol=1e-3
        )

    def test_block_granularity_per_shard(self, xwb):
        x, _, _ = xwb
        w = jax.random.normal(jax.random.PRNGKey(11), (48, 256))
        b = jax.random.normal(jax.random.PRNGKey(12), (256,))
        pol = dataclasses.replace(
            tpu_default(0.5), block_size=32, tp_shards=4
        )
        _, dw, _ = _dense_grads(x, w, b, pol)
        kept_blocks = (
            (np.abs(np.asarray(dw)).sum(0) != 0).reshape(8, 32).any(1).sum()
        )
        assert kept_blocks == 4  # 8 blocks, keep 1 per shard x 4 shards
