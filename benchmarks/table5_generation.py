"""Paper Table 5: DDPM generation backward-FLOPs, dense vs ssProp,
plus a measured reduced train step (time-parity claim)."""
import jax

from benchmarks.common import emit, time_fn
from repro.core.policy import SsPropPolicy, paper_default
from repro.core.schedulers import average_rate
from repro.models import ddpm
from repro.optim import adam

DATASETS = {
    "mnist": ((1, 28, 28), 128, 200),
    "fashionmnist": ((1, 28, 28), 128, 200),
    "celeba": ((3, 64, 64), 128, 1000),
}


def run():
    avg = average_rate("epoch_bar", total_steps=100, steps_per_epoch=10, target=0.8)
    for ds, (image, batch, timesteps) in DATASETS.items():
        dense, _ = ddpm.flops_per_iter(batch, image, base=64)
        _, sp = ddpm.flops_per_iter(batch, image, base=64, drop_rate=avg)
        emit(
            f"table5/{ds}/ddpm/flops",
            0.0,
            f"dense_B={dense/1e9:.2f};ssprop_B={sp/1e9:.2f};saved={1-sp/dense:.3f};T={timesteps}",
        )

    # measured reduced step
    params = ddpm.init_params(jax.random.PRNGKey(0), channels=1, base=16, t_dim=64)
    sched = ddpm.make_schedule(50)
    opt = adam.init(params)
    ocfg = adam.adamw()
    x0 = jax.random.normal(jax.random.PRNGKey(1), (8, 1, 16, 16))

    def make(policy):
        @jax.jit
        def step(p, o, x, rng):
            lv, g = jax.value_and_grad(lambda p: ddpm.loss_fn(p, sched, x, rng, policy))(p)
            p2, o2, _ = adam.apply_updates(ocfg, p, g, o)
            return p2, o2, lv

        rng = jax.random.PRNGKey(2)
        return lambda: step(params, opt, x0, rng)

    t_d = time_fn(make(SsPropPolicy(0.0)), iters=3)
    t_s = time_fn(make(paper_default(0.8)), iters=3)
    emit("table5/walltime/ddpm/dense", t_d, "reduced-cpu")
    emit("table5/walltime/ddpm/ssprop80", t_s, f"ratio={t_s/t_d:.2f}")
