"""Shared benchmark utilities."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def time_fn(fn, *args, iters=5, warmup=2):
    """Median wall time (µs) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
