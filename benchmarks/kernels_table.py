"""Kernel-level before/after table for the Pallas fusion pass.

One row per fusion, each an A/B against the path it replaces:

* **fused im2col** — whole-model backward HBM traffic with the
  materializing canonical path (real ``X2``/``dX2`` patch buffers)
  vs the engine's fused routing, from the analytic bytes model
  (``repro.core.flops.conv_backward_bytes_policy``). Asserted: fused
  never moves more bytes (the traffic model is also the routing gate).
* **paged attention** — the serving engine with the per-layer
  ``pool[block_tables]`` gather vs the in-place Pallas kernel.
  Asserted: token-for-token parity and the 3x->1x pool-bytes model.
* **micro parity cells** — the fused kernels against their materialized
  oracles on one concrete small geometry, numerically (asserted) and
  wall-clock (informational: interpret-mode timings don't predict TPU).

Emits ``name,us_per_call,derived`` CSV like every table and writes
``BENCH_kernels.json`` next to this file.

Run:  PYTHONPATH=src python benchmarks/kernels_table.py [--smoke]
"""
import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn

ATTN_ARCH = "qwen2.5-3b"


def conv_micro_rows() -> list:
    """Fused vs materializing backward on one concrete layer, asserted
    numerically equal (to fp32 tolerance) and timed informational."""
    from repro.core.conv import sparse_conv2d
    from repro.core.policy import tpu_default

    pol = dataclasses.replace(tpu_default(0.5), block_size=4, use_pallas=True)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 16, 16), jnp.float32)
    w = jax.random.normal(key, (16, 8, 3, 3), jnp.float32) * 0.1
    grads, times = {}, {}
    for label, fuse in (("fused", True), ("materializing", False)):
        p = dataclasses.replace(pol, fuse_im2col=fuse)

        def f(x, w):
            return sparse_conv2d(x, w, padding=1, policy=p).sum()

        g = jax.jit(jax.grad(f, argnums=(0, 1)))
        grads[label] = jax.block_until_ready(g(x, w))
        times[label] = time_fn(g, x, w, iters=3, warmup=1)
    dx_err = float(jnp.max(jnp.abs(grads["fused"][0] - grads["materializing"][0])))
    dw_err = float(jnp.max(jnp.abs(grads["fused"][1] - grads["materializing"][1])))
    assert dx_err < 1e-4 and dw_err < 1e-4, (
        f"fused im2col diverged from materialized oracle: "
        f"dx_err={dx_err} dw_err={dw_err}"
    )
    return [{
        "kernel": "conv_backward_fused_im2col",
        "shape": "b2c8x16k3/bs4/drop0.5",
        "dx_err": dx_err,
        "dw_err": dw_err,
        "fused_us": times["fused"],
        "materializing_us": times["materializing"],
    }]


def attn_micro_row() -> dict:
    """Paged-attention kernel vs the gather+masked-attention reference
    on one small paged cache — max abs error asserted."""
    from repro.kernels import ops as kops

    key = jax.random.PRNGKey(1)
    b, s, h, kv, d = 3, 2, 4, 2, 8
    n_pages, bs_pg, nb = 10, 4, 3
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k_pool = jax.random.normal(ks[1], (n_pages, bs_pg, kv, d), jnp.float32)
    v_pool = jax.random.normal(ks[2], (n_pages, bs_pg, kv, d), jnp.float32)
    tables = jax.random.randint(ks[3], (b, nb), 0, n_pages)
    qpos = jnp.array([[3, 4], [0, 1], [7, 8]], jnp.int32)

    out = kops.paged_attention(q, k_pool, v_pool, tables, qpos)

    # reference: materialize the gather, run masked attention per slot
    kg = k_pool[tables].reshape(b, nb * bs_pg, kv, d)
    vg = v_pool[tables].reshape(b, nb * bs_pg, kv, d)
    g = h // kv
    kk = jnp.repeat(kg, g, axis=2)
    vv = jnp.repeat(vg, g, axis=2)
    t = jnp.arange(nb * bs_pg)
    mask = t[None, None, :] <= qpos[:, :, None]
    scores = jnp.einsum("bshd,bthd->bhst", q, kk) / np.sqrt(d)
    scores = jnp.where(mask[:, None], scores, -1e30)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(scores, axis=-1), vv)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-4, f"paged attention diverged from gather oracle: {err}"
    us = time_fn(
        lambda: kops.paged_attention(q, k_pool, v_pool, tables, qpos),
        iters=3, warmup=1,
    )
    return {
        "kernel": "paged_attention",
        "shape": f"b{b}s{s}h{h}kv{kv}d{d}/pages{n_pages}x{bs_pg}",
        "max_err": err,
        "kernel_us": us,
    }


def run(json_path=None, smoke=False):
    from benchmarks import roofline, serve_latency

    rows = []
    for row in roofline.iter_fusion_rows():
        rows.append({"kernel": "fused_im2col", **row})
        emit(
            f"kernels/fused_im2col/{row['arch']}",
            row["fused_s"] * 1e6,
            f"mat_bytes={row['materializing_bytes']};"
            f"fused_bytes={row['fused_bytes']};"
            f"bytes_saved={row['bytes_saved']:.3f}",
        )
    for row in conv_micro_rows():
        rows.append(row)
        emit(
            f"kernels/{row['kernel']}",
            row["fused_us"],
            f"mat_us={row['materializing_us']:.1f};"
            f"dx_err={row['dx_err']:.2e};dw_err={row['dw_err']:.2e}",
        )
    arow = attn_micro_row()
    rows.append(arow)
    emit(
        f"kernels/{arow['kernel']}",
        arow["kernel_us"],
        f"max_err={arow['max_err']:.2e};gather parity OK",
    )
    if not smoke:
        srow = serve_latency.bench_attn_kernel(ATTN_ARCH)
        rows.append({"kernel": "paged_attention_engine", **srow})
        emit(
            f"kernels/paged_attention_engine/{srow['arch']}",
            srow["kernel_wall_s"] / max(srow["kernel_steps"], 1) * 1e6,
            f"kv_bytes/step {srow['kernel_kv_bytes_per_step']} vs gather"
            f" {srow['gather_kv_bytes_per_step']};token parity OK",
        )
    path = json_path or os.path.join(os.path.dirname(__file__), "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="skip the engine-level paged-attention A/B (CI)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json, smoke=args.smoke)


if __name__ == "__main__":
    main()
