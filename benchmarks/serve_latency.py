"""Continuous batching vs. static lock-step, and paged vs. contiguous.

Five serving-side headlines:

1. A staggered-arrival (Poisson) workload with heterogeneous generation
   lengths through the continuous-batching engine completes in
   measurably fewer model steps (higher generated tokens per step at
   equal slot capacity) than the lock-step baseline, which must batch
   arrivals into static waves and stall every wave on its longest
   request.
2. On a **long-tail** workload (mostly short generations, a few long
   ones) the **paged** KV cache admits strictly more concurrent
   requests — and finishes in fewer steps — than the contiguous layout
   at **equal cache memory**: contiguous slots reserve worst-case rows
   per request, pages are spent only on tokens actually cached. The
   same comparison also measures the decode-width ladder ({1, 4, chunk}
   vs {1, chunk}): fewer padded token-slots on mixed steps.
3. A **sampled** workload (per-request temperature/top-k/top-p + seeded
   PRNG lanes) pays no extra steps over greedy, and its outputs match
   the sampled lock-step oracle token-for-token.
4. The Pallas **paged-attention kernel** (``attn_kernel=True``) is a
   pure re-addressing of the paged decode: token-for-token identical to
   the pool-gather path while reading each K/V page in place through
   the block table — 1x the pool bytes per step against the gather's 3x
   (pages read + contiguous copy written + copy read). Parity and the
   bytes model are both asserted.
5. **Swap** preemption costs no recompute steps: a pool too small for
   the working set forces evictions, and restoring the victim's staged
   cache finishes the workload in no more engine steps than replaying
   its token history (the swap-vs-recompute cost row); a seeded sampled
   run under forced swap preemption is bit-identical to the same
   workload with a pressure-free pool.
6. **Speculative decoding** (``spec_k > 0``) buys strictly fewer verify
   steps for the same token stream: a self-draft run (drafter == target,
   acceptance exactly 1.0) must finish the identical workload in fewer
   compute steps than ``spec_k=0`` with token-for-token identical
   output — both asserted. The row reports acceptance rate, generated
   tokens per verify step and the drafter invocations the savings cost.

Per-request outputs are verified identical between every engine pair
before any number is reported; the paged/sampled/swap claims are hard
asserts.

Emits CSV rows (``name,us_per_call,derived``) like every other table and
writes ``BENCH_serve.json`` with throughput, p50/p99 per-token latency,
slot utilization and the engine comparisons per arch.

Run:  PYTHONPATH=src python benchmarks/serve_latency.py [--arch qwen2.5-3b]
      PYTHONPATH=src python benchmarks/serve_latency.py --smoke
        (CI: one arch — sampled, forced-preemption, attn-kernel and
        speculative cells only)
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_config
from repro.models import model as lm
from repro.serve import (
    ContinuousBatchingEngine,
    ServeConfig,
    generate_lockstep,
    lockstep_waves,
    longtail_workload,
    poisson_workload,
)

# one arch per family: decoder, moe, ssm, encdec
ARCHS = ("qwen2.5-3b", "kimi-k2-1t-a32b", "mamba2-1.3b", "whisper-large-v3")

SLOTS = 4
N_REQUESTS = 12
PROMPT_LEN = 6
GEN_RANGE = (3, 16)
MAX_SEQ = 24
ARRIVAL_RATE = 1.5


def bench_arch(arch: str) -> dict:
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = poisson_workload(
        cfg, n_requests=N_REQUESTS, arrival_rate=ARRIVAL_RATE,
        prompt_len=PROMPT_LEN, gen_len=GEN_RANGE, seed=11,
        uniform_prompts=True,
    )

    engine = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=PROMPT_LEN),
    )
    for r in reqs:
        engine.submit(r)
    out = engine.run()
    stats = engine.stats()

    # lock-step baseline: static waves in arrival order; verify parity.
    lock_steps = 0
    lock_s = 0.0
    for wave in lockstep_waves(reqs, SLOTS):
        res = generate_lockstep(
            cfg, params,
            np.stack([r.prompt for r in wave]),
            [r.max_new_tokens for r in wave],
            max_seq=MAX_SEQ,
            frames=np.stack([r.frames for r in wave])
            if cfg.family == "encdec"
            else None,
        )
        lock_steps += res["steps"]
        lock_s += res["prefill_s"] + res["decode_s"]
        for r, toks in zip(wave, res["tokens"], strict=True):
            if not np.array_equal(out[r.rid], toks):
                raise RuntimeError(
                    f"{arch} rid={r.rid}: continuous != lockstep greedy output"
                )

    gen_total = sum(len(v) for v in out.values())
    return {
        "arch": arch,
        "family": cfg.family,
        "requests": N_REQUESTS,
        "slots": SLOTS,
        "generated_tokens": gen_total,
        "continuous_steps": stats["compute_steps"],
        "lockstep_steps": lock_steps,
        "step_ratio": lock_steps / max(stats["compute_steps"], 1),
        "continuous_tokens_per_step": gen_total / max(stats["compute_steps"], 1),
        "lockstep_tokens_per_step": gen_total / max(lock_steps, 1),
        "slot_utilization": stats["slot_utilization"],
        "tokens_per_s": stats["tokens_per_s"],
        "p50_token_latency_us": stats["p50_token_latency_s"] * 1e6,
        "p99_token_latency_us": stats["p99_token_latency_s"] * 1e6,
        "wall_s": stats["wall_s"],
        "lockstep_wall_s": lock_s,
    }


# --- paged vs contiguous at equal cache memory (long-tail workload) ---
# contiguous: 4 slots × 32 rows = 128 cached tokens reserved worst-case.
# paged: the SAME 128 tokens as 16 pages × 8, but 8 slots — the short
# majority shares the memory the long tail actually uses.
LT_MAX_SEQ = 32
LT_CONT_SLOTS = 4
LT_BLOCK = 8
LT_BLOCKS = LT_CONT_SLOTS * LT_MAX_SEQ // LT_BLOCK  # equal memory: 16 pages
LT_PAGED_SLOTS = 8
LT_REQUESTS = 16


def _lt_workload(cfg):
    return longtail_workload(
        cfg, n_requests=LT_REQUESTS, arrival_rate=2.0, prompt_len=(4, 7),
        gen_short=(3, 6), gen_long=(20, 26), tail_frac=0.25, seed=17,
    )


def _run_paged_engine(cfg, params, reqs, serve_cfg):
    eng = ContinuousBatchingEngine(cfg, params, serve_cfg)
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    return eng, out


def bench_paged_longtail(arch: str) -> dict:
    """Long-tail workload through contiguous and paged engines at equal
    cache memory; also A/Bs the decode-width ladder. The paging and
    ladder claims are asserted, not just reported."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    cont_eng, cont_out = _run_paged_engine(
        cfg, params, _lt_workload(cfg),
        ServeConfig(max_slots=LT_CONT_SLOTS, max_seq=LT_MAX_SEQ,
                    prefill_chunk=LT_BLOCK),
    )
    paged_eng, paged_out = _run_paged_engine(
        cfg, params, _lt_workload(cfg),
        ServeConfig(max_slots=LT_PAGED_SLOTS, max_seq=LT_MAX_SEQ,
                    prefill_chunk=LT_BLOCK, block_size=LT_BLOCK,
                    n_blocks=LT_BLOCKS),
    )
    # the ladder A/B: same paged engine, legacy {1, chunk} widths only
    legacy_eng, legacy_out = _run_paged_engine(
        cfg, params, _lt_workload(cfg),
        ServeConfig(max_slots=LT_PAGED_SLOTS, max_seq=LT_MAX_SEQ,
                    prefill_chunk=LT_BLOCK, block_size=LT_BLOCK,
                    n_blocks=LT_BLOCKS, decode_widths=(1,)),
    )

    for rid in cont_out:  # greedy parity across all three before reporting
        if not np.array_equal(cont_out[rid], paged_out[rid]) or not np.array_equal(
            cont_out[rid], legacy_out[rid]
        ):
            raise RuntimeError(f"{arch} rid={rid}: paged != contiguous greedy")

    cs, ps = cont_eng.stats(), paged_eng.stats()
    # The acceptance claims — fail loudly if paging stops paying off.
    assert ps["peak_concurrency"] > cs["peak_concurrency"], (
        f"{arch}: paged admitted {ps['peak_concurrency']} <= "
        f"contiguous {cs['peak_concurrency']} at equal cache memory"
    )
    assert ps["compute_steps"] < cs["compute_steps"], (
        f"{arch}: paged took {ps['compute_steps']} steps >= "
        f"contiguous {cs['compute_steps']}"
    )
    ls = legacy_eng.stats()
    assert ps["padded_tokens"] < ls["padded_tokens"], (
        f"{arch}: width ladder padded {ps['padded_tokens']} >= "
        f"two-width {ls['padded_tokens']}"
    )
    return {
        "arch": arch,
        "workload": "longtail",
        "cache_tokens": LT_CONT_SLOTS * LT_MAX_SEQ,
        "requests": LT_REQUESTS,
        "contiguous_slots": LT_CONT_SLOTS,
        "paged_slots": LT_PAGED_SLOTS,
        "block_size": LT_BLOCK,
        "n_blocks": LT_BLOCKS,
        "contiguous_steps": cs["compute_steps"],
        "paged_steps": ps["compute_steps"],
        "step_ratio": cs["compute_steps"] / max(ps["compute_steps"], 1),
        "contiguous_peak_concurrency": cs["peak_concurrency"],
        "paged_peak_concurrency": ps["peak_concurrency"],
        "contiguous_slot_utilization": cs["slot_utilization"],
        "paged_slot_utilization": ps["slot_utilization"],
        "paged_preemptions": ps["preemptions"],
        "ladder_padded_tokens": ps["padded_tokens"],
        "two_width_padded_tokens": ls["padded_tokens"],
        "ladder_padding_saved": 1.0 - ps["padded_tokens"] / max(ls["padded_tokens"], 1),
    }


# --- paged-attention kernel vs pool gather (equal engines) -----------
AK_BLOCK = 4


def _attn_kv_bytes_per_step(cfg, serve_cfg) -> int:
    """HBM bytes one decode step moves through the K/V page pool.

    The gather path materializes ``pool[block_tables]`` per attention
    layer: pool pages read once, the gathered contiguous copy written
    and then read by attention — 3x the pool bytes. The Pallas kernel
    reads each page in place via the block table: 1x. This model is the
    asserted quantity; interpret-mode wall clock is not predictive.
    """
    if cfg.family == "ssm" and not getattr(cfg, "attn_every", 0):
        return 0
    n_attn = (
        cfg.n_layers // cfg.attn_every if getattr(cfg, "attn_every", 0)
        else cfg.n_layers
    )
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return (
        serve_cfg.max_slots * serve_cfg.blocks_per_slot * serve_cfg.block_size
        * cfg.n_kv_heads * cfg.head_dim * 2 * itemsize * n_attn
    )


def bench_attn_kernel(arch: str) -> dict:
    """Paged engine with the pool gather vs the in-place Pallas kernel.

    Identical ServeConfig except ``attn_kernel``; every request's tokens
    must match exactly (the kernel is a pure re-addressing of the same
    attention) and the kernel's modeled per-step pool traffic must not
    exceed the gather's — both asserted before the row is reported.
    """
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    base = dict(max_slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=PROMPT_LEN,
                block_size=AK_BLOCK)

    def workload():
        return poisson_workload(
            cfg, n_requests=N_REQUESTS, arrival_rate=ARRIVAL_RATE,
            prompt_len=PROMPT_LEN, gen_len=GEN_RANGE, seed=11,
            uniform_prompts=True,
        )

    gather_cfg = ServeConfig(**base)
    kernel_cfg = ServeConfig(**base, attn_kernel=True)
    g_eng, g_out = _run_paged_engine(cfg, params, workload(), gather_cfg)
    k_eng, k_out = _run_paged_engine(cfg, params, workload(), kernel_cfg)
    for rid in g_out:
        if not np.array_equal(g_out[rid], k_out[rid]):
            raise RuntimeError(
                f"{arch} rid={rid}: paged-attention kernel != pool gather"
            )
    kv = _attn_kv_bytes_per_step(cfg, gather_cfg)
    gather_bytes, kernel_bytes = 3 * kv, kv
    assert kernel_bytes <= gather_bytes, (
        f"{arch}: kernel models {kernel_bytes} B/step > gather {gather_bytes}"
    )
    gs, ks = g_eng.stats(), k_eng.stats()
    return {
        "arch": arch,
        "family": cfg.family,
        "workload": "attn_kernel",
        "requests": N_REQUESTS,
        "slots": SLOTS,
        "block_size": AK_BLOCK,
        "gather_steps": gs["compute_steps"],
        "kernel_steps": ks["compute_steps"],
        "gather_kv_bytes_per_step": gather_bytes,
        "kernel_kv_bytes_per_step": kernel_bytes,
        "kv_bytes_saved": 1.0 - kernel_bytes / gather_bytes if kv else 0.0,
        "gather_wall_s": gs["wall_s"],
        "kernel_wall_s": ks["wall_s"],
        "token_parity": True,
    }


def _emit_attn_kernel(row):
    emit(
        f"serve_attn_kernel_{row['arch']}",
        row["kernel_wall_s"] / max(row["kernel_steps"], 1) * 1e6,
        f"in-place pages {row['kernel_kv_bytes_per_step']} B/step vs gather"
        f" {row['gather_kv_bytes_per_step']}"
        f" (-{row['kv_bytes_saved']*100:.0f}%);"
        f" steps {row['kernel_steps']} vs {row['gather_steps']};"
        f" token parity OK",
    )


# --- sampled workload: parity vs the sampled lock-step oracle --------
SAMPLED_TEMP = 0.8
SAMPLED_TOP_K = 16
SAMPLED_TOP_P = 0.95


def bench_sampled(arch: str) -> dict:
    """Sampled Poisson workload through the continuous engine; every
    request verified token-for-token against the sampled lock-step
    oracle before the row is reported."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = poisson_workload(
        cfg, n_requests=N_REQUESTS, arrival_rate=ARRIVAL_RATE,
        prompt_len=PROMPT_LEN, gen_len=GEN_RANGE, seed=11,
        uniform_prompts=True, temperature=SAMPLED_TEMP,
        top_k=SAMPLED_TOP_K, top_p=SAMPLED_TOP_P,
    )
    engine = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=PROMPT_LEN),
    )
    for r in reqs:
        engine.submit(r)
    out = engine.run()
    stats = engine.stats()

    for wave in lockstep_waves(reqs, SLOTS):
        res = generate_lockstep(
            cfg, params,
            np.stack([r.prompt for r in wave]),
            [r.max_new_tokens for r in wave],
            max_seq=MAX_SEQ,
            frames=np.stack([r.frames for r in wave])
            if cfg.family == "encdec"
            else None,
            sampling=[r.sampling for r in wave],
        )
        for r, toks in zip(wave, res["tokens"], strict=True):
            if not np.array_equal(out[r.rid], toks):
                raise RuntimeError(
                    f"{arch} rid={r.rid}: continuous != lockstep sampled output"
                )

    gen_total = sum(len(v) for v in out.values())
    return {
        "arch": arch,
        "family": cfg.family,
        "workload": "sampled",
        "temperature": SAMPLED_TEMP,
        "top_k": SAMPLED_TOP_K,
        "top_p": SAMPLED_TOP_P,
        "requests": N_REQUESTS,
        "slots": SLOTS,
        "generated_tokens": gen_total,
        "sampled_steps": stats["compute_steps"],
        "tokens_per_step": gen_total / max(stats["compute_steps"], 1),
        "slot_utilization": stats["slot_utilization"],
        "tokens_per_s": stats["tokens_per_s"],
        "p50_token_latency_us": stats["p50_token_latency_s"] * 1e6,
        "p99_token_latency_us": stats["p99_token_latency_s"] * 1e6,
        "wall_s": stats["wall_s"],
    }


# --- swap vs recompute preemption cost (small-pool pressure) ---------
PRE_SLOTS = 3
PRE_BLOCK = 4
PRE_BLOCKS = 7  # < 3 slots x 6 pages worst case -> forced evictions
PRE_REQUESTS = 6


def _pressure_workload(cfg, temperature=0.0):
    return poisson_workload(
        cfg, n_requests=PRE_REQUESTS, arrival_rate=2.0, prompt_len=(3, 7),
        gen_len=(6, 12), seed=5, temperature=temperature,
        top_k=SAMPLED_TOP_K,
    )


def _run_pressure(cfg, params, reqs, *, preempt, n_blocks=PRE_BLOCKS):
    eng = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=PRE_SLOTS, max_seq=MAX_SEQ,
                    prefill_chunk=PRE_BLOCK, block_size=PRE_BLOCK,
                    n_blocks=n_blocks, preempt=preempt),
    )
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    return eng.stats(), out


def bench_preemption(arch: str) -> dict:
    """Preemption-cost A/B under pool pressure.

    Greedy workload: swap vs recompute at the same (too-small) pool —
    swap must finish in no more engine steps, with identical outputs.
    Sampled workload: forced swap preemption must be bit-identical to a
    pressure-free pool (the determinism claim recompute can't make).
    """
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    swap_st, swap_out = _run_pressure(
        cfg, params, _pressure_workload(cfg), preempt="swap")
    rec_st, rec_out = _run_pressure(
        cfg, params, _pressure_workload(cfg), preempt="recompute")
    assert swap_st["swap_preemptions"] > 0, f"{arch}: pool never pressured"
    assert rec_st["recompute_preemptions"] > 0, f"{arch}: pool never pressured"
    for rid in rec_out:
        if not np.array_equal(swap_out[rid], rec_out[rid]):
            raise RuntimeError(f"{arch} rid={rid}: swap != recompute greedy")
    assert swap_st["compute_steps"] <= rec_st["compute_steps"], (
        f"{arch}: swap took {swap_st['compute_steps']} steps > "
        f"recompute {rec_st['compute_steps']}"
    )

    sampled = _pressure_workload(cfg, temperature=SAMPLED_TEMP)
    forced_st, forced_out = _run_pressure(cfg, params, sampled, preempt="auto")
    sampled2 = _pressure_workload(cfg, temperature=SAMPLED_TEMP)
    free_st, free_out = _run_pressure(
        cfg, params, sampled2, preempt="auto",
        n_blocks=PRE_SLOTS * (-(-MAX_SEQ // PRE_BLOCK)),
    )
    assert forced_st["swap_preemptions"] > 0, f"{arch}: sampled never preempted"
    assert free_st["preemptions"] == 0, f"{arch}: reference pool pressured"
    for rid in free_out:
        if not np.array_equal(forced_out[rid], free_out[rid]):
            raise RuntimeError(
                f"{arch} rid={rid}: sampled output changed under swap preemption"
            )

    return {
        "arch": arch,
        "family": cfg.family,
        "workload": "preemption",
        "requests": PRE_REQUESTS,
        "slots": PRE_SLOTS,
        "block_size": PRE_BLOCK,
        "n_blocks": PRE_BLOCKS,
        "swap_steps": swap_st["compute_steps"],
        "recompute_steps": rec_st["compute_steps"],
        "step_ratio": rec_st["compute_steps"] / max(swap_st["compute_steps"], 1),
        "swap_wall_s": swap_st["wall_s"],
        "recompute_wall_s": rec_st["wall_s"],
        "swap_preemptions": swap_st["swap_preemptions"],
        "recompute_preemptions": rec_st["recompute_preemptions"],
        "swapped_bytes": swap_st["swapped_bytes"],
        "sampled_swap_preemptions": forced_st["swap_preemptions"],
        "sampled_deterministic": True,
    }


# --- speculative decoding: fewer steps, identical stream -------------
SPEC_K = 3
SPEC_CHUNK = 4  # verify width k+1; also the decode ladder width


def bench_speculative(arch: str) -> dict:
    """Speculative decoding A/B on a predictable (greedy, self-draft)
    workload.

    The drafter IS the target model, so every proposal is accepted
    (acceptance rate exactly 1.0) and the verify-step saving is the
    upper bound spec_k admits. Token parity with the ``spec_k=0`` engine
    and a strict step reduction are both asserted before the row is
    reported.
    """
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    base = dict(max_slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=SPEC_CHUNK)

    def workload():
        return poisson_workload(
            cfg, n_requests=N_REQUESTS, arrival_rate=ARRIVAL_RATE,
            prompt_len=PROMPT_LEN, gen_len=GEN_RANGE, seed=11,
            uniform_prompts=True,
        )

    base_eng, base_out = _run_paged_engine(
        cfg, params, workload(), ServeConfig(**base))
    spec_eng, spec_out = _run_paged_engine(
        cfg, params, workload(), ServeConfig(**base, spec_k=SPEC_K))
    for rid in base_out:
        if not np.array_equal(base_out[rid], spec_out[rid]):
            raise RuntimeError(
                f"{arch} rid={rid}: speculative != non-speculative output"
            )
    bs, ss = base_eng.stats(), spec_eng.stats()
    assert ss["acceptance_rate"] == 1.0, (
        f"{arch}: self-draft acceptance {ss['acceptance_rate']} != 1.0"
    )
    assert ss["compute_steps"] < bs["compute_steps"], (
        f"{arch}: speculative took {ss['compute_steps']} verify steps >= "
        f"baseline {bs['compute_steps']}"
    )
    gen_total = sum(len(v) for v in spec_out.values())
    return {
        "arch": arch,
        "family": cfg.family,
        "workload": "speculative",
        "spec_k": SPEC_K,
        "requests": N_REQUESTS,
        "slots": SLOTS,
        "generated_tokens": gen_total,
        "baseline_steps": bs["compute_steps"],
        "speculative_steps": ss["compute_steps"],
        "step_ratio": bs["compute_steps"] / max(ss["compute_steps"], 1),
        "spec_proposed": ss["spec_proposed"],
        "spec_accepted": ss["spec_accepted"],
        "acceptance_rate": ss["acceptance_rate"],
        "draft_steps": ss["draft_steps"],
        "baseline_tokens_per_step": gen_total / max(bs["compute_steps"], 1),
        "speculative_tokens_per_step": gen_total / max(ss["compute_steps"], 1),
        "baseline_wall_s": bs["wall_s"],
        "speculative_wall_s": ss["wall_s"],
        "token_parity": True,
    }


def _emit_speculative(row):
    emit(
        f"serve_speculative_{row['arch']}",
        row["speculative_wall_s"] / max(row["speculative_steps"], 1) * 1e6,
        f"spec_k {row['spec_k']}: {row['speculative_steps']} verify steps vs"
        f" {row['baseline_steps']} (x{row['step_ratio']:.2f});"
        f" acceptance {row['acceptance_rate']:.2f}"
        f" ({row['spec_accepted']}/{row['spec_proposed']});"
        f" {row['speculative_tokens_per_step']:.2f} gen tok/step vs"
        f" {row['baseline_tokens_per_step']:.2f};"
        f" {row['draft_steps']} draft steps; token parity OK",
    )


def _emit_sampled(row):
    emit(
        f"serve_sampled_{row['arch']}",
        row["wall_s"] / max(row["sampled_steps"], 1) * 1e6,
        f"temp {row['temperature']} top-k {row['top_k']} top-p {row['top_p']};"
        f" steps {row['sampled_steps']};"
        f" {row['tokens_per_step']:.2f} gen tok/step;"
        f" util {row['slot_utilization']*100:.0f}%;"
        f" lockstep parity OK",
    )


def _emit_preemption(row):
    emit(
        f"serve_preempt_swap_vs_recompute_{row['arch']}",
        0.0,
        f"swap {row['swap_steps']} steps vs recompute"
        f" {row['recompute_steps']} (x{row['step_ratio']:.2f});"
        f" {row['swap_preemptions']} swaps"
        f" ({row['swapped_bytes']} bytes staged) vs"
        f" {row['recompute_preemptions']} recomputes;"
        f" sampled deterministic under {row['sampled_swap_preemptions']}"
        f" forced swaps",
    )


def run(archs=ARCHS, json_path=None):
    rows = []
    for arch in archs:
        row = bench_arch(arch)
        rows.append(row)
        emit(
            f"serve_continuous_{arch}",
            row["wall_s"] / max(row["continuous_steps"], 1) * 1e6,
            f"steps {row['continuous_steps']} vs lockstep {row['lockstep_steps']}"
            f" (x{row['step_ratio']:.2f}); {row['continuous_tokens_per_step']:.2f}"
            f" vs {row['lockstep_tokens_per_step']:.2f} gen tok/step;"
            f" util {row['slot_utilization']*100:.0f}%;"
            f" p50/p99 {row['p50_token_latency_us']:.0f}/{row['p99_token_latency_us']:.0f} us/tok",
        )
    for arch in archs:
        row = bench_paged_longtail(arch)
        rows.append(row)
        emit(
            f"serve_paged_longtail_{arch}",
            0.0,
            f"steps {row['paged_steps']} vs contiguous {row['contiguous_steps']}"
            f" (x{row['step_ratio']:.2f}) at {row['cache_tokens']} cache tokens;"
            f" peak concurrency {row['paged_peak_concurrency']} vs"
            f" {row['contiguous_peak_concurrency']};"
            f" preemptions {row['paged_preemptions']};"
            f" ladder pads {row['ladder_padded_tokens']} vs"
            f" {row['two_width_padded_tokens']}"
            f" (-{row['ladder_padding_saved']*100:.0f}%)",
        )
    for arch in archs:
        row = bench_attn_kernel(arch)
        rows.append(row)
        _emit_attn_kernel(row)
    for arch in archs:
        row = bench_sampled(arch)
        rows.append(row)
        _emit_sampled(row)
    for arch in archs:
        row = bench_preemption(arch)
        rows.append(row)
        _emit_preemption(row)
    for arch in archs:
        row = bench_speculative(arch)
        rows.append(row)
        _emit_speculative(row)
    path = json_path or os.path.join(os.path.dirname(__file__), "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def run_smoke(arch=ARCHS[0], json_path=None):
    """CI-sized run: one arch — the sampled workload, the forced swap
    preemption A/B, the paged-attention kernel A/B and the speculative
    decoding A/B (each internally asserts parity/determinism).
    Does NOT overwrite BENCH_serve.json unless --json is given."""
    rows = [bench_sampled(arch), bench_preemption(arch),
            bench_attn_kernel(arch), bench_speculative(arch)]
    _emit_sampled(rows[0])
    _emit_preemption(rows[1])
    _emit_attn_kernel(rows[2])
    _emit_speculative(rows[3])
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default=None)
    ap.add_argument("--json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="one arch: sampled, forced-preemption, attn-kernel "
                    "and speculative cells only (CI)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke(args.arch or ARCHS[0], json_path=args.json)
        return
    run((args.arch,) if args.arch else ARCHS, json_path=args.json)


if __name__ == "__main__":
    main()
