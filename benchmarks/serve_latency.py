"""Continuous batching vs. static lock-step under staggered traffic.

The serving-side headline: a staggered-arrival (Poisson) workload with
heterogeneous generation lengths through the continuous-batching engine
completes in measurably fewer model steps (higher generated tokens per
step at equal slot capacity) than the lock-step baseline, which must
batch arrivals into static waves and stall every wave on its longest
request. Per-request greedy outputs are verified identical between the
two before any number is reported.

Emits CSV rows (``name,us_per_call,derived``) like every other table and
writes ``BENCH_serve.json`` with throughput, p50/p99 per-token latency
and slot utilization per arch.

Run:  PYTHONPATH=src python benchmarks/serve_latency.py [--arch qwen2.5-3b]
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_config
from repro.models import model as lm
from repro.serve import (
    ContinuousBatchingEngine,
    ServeConfig,
    generate_lockstep,
    lockstep_waves,
    poisson_workload,
)

# one arch per family: decoder, moe, ssm, encdec
ARCHS = ("qwen2.5-3b", "kimi-k2-1t-a32b", "mamba2-1.3b", "whisper-large-v3")

SLOTS = 4
N_REQUESTS = 12
PROMPT_LEN = 6
GEN_RANGE = (3, 16)
MAX_SEQ = 24
ARRIVAL_RATE = 1.5


def bench_arch(arch: str) -> dict:
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = poisson_workload(
        cfg, n_requests=N_REQUESTS, arrival_rate=ARRIVAL_RATE,
        prompt_len=PROMPT_LEN, gen_len=GEN_RANGE, seed=11,
        uniform_prompts=True,
    )

    engine = ContinuousBatchingEngine(
        cfg, params,
        ServeConfig(max_slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=PROMPT_LEN),
    )
    for r in reqs:
        engine.submit(r)
    out = engine.run()
    stats = engine.stats()

    # lock-step baseline: static waves in arrival order; verify parity.
    lock_steps = 0
    lock_s = 0.0
    for wave in lockstep_waves(reqs, SLOTS):
        res = generate_lockstep(
            cfg, params,
            np.stack([r.prompt for r in wave]),
            [r.max_new_tokens for r in wave],
            max_seq=MAX_SEQ,
            frames=np.stack([r.frames for r in wave])
            if cfg.family == "encdec"
            else None,
        )
        lock_steps += res["steps"]
        lock_s += res["prefill_s"] + res["decode_s"]
        for r, toks in zip(wave, res["tokens"]):
            if not np.array_equal(out[r.rid], toks):
                raise RuntimeError(
                    f"{arch} rid={r.rid}: continuous != lockstep greedy output"
                )

    gen_total = sum(len(v) for v in out.values())
    return {
        "arch": arch,
        "family": cfg.family,
        "requests": N_REQUESTS,
        "slots": SLOTS,
        "generated_tokens": gen_total,
        "continuous_steps": stats["compute_steps"],
        "lockstep_steps": lock_steps,
        "step_ratio": lock_steps / max(stats["compute_steps"], 1),
        "continuous_tokens_per_step": gen_total / max(stats["compute_steps"], 1),
        "lockstep_tokens_per_step": gen_total / max(lock_steps, 1),
        "slot_utilization": stats["slot_utilization"],
        "tokens_per_s": stats["tokens_per_s"],
        "p50_token_latency_us": stats["p50_token_latency_s"] * 1e6,
        "p99_token_latency_us": stats["p99_token_latency_s"] * 1e6,
        "wall_s": stats["wall_s"],
        "lockstep_wall_s": lock_s,
    }


def run(archs=ARCHS, json_path=None):
    rows = []
    for arch in archs:
        row = bench_arch(arch)
        rows.append(row)
        emit(
            f"serve_continuous_{arch}",
            row["wall_s"] / max(row["continuous_steps"], 1) * 1e6,
            f"steps {row['continuous_steps']} vs lockstep {row['lockstep_steps']}"
            f" (x{row['step_ratio']:.2f}); {row['continuous_tokens_per_step']:.2f}"
            f" vs {row['lockstep_tokens_per_step']:.2f} gen tok/step;"
            f" util {row['slot_utilization']*100:.0f}%;"
            f" p50/p99 {row['p50_token_latency_us']:.0f}/{row['p99_token_latency_us']:.0f} us/tok",
        )
    path = json_path or os.path.join(os.path.dirname(__file__), "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run((args.arch,) if args.arch else ARCHS, json_path=args.json)


if __name__ == "__main__":
    main()
