"""Paper Table 4: classification backward-FLOPs, dense vs ssProp.

The FLOPs columns are analytic (Eq. 6/7) over the real layer shapes —
they reproduce the paper's numbers exactly (285.32B/669.75B per iter on
CIFAR). Wall time is measured on a reduced CPU-sized step to demonstrate
the time-parity claim (sparse step not slower than dense).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.policy import SsPropPolicy, paper_default
from repro.core.schedulers import average_rate
from repro.models import resnet
from repro.optim import adam

# dataset -> (image, batch) per paper Tables 1-2
DATASETS = {
    "mnist": ((1, 28, 28), 128),
    "fashionmnist": ((1, 28, 28), 128),
    "cifar10": ((3, 32, 32), 128),
    "cifar100": ((3, 32, 32), 128),
    "celeba": ((3, 64, 64), 128),
    "imagenet1k": ((3, 224, 224), 32),
}

PAPER_TABLE4 = {  # (dense B/iter, paper ssprop B/iter) for resnet18/50
    ("cifar10", "resnet18"): (285.32, 171.61),
    ("cifar10", "resnet50"): (669.75, 404.18),
    ("mnist", "resnet18"): (234.10, 140.79),
    ("imagenet1k", "resnet18"): (3495.14, 2102.19),
}


def _step(name, image, batch, policy):
    params = resnet.init_params(name, jax.random.PRNGKey(0), num_classes=10)
    opt = adam.init(params)
    cfg = adam.AdamConfig(lr=2e-4)

    def loss_fn(p, x, y):
        logits = resnet.forward(name, p, x, policy)
        return -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y].mean()

    @jax.jit
    def step(p, o, x, y):
        lv, g = jax.value_and_grad(loss_fn)(p, x, y)
        p2, o2, _ = adam.apply_updates(cfg, p, g, o)
        return p2, o2, lv

    x = jax.random.normal(jax.random.PRNGKey(1), (batch,) + image)
    y = jax.random.randint(jax.random.PRNGKey(2), (batch,), 0, 10)
    return lambda: step(params, opt, x, y)


def run():
    # analytic FLOPs table (all datasets × resnet18/50), avg bar rate 0.4
    avg = average_rate("epoch_bar", total_steps=100, steps_per_epoch=10, target=0.8)
    for ds, (image, batch) in DATASETS.items():
        for name in ("resnet18", "resnet50"):
            dense, _ = resnet.flops_per_iter(name, batch, image)
            _, sp = resnet.flops_per_iter(name, batch, image, avg)
            saved = 1 - sp / dense
            emit(
                f"table4/{ds}/{name}/flops",
                0.0,
                f"dense_B={dense/1e9:.2f};ssprop_B={sp/1e9:.2f};saved={saved:.3f}",
            )
    # paper cross-check
    for (ds, name), (paper_dense, paper_sp) in PAPER_TABLE4.items():
        image, batch = DATASETS[ds]
        dense, _ = resnet.flops_per_iter(name, batch, image)
        _, sp = resnet.flops_per_iter(name, batch, image, avg)
        emit(
            f"table4/check/{ds}/{name}",
            0.0,
            f"ours_dense={dense/1e9:.2f};paper_dense={paper_dense};"
            f"ours_ssprop={sp/1e9:.2f};paper_ssprop={paper_sp}",
        )
    # measured wall time (reduced: 16x16 images, batch 16, CPU)
    for name in ("resnet18",):
        f_dense = _step(name, (3, 16, 16), 16, SsPropPolicy(0.0))
        f_sp = _step(name, (3, 16, 16), 16, paper_default(0.8))
        t_d = time_fn(f_dense, iters=3)
        t_s = time_fn(f_sp, iters=3)
        emit(f"table4/walltime/{name}/dense", t_d, "reduced-cpu")
        emit(f"table4/walltime/{name}/ssprop80", t_s, f"ratio={t_s/t_d:.2f}")
