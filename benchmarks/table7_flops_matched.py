"""Paper Table 7 (Q2): sparse ResNet-50 vs FLOPs-matched ResNet-26.

The paper's control: ResNet-26 (BasicBlock 2-3-5-2) consumes ~the same
backward FLOPs as an ssProp-sparsified ResNet-50. We reproduce the FLOPs
match analytically and train both reduced variants on the synthetic task
to show both modes learn (paper: ssProp-50 ≈ ResNet-26 accuracy; both
ssProp variants beat their dense counterparts on over-fit-prone data).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.policy import SsPropPolicy, paper_default
from repro.data.pipeline import ImagePipeline, ImagePipelineConfig
from repro.models import resnet
from repro.optim import adam


def _train(name, policy, steps=16, seed=0):
    pipe = ImagePipeline(ImagePipelineConfig((3, 16, 16), 10, 32, seed=5), n_train=256)
    params = resnet.init_params(name, jax.random.PRNGKey(seed), num_classes=10)
    opt = adam.init(params)
    ocfg = adam.AdamConfig(lr=1e-3)

    def loss_fn(p, x, y):
        logits = resnet.forward(name, p, x, policy)
        return -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y].mean()

    @jax.jit
    def step(p, o, x, y):
        lv, g = jax.value_and_grad(loss_fn)(p, x, y)
        p2, o2, _ = adam.apply_updates(ocfg, p, g, o)
        return p2, o2, lv

    loss = None
    for i in range(steps):
        b = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        params, opt, loss = step(params, opt, b["images"], b["labels"])
    ev = pipe.eval_batch(128)
    logits = resnet.forward(name, params, jnp.asarray(ev["images"]), SsPropPolicy(0.0), train=False)
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(ev["labels"])).mean())
    return float(loss), acc


def run():
    d26, _ = resnet.flops_per_iter("resnet26", 128, (3, 32, 32))
    d50, s50 = resnet.flops_per_iter("resnet50", 128, (3, 32, 32), 0.4)
    emit("table7/flops_match", 0.0,
         f"resnet26_dense_B={d26/1e9:.2f};ssprop50_avg_B={s50/1e9:.2f};"
         f"ratio={s50/d26:.3f};paper=440.19_vs_404.18")

    for name, pol, tag in [
        ("resnet26", SsPropPolicy(0.0), "dense"),
        ("resnet26", paper_default(0.8), "ssprop"),
        ("resnet50", SsPropPolicy(0.0), "dense"),
        ("resnet50", paper_default(0.8), "ssprop"),
    ]:
        lv, acc = _train(name, pol)
        emit(f"table7/train/{name}/{tag}", 0.0, f"loss={lv:.3f};acc={acc:.3f}")
