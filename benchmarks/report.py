"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
results JSONs. Run after the dry-run matrix + probes:

  PYTHONPATH=src python -m benchmarks.report
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS

DRY = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _load(arch, shape, mesh, policy):
    f = os.path.join(DRY, f"{arch}__{shape}__{mesh}__{policy}.json")
    if os.path.exists(f):
        with open(f) as fh:
            return json.load(fh)
    return None


def dryrun_table():
    lines = [
        "| arch | shape | mesh | status | HLO GFLOP/dev (module) | args GiB/dev | temp GiB/dev | wire GiB/dev/step | top collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                r = _load(arch, shape, mesh, "ssprop")
                if r is None:
                    continue
                if r["status"] == "skipped":
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | N/A (sub-quadratic rule) | | | | | |"
                    )
                    continue
                dev = r["devices"]
                mem = r.get("memory", {})
                colls = r.get("collectives", {})
                top = sorted(
                    colls.items(),
                    key=lambda kv: -kv[1].get("stepped_bytes", kv[1]["bytes"]),
                )[:2]
                tops = "; ".join(
                    f"{k}×{v['count']}"
                    for k, v in top
                )
                fl = r.get("cost", {}).get("flops", 0) / 1e9
                ar = mem.get("argument_bytes", 0) / dev / 2**30
                tm = mem.get("temp_bytes", 0) / 2**30
                w = r.get("collective_wire_bytes", 0) / 2**30
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {r['status']} | "
                    f"{fl:.1f} | {ar:.2f} | {tm:.2f} | {w:.3f} | {tops} |"
                )
    return "\n".join(lines)


def roofline_table(policy="ssprop"):
    from benchmarks import roofline as R

    lines = [
        "| arch | shape | compute s | memory s (model) | memory s (HLO-bytes UB) | collective s | dominant | roofline frac | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            row = R.roofline_row(arch, shape, policy=policy)
            if row.get("status") != "ok":
                lines.append(f"| {arch} | {shape} | — | — | — | — | {row['status']} | | |")
                continue
            lines.append(
                f"| {arch} | {shape} | {row['compute_s']:.4f} | "
                f"{row['memory_s']:.4f} | {row['memory_hlo_s']:.4f} | "
                f"{row['collective_s']:.4f} | {row['dominant']} | "
                f"{row['roofline_fraction']:.3f} | {row['useful_ratio']:.2f} |"
            )
    return "\n".join(lines)


def variants_table(cells):
    from benchmarks import roofline as R

    lines = [
        "| cell | policy | compute s | collective s | temp GiB/dev | wire GiB/step |",
        "|---|---|---|---|---|---|",
    ]
    for arch, shape in cells:
        for pol in ("dense", "ssprop", "ssprop_tp", "opt"):
            r = _load(arch, shape, "single", pol)
            if r is None or r["status"] != "ok":
                continue
            row = R.roofline_row(arch, shape, policy=pol)
            comp = f"{row['compute_s']:.4f}" if row.get("status") == "ok" else "—"
            co = r.get("collective_wire_bytes", 0) / 50e9
            t = r.get("memory", {}).get("temp_bytes", 0) / 2**30
            w = r.get("collective_wire_bytes", 0) / 2**30
            lines.append(
                f"| {arch} × {shape} | {pol} | {comp} | "
                f"{co:.4f} | {t:.2f} | {w:.3f} |"
            )
    return "\n".join(lines)


def main():
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table (ssprop baseline)\n")
    print(roofline_table())
    print("\n## Hillclimb variants\n")
    print(
        variants_table(
            [
                ("mistral-large-123b", "train_4k"),
                ("kimi-k2-1t-a32b", "prefill_32k"),
                ("nemotron-4-15b", "decode_32k"),
            ]
        )
    )


if __name__ == "__main__":
    main()
