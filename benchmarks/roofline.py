"""Roofline analysis: three terms per (arch × shape × mesh), from the
dry-run artifacts + an honest-FLOPs probe.

Methodology (documented in EXPERIMENTS.md §Roofline):

* **Collective term** — parsed from the *production-mesh* compiled HLO
  (dry-run JSONs). Shapes there are per-device, so
  ``collective_t = wire_bytes_per_chip / link_bw``.
* **Compute / memory terms** — XLA's ``cost_analysis`` counts while-loop
  bodies (our layer scan, grad-accum scan, attention q-chunk scan) ONCE
  (verified empirically), so the production-mesh numbers undercount by
  the trip counts. We therefore compile a **probe**: the same step with
  layers UNROLLED (``scan_layers=False``), one microbatch (``accum=1``),
  unchunked attention, on a single device — every FLOP visible to XLA —
  and scale: ``total = probe_flops(one period-stack pass) × accum``;
  per-chip = total / chips (matmul FLOPs shard evenly; padding waste is
  a second-order effect noted per-cell). To bound probe compile time on
  the 88-95-layer models, we compile 1-period and 2-period variants and
  extrapolate linearly (periods are shape-identical, so the per-period
  delta is exact).
* **MODEL_FLOPS** = 6·N·D (train, N=active params, D=tokens/step),
  2·N·D (prefill), 2·N·B (decode).

Hardware: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
import argparse
import dataclasses
import functools
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.policy import SsPropPolicy, paper_default, tpu_default
from repro.data.pipeline import input_specs
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.optim import adam

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link
CHIPS = 256  # single-pod roofline basis

PROBE_DIR = os.path.join(os.path.dirname(__file__), "results", "probe")
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _probe_cfg(cfg, periods: int):
    plen = len(transformer.period_pattern(cfg))
    return dataclasses.replace(
        cfg,
        n_layers=plen * periods,
        n_enc_layers=min(cfg.n_enc_layers, periods) if cfg.n_enc_layers else 0,
        scan_layers=False,
        attn_q_chunk=1 << 30,
    )


def _probe_compile(cfg, shape, policy, accum):
    """Compile one unrolled variant on the host device; return cost dict."""
    if shape.kind == "train":
        micro = dataclasses.replace(shape, global_batch=max(1, shape.global_batch // accum))
        batch = input_specs(cfg, micro)
        fn = steps_lib.make_train_step(cfg, policy, adam.AdamConfig(lr=2e-4), accum=1)
        a_params, a_opt = steps_lib.abstract_state(cfg)
        lowered = jax.jit(fn).lower(a_params, a_opt, batch)
    elif shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        fn = steps_lib.make_prefill_step(cfg)
        a_params, _ = steps_lib.abstract_state(cfg)
        lowered = jax.jit(fn).lower(a_params, batch)
    else:
        a_params, _ = steps_lib.abstract_state(cfg)
        a_cache = steps_lib.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        state = {
            "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "cache": a_cache,
        }
        if cfg.family == "encdec":
            state["enc_out"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        fn = steps_lib.make_serve_step(cfg)
        lowered = jax.jit(fn).lower(a_params, state)
    c = lowered.compile().cost_analysis()
    return {"flops": float(c.get("flops", 0.0)),
            "bytes": float(c.get("bytes accessed", 0.0))}


def probe_cell(arch: str, shape_name: str, policy_name: str, cache=True):
    """Honest total-step FLOPs/bytes via 1- and 2-period extrapolation."""
    os.makedirs(PROBE_DIR, exist_ok=True)
    fname = os.path.join(PROBE_DIR, f"{arch}__{shape_name}__{policy_name}.json")
    if cache and os.path.exists(fname):
        with open(fname) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cfg.supports_shape(shape)
    if not ok:
        rec = {"status": "skipped", "why": why}
    else:
        import dataclasses as _dc
        if policy_name == "ssprop":
            policy = tpu_default(0.8)
        elif policy_name == "ssprop_tp":
            policy = _dc.replace(tpu_default(0.8), tp_shards=16)
        elif policy_name == "opt":
            policy = _dc.replace(
                tpu_default(0.8), tp_shards=16, bwd_dtype="bfloat16"
            )
        else:
            policy = SsPropPolicy(0.0)
        accum = steps_lib.microbatch_plan(cfg, shape, dp=16)
        np_full = transformer.n_periods(cfg)
        c1 = _probe_compile(_probe_cfg(cfg, 1), shape, policy, accum)
        c2 = _probe_compile(_probe_cfg(cfg, 2), shape, policy, accum)
        per_period = {k: c2[k] - c1[k] for k in c1}
        stack_pass = {k: c1[k] + (np_full - 1) * per_period[k] for k in c1}
        # enc-dec: encoder layers beyond the probe's 1-2 also extrapolate
        if cfg.n_enc_layers > 2:
            # encoder layer cost is inside per_period delta only when the
            # probe raised n_enc_layers with periods; our probe caps the
            # encoder at `periods`, so the same linear rule applies.
            pass
        total = {k: stack_pass[k] * (accum if shape.kind == "train" else 1) for k in c1}
        rec = {
            "status": "ok",
            "accum": accum,
            "n_periods": np_full,
            "probe_1": c1,
            "probe_2": c2,
            "total_flops": total["flops"],
            "total_bytes": total["bytes"],
        }
    rec.update({"arch": arch, "shape": shape_name, "policy": policy_name})
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def memory_model_bytes(cfg, shape, accum: int, chips: int = CHIPS) -> float:
    """Analytic HBM traffic per chip per step (fusion-aware lower model).

    ``cost_analysis()['bytes accessed']`` sums every HLO op's operands as
    if nothing fuses — a loose upper bound. This model counts the
    traffic a fused TPU executable actually pays: weight reads per
    microbatch, gradient/optimizer update traffic, activation
    save/restore at the remat boundaries, and KV/state cache traffic.
    Both numbers are reported; the §Roofline 'memory' term uses this one
    and the HLO number is kept as 'memory_hlo_s'.
    """
    p_bytes = cfg.param_count() * 2 / chips  # bf16 weights per chip
    if shape.kind == "train":
        tokens_chip = shape.seq_len * shape.global_batch / chips
        act = tokens_chip * cfg.d_model * cfg.n_layers * 2 * 6  # save+reread+recompute
        grads = 3 * p_bytes  # write + read + zero-init
        adam = cfg.param_count() * 4 * 4 / chips  # m,v read+write fp32
        return accum * p_bytes + grads + adam + act
    if shape.kind == "prefill":
        tokens_chip = shape.seq_len * shape.global_batch / chips
        return p_bytes + tokens_chip * cfg.d_model * cfg.n_layers * 2 * 2
    # decode: all weights + cache read/write per token
    if cfg.family == "ssm":
        cache = (
            shape.global_batch * cfg.n_ssm_heads * cfg.ssm_state * cfg.ssm_headdim
            * 4 * cfg.n_layers / chips
        )
    else:
        n_attn = (
            cfg.n_layers // cfg.attn_every if cfg.attn_every else cfg.n_layers
        )
        cache = (
            2 * shape.global_batch * shape.seq_len * cfg.n_kv_heads * cfg.head_dim
            * 2 * n_attn / chips
        )
        if cfg.attn_every:
            cache += (
                shape.global_batch * cfg.n_ssm_heads * cfg.ssm_state
                * cfg.ssm_headdim * 4
                * (cfg.n_layers - n_attn) / chips
            )
    # active weights only (MoE decode touches top-k + shared experts)
    p_active = cfg.active_param_count() * 2 / chips
    return p_active + 2 * cache


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per request


def lm_site_rows(arch, shape_name, policy_name="ssprop"):
    """Jaxpr-derived per-site projection FLOPs for one (arch, shape).

    Each row carries the plain forward cost and the *measured* backward
    contraction interval from tracing the site's actual backward program
    (``repro.analysis.savings``) — the per-site replacement for the 6ND
    ``model_flops`` estimate. The trailing ``lm_site_total`` row sums
    ``count * (fwd + bwd)`` and reports the ratio against 6ND so the
    aggregate drift of the estimate is visible per cell.
    """
    from repro.analysis import savings

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if policy_name in _CONV_POLICIES:
        policy = _CONV_POLICIES[policy_name]()
    else:  # the dryrun/probe policy names ("ssprop", "ssprop_tp", ...)
        policy = tpu_default(0.8)
    rows = []
    tot_fwd = tot_lo = tot_hi = 0
    for site, count, fwd, lo, hi in savings.lm_site_flops(
        cfg, policy, batch=shape.global_batch, seq=shape.seq_len
    ):
        tot_fwd += count * fwd
        tot_lo += count * lo
        tot_hi += count * hi
        rows.append({
            "arch": arch, "shape": shape_name, "policy": policy_name,
            "kind": "lm_site", "site": site, "count": count,
            "fwd_flops": fwd, "bwd_flops_lo": lo, "bwd_flops_hi": hi,
        })
    mf = model_flops(cfg, shape)
    mid = tot_fwd + (tot_lo + tot_hi) / 2
    rows.append({
        "arch": arch, "shape": shape_name, "policy": policy_name,
        "kind": "lm_site_total", "fwd_flops": tot_fwd,
        "bwd_flops_lo": tot_lo, "bwd_flops_hi": tot_hi,
        "model_flops_6nd": mf, "ratio_vs_6nd": mid / mf,
    })
    return rows


_CONV_POLICIES = {
    "dense": lambda: SsPropPolicy(0.0),
    "ssprop_channel": lambda: paper_default(0.8),
    "ssprop_block": lambda: tpu_default(0.8),
    "ssprop_block_pallas": lambda: dataclasses.replace(
        tpu_default(0.8), use_pallas=True
    ),
}
# the per-site policy-program row rides alongside the global policies
_CONV_POLICY_NAMES = tuple(_CONV_POLICIES) + ("ssprop_per_site",)

_CONV_CELLS = [
    # (model, batch, image) — paper Table 4/5 shapes
    ("resnet18", 128, (3, 32, 32)),
    ("resnet50", 128, (3, 32, 32)),
    ("ddpm", 128, (1, 32, 32)),
]


def _conv_policy(model: str, policy_name: str):
    """The policy (or resolved per-site table) for one conv row."""
    if policy_name != "ssprop_per_site":
        return _CONV_POLICIES[policy_name]()
    # A genuinely per-site program: stems/heads and the outermost blocks
    # dense (where gradient quality matters most per FLOP), everything
    # else at the paper's 0.8 — FLOPs are then summed over the resolved
    # site table, each conv at its own keep count.
    from repro.core.policy import PolicyProgram, PolicyRules
    from repro.models import ddpm, resnet

    base = paper_default(0.8)
    if model == "ddpm":
        sites, depth = ddpm.site_names()
        rules = PolicyRules.of(
            ("stem", 0.0), ("out", 0.0), ("mid*/*", 0.5), ("*", 0.8), base=base
        )
    else:
        sites, depth = resnet.site_names(model)
        rules = PolicyRules.of(
            ("stem", 0.0), ("block_{0,-1}/*", 0.0), ("*", 0.8), base=base
        )
    from repro.core.schedulers import Constant

    program = PolicyProgram(rules=rules, schedule=Constant(target=0.8))
    return program.resolve(sites, depth=depth).peak()


def _conv_flops(model: str, batch: int, image, policy):
    from repro.models import ddpm, resnet

    if model == "ddpm":
        return ddpm.flops_per_iter(batch, image, policy=policy)
    return resnet.flops_per_iter(model, batch, image, policy=policy)


def _conv_sites(model: str, image):
    from repro.models import ddpm, resnet

    if model == "ddpm":
        return ddpm.iter_conv_shapes(image)
    return resnet.iter_conv_shapes(model, image)


def _conv_bytes(model: str, batch: int, image, policy, fused=None) -> int:
    """Whole-model backward HBM traffic (conv_backward_bytes_policy).

    ``fused=None`` counts what the engine actually routes (the traffic
    model picks fused vs materializing per site); False/True force one
    regime for the A/B rows.
    """
    from repro.core import flops as F

    return sum(
        F.conv_backward_bytes_site(
            batch, h, w, ci, co, k, policy, site, fused=fused
        )
        for site, ci, co, k, h, w in _conv_sites(model, image)
    )


@functools.lru_cache(maxsize=None)
def _conv_param_bytes(model: str, image) -> float:
    from repro.models import ddpm, resnet

    if model == "ddpm":
        shapes = jax.eval_shape(
            lambda k: ddpm.init_params(k, channels=image[0]), jax.random.PRNGKey(0)
        )
    else:
        shapes = jax.eval_shape(
            lambda k: resnet.init_params(model, k, in_channels=image[0]),
            jax.random.PRNGKey(0),
        )
    return float(
        sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(shapes))
    )


def conv_roofline_row(model: str, batch: int, image, policy_name: str):
    """Backward-pass roofline terms for a conv model under one policy.

    Compute comes from the policy-aware Eq. 6/9 model
    (``conv_backward_flops_policy``): block granularity counts whole
    kept blocks and the Pallas path counts its 128-aligned tile padding,
    so the block/Pallas rows genuinely reflect what the unified backward
    engine executes, not the nominal channel top-k rate. The memory term
    is the policy-aware bytes-moved model
    (``conv_backward_bytes_policy`` summed over the model's conv sites)
    plus the weights traffic (grad write + read + param read) — the
    bytes column rides next to the FLOPs columns so compute- vs
    memory-bound is read off the same row.
    """
    policy = _conv_policy(model, policy_name)
    dense_f, policy_f = _conv_flops(model, batch, image, policy)
    p_bytes = _conv_param_bytes(model, image)
    bytes_moved = _conv_bytes(model, batch, image, policy)
    compute_t = policy_f / PEAK_FLOPS
    memory_t = (bytes_moved + 3 * p_bytes) / HBM_BW
    return {
        "arch": model,
        "shape": f"b{batch}x{image[1]}",
        "policy": policy_name,
        "status": "ok",
        "compute_s": compute_t,
        "memory_s": memory_t,
        "dominant": "compute" if compute_t >= memory_t else "memory",
        "dense_flops": dense_f,
        "policy_flops": policy_f,
        "bytes_moved": bytes_moved,
        "saved": 1.0 - policy_f / dense_f,
    }


def iter_conv_rows():
    """All (model × policy) conv roofline rows — shared by run()/main()."""
    for model, batch, image in _CONV_CELLS:
        for pname in _CONV_POLICY_NAMES:
            yield conv_roofline_row(model, batch, image, pname)


def conv_fusion_row(model: str, batch: int, image, policy_name: str):
    """Before/after HBM traffic of the fused-im2col backward.

    'Before' forces the materializing canonical path (real ``X2``/``dX2``
    patch buffers at every site); 'after' is the engine's actual routing
    (the traffic model picks fused or materializing per site). The
    assertion is the fusion's contract: the routed path never moves more
    bytes than materializing, because routing falls back wherever fusing
    would lose (1x1 convs, tiny-``C_in`` stems, degenerate outputs).
    """
    policy = _conv_policy(model, policy_name)
    mat = _conv_bytes(model, batch, image, policy, fused=False)
    fus = _conv_bytes(model, batch, image, policy, fused=None)
    assert fus <= mat, (
        f"fused im2col moves more bytes than materializing for {model}/"
        f"{policy_name}: {fus} > {mat} — the routing gate is broken"
    )
    return {
        "arch": model,
        "shape": f"b{batch}x{image[1]}",
        "policy": policy_name,
        "status": "ok",
        "materializing_bytes": mat,
        "fused_bytes": fus,
        "materializing_s": mat / HBM_BW,
        "fused_s": fus / HBM_BW,
        "bytes_saved": 1.0 - fus / mat,
    }


# fusion A/B only makes sense where the engine has a fused path to take
_FUSION_POLICY_NAMES = ("ssprop_block_pallas",)


def _measured_fusion_cell():
    """One measured wall-clock A/B of fuse_im2col on a small layer.

    Interpret-mode Pallas timings do not predict TPU wall-clock — the
    asserted quantity is the analytic bytes model above; this row exists
    so the harness records that both variants actually execute, and the
    timing is informational.
    """
    import time

    from repro.core.conv import sparse_conv2d

    pol = dataclasses.replace(tpu_default(0.5), block_size=4, use_pallas=True)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 16, 16), jnp.float32)
    w = jax.random.normal(key, (16, 8, 3, 3), jnp.float32) * 0.1
    out = {}
    for label, fuse in (("fused", True), ("materializing", False)):
        p = dataclasses.replace(pol, fuse_im2col=fuse)

        def f(x, w):
            return sparse_conv2d(x, w, padding=1, policy=p).sum()

        g = jax.jit(jax.grad(f, argnums=(0, 1)))
        jax.block_until_ready(g(x, w))  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(g(x, w))
        out[label] = time.perf_counter() - t0
    return out


def iter_fusion_rows():
    """All fused-vs-materializing A/B rows — shared by run()/main()."""
    for model, batch, image in _CONV_CELLS:
        for pname in _FUSION_POLICY_NAMES:
            yield conv_fusion_row(model, batch, image, pname)


def _load_dryrun(arch, shape_name, mesh, policy):
    f = os.path.join(DRYRUN_DIR, f"{arch}__{shape_name}__{mesh}__{policy}.json")
    if not os.path.exists(f):
        return None
    with open(f) as fh:
        return json.load(fh)


def roofline_row(arch, shape_name, policy="ssprop", mesh="single"):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    dr = _load_dryrun(arch, shape_name, mesh, policy)
    pr = probe_cell(arch, shape_name, policy)
    if pr.get("status") != "ok" or dr is None or dr.get("status") != "ok":
        return {
            "arch": arch, "shape": shape_name, "policy": policy,
            "status": pr.get("why") or (dr or {}).get("status", "missing"),
        }
    chips = dr["devices"]
    compute_t = pr["total_flops"] / chips / PEAK_FLOPS
    memory_hlo_t = pr["total_bytes"] / chips / HBM_BW
    memory_t = memory_model_bytes(cfg, shape, pr.get("accum", 1), chips) / HBM_BW
    coll_t = dr["collective_wire_bytes"] / LINK_BW  # already per-chip
    mf = model_flops(cfg, shape)
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = compute_t / bound if bound > 0 else 0.0
    return {
        "arch": arch,
        "shape": shape_name,
        "policy": policy,
        "status": "ok",
        "compute_s": compute_t,
        "memory_s": memory_t,
        "memory_hlo_s": memory_hlo_t,
        "collective_s": coll_t,
        "dominant": dom,
        "roofline_fraction": frac,  # compute / dominant: 1.0 == compute-bound
        "model_flops": mf,
        "hlo_flops": pr["total_flops"],
        "useful_ratio": mf / pr["total_flops"] if pr["total_flops"] else 0.0,
    }


def run():
    """Benchmark-harness entry: emit roofline rows for available cells."""
    from benchmarks.common import emit

    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            row = roofline_row(arch, shape_name)
            if row.get("status") != "ok":
                emit(f"roofline/{arch}/{shape_name}", 0.0, f"status={row['status']}")
                continue
            emit(
                f"roofline/{arch}/{shape_name}",
                row["compute_s"] * 1e6,
                f"dom={row['dominant']};frac={row['roofline_fraction']:.3f};"
                f"mem_s={row['memory_s']:.4f};coll_s={row['collective_s']:.4f};"
                f"useful={row['useful_ratio']:.3f}",
            )
    # conv rows: the op the paper is about, through the policy-aware
    # FLOPs model (channel vs block vs block+Pallas keep counts).
    for row in iter_conv_rows():
        emit(
            f"roofline/conv/{row['arch']}/{row['policy']}",
            row["compute_s"] * 1e6,
            f"dom={row['dominant']};saved={row['saved']:.3f};"
            f"mem_s={row['memory_s']:.4f};bytes={row['bytes_moved']}",
        )
    # fused-im2col before/after: HBM traffic with vs without the patch
    # buffers, the quantity the fusion pass exists to cut.
    for row in iter_fusion_rows():
        emit(
            f"roofline/conv_fusion/{row['arch']}/{row['policy']}",
            row["fused_s"] * 1e6,
            f"mat_s={row['materializing_s']:.4f};"
            f"bytes_saved={row['bytes_saved']:.3f};"
            f"mat_bytes={row['materializing_bytes']};"
            f"fused_bytes={row['fused_bytes']}",
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--policy", default="ssprop")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--conv", action="store_true",
                    help="emit the conv-model rows (policy-aware FLOPs)")
    ap.add_argument("--fused", action="store_true",
                    help="emit fused-vs-materializing im2col A/B rows "
                    "(asserts fused bytes <= materializing) plus one "
                    "measured wall-clock cell")
    ap.add_argument("--lm-sites", action="store_true",
                    help="emit jaxpr-derived per-site projection rows "
                    "(measured backward interval, replacing the 6ND "
                    "estimate) for the selected cell(s)")
    ap.add_argument("--json", default="")
    args = ap.parse_args()
    rows = []
    if args.lm_sites:
        cells = (
            [(a, s) for a in ARCH_IDS for s in SHAPES]
            if args.all
            else [(args.arch, args.shape)]
        )
        for a, s in cells:
            for row in lm_site_rows(a, s, args.policy):
                rows.append(row)
                if row["kind"] == "lm_site":
                    print(
                        f"{a:28s} {s:12s} {row['site']:24s} "
                        f"x{row['count']:<3d} fwd={row['fwd_flops']:.3e} "
                        f"bwd=[{row['bwd_flops_lo']:.3e}, "
                        f"{row['bwd_flops_hi']:.3e}]"
                    )
                else:
                    print(
                        f"{a:28s} {s:12s} {'TOTAL':24s}      "
                        f"fwd={row['fwd_flops']:.3e} "
                        f"bwd=[{row['bwd_flops_lo']:.3e}, "
                        f"{row['bwd_flops_hi']:.3e}] "
                        f"6ND={row['model_flops_6nd']:.3e} "
                        f"ratio={row['ratio_vs_6nd']:.3f}"
                    )
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=1)
        return
    if args.conv or args.fused:
        if args.conv:
            for row in iter_conv_rows():
                rows.append(row)
                print(
                    f"{row['arch']:10s} {row['shape']:8s} {row['policy']:20s} "
                    f"comp={row['compute_s']:.4f}s mem={row['memory_s']:.4f}s "
                    f"bytes={row['bytes_moved']/1e9:.2f}GB "
                    f"saved={row['saved']:.3f} dom={row['dominant']}"
                )
        if args.fused:
            for row in iter_fusion_rows():
                rows.append(row)
                print(
                    f"{row['arch']:10s} {row['shape']:8s} {row['policy']:20s} "
                    f"mat={row['materializing_bytes']/1e9:.2f}GB "
                    f"fused={row['fused_bytes']/1e9:.2f}GB "
                    f"({row['materializing_s']:.4f}s -> {row['fused_s']:.4f}s, "
                    f"bytes_saved={row['bytes_saved']:.3f})"
                )
            t = _measured_fusion_cell()
            rows.append({"arch": "micro", "policy": "measured", **t})
            print(
                f"measured (interpret, informational): "
                f"fused={t['fused']:.3f}s materializing={t['materializing']:.3f}s"
            )
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=1)
        return
    cells = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    for a, s in cells:
        row = roofline_row(a, s, policy=args.policy)
        rows.append(row)
        if row.get("status") == "ok":
            print(
                f"{a:28s} {s:12s} comp={row['compute_s']:.4f}s "
                f"mem={row['memory_s']:.4f}s coll={row['collective_s']:.4f}s "
                f"dom={row['dominant']:10s} frac={row['roofline_fraction']:.3f} "
                f"useful={row['useful_ratio']:.2f}"
            )
        else:
            print(f"{a:28s} {s:12s} -- {row['status']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
