# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (
        fig2_sensitivity,
        kernels_table,
        roofline,
        serve_latency,
        table4_classification,
        table5_generation,
        table6_dropout,
        table7_flops_matched,
    )

    print("name,us_per_call,derived")
    table4_classification.run()
    table5_generation.run()
    table6_dropout.run()
    table7_flops_matched.run()
    fig2_sensitivity.run()
    roofline.run()
    serve_latency.run()  # writes BENCH_serve.json next to this file
    kernels_table.run()  # writes BENCH_kernels.json next to this file


if __name__ == "__main__":
    main()
