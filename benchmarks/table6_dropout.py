"""Paper Table 6: ssProp vs/with Dropout (Q1, over-fitting prevention).

FLOPs: Dropout *adds* backward cost (Eq. 8) while ssProp removes ~40%.
Behaviour: on the finite synthetic image task, train/eval gap shrinks
with either regularizer and shrinks further with both combined —
reproducing the paper's Q1 trend (exact accuracies need the real
datasets; the trend is the claim we can verify offline).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import flops as F
from repro.core.policy import SsPropPolicy, paper_default
from repro.data.pipeline import ImagePipeline, ImagePipelineConfig
from repro.models import resnet
from repro.optim import adam


def _run_mode(drop_ssprop, drop_dropout, steps=24, seed=0):
    pipe = ImagePipeline(ImagePipelineConfig((3, 16, 16), 10, 32, seed=3), n_train=128)
    name = "resnet18"
    params = resnet.init_params(name, jax.random.PRNGKey(seed), num_classes=10)
    opt = adam.init(params)
    ocfg = adam.AdamConfig(lr=1e-3)
    pol = paper_default(drop_ssprop) if drop_ssprop else SsPropPolicy(0.0)

    def loss_fn(p, x, y, key):
        logits = resnet.forward(
            name, p, x, pol, dropout_rate=drop_dropout, dropout_key=key
        )
        return -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y].mean()

    @jax.jit
    def step(p, o, x, y, key):
        lv, g = jax.value_and_grad(loss_fn)(p, x, y, key)
        p2, o2, _ = adam.apply_updates(ocfg, p, g, o)
        return p2, o2, lv

    key = jax.random.PRNGKey(100 + seed)
    train_loss = None
    for i in range(steps):
        b = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        key, sub = jax.random.split(key)
        params, opt, train_loss = step(params, opt, b["images"], b["labels"], sub)
    ev = pipe.eval_batch(128)
    logits = resnet.forward(name, params, jnp.asarray(ev["images"]), SsPropPolicy(0.0), train=False)
    eval_loss = float(
        -jax.nn.log_softmax(logits)[jnp.arange(128), jnp.asarray(ev["labels"])].mean()
    )
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(ev["labels"])).mean())
    return float(train_loss), eval_loss, acc


def run():
    # FLOPs interaction (CIFAR ResNet-50 shapes): dropout adds Eq. 8 cost
    d50, _ = resnet.flops_per_iter("resnet50", 128, (3, 32, 32))
    _, s50 = resnet.flops_per_iter("resnet50", 128, (3, 32, 32), 0.4)
    drop_extra = sum(
        F.dropout_backward_flops(128, hw, hw, c)
        for hw, c in [(32, 256), (16, 512), (8, 1024), (4, 2048)]
    )
    emit("table6/flops/resnet50", 0.0,
         f"dense_B={d50/1e9:.2f};w_dropout_B={(d50+drop_extra)/1e9:.2f};w_ssprop_B={s50/1e9:.2f}")

    # behavioural trend on the finite synthetic task
    modes = {
        "baseline": (0.0, 0.0),
        "ssprop_0.4": (0.4, 0.0),
        "dropout_0.2": (0.0, 0.2),
        "both_0.2+0.2": (0.2, 0.2),
    }
    for mode, (sp, dr) in modes.items():
        tr, ev, acc = _run_mode(sp, dr)
        gap = ev - tr
        emit(f"table6/overfit/{mode}", 0.0,
             f"train={tr:.3f};eval={ev:.3f};gap={gap:.3f};acc={acc:.3f}")
