"""Paper Fig. 2 sensitivity analysis (reduced, synthetic):

(a) drop-rate sweep, (b) top-k vs random selection, (c) schedules
(constant / linear / cosine / bar / epoch_bar — first-class
:class:`~repro.core.schedulers.Schedule` objects from the registry) at
a fixed target, (d) scheduler period, (e) backward-engine path —
channel top-k vs 32-channel blocks vs blocks through the Pallas
gathered kernels (interpret mode on CPU), (f) a per-site **policy
program** (stem + first/last block dense, the rest at 0.8) driven end
to end through ``resolved.policies_for_step``, with its FLOPs counted
over the resolved site table. Reproduces the paper's qualitative
findings: accuracy falls with rate; random falls faster than top-k;
schedulers beat constant; the 2-epoch bar is at least as good as
iteration-periodic bars; and the TPU-native block/Pallas paths track
the channel path's accuracy.

Run standalone (CI smoke: ``--reduced`` trims the grid to one cell per
section): ``python benchmarks/fig2_sensitivity.py --reduced``.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import flops as F
from repro.core.policy import (
    PolicyProgram,
    PolicyRules,
    SsPropPolicy,
    paper_default,
    tpu_default,
)
from repro.core.schedulers import EpochBar, PeriodicBar, make_schedule
from repro.data.pipeline import ImagePipeline, ImagePipelineConfig
from repro.models import resnet
from repro.optim import adam

_NAME = "resnet18"
_STEPS = 16
_SPE = 4  # steps per "epoch"


def _pipe_params_opt(seed):
    pipe = ImagePipeline(ImagePipelineConfig((3, 16, 16), 10, 32, seed=7), n_train=256)
    params = resnet.init_params(_NAME, jax.random.PRNGKey(seed), num_classes=10)
    return pipe, params, adam.init(params)


def _make_step(pol, ocfg):
    def loss_fn(p, x, y):
        logits = resnet.forward(_NAME, p, x, pol)
        return -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y].mean()

    @jax.jit
    def step(p, o, x, y):
        lv, g = jax.value_and_grad(loss_fn)(p, x, y)
        p2, o2, _ = adam.apply_updates(ocfg, p, g, o)
        return p2, o2, lv

    return step


def _eval(pipe, params):
    ev = pipe.eval_batch(128)
    logits = resnet.forward(
        _NAME, params, jnp.asarray(ev["images"]), SsPropPolicy(0.0), train=False
    )
    return float((jnp.argmax(logits, -1) == jnp.asarray(ev["labels"])).mean())


def _train(rate_fn, selection="topk", steps=_STEPS, seed=0, policy_fn=None):
    """Train under a per-step rate function (legacy global-policy path)."""
    pipe, params, opt = _pipe_params_opt(seed)
    ocfg = adam.AdamConfig(lr=1e-3)
    cache = {}

    def get_step(rate):
        key = round(rate, 2)
        if key not in cache:
            if rate == 0:
                pol = SsPropPolicy(0.0)
            elif policy_fn is not None:
                pol = policy_fn(rate)
            else:
                pol = dataclasses.replace(paper_default(rate), selection=selection)
            cache[key] = _make_step(pol, ocfg)
        return cache[key]

    for i in range(steps):
        b = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        params, opt, _ = get_step(rate_fn(i))(params, opt, b["images"], b["labels"])
    return _eval(pipe, params)


def _train_program(resolved, steps=_STEPS, seed=0):
    """Train under a resolved policy program: the step cache is keyed on
    the per-step SitePolicies table, exactly like launch/train.py."""
    pipe, params, opt = _pipe_params_opt(seed)
    ocfg = adam.AdamConfig(lr=1e-3)
    cache = {}
    for i in range(steps):
        table = resolved.policies_for_step(i)
        if table not in cache:
            cache[table] = _make_step(table, ocfg)
        b = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        params, opt, _ = cache[table](params, opt, b["images"], b["labels"])
    return _eval(pipe, params), len(cache)


def per_site_program(steps_per_epoch=_SPE):
    """The Fig. 2(f) program: stem + first/last block dense, rest 0.8."""
    rules = PolicyRules.of(
        ("stem", 0.0),
        ("block_{0,-1}/*", 0.0),
        ("*", 0.8),
        base=paper_default(0.8),
    )
    program = PolicyProgram(
        rules=rules, schedule=EpochBar(target=0.8, steps_per_epoch=steps_per_epoch)
    )
    sites, depth = resnet.site_names(_NAME)
    return program.resolve(sites, depth=depth)


def run(reduced: bool = False):
    steps = 8 if reduced else _STEPS
    # (a) drop-rate sweep, constant schedule
    for rate in (0.0, 0.8) if reduced else (0.0, 0.5, 0.8, 0.95):
        acc = _train(lambda i, r=rate: r, steps=steps)
        emit(f"fig2a/rate_{rate}", 0.0, f"acc={acc:.3f}")
    # (b) selection method at 0.8
    for sel in () if reduced else ("topk", "random"):
        acc = _train(lambda i: 0.8, selection=sel)
        emit(f"fig2b/select_{sel}", 0.0, f"acc={acc:.3f}")
    # (c) schedules to target 0.8 — built from the registry
    names = ("epoch_bar",) if reduced else ("constant", "linear", "cosine", "bar", "epoch_bar")
    for name in names:
        sched = make_schedule(
            name, target=0.8, total_steps=steps, steps_per_epoch=_SPE
        )
        acc = _train(sched.rate, steps=steps)
        emit(f"fig2c/sched_{name}", 0.0, f"acc={acc:.3f}")
    # (d) periodic bar periods
    for period in () if reduced else (8, 16):
        sched = PeriodicBar(target=0.8, period=period)
        acc = _train(sched.rate)
        emit(f"fig2d/period_{period}", 0.0, f"acc={acc:.3f}")
    # (e) backward-engine paths at 0.8: channel top-k (paper) vs block
    # granularity vs block + Pallas gathered kernels — the conv rows run
    # through core/backward.py's unified pipeline in all three.
    engine_paths = {
        "channel": lambda r: paper_default(r),
        "block": lambda r: dataclasses.replace(tpu_default(r), block_size=32),
        "block_pallas": lambda r: dataclasses.replace(
            tpu_default(r), block_size=32, use_pallas=True
        ),
    }
    if reduced:
        engine_paths = {"block": engine_paths["block"]}
    for pname, pfn in engine_paths.items():
        acc = _train(lambda i: 0.8, policy_fn=pfn, steps=steps)
        emit(f"fig2e/engine_{pname}", 0.0, f"acc={acc:.3f}")
    # (f) per-site policy program: trains through policies_for_step and
    # accounts FLOPs over the resolved site table, not one global rate.
    resolved = per_site_program()
    acc, n_steps_compiled = _train_program(resolved, steps=steps)
    peak = resolved.peak()
    dense_f, site_f = resnet.flops_per_iter(_NAME, 32, (3, 16, 16), policy=peak)
    _, global_f = resnet.flops_per_iter(
        _NAME, 32, (3, 16, 16), policy=paper_default(0.8)
    )
    assert n_steps_compiled <= len(resolved.schedule.rate_buckets), (
        n_steps_compiled, resolved.schedule.rate_buckets
    )
    # the dense-pinned stem/first/last sites must show up in the count
    assert global_f < site_f < dense_f, (global_f, site_f, dense_f)
    emit(
        "fig2f/per_site_program", 0.0,
        f"acc={acc:.3f};saved={F.savings_fraction(dense_f, site_f):.3f};"
        f"executables={n_steps_compiled}",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--reduced", action="store_true",
        help="one cell per section (CI smoke for the per-site FLOPs path)",
    )
    args = ap.parse_args()
    run(reduced=args.reduced)


if __name__ == "__main__":
    main()
