"""Paper Fig. 2 sensitivity analysis (reduced, synthetic):

(a) drop-rate sweep, (b) top-k vs random selection, (c) schedulers
(constant / linear / cosine / bar) at a fixed target, (d) scheduler
period, (e) backward-engine path — channel top-k vs 32-channel blocks
vs blocks through the Pallas gathered kernels (interpret mode on CPU).
Reproduces the paper's qualitative findings: accuracy falls with rate;
random falls faster than top-k; schedulers beat constant; the 2-epoch
bar is at least as good as iteration-periodic bars; and the TPU-native
block/Pallas paths track the channel path's accuracy.
"""
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.policy import SsPropPolicy, paper_default, tpu_default
from repro.core.schedulers import drop_rate_for_step
from repro.data.pipeline import ImagePipeline, ImagePipelineConfig
from repro.models import resnet
from repro.optim import adam

_NAME = "resnet18"
_STEPS = 16
_SPE = 4  # steps per "epoch"


def _train(rate_fn, selection="topk", steps=_STEPS, seed=0, policy_fn=None):
    pipe = ImagePipeline(ImagePipelineConfig((3, 16, 16), 10, 32, seed=7), n_train=256)
    params = resnet.init_params(_NAME, jax.random.PRNGKey(seed), num_classes=10)
    opt = adam.init(params)
    ocfg = adam.AdamConfig(lr=1e-3)
    cache = {}

    def get_step(rate):
        key = round(rate, 2)
        if key not in cache:
            if rate == 0:
                pol = SsPropPolicy(0.0)
            elif policy_fn is not None:
                pol = policy_fn(rate)
            else:
                pol = dataclasses.replace(paper_default(rate), selection=selection)

            def loss_fn(p, x, y, k):
                logits = resnet.forward(_NAME, p, x, pol)
                return -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y].mean()

            @jax.jit
            def step(p, o, x, y, k):
                lv, g = jax.value_and_grad(loss_fn)(p, x, y, k)
                p2, o2, _ = adam.apply_updates(ocfg, p, g, o)
                return p2, o2, lv

            cache[key] = step
        return cache[key]

    key = jax.random.PRNGKey(123)
    for i in range(steps):
        b = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        key, sub = jax.random.split(key)
        step = get_step(rate_fn(i))
        params, opt, loss = step(params, opt, b["images"], b["labels"], sub)
    ev = pipe.eval_batch(128)
    logits = resnet.forward(_NAME, params, jnp.asarray(ev["images"]), SsPropPolicy(0.0), train=False)
    return float((jnp.argmax(logits, -1) == jnp.asarray(ev["labels"])).mean())


def run():
    # (a) drop-rate sweep, constant schedule
    for rate in (0.0, 0.5, 0.8, 0.95):
        acc = _train(lambda i, r=rate: r)
        emit(f"fig2a/rate_{rate}", 0.0, f"acc={acc:.3f}")
    # (b) selection method at 0.8
    for sel in ("topk", "random"):
        acc = _train(lambda i: 0.8, selection=sel)
        emit(f"fig2b/select_{sel}", 0.0, f"acc={acc:.3f}")
    # (c) schedulers to target 0.8
    for sched in ("constant", "linear", "cosine", "bar", "epoch_bar"):
        acc = _train(
            lambda i, s=sched: drop_rate_for_step(
                s, step=i, steps_per_epoch=_SPE, total_steps=_STEPS, target=0.8
            )
        )
        emit(f"fig2c/sched_{sched}", 0.0, f"acc={acc:.3f}")
    # (d) periodic bar periods
    for period in (8, 16):
        acc = _train(
            lambda i, p=period: drop_rate_for_step(
                "periodic_bar", step=i, steps_per_epoch=_SPE,
                total_steps=_STEPS, target=0.8, period=p,
            )
        )
        emit(f"fig2d/period_{period}", 0.0, f"acc={acc:.3f}")
    # (e) backward-engine paths at 0.8: channel top-k (paper) vs block
    # granularity vs block + Pallas gathered kernels — the conv rows run
    # through core/backward.py's unified pipeline in all three.
    engine_paths = {
        "channel": lambda r: paper_default(r),
        "block": lambda r: dataclasses.replace(tpu_default(r), block_size=32),
        "block_pallas": lambda r: dataclasses.replace(
            tpu_default(r), block_size=32, use_pallas=True
        ),
    }
    for pname, pfn in engine_paths.items():
        acc = _train(lambda i: 0.8, policy_fn=pfn)
        emit(f"fig2e/engine_{pname}", 0.0, f"acc={acc:.3f}")
