import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Test runs may shrink the placeholder
# device pool via REPRO_DRYRUN_DEVICES (read before jax import too).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run driver.

For every (architecture × input-shape × mesh) cell: build abstract
(ShapeDtypeStruct) params / optimizer state / inputs with production
shardings, ``jit(step).lower(...).compile()`` against the 16×16 (256
chips) or 2×16×16 (512 chips) mesh, and record
``memory_analysis()`` / ``cost_analysis()`` / the collective schedule
parsed from the compiled HLO. No tensor is ever allocated.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single --policy ssprop
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.policy import DENSE, PolicyProgram, tpu_default
from repro.data.pipeline import input_specs
from repro.dist import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.optim import adam

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str, loop_mults=None):
    """Per-device collective bytes by op kind, from compiled (SPMD) HLO.

    Result shapes in the partitioned module are per-device. Wire-cost
    factors: ring all-reduce sends+receives ≈ 2x the shard bytes;
    all-gather/reduce-scatter/all-to-all/permute ≈ 1x.

    ``loop_mults``: per-loop-depth trip multipliers. HLO text lists a
    while body ONCE; an op whose op_name metadata sits N ``while/body``
    frames deep executes ``loop_mults[N]`` times per step (train:
    [1, accum, accum*n_periods, ...]). Without this the wire bytes of
    scanned layers are undercounted by up to ~1000x on the big models.
    """
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line[: m.start()]:
            continue
        kind = m.group(1)
        lhs = line.split(" = ", 1)
        shapes = _SHAPE_RE.findall(lhs[1][: m.start() - len(lhs[0]) - 3] if len(lhs) > 1 else line[: m.start()])
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        mult = 1
        if loop_mults:
            depth = line.count("while/body")
            mult = loop_mults[min(depth, len(loop_mults) - 1)]
        rec = out.setdefault(kind, {"count": 0, "bytes": 0, "stepped_bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
        rec["stepped_bytes"] += int(nbytes * mult)
    factor = {"all-reduce": 2.0}
    wire = sum(
        v.get("stepped_bytes", v["bytes"]) * factor.get(k, 1.0)
        for k, v in out.items()
    )
    return out, int(wire)


def _sds(tree, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree,
        shardings,
    )


def build_cell(arch: str, shape_name: str, mesh, policy_name: str):
    """Returns (fn, example_args_as_sds, meta) for one dry-run cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cfg.supports_shape(shape)
    if not ok:
        return None, None, {"skipped": why}

    import dataclasses as _dc

    dp = 1
    for a in dp_axes(mesh):
        dp *= mesh.shape[a]

    if policy_name == "ssprop":
        policy = tpu_default(0.8)
    elif policy_name == "ssprop_tp":
        # §Perf iteration 1: TP-local per-shard top-k (comm-free gather)
        policy = _dc.replace(tpu_default(0.8), tp_shards=int(mesh.shape["model"]))
    elif policy_name == "opt":
        # §Perf combined: TP-local selection + DP-local MoE dispatch +
        # seq-sharded decode + bf16 backward + donated decode state
        # (see EXPERIMENTS.md §Perf iterations 1-5)
        policy = _dc.replace(
            tpu_default(0.8),
            tp_shards=int(mesh.shape["model"]),
            bwd_dtype="bfloat16",
        )
        cfg = _dc.replace(cfg, moe_dp_groups=dp, decode_seq_shard=True)
    elif policy_name == "dense":
        policy = DENSE
    else:
        raise ValueError(policy_name)

    # The cell's control surface is a (trivial one-rule) policy program;
    # the compiled step consumes its resolved site table — the same
    # object a per-site program would hand the train loop.
    from repro.models import model as _lm

    sites, depth = _lm.site_names(cfg)
    policy = PolicyProgram.single(policy).resolve(sites, depth=depth).peak()

    a_params, a_opt = steps_lib.abstract_state(cfg)
    p_sh = shd.param_shardings(mesh, a_params, replicate_kv=(policy_name == "opt"))
    params_sds = _sds(a_params, p_sh)

    from repro.models import transformer as _tf

    np_ = _tf.n_periods(cfg) if cfg.family != "encdec" else cfg.n_layers
    chunks = max(1, shape.seq_len // 1024)
    meta = {
        "arch": arch,
        "shape": shape_name,
        "policy": policy_name,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "n_periods": np_,
    }

    if shape.kind == "train":
        accum = steps_lib.microbatch_plan(cfg, shape, dp)
        meta["accum"] = accum
        meta["loop_mults"] = [1, accum, accum * np_, accum * np_ * chunks]
        opt_sh = shd.opt_state_shardings(mesh, a_params)
        opt_sds = adam.AdamState(
            step=jax.ShapeDtypeStruct((), jnp.int32, sharding=shd.replicated(mesh)),
            m=_sds(a_opt.m, opt_sh),
            v=_sds(a_opt.v, opt_sh),
        )
        batch = input_specs(cfg, shape)
        batch_sds = _sds(batch, shd.batch_shardings(mesh, batch))
        opt_cfg = adam.AdamConfig(lr=2e-4, clip_norm=1.0)
        fn = steps_lib.make_train_step(cfg, policy, opt_cfg, accum=accum)
        return fn, (params_sds, opt_sds, batch_sds), meta

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        batch_sds = _sds(batch, shd.batch_shardings(mesh, batch))
        fn = steps_lib.make_prefill_step(cfg)
        meta["loop_mults"] = [1, np_, np_ * chunks]
        return fn, (params_sds, batch_sds), meta

    # decode
    b = shape.global_batch
    a_cache = steps_lib.abstract_cache(cfg, b, shape.seq_len)
    cache_sds = _sds(
        a_cache,
        shd.cache_shardings(mesh, a_cache, seq_shard=(policy_name == "opt")),
    )
    dpax = dp_axes(mesh)
    baxis = dpax if len(dpax) > 1 else (dpax[0] if dpax else None)
    state = {
        "tokens": jax.ShapeDtypeStruct(
            (b, 1),
            jnp.int32,
            sharding=NamedSharding(mesh, shd.fit_spec(P(baxis, None), (b, 1), mesh)),
        ),
        "pos": jax.ShapeDtypeStruct((), jnp.int32, sharding=shd.replicated(mesh)),
        "cache": cache_sds,
    }
    if cfg.family == "encdec":
        state["enc_out"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model),
            jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(baxis, None, None)),
        )
    fn = steps_lib.make_serve_step(cfg)
    meta["decode"] = True
    meta["loop_mults"] = [1, np_, np_]
    return fn, (params_sds, state), meta


def _placement_report(args_sds) -> dict:
    """Input placements of one cell, by pytree path — the cheap audit
    surface for the sharding rule table (``--placements-only``): the
    first tree is the params (reported as a spec → leaf-count
    histogram), the rest (batch / opt state / serve state) leaf by
    leaf. No lowering, no compile."""
    params, *rest = args_sds
    hist: dict = {}
    for _, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        k = str(leaf.sharding.spec)
        hist[k] = hist.get(k, 0) + 1
    inputs = {}
    for tree in rest:
        for path, leaf in jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: hasattr(x, "sharding")
        )[0]:
            if hasattr(leaf, "sharding"):
                inputs[jax.tree_util.keystr(path)] = str(leaf.sharding.spec)
    return {"param_spec_histogram": hist, "inputs": inputs}


def run_cell(
    arch, shape_name, mesh_kind, policy_name, out_dir=None, verbose=True,
    placements_only=False,
):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    fn, args, meta = build_cell(arch, shape_name, mesh, policy_name)
    rec = dict(meta, mesh=mesh_kind, devices=mesh.devices.size)
    if fn is None:
        rec["status"] = "skipped"
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: SKIP ({meta['skipped']})")
        return rec
    if placements_only:
        rec["placements"] = _placement_report(args)
        rec["status"] = "ok"
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_kind} × {policy_name}: placements")
            for k, v in rec["placements"]["inputs"].items():
                print(f"  {k}: {v}")
        print(json.dumps(rec["placements"]))
        return rec
    try:
        with mesh:
            donate = (0, 1) if meta.get("accum") else ()
            if meta.get("decode") and policy_name == "opt":
                donate = (1,)  # donate the serving state (cache) buffers
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            try:
                mem = compiled.memory_analysis()
                rec["memory"] = {
                    "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                    "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                    "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                    "generated_code_bytes": int(
                        getattr(mem, "generated_code_size_in_bytes", 0)
                    ),
                }
            except Exception as e:  # pragma: no cover
                rec["memory"] = {"error": str(e)}
            try:
                cost = compiled.cost_analysis()
                rec["cost"] = {
                    "flops": float(cost.get("flops", -1)),
                    "bytes_accessed": float(cost.get("bytes accessed", -1)),
                }
            except Exception as e:  # pragma: no cover
                rec["cost"] = {"error": str(e)}
            hlo = compiled.as_text()
            colls, wire = parse_collectives(hlo, meta.get("loop_mults"))
            rec["collectives"] = colls
            rec["collective_wire_bytes"] = wire
            rec["status"] = "ok"
            rec["lower_s"] = round(t_lower, 2)
            rec["compile_s"] = round(t_compile, 2)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if verbose:
        s = rec["status"]
        extra = ""
        if s == "ok":
            mb = rec["memory"].get("argument_bytes", 0) / mesh.devices.size / 2**30
            extra = (
                f" flops/dev={rec['cost'].get('flops', 0):.3e}"
                f" args/dev={mb:.2f}GiB wire/dev={rec['collective_wire_bytes']/2**30:.3f}GiB"
                f" (lower {rec['lower_s']}s compile {rec['compile_s']}s)"
            )
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind} × {policy_name}: {s}{extra}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_kind}__{policy_name}.json"
        rec.pop("traceback", None) if rec["status"] == "ok" else None
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--policy", choices=["ssprop", "ssprop_tp", "opt", "dense"], default="ssprop")
    ap.add_argument("--all", action="store_true", help="every (arch × shape)")
    ap.add_argument("--placements-only", action="store_true",
                    help="report input placements (JSON) without "
                         "lowering/compiling — fast rule-table audit")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = 0
    for a, s in cells:
        rec = run_cell(
            a, s, args.mesh, args.policy,
            out_dir=None if args.placements_only else args.out,
            placements_only=args.placements_only,
        )
        if rec["status"] == "error":
            failures += 1
            print(rec.get("error"))
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
