"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because
the dry-run forces 512 host devices via XLA_FLAGS before first jax init,
while tests/benches must see the single real CPU device.
"""
from __future__ import annotations

import jax

from repro.dist import compat as _compat  # noqa: F401  (installs jax shims)


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions (axis_types is newer-only)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes)
            )
        except TypeError:  # pragma: no cover - mid-vintage jax
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the real local devices (tests, examples)."""
    return _make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh: ('pod','data') when present."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n
