"""Jitted train/serve step builders shared by train.py, dryrun.py, tests.

``make_train_step`` builds a gradient-accumulation (microbatched) step:
the global batch is split into ``accum`` microbatches scanned
sequentially with summed grads — at 123B scale the per-device activation
carry of a full 256-batch remat'd scan would exceed HBM; microbatching is
how production frameworks bound it. One optimizer update per step.

``make_serve_step`` is a single-token decode step over the KV/SSM cache.

Both serving steps share :func:`sample_tokens`: sampling parameters ride
in the step state as per-slot *data* arrays (``temps``/``top_ks``/
``top_ps`` plus a ``[B, 2]`` PRNG-lane array ``rng``), so one compiled
executable per step width serves any mix of greedy and sampled slots —
the same "occupancy is data" design as ``count``/``block_tables``. When
the state omits ``rng`` the step falls back to pure greedy argmax
(legacy callers: dryrun, roofline).
"""
from __future__ import annotations

from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.policy import DENSE, PolicyLike
from repro.models import model as lm
from repro.optim import adam


def microbatch_plan(cfg: ModelConfig, shape: ShapeConfig, dp: int) -> int:
    """Number of grad-accumulation microsteps for a train cell.

    Budget ≈ 8k tokens per data shard per microstep (bounds the remat
    carry [micro_b/dp, S, d] · n_layers to ~GBs at d=12k).
    """
    if shape.kind != "train":
        return 1
    budget = max(1, 8192 // shape.seq_len)  # examples per shard
    if cfg.d_model >= 8192:
        budget = 1
    micro_global = min(shape.global_batch, dp * budget)
    accum = max(1, shape.global_batch // micro_global)
    while shape.global_batch % accum:
        accum += 1
    return accum


def make_train_step(
    cfg: ModelConfig,
    policy: PolicyLike,
    opt_cfg: adam.AdamConfig,
    *,
    accum: int = 1,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss(params, microbatch):
        return lm.loss_fn(cfg, params, microbatch, policy)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss_v, metrics), grads = grad_fn(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def body(acc, mb):
                (loss_v, metrics), g = grad_fn(params, mb)
                acc_g, acc_l = acc
                return (
                    jax.tree.map(jnp.add, acc_g, g),
                    acc_l + loss_v / accum,
                ), metrics

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_v), metrics = jax.lax.scan(body, (zero_g, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        new_params, new_opt, om = adam.apply_updates(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss_v, **om)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        loss_v, metrics = lm.loss_fn(cfg, params, batch, DENSE)
        return metrics["ce"]

    return eval_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Forward pass over the full prompt (inference-prefill shape)."""

    def prefill(params, batch):
        logits, _ = lm.forward(cfg, params, batch, DENSE)
        return jnp.argmax(logits[:, -1], axis=-1)

    return prefill


# Static bound on per-request top_k: `top_ks` is per-slot *data* (traced),
# but `jax.lax.top_k` needs a static k — so the step computes the top
# TOP_K_CAP values once (O(V·log cap), vs the old full-vocab sort) and
# indexes the k-th per slot. SamplingParams validates top_k <= TOP_K_CAP.
TOP_K_CAP = 128


def sample_tokens(logits, *, rng, temps, top_ks, top_ps, fold):
    """Per-slot temperature / top-k / top-p sampling over ``[B, V]`` logits.

    All controls are per-slot data: ``temps [B]`` (0 = greedy argmax for
    that slot), ``top_ks [B]`` int32 (0 = off), ``top_ps [B]`` (1.0 =
    off), ``rng [B, 2]`` uint32 base PRNG lanes, ``fold [B]`` int32 the
    per-token fold value (the absolute cache position of the token whose
    logits these are). The subkey for each draw is
    ``fold_in(rng[b], fold[b])`` — a pure function of (seed, position),
    so the sampled stream is invariant to chunking, batch composition
    and preemption. Returns ``[B]`` int32 tokens.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    v = logits.shape[-1]
    scaled = logits / jnp.where(temps > 0, temps, 1.0)[:, None]
    # top-k: mask everything below the k-th largest logit (k = 0 -> off)
    cap = min(v, TOP_K_CAP)
    top_vals, _ = jax.lax.top_k(scaled, cap)  # [B, cap], sorted desc
    kth = jnp.take_along_axis(
        top_vals, (jnp.clip(top_ks, 1, cap) - 1)[:, None], axis=-1
    )
    scaled = jnp.where(
        (top_ks[:, None] > 0) & (scaled < kth), -jnp.inf, scaled
    )
    # top-p (nucleus): keep the smallest sorted prefix with mass >= p.
    # The exclusive cumsum comparison always keeps the top-1 token.
    idx = jnp.argsort(-scaled, axis=-1)
    probs = jax.nn.softmax(jnp.take_along_axis(scaled, idx, axis=-1), axis=-1)
    keep_sorted = (jnp.cumsum(probs, axis=-1) - probs) < top_ps[:, None]
    keep = jnp.take_along_axis(keep_sorted, jnp.argsort(idx, axis=-1), axis=-1)
    scaled = jnp.where(keep, scaled, -jnp.inf)
    keys = jax.vmap(jax.random.fold_in)(rng, fold)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def _emit_tokens(logits, state, fold):
    """Greedy-or-sampled next tokens for a serving step's logits."""
    rng = state.get("rng")
    if rng is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return sample_tokens(
        logits, rng=rng, temps=state["temps"], top_ks=state["top_ks"],
        top_ps=state["top_ps"], fold=fold,
    )


def sample_tokens_chunk(logits, *, rng, temps, top_ks, top_ps, fold):
    """Per-position sampling over a ``[B, C, V]`` chunk of logits.

    ``fold [B, C]`` carries each position's absolute cache position.
    Flattens to ``[B*C, V]`` rows and reuses :func:`sample_tokens` with
    each slot's controls repeated across its chunk — every row's
    computation is identical to the width-1 call, so per-position
    emission is bit-exact with single-token decode at the same fold.
    Returns ``[B, C]`` int32 tokens.
    """
    b, c, v = logits.shape

    def rep(a):
        return jnp.repeat(a, c, axis=0)

    toks = sample_tokens(
        logits.reshape(b * c, v), rng=rep(rng), temps=rep(temps),
        top_ks=rep(top_ks), top_ps=rep(top_ps), fold=fold.reshape(b * c),
    )
    return toks.reshape(b, c)


def _emit_chunk_tokens(logits, state, fold):
    """Greedy-or-sampled tokens for every chunk position. [B,C,V] -> [B,C]."""
    rng = state.get("rng")
    if rng is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return sample_tokens_chunk(
        logits, rng=rng, temps=state["temps"], top_ks=state["top_ks"],
        top_ps=state["top_ps"], fold=fold,
    )


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One new token against a seq_len KV cache.

    state = {"tokens": [B,1] int32, "pos": scalar int32, "cache": pytree,
             optional "enc_out": [B, enc_seq, d], optional sampling
             arrays "rng" [B,2] u32 / "temps" [B] / "top_ks" [B] /
             "top_ps" [B] (absent -> greedy)}.
    Returns (next_tokens [B,1], new_state).
    """

    def serve_step(params, state):
        enc_out = state.get("enc_out")
        logits, new_cache = lm.decode_step(
            cfg, params, state["tokens"], state["cache"], state["pos"],
            enc_out=enc_out,
        )
        fold = jnp.full((logits.shape[0],), state["pos"], jnp.int32)
        nxt = _emit_tokens(logits, state, fold)[:, None]
        new_state = dict(state, tokens=nxt, pos=state["pos"] + 1, cache=new_cache)
        return new_state

    return serve_step


def make_slot_step(
    cfg: ModelConfig, *, paged_kernel: bool = False, spec: bool = False
) -> Callable:
    """Mixed prefill/decode step over per-slot state (continuous batching).

    ``paged_kernel=True`` (paged cache only) routes decode attention
    through the Pallas paged-attention kernel — pages read in place via
    the block table instead of the per-layer pool gather.

    state = {"tokens": [B,C] int32, "count": [B] int32 (real tokens per
    slot; 0 = idle), "pos": [B] int32 (per-slot cache offsets),
    "cache": pytree, optional "enc_out": [B, enc_seq, d], optional
    "block_tables": [B, NB] int32 (paged cache: logical block ->
    physical page per slot), optional per-slot sampling arrays
    "rng" [B,2] u32 / "temps" [B] / "top_ks" [B] / "top_ps" [B]
    (absent -> greedy argmax everywhere)}.

    One compiled step serves any slot occupancy: which slots decode,
    which prefill a chunk and which sit idle is *data* (count/pos), not
    shape — with the paged cache the page assignment is data too (block
    tables ride in the state dict), and so are the sampling controls:
    each slot's temperature/top-k/top-p and PRNG lane are arrays, so one
    executable per chunk width serves any mix of greedy and sampled
    slots. The per-token subkey folds the slot's lane by the absolute
    position of its last real token (``pos + count - 1``), keeping the
    sampled stream independent of chunking and preemption. Returns
    ``(next_tokens [B] int32, new_state)`` with the cache written and
    ``pos`` advanced by ``count``; rows with count==0 return garbage
    tokens the scheduler ignores.

    ``spec=True`` builds the speculative verify step instead. The state
    gains ``"is_spec" [B]`` bool; a speculative slot's ``tokens`` row is
    ``[t0, d1, .., d_{n-1}]`` — the last committed token followed by
    ``n-1`` draft proposals — with ``count = n``. The step emits the
    target's token at *every* chunk position with that position's fold
    (``fold[b, j] = pos[b] + j``), accepts the longest prefix where
    draft ``d_{j+1}`` equals the target's token at position ``j``
    (exact-match acceptance), and commits only the accepted prefix:
    ``keep = accepted + 1`` tokens are consumed, ``pos`` advances by
    ``keep``, and the SSM state is selected at the accepted position
    inside the step (:func:`repro.models.model.commit_spec_cache`), so
    the cache pytree out matches the non-speculative layout exactly.
    Rejected KV writes land beyond the committed ``pos``, where the
    per-slot causal mask fences them until they are overwritten.
    Non-speculative rows (``is_spec`` False — prefill chunks, plain
    decode, idle) take ``keep = count``, making this a strict superset
    of the plain step: one executable per width serves any mix. Returns
    ``((tokens [B, C] int32, keep [B] int32), new_state)`` — the caller
    emits ``tokens[b, :keep[b]]`` for a speculative slot and
    ``tokens[b, count[b]-1]`` otherwise.
    """

    def slot_step(params, state):
        if not spec:
            logits, new_cache = lm.decode_slots(
                cfg, params, state["tokens"], state["cache"],
                state["pos"], state["count"], enc_out=state.get("enc_out"),
                block_tables=state.get("block_tables"),
                paged_kernel=paged_kernel,
            )
            nxt = _emit_tokens(logits, state, state["pos"] + state["count"] - 1)
            new_state = dict(
                state, cache=new_cache, pos=state["pos"] + state["count"]
            )
            return nxt, new_state

        tokens, count = state["tokens"], state["count"]
        b, c = tokens.shape
        logits, new_cache = lm.decode_slots(
            cfg, params, tokens, state["cache"],
            state["pos"], count, enc_out=state.get("enc_out"),
            block_tables=state.get("block_tables"),
            paged_kernel=paged_kernel,
            all_logits=True, spec_states=True,
        )
        fold = state["pos"][:, None] + jnp.arange(c)[None, :]  # [B, C]
        tok = _emit_chunk_tokens(logits, state, fold)  # [B, C]
        if c > 1:
            # draft token d_{j+1} rides in the *input* row: accept while
            # the target's token at position j reproduces it.
            matches = (tok[:, :-1] == tokens[:, 1:]) & (
                jnp.arange(c - 1)[None, :] < (count - 1)[:, None]
            )
            acc = jnp.cumprod(matches.astype(jnp.int32), axis=1).sum(axis=1)
            keep = jnp.where(state["is_spec"] & (count > 1), acc + 1, count)
        else:
            keep = count
        new_cache = lm.commit_spec_cache(new_cache, keep)
        new_state = dict(state, cache=new_cache, pos=state["pos"] + keep)
        return (tok, keep), new_state

    return slot_step


def abstract_state(cfg: ModelConfig, rng=None):
    """eval_shape of (params, opt_state) — no allocation."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    a_params = jax.eval_shape(lambda r: lm.init_params(cfg, r), rng)
    a_opt = jax.eval_shape(adam.init, a_params)
    return a_params, a_opt


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, max_seq, dtype=jnp.dtype(cfg.dtype))
    )
