"""Batched LM serving driver (prefill + decode loop).

Serves a model with batched requests: prefill builds the KV/SSM cache
from the prompt batch via the full forward pass, then the jitted
single-token serve step autoregressively extends all requests in
lock-step (static batch; real serving would use continuous batching —
the cache layout here, batch-major with per-slot position, is what a
continuous batcher needs).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --reduced --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import model as lm


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    return ap


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)
    rng = jax.random.PRNGKey(args.seed)
    max_seq = args.prompt_len + args.gen + (cfg.n_patches or 0)

    with jax.set_mesh(mesh):
        params = lm.init_params(cfg, rng)
        prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab)

        # ---- prefill: run the prompt through decode steps to build the
        # cache (teacher-forced); production would use a chunked prefill
        # kernel — decode_32k/prefill_32k cells cover both shapes.
        cache = lm.init_cache(cfg, args.batch, max_seq, dtype=jnp.float32)
        enc_out = None
        if cfg.family == "encdec":
            frames = jax.random.normal(rng, (args.batch, cfg.enc_seq, cfg.d_model))
            enc_out = lm.encode(cfg, params, frames.astype(jnp.dtype(cfg.dtype)))
        serve_step = jax.jit(steps_lib.make_serve_step(cfg))

        state = {"tokens": prompts[:, :1], "pos": jnp.int32(0), "cache": cache}
        if enc_out is not None:
            state["enc_out"] = enc_out
        t0 = time.time()
        for t in range(1, args.prompt_len):
            state = serve_step(params, state)
            state["tokens"] = prompts[:, t : t + 1]  # teacher-forced prefill
        prefill_s = time.time() - t0

        generated = []
        t0 = time.time()
        for _ in range(args.gen):
            state = serve_step(params, state)
            generated.append(np.asarray(state["tokens"])[:, 0])
        decode_s = time.time() - t0

    gen = np.stack(generated, axis=1)
    tput = args.batch * args.gen / max(decode_s, 1e-9)
    return {
        "generated": gen,
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "tokens_per_s": tput,
    }


def main():
    args = build_parser().parse_args()
    out = run(args)
    print(f"[serve] batch={args.batch} gen={args.gen}")
    print(f"[serve] prefill {out['prefill_s']*1e3:.0f} ms, decode {out['decode_s']*1e3:.0f} ms"
          f" ({out['tokens_per_s']:.1f} tok/s)")
    print("[serve] first request tokens:", out["generated"][0][:16].tolist())


if __name__ == "__main__":
    main()
