"""LM serving CLI — thin front-end over the continuous-batching engine.

The serving loop itself lives in :mod:`repro.serve`: a slot-based
request scheduler with chunked prefill (requests join and leave the
batch mid-flight). ``--engine paged`` switches the KV cache to the
paged/block layout (``--block-size`` tokens per page, ``--n-blocks``
pool size — 0 sizes the pool to contiguous parity); ``--engine
lockstep`` runs the static lock-step baseline instead (every request
arrives together, the whole batch stalls until the longest generation
finishes) — kept for A/B comparison and as the parity oracle.

Sampling: ``--temperature`` > 0 samples every request (with
``--top-k``/``--top-p``) under per-request seeds derived from
``--seed``; the default 0 keeps greedy argmax. ``--preempt
swap|recompute|auto`` picks the pool-exhaustion policy (paged engine);
sampled requests require swap (auto does the right thing). ``--stream``
prints each token event as it is emitted instead of only the final
summary.

Speculative decoding: ``--spec-k k`` has a drafter propose k tokens per
decode slot which the target verifies in one chunk — the token stream
is bit-identical, only the step count drops. ``--draft-layers n`` builds
a depth-reduced drafter from the same architecture (0, the default,
self-drafts with the target — every proposal accepted; useful as a
sanity check, not a speedup, since the drafter is as expensive as the
target).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --reduced --batch 4 --prompt-len 16 --gen 32 --arrival-rate 0.5 \
      --temperature 0.8 --top-p 0.95 --stream
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as lm
from repro.serve import (
    ContinuousBatchingEngine,
    ServeConfig,
    generate_lockstep,
    lockstep_waves,
    poisson_workload,
)


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4, help="slot capacity B")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=0,
                    help="requests to serve (default: one per slot)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals per engine tick (0 = all at t=0)")
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--token-budget", type=int, default=0)
    ap.add_argument("--engine", choices=("paged", "continuous", "lockstep"),
                    default="continuous")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV page (paged engine)")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="page-pool size (0 = contiguous-parity pool)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus mass (1.0 = off)")
    ap.add_argument("--preempt", choices=("auto", "swap", "recompute"),
                    default="auto",
                    help="pool-exhaustion policy (paged engine)")
    ap.add_argument("--attn-kernel", action="store_true",
                    help="Pallas paged-attention kernel: read K/V pages "
                    "in place via the block table (paged engine only)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens proposed per "
                    "decode slot per step (0 = off)")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="drafter depth for speculative decoding (0 = "
                    "self-draft with the target model)")
    ap.add_argument("--stream", action="store_true",
                    help="print token events as they are emitted")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    return ap


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)
    rng = jax.random.PRNGKey(args.seed)
    n_requests = args.requests or args.batch
    max_seq = args.prompt_len + args.gen + (cfg.n_patches or 0)

    with jax.set_mesh(mesh):
        params = lm.init_params(cfg, rng)
        reqs = poisson_workload(
            cfg,
            n_requests=n_requests,
            arrival_rate=args.arrival_rate or 1e9,  # 0 -> everything at t=0
            prompt_len=args.prompt_len,
            gen_len=args.gen,
            seed=args.seed,
            uniform_prompts=True,
            temperature=args.temperature,
            top_k=args.top_k,
            top_p=args.top_p,
        )

        if args.engine == "lockstep":
            # equal capacity with the continuous engine: static waves of
            # --batch requests in arrival order, each stalling on its
            # longest generation.
            steps = gen_tokens = 0
            prefill_s = decode_s = 0.0
            tokens_by_rid = {}
            for wave in lockstep_waves(reqs, args.batch):
                out = generate_lockstep(
                    cfg, params,
                    np.stack([r.prompt for r in wave]),
                    [r.max_new_tokens for r in wave],
                    max_seq=max_seq,
                    frames=np.stack([r.frames for r in wave])
                    if cfg.family == "encdec"
                    else None,
                    sampling=[r.sampling for r in wave],
                )
                steps += out["steps"]
                gen_tokens += out["generated_tokens"]
                prefill_s += out["prefill_s"]
                decode_s += out["decode_s"]
                for r, toks in zip(wave, out["tokens"], strict=True):
                    tokens_by_rid[r.rid] = toks
            gen = np.stack([tokens_by_rid[r.rid] for r in reqs])
            return {
                "generated": gen,
                "steps": steps,
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "tokens_per_s": gen_tokens / max(prefill_s + decode_s, 1e-9),
                "slot_utilization": 1.0,
            }

        paged = args.engine == "paged"
        draft_cfg = draft_params = None
        if args.spec_k and args.draft_layers:
            draft_cfg = cfg.reduced(n_layers=args.draft_layers)
            draft_params = lm.init_params(draft_cfg, jax.random.PRNGKey(args.seed + 1))
        engine = ContinuousBatchingEngine(
            cfg,
            params,
            ServeConfig(
                max_slots=args.batch,
                max_seq=max_seq,
                prefill_chunk=args.prefill_chunk,
                token_budget=args.token_budget,
                block_size=args.block_size if paged else 0,
                n_blocks=args.n_blocks if paged else 0,
                attn_kernel=args.attn_kernel,
                preempt=args.preempt,
                spec_k=args.spec_k,
            ),
            mesh=mesh,
            draft_cfg=draft_cfg,
            draft_params=draft_params,
        )
        for r in reqs:
            engine.submit(r)
        on_token = None
        if args.stream:
            def on_token(ev):
                tail = " <eos>" if ev.is_last else ""
                print(f"[stream] rid={ev.rid} token={ev.token}{tail}")
        results = engine.run(on_token=on_token)
        stats = engine.stats()

    gen = np.stack([results[r.rid] for r in reqs])
    return {
        "generated": gen,
        "steps": stats["compute_steps"],
        "prefill_s": stats["prefill_s"],
        "decode_s": stats["decode_s"],
        "tokens_per_s": stats["generated_tokens"]
        / max(stats["prefill_s"] + stats["decode_s"], 1e-9),
        "tokens_per_step": stats["tokens_per_step"],
        "slot_utilization": stats["slot_utilization"],
        "peak_concurrency": stats["peak_concurrency"],
        "preemptions": stats["preemptions"],
        "swap_preemptions": stats["swap_preemptions"],
        "recompute_preemptions": stats["recompute_preemptions"],
        "spec_proposed": stats["spec_proposed"],
        "spec_accepted": stats["spec_accepted"],
        "acceptance_rate": stats["acceptance_rate"],
        "draft_steps": stats["draft_steps"],
    }


def main():
    args = build_parser().parse_args()
    out = run(args)
    print(f"[serve] engine={args.engine} slots={args.batch} gen={args.gen} "
          f"steps={out['steps']}")
    print(f"[serve] prefill {out['prefill_s']*1e3:.0f} ms, decode {out['decode_s']*1e3:.0f} ms"
          f" ({out['tokens_per_s']:.1f} tok/s, "
          f"slot util {out['slot_utilization']*100:.0f}%)")
    if "preemptions" in out:
        print(f"[serve] peak concurrency {out['peak_concurrency']}, "
              f"preemptions {out['preemptions']} "
              f"(swap {out['swap_preemptions']}, "
              f"recompute {out['recompute_preemptions']})")
    if args.spec_k and "spec_proposed" in out:
        print(f"[serve] speculative: accepted {out['spec_accepted']}"
              f"/{out['spec_proposed']} draft tokens "
              f"({out['acceptance_rate']*100:.0f}%), "
              f"{out['draft_steps']} draft steps")
    print("[serve] first request tokens:", out["generated"][0][:16].tolist())


if __name__ == "__main__":
    main()
