"""Fault-tolerant LM training driver (end-to-end, any --arch).

Wires together: config registry → synthetic data pipeline → sharded
params/optimizer → a resolved ssProp **policy program** (per-site rules
× schedule; the paper's bar schedule compiles to two executables: dense
epoch / sparse epoch) → async checkpointing → heartbeat + restart
policy. On restart it resumes from the latest committed checkpoint; the
pure-function-of-step data pipeline makes the replay exact.

The sparsity control surface is ONE object: a
:class:`repro.core.policy.PolicyProgram` built from ``--rules`` (the
``pattern=rate;...`` mini-grammar over the model's site names, see
``docs/policies.md``) and ``--scheduler``; the loop just asks
``resolved.policies_for_step(step)``.

**Multi-process mode** (``--coord-dir`` + ``--world-size N`` +
``--rank r``): every rank runs this driver as its own OS process
against a shared coordination directory. Each rank heartbeats, the
leader (lowest active rank) runs the :class:`FleetSupervisor` poll,
and every step is guarded by a membership-epoch check — a stale rank
is evicted (epoch bump), survivors abort with ``MembershipChanged``
and restart resharded from the last committed checkpoint, and a
relaunched rank rejoins through the un-evict protocol. Checkpoints
are **per-host sharded**: each rank writes only ``shard_<r>.msgpack``
and the leader commits once every active peer's shard lands.

Compute is replicated across ranks (every rank steps the full global
batch): loss trajectories are bit-identical at any fleet size, which
is what lets the chaos tests assert kill → shrink → rejoin leaves the
trajectory exactly equal to an uninterrupted run. The *distributed*
state — membership epochs, shard plans, commit barriers — is the real
multi-host protocol. See ``docs/distributed.md``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --reduced --steps 50 --ckpt-dir /tmp/run1
  # per-site: first/last layer dense, attention at 0.5, the rest at 0.8
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --reduced --steps 50 --no-scan-layers \
      --rules 'layer_{0,-1}/*=dense;*/attn/*=0.5;*=0.8'
  # crash/resume: re-running the same command continues from the latest
  # checkpoint.
  # 4-rank fleet on one machine (each line its own process):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --reduced --steps 50 --ckpt-dir /tmp/fleet/ckpt \
      --coord-dir /tmp/fleet --world-size 4 --rank 0  # ... rank 1..3
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.policy import PolicyProgram, PolicyRules, paper_default, tpu_default
from repro.core.schedulers import make_schedule
from repro.data.pipeline import TokenPipeline, TokenPipelineConfig
from repro.dist import sharding as shd
from repro.dist import compat as dist_compat
from repro.dist.fault import (
    FleetSupervisor,
    Heartbeat,
    HeartbeatThread,
    RestartPolicy,
    StragglerSupervisor,
)
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import model as lm
from repro.optim import adam


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--drop-rate", type=float, default=0.8)
    ap.add_argument("--scheduler", default="epoch_bar")
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--period", type=int, default=100,
                    help="periodic_bar scheduler period (iterations)")
    ap.add_argument("--granularity", choices=["channel", "block"], default="channel")
    ap.add_argument("--rules", default="",
                    help="per-site rules 'pattern=rate;...' over the model's "
                         "site names (rate may be 'dense'); empty = one "
                         "global rule at --drop-rate")
    ap.add_argument("--no-scan-layers", action="store_true",
                    help="unroll the layer stack (required for per-depth "
                         "rules like layer_{0,-1}/*)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash once (fault-tolerance demo/test)")
    # multi-process fleet (see module docstring / docs/distributed.md)
    ap.add_argument("--coord-dir", default="",
                    help="shared coordination dir; with --world-size > 1 "
                         "enables the rank-complete fault protocol and "
                         "per-host sharded checkpoints")
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--world-size", type=int, default=1)
    ap.add_argument("--hb-interval", type=float, default=1.0,
                    help="seconds between heartbeat touches")
    ap.add_argument("--hb-timeout", type=float, default=5.0,
                    help="heartbeat staleness before eviction")
    ap.add_argument("--commit-timeout", type=float, default=30.0,
                    help="leader wait for peers' checkpoint shards")
    ap.add_argument("--rejoin-timeout", type=float, default=60.0,
                    help="evicted rank's wait to be re-admitted")
    ap.add_argument("--step-delay", type=float, default=0.0,
                    help="sleep per step (chaos tests: stretch the run "
                         "so a kill lands mid-training)")
    return ap


def build_program(args, base_policy) -> PolicyProgram:
    """The one control surface: rules (site patterns) × schedule."""
    schedule = make_schedule(
        args.scheduler,
        target=args.drop_rate,
        total_steps=args.steps,
        steps_per_epoch=args.steps_per_epoch,
        period=args.period,
        rate_buckets=base_policy.rate_buckets,
    )
    if args.rules:
        rules = PolicyRules.parse(args.rules, base=base_policy)
    else:
        rules = PolicyRules.single(base_policy)
    return PolicyProgram(rules=rules, schedule=schedule)


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if getattr(args, "no_scan_layers", False):
        cfg = dataclasses.replace(cfg, scan_layers=False)
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)

    pipe = TokenPipeline(
        TokenPipelineConfig(cfg.vocab, args.seq_len, args.global_batch, args.seed)
    )

    base_policy = (
        paper_default(args.drop_rate)
        if args.granularity == "channel"
        else tpu_default(args.drop_rate)
    )
    program = build_program(args, base_policy)
    sites, depth = lm.site_names(cfg)
    resolved = program.resolve(sites, depth=depth)
    opt_cfg = adam.AdamConfig(lr=args.lr, clip_norm=1.0, total_steps=args.steps)

    a_params, _ = steps_lib.abstract_state(cfg)
    p_sh = shd.param_shardings(mesh, a_params)
    opt_sh = shd.opt_state_shardings(mesh, a_params)

    # one compiled executable per schedule bucket (paper: 2 for epoch_bar);
    # the per-step SitePolicies table is the cache key, so per-site
    # programs cost no extra retraces beyond the schedule's buckets.
    step_cache = {}

    def get_step(step: int):
        table = resolved.policies_for_step(step)
        if table not in step_cache:
            fn = steps_lib.make_train_step(cfg, table, opt_cfg)
            step_cache[table] = jax.jit(fn, donate_argnums=(0, 1))
        return step_cache[table]

    ckpt_dir = args.ckpt_dir
    rank = getattr(args, "rank", 0)
    world = getattr(args, "world_size", 1)
    coord_dir = getattr(args, "coord_dir", "")
    multi = bool(coord_dir) and world > 1

    sup = None
    loss_log = None
    if coord_dir:
        # per-rank loss log (jsonl, append-only): replayed steps after a
        # restart append AGAIN, so readers take the LAST occurrence of a
        # step — exactly the value an uninterrupted run would have
        os.makedirs(os.path.join(coord_dir, "loss"), exist_ok=True)
        loss_log = os.path.join(coord_dir, "loss", f"rank_{rank:05d}.jsonl")
    if multi:
        # background beater: heartbeat = PROCESS liveness, so a rank
        # stuck in a long XLA compile is not falsely evicted while a
        # SIGKILLed one is detected within --hb-timeout
        hb = Heartbeat(
            os.path.join(coord_dir, "hb"), rank=rank,
            interval_s=args.hb_interval,
        )
        HeartbeatThread(hb).start()
        dist_compat.initialize(
            coord_dir, process_id=rank, num_processes=world,
            timeout_s=args.rejoin_timeout,
        )
        sup = FleetSupervisor(coord_dir, world, timeout_s=args.hb_timeout)
    else:
        hb = Heartbeat(os.path.join(ckpt_dir, "hb"), rank=0) if ckpt_dir else None
    strag = StragglerSupervisor()
    restart_policy = RestartPolicy(max_restarts=3, backoff_s=0.1)
    history = []
    injected = {"done": False}

    def log_loss(step: int, loss: float) -> None:
        if loss_log:
            with open(loss_log, "a") as f:
                f.write(json.dumps({"step": step, "loss": loss}) + "\n")

    def attempt(attempt_idx: int):
        # Evicted stragglers stay out of the fleet across restarts. A
        # single-host run only beats rank 0 (which can never straggle —
        # it is its own baseline), but a multi-host attempt would size
        # its data split around the survivors here.
        if restart_policy.excluded_ranks:
            print(f"[train] resharding around ranks {restart_policy.excluded_ranks}")
        membership = None
        active = [rank]
        if multi:
            membership = sup.view.read()
            if rank not in membership.active:
                # we were evicted (crash, stall, ...) — file a rejoin
                # request and wait for the supervisor to re-admit us
                sup.request_rejoin(rank)
                print(f"[train] rank {rank} evicted; requesting rejoin")
                membership = sup.wait_active(
                    rank, timeout_s=args.rejoin_timeout
                )
            active = list(membership.active)
            print(
                f"[train] rank {rank} attempt {attempt_idx}: "
                f"epoch {membership.epoch} active={active}"
            )
        saver = None
        if ckpt_dir:
            saver = ckpt_lib.AsyncCheckpointer(
                ckpt_dir,
                rank=rank,
                ranks=active if multi else None,
                commit_timeout_s=args.commit_timeout,
            )
        with jax.set_mesh(mesh):
            params = jax.jit(
                lambda r: lm.init_params(cfg, r), out_shardings=p_sh
            )(jax.random.PRNGKey(args.seed))
            opt_state = adam.AdamState(
                step=jnp.zeros((), jnp.int32),
                m=jax.jit(lambda p: jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    out_shardings=opt_sh)(params),
                v=jax.jit(lambda p: jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    out_shardings=opt_sh)(params),
            )
            start = 0
            if ckpt_dir:
                latest = ckpt_lib.latest_step(ckpt_dir)
                if latest is not None:
                    state = ckpt_lib.restore(
                        ckpt_dir, latest,
                        {"params": params, "m": opt_state.m, "v": opt_state.v},
                        shardings={"params": p_sh, "m": opt_sh, "v": opt_sh},
                    )
                    params = state["params"]
                    opt_state = adam.AdamState(
                        jnp.asarray(latest, jnp.int32), state["m"], state["v"]
                    )
                    start = latest
                    print(f"[train] resumed from step {latest}")

            for step in range(start, args.steps):
                if multi:
                    if sup.should_poll(rank):
                        sup.poll()
                    # abort + reshard if the fleet changed under us
                    membership = sup.check_epoch(membership.epoch)
                if step == args.fail_at_step and not injected["done"]:
                    injected["done"] = True
                    raise RuntimeError("injected failure (fault-tolerance test)")
                if args.step_delay > 0:
                    time.sleep(args.step_delay)
                fn = get_step(step)
                rate = program.schedule.rate(step)
                batch = jax.tree.map(jnp.asarray, pipe.batch_at(step))
                t0 = time.time()
                params, opt_state, metrics = fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                strag.record(rank, dt)
                strag.check(excluded=restart_policy.excluded_ranks)
                if hb:
                    hb.beat()
                history.append(loss)
                log_loss(step, loss)
                if step % args.log_every == 0 or step == args.steps - 1:
                    print(
                        f"[train] step {step:5d} rate={rate:.2f} "
                        f"loss={loss:.4f} ({dt*1e3:.0f} ms)"
                    )
                if saver and (step + 1) % args.ckpt_every == 0:
                    saver.save(
                        step + 1,
                        {"params": params, "m": opt_state.m, "v": opt_state.v},
                    )
            if saver:
                saver.wait()
                if saver.last_error is not None:
                    # a failed FINAL save must not report success — mid-run
                    # save errors (e.g. a torn commit after a peer died)
                    # surface on the next attempt's restore instead
                    raise saver.last_error
        return {"history": history, "final_loss": history[-1] if history else None}

    out = restart_policy.run(
        attempt,
        on_restart=lambda i, e: print(f"[train] restart {i}: {e}"),
        on_evict=lambda r, e: print(f"[train] evicted straggler rank {r}: {e}"),
        on_reshard=lambda m: print(
            f"[train] rank {rank} resharding to epoch {m.epoch} "
            f"active={list(m.active)}"
        ),
    )
    if coord_dir:
        # durable completion marker for the multi-process harness
        os.makedirs(os.path.join(coord_dir, "done"), exist_ok=True)
        done = os.path.join(coord_dir, "done", f"rank_{rank:05d}.json")
        tmp = f"{done}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"rank": rank, "final_loss": out["final_loss"],
                       "steps": args.steps}, f)
        os.replace(tmp, done)
    return out


def main():
    args = build_parser().parse_args()
    out = run(args)
    if out["final_loss"] is None:
        print("[train] nothing to do: already at the target step")
    else:
        print(f"[train] done. final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
