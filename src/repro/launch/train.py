"""Fault-tolerant LM training driver (end-to-end, any --arch).

Wires together: config registry → synthetic data pipeline → sharded
params/optimizer → a resolved ssProp **policy program** (per-site rules
× schedule; the paper's bar schedule compiles to two executables: dense
epoch / sparse epoch) → async checkpointing → heartbeat + restart
policy. On restart it resumes from the latest committed checkpoint; the
pure-function-of-step data pipeline makes the replay exact.

The sparsity control surface is ONE object: a
:class:`repro.core.policy.PolicyProgram` built from ``--rules`` (the
``pattern=rate;...`` mini-grammar over the model's site names, see
``docs/policies.md``) and ``--scheduler``; the loop just asks
``resolved.policies_for_step(step)``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --reduced --steps 50 --ckpt-dir /tmp/run1
  # per-site: first/last layer dense, attention at 0.5, the rest at 0.8
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --reduced --steps 50 --no-scan-layers \
      --rules 'layer_{0,-1}/*=dense;*/attn/*=0.5;*=0.8'
  # crash/resume: re-running the same command continues from the latest
  # checkpoint.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.policy import PolicyProgram, PolicyRules, paper_default, tpu_default
from repro.core.schedulers import make_schedule
from repro.data.pipeline import TokenPipeline, TokenPipelineConfig
from repro.dist import sharding as shd
from repro.dist.fault import Heartbeat, RestartPolicy, StragglerSupervisor
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import model as lm
from repro.optim import adam


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--drop-rate", type=float, default=0.8)
    ap.add_argument("--scheduler", default="epoch_bar")
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--period", type=int, default=100,
                    help="periodic_bar scheduler period (iterations)")
    ap.add_argument("--granularity", choices=["channel", "block"], default="channel")
    ap.add_argument("--rules", default="",
                    help="per-site rules 'pattern=rate;...' over the model's "
                         "site names (rate may be 'dense'); empty = one "
                         "global rule at --drop-rate")
    ap.add_argument("--no-scan-layers", action="store_true",
                    help="unroll the layer stack (required for per-depth "
                         "rules like layer_{0,-1}/*)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash once (fault-tolerance demo/test)")
    return ap


def build_program(args, base_policy) -> PolicyProgram:
    """The one control surface: rules (site patterns) × schedule."""
    schedule = make_schedule(
        args.scheduler,
        target=args.drop_rate,
        total_steps=args.steps,
        steps_per_epoch=args.steps_per_epoch,
        period=args.period,
        rate_buckets=base_policy.rate_buckets,
    )
    if args.rules:
        rules = PolicyRules.parse(args.rules, base=base_policy)
    else:
        rules = PolicyRules.single(base_policy)
    return PolicyProgram(rules=rules, schedule=schedule)


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if getattr(args, "no_scan_layers", False):
        cfg = dataclasses.replace(cfg, scan_layers=False)
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)

    pipe = TokenPipeline(
        TokenPipelineConfig(cfg.vocab, args.seq_len, args.global_batch, args.seed)
    )

    base_policy = (
        paper_default(args.drop_rate)
        if args.granularity == "channel"
        else tpu_default(args.drop_rate)
    )
    program = build_program(args, base_policy)
    sites, depth = lm.site_names(cfg)
    resolved = program.resolve(sites, depth=depth)
    opt_cfg = adam.AdamConfig(lr=args.lr, clip_norm=1.0, total_steps=args.steps)

    a_params, _ = steps_lib.abstract_state(cfg)
    p_sh = shd.param_shardings(mesh, a_params)
    opt_sh = shd.opt_state_shardings(mesh, a_params)

    # one compiled executable per schedule bucket (paper: 2 for epoch_bar);
    # the per-step SitePolicies table is the cache key, so per-site
    # programs cost no extra retraces beyond the schedule's buckets.
    step_cache = {}

    def get_step(step: int):
        table = resolved.policies_for_step(step)
        if table not in step_cache:
            fn = steps_lib.make_train_step(cfg, table, opt_cfg)
            step_cache[table] = jax.jit(fn, donate_argnums=(0, 1))
        return step_cache[table]

    ckpt_dir = args.ckpt_dir
    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    hb = Heartbeat(os.path.join(ckpt_dir, "hb"), rank=0) if ckpt_dir else None
    strag = StragglerSupervisor()
    restart_policy = RestartPolicy(max_restarts=3, backoff_s=0.1)
    history = []
    injected = {"done": False}

    def attempt(attempt_idx: int):
        # Evicted stragglers stay out of the fleet across restarts. A
        # single-host run only beats rank 0 (which can never straggle —
        # it is its own baseline), but a multi-host attempt would size
        # its data split around the survivors here.
        if restart_policy.excluded_ranks:
            print(f"[train] resharding around ranks {restart_policy.excluded_ranks}")
        with jax.set_mesh(mesh):
            params = jax.jit(
                lambda r: lm.init_params(cfg, r), out_shardings=p_sh
            )(jax.random.PRNGKey(args.seed))
            opt_state = adam.AdamState(
                step=jnp.zeros((), jnp.int32),
                m=jax.jit(lambda p: jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    out_shardings=opt_sh)(params),
                v=jax.jit(lambda p: jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    out_shardings=opt_sh)(params),
            )
            start = 0
            if ckpt_dir:
                latest = ckpt_lib.latest_step(ckpt_dir)
                if latest is not None:
                    state = ckpt_lib.restore(
                        ckpt_dir, latest,
                        {"params": params, "m": opt_state.m, "v": opt_state.v},
                        shardings={"params": p_sh, "m": opt_sh, "v": opt_sh},
                    )
                    params = state["params"]
                    opt_state = adam.AdamState(
                        jnp.asarray(latest, jnp.int32), state["m"], state["v"]
                    )
                    start = latest
                    print(f"[train] resumed from step {latest}")

            for step in range(start, args.steps):
                if step == args.fail_at_step and not injected["done"]:
                    injected["done"] = True
                    raise RuntimeError("injected failure (fault-tolerance test)")
                fn = get_step(step)
                rate = program.schedule.rate(step)
                batch = jax.tree.map(jnp.asarray, pipe.batch_at(step))
                t0 = time.time()
                params, opt_state, metrics = fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                strag.record(0, dt)
                strag.check(excluded=restart_policy.excluded_ranks)
                if hb:
                    hb.beat()
                history.append(loss)
                if step % args.log_every == 0 or step == args.steps - 1:
                    print(
                        f"[train] step {step:5d} rate={rate:.2f} "
                        f"loss={loss:.4f} ({dt*1e3:.0f} ms)"
                    )
                if saver and (step + 1) % args.ckpt_every == 0:
                    saver.save(
                        step + 1,
                        {"params": params, "m": opt_state.m, "v": opt_state.v},
                    )
            if saver:
                saver.wait()
        return {"history": history, "final_loss": history[-1] if history else None}

    return restart_policy.run(
        attempt,
        on_restart=lambda i, e: print(f"[train] restart {i}: {e}"),
        on_evict=lambda r, e: print(f"[train] evicted straggler rank {r}: {e}"),
    )


def main():
    args = build_parser().parse_args()
    out = run(args)
    if out["final_loss"] is None:
        print("[train] nothing to do: already at the target step")
    else:
        print(f"[train] done. final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
