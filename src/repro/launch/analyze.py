"""Static program auditor CLI (``python -m repro.launch.analyze``).

Runs the :mod:`repro.analysis` checks for one config — nothing is
executed or compiled, only traced and walked:

* **savings** — per-site jaxpr-measured backward FLOPs vs the analytic
  tables (``core/flops.py``), exact;
* **lints** — f32 contractions inside ``bwd_dtype="bfloat16"`` regions,
  host callbacks in jitted programs, dead contraction FLOPs
  (``--step-lint`` walks the full gradient-accumulation train step);
* **retrace** — compiled-executable budgets for the policy program and
  (``--serve``) the serving engine's width ladder;
* **pallas** — in-bounds / divisibility / VMEM / traffic checks of the
  kernel launch geometries the config would use.

Examples::

    python -m repro.launch.analyze --arch qwen2.5-3b --reduced --serve
    python -m repro.launch.analyze --model resnet18 --image 3,32,32 \
        --batch 8 --use-pallas --granularity block
    python -m repro.launch.analyze --arch mamba2-1.3b --reduced \
        --step-lint --json report.json

Exit status is non-zero iff any check errored (see docs/analysis.md for
the check and tolerance semantics).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import pallas_check, retrace, savings
from repro.analysis.jaxpr_walk import count as jaxpr_count
from repro.analysis.lints import lint_step_counts
from repro.analysis.report import INFO, Report
from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.core import flops as ftab
from repro.core.policy import PolicyProgram, PolicyRules, SsPropPolicy
from repro.core.schedulers import make_schedule

CONV_MODELS = ("resnet18", "resnet34", "resnet50", "ddpm")


def build_parser():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    tgt = ap.add_mutually_exclusive_group(required=True)
    tgt.add_argument("--arch", choices=ARCH_IDS,
                     help="transformer-family config to audit")
    tgt.add_argument("--model", choices=CONV_MODELS,
                     help="conv model to audit")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--image", default="3,32,32",
                    help="conv input C,H,W (with --model)")
    ap.add_argument("--batch", type=int, default=8,
                    help="conv batch size (with --model)")
    ap.add_argument("--drop-rate", type=float, default=0.8)
    ap.add_argument("--granularity", choices=["channel", "block"],
                    default="block")
    ap.add_argument("--block-size", type=int, default=128)
    ap.add_argument("--use-pallas", action="store_true",
                    help="audit the Pallas kernel routes")
    ap.add_argument("--bwd-dtype", choices=["", "bfloat16"], default="",
                    help="audit with bf16 backward contractions (and lint "
                    "that no f32 contraction leaks in)")
    ap.add_argument("--rules", default="",
                    help="per-site rules 'pattern=rate;...' (train.py "
                    "grammar)")
    ap.add_argument("--scheduler", default="epoch_bar")
    ap.add_argument("--step-lint", action="store_true",
                    help="trace the full train step and lint it "
                    "(callbacks, dead FLOPs)")
    ap.add_argument("--serve", action="store_true",
                    help="audit the serve plane: retrace budget + paged "
                    "attention kernel geometry")
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--spec-k", type=int, default=0)
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="KV page size for the paged-attention check")
    ap.add_argument("--json", default="", help="write findings JSON here")
    ap.add_argument("--verbose", action="store_true",
                    help="render info findings too")
    return ap


def build_program(args) -> PolicyProgram:
    base = SsPropPolicy(
        drop_rate=args.drop_rate,
        target_rate=args.drop_rate,
        granularity=args.granularity,
        block_size=args.block_size,
        use_pallas=args.use_pallas,
        bwd_dtype=args.bwd_dtype,
    )
    rules = (
        PolicyRules.parse(args.rules, base)
        if args.rules
        else PolicyRules.single(base)
    )
    schedule = make_schedule(args.scheduler, target=args.drop_rate)
    return PolicyProgram(rules=rules, schedule=schedule)


def _conv_geometries(model: str, image, batch: int):
    if model == "ddpm":
        from repro.models import ddpm

        return list(ddpm.iter_conv_shapes(image))
    from repro.models import resnet

    return list(resnet.iter_conv_shapes(model, image))


def analyze_conv(args) -> list[Report]:
    image = tuple(int(v) for v in args.image.split(","))
    program = build_program(args)
    geoms = _conv_geometries(args.model, image, args.batch)
    sites = [g[0] for g in geoms]
    table = program.resolve(sites).peak()

    sav = Report(f"savings:{args.model}")
    pal = Report(f"pallas:{args.model}")
    for site, c_in, c_out, k, h_out, w_out in geoms:
        pol = table[site]
        savings.audit_conv_site(
            sav, site, args.batch, h_out, w_out, c_in, c_out, k, pol
        )
        if ftab._conv_fused_route(
            args.batch, h_out, w_out, c_in, c_out, k, pol, 1
        ):
            pallas_check.check_conv_fused_site(
                pal, site, args.batch, h_out, w_out, c_in, c_out, k, pol
            )
    ret = Report(f"retrace:{args.model}")
    retrace.check_train_retrace(ret, program, sites)
    return [sav, pal, ret]


def analyze_lm(args) -> list[Report]:
    from repro.launch import steps as steps_lib
    from repro.models import model as lm

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    program = build_program(args)
    sites, depth = lm.site_names(cfg)
    table = program.resolve(sites, depth=depth).peak()

    reports = [
        savings.audit_lm(
            cfg, table, batch=args.global_batch, seq=args.seq_len
        )
    ]
    ret = Report(f"retrace:{cfg.name}")
    retrace.check_train_retrace(ret, program, sites, depth=depth)
    if args.serve:
        from repro.serve.scheduler import ServeConfig

        serve_cfg = ServeConfig(
            max_slots=args.global_batch,
            max_seq=args.seq_len,
            prefill_chunk=args.prefill_chunk,
            spec_k=args.spec_k,
            block_size=args.kv_block_size,
        )
        retrace.check_serve_retrace(ret, cfg, serve_cfg)
        pal = Report(f"pallas:{cfg.name}")
        nb = -(-args.seq_len // args.kv_block_size)
        pallas_check.check_paged_attention_site(
            pal,
            b=args.global_batch,
            s=args.prefill_chunk,
            h=cfg.n_heads,
            d=cfg.head_dim,
            n_pages=args.global_batch * nb,
            bs_pg=args.kv_block_size,
            kvh=cfg.n_kv_heads,
            nb=nb,
        )
        reports.append(pal)
    reports.append(ret)

    if args.step_lint:
        import jax

        from repro.data.pipeline import input_specs
        from repro.optim import adam

        shape = ShapeConfig("analyze", args.seq_len, args.global_batch, "train")
        fn = steps_lib.make_train_step(
            cfg, table, adam.AdamConfig(), accum=1
        )
        a_params, a_opt = steps_lib.abstract_state(cfg)
        batch = input_specs(cfg, shape)
        closed = jax.make_jaxpr(fn)(a_params, a_opt, batch)
        counts = jaxpr_count(closed, name="train_step")
        step = Report(f"step:{cfg.name}")
        lint_step_counts(step, "train_step", counts)
        step.add(
            "savings",
            INFO,
            "train_step",
            f"whole-step contraction FLOPs in [{counts.flops_lo:,}, "
            f"{counts.flops_hi:,}]",
            flops_lo=counts.flops_lo,
            flops_hi=counts.flops_hi,
        )
        reports.append(step)
    return reports


def main(argv=None):
    args = build_parser().parse_args(argv)
    reports = analyze_conv(args) if args.model else analyze_lm(args)
    for rep in reports:
        print(rep.render(verbose=args.verbose))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                [json.loads(rep.to_json()) for rep in reports], f, indent=2
            )
    n_err = sum(len(rep.errors()) for rep in reports)
    print(f"analyze: {n_err} error(s) across {len(reports)} report(s)")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
