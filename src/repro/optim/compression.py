"""Top-k gradient compression with error feedback (DP collective lever).

At 1000+-node scale the DP all-reduce of dense grads can dominate step
time. This module compresses each gradient tensor to its top-k magnitude
entries before the all-reduce and accumulates the residual locally
(error feedback, Stich et al. 2018) so the update stays unbiased over
time. Composes naturally with ssProp: ssProp already zeroes (1-D) of dW
rows, so the compressor's effective k captures most remaining mass.

Usage (inside the jitted train step, before psum/pmean over DP):
    cgrads, new_residual = compress_tree(grads, residual, ratio=0.01)
    # all-reduce cgrads (values are exact at kept coords, zero elsewhere)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def topk_compress(g: jax.Array, k: int) -> jax.Array:
    """Keep the k largest-|.| entries of g (flattened), zero the rest."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    k = max(1, min(k, n))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(g.shape)


def compress_tree(
    grads: Any, residual: Any, *, ratio: float = 0.01, min_size: int = 4096
) -> tuple[Any, Any]:
    """Error-feedback top-k over every leaf larger than ``min_size``.

    Returns (compressed_grads, new_residual). Small tensors (norms,
    biases) pass through uncompressed — their bytes are negligible and
    their precision matters.
    """

    def one(g, r):
        if g.size < min_size:
            return g, jnp.zeros_like(g)
        acc = g.astype(jnp.float32) + r
        k = max(1, int(g.size * ratio))
        kept = topk_compress(acc, k)
        return kept.astype(g.dtype), acc - kept

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r, strict=True)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
    )


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(params, ratio: float = 0.01, min_size: int = 4096) -> int:
    """Bytes on the wire after compression (values + int32 indices)."""
    total = 0
    for p in jax.tree.leaves(params):
        if p.size < min_size:
            total += p.size * p.dtype.itemsize
        else:
            k = max(1, int(p.size * ratio))
            total += k * (p.dtype.itemsize + 4)
    return total
