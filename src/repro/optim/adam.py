"""Adam/AdamW in pure JAX, with LR schedules and global-norm clipping.

State is a pytree mirror of the params (``m``/``v`` in fp32 regardless of
param dtype — bf16 moments diverge), plus a scalar step. ZeRO-1 sharding
of the moments is applied by the launcher via sharding constraints
(dist/sharding.py::zero1_spec); this module is distribution-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 2e-4  # paper's classification default
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # >0 -> AdamW (decoupled)
    clip_norm: float = 0.0  # 0 disables
    schedule: str = "constant"  # constant | cosine | warmup_cosine
    warmup_steps: int = 0
    total_steps: int = 0
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    lr = jnp.float32(cfg.lr)
    if cfg.schedule == "constant":
        return lr
    total = max(cfg.total_steps, 1)
    if cfg.schedule in ("cosine", "warmup_cosine"):
        warm = cfg.warmup_steps if cfg.schedule == "warmup_cosine" else 0
        warm_lr = lr * jnp.clip(s / max(warm, 1), 0.0, 1.0) if warm else lr
        prog = jnp.clip((s - warm) / max(total - warm, 1), 0.0, 1.0)
        cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warm, warm_lr, lr * cos)
    raise ValueError(f"unknown schedule {cfg.schedule}")


def init(params) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def apply_updates(
    cfg: AdamConfig, params, grads, state: AdamState
) -> tuple[Any, AdamState, dict]:
    """One Adam(W) step. Returns (new_params, new_state, metrics)."""
    gn = global_norm(grads)
    if cfg.clip_norm > 0:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_n = b1 * m + (1 - b1) * g32
        v_n = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_n / bc1
        vhat = v_n / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), m_n, v_n

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gn, "lr": lr}


def adamw(cfg: AdamConfig | None = None) -> AdamConfig:
    """The paper's generation-task optimizer (AdamW, default params)."""
    return cfg or AdamConfig(lr=1e-3, weight_decay=1e-2)
