"""Drop-rate schedules (paper Fig. 2(c)/(d)) — first-class objects.

A :class:`Schedule` maps the training step to a drop rate in
``[0, target]``. Schedules run in the *host* training loop (Python
floats), because the keep count K must be static under jit (see
``policy.py``); each schedule owns its own :meth:`~Schedule.rate`,
:meth:`~Schedule.average_rate` and bucket quantization, so the train
loop never touches raw rates — it asks a
:class:`~repro.core.policy.PolicyProgram` for the step's per-site
policies and the program asks the schedule.

The paper's winner is the **bar schedule with a 2-epoch period**
(:class:`EpochBar`): dense on even epochs, full target rate on odd
epochs — the average rate over training is ``target / 2`` (≈40% for the
80% target), matching the paper's "nearly 40% computation saved".

The registry :data:`SCHEDULES` maps the legacy string names to classes;
:func:`make_schedule` builds one from a name plus the run shape. The
module-level ``*_schedule`` functions and :func:`drop_rate_for_step` /
:func:`average_rate` remain as thin shims over the objects for older
call sites.
"""
from __future__ import annotations

import dataclasses
import math

_DEFAULT_BUCKETS = (0.0, 0.25, 0.5, 0.8, 0.95)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Base class: step → drop rate, plus bucket quantization.

    Attributes:
      target: the schedule's peak drop rate (e.g. 0.8 for the paper's
        bar schedule). ``rate(step)`` never exceeds it.
      rate_buckets: allowed compiled drop rates. :meth:`bucketed_rate`
        rounds the scheduled rate to the nearest bucket so the jit
        cache stays small — at most ``len(rate_buckets)`` distinct
        compiled steps per run, whatever the schedule's shape.
    """

    target: float = 0.8
    rate_buckets: tuple[float, ...] = _DEFAULT_BUCKETS

    def rate(self, step: int) -> float:
        """Raw scheduled drop rate at ``step`` (subclasses implement)."""
        raise NotImplementedError

    def bucketed_rate(self, step: int) -> float:
        """``rate(step)`` rounded to the nearest allowed bucket."""
        r = self.rate(step)
        return min(self.rate_buckets, key=lambda b: abs(b - r))

    def scale(self, step: int) -> float:
        """Activation fraction in [0, 1]: bucketed rate / target.

        This is what a :class:`~repro.core.policy.PolicyProgram` uses to
        modulate *per-site* target rates: every site runs at
        ``site_target * scale(step)``, so a bar schedule flips all sites
        between dense and their own targets in lock-step. Quantized
        through the schedule's buckets, so a whole run sees at most
        ``len(rate_buckets)`` distinct scales (and therefore at most
        that many compiled executables).
        """
        if self.target <= 0.0:
            return 0.0
        return min(self.bucketed_rate(step) / self.target, 1.0)

    def average_rate(self, total_steps: int) -> float:
        """Mean raw drop rate over ``total_steps`` (drives total-FLOPs
        accounting). Exact summation; subclasses with a closed form
        override."""
        if total_steps <= 0:
            return 0.0
        return sum(self.rate(s) for s in range(total_steps)) / total_steps


@dataclasses.dataclass(frozen=True)
class Constant(Schedule):
    """Fixed drop rate for the whole run (paper's 'constant' baseline)."""

    def rate(self, step: int) -> float:
        del step
        return self.target

    def average_rate(self, total_steps: int) -> float:
        return self.target if total_steps > 0 else 0.0


@dataclasses.dataclass(frozen=True)
class Linear(Schedule):
    """Ramp 0 → target linearly from first to last step."""

    total_steps: int = 100

    def rate(self, step: int) -> float:
        progress = step / max(self.total_steps - 1, 1)
        return self.target * min(max(progress, 0.0), 1.0)


@dataclasses.dataclass(frozen=True)
class Cosine(Schedule):
    """Ramp 0 → target with a cosine ease-in."""

    total_steps: int = 100

    def rate(self, step: int) -> float:
        progress = step / max(self.total_steps - 1, 1)
        p = min(max(progress, 0.0), 1.0)
        return self.target * 0.5 * (1.0 - math.cos(math.pi * p))


@dataclasses.dataclass(frozen=True)
class Bar(Schedule):
    """Step function: 0 for the first half of training, target after."""

    total_steps: int = 100

    def rate(self, step: int) -> float:
        progress = step / max(self.total_steps - 1, 1)
        return self.target if progress >= 0.5 else 0.0


@dataclasses.dataclass(frozen=True)
class EpochBar(Schedule):
    """The paper's best config: 2-epoch period bar.

    Epoch 0, 2, 4, ... train dense; epoch 1, 3, 5, ... train at the
    target rate. (Paper numbers epochs from 1 and trains normally in
    epochs 1, 3, 5 — identical parity pattern.) Over a whole run the
    average rate is ``target / 2`` — the paper's ~40% saving at 0.8.
    """

    steps_per_epoch: int = 1

    def rate(self, step: int) -> float:
        epoch = step // max(self.steps_per_epoch, 1)
        return self.target if (epoch % 2 == 1) else 0.0

    def average_rate(self, total_steps: int) -> float:
        # Closed form target/2 (the paper's saving claim) holds exactly
        # for whole 2-epoch periods; partial runs sum the true per-step
        # rates — a 1-epoch run trains entirely dense and must report 0.
        if total_steps <= 0:
            return 0.0
        if total_steps % (2 * max(self.steps_per_epoch, 1)) == 0:
            return self.target / 2.0
        return super().average_rate(total_steps)


@dataclasses.dataclass(frozen=True)
class PeriodicBar(Schedule):
    """Iteration-periodic bar (paper Fig. 2(d), 30–300-iteration periods).

    First half of each period dense, second half at target rate.
    """

    period: int = 100

    def __post_init__(self):
        if self.period <= 0:
            raise ValueError("period must be positive")

    def rate(self, step: int) -> float:
        return self.target if (step % self.period) >= (self.period // 2) else 0.0

    def average_rate(self, total_steps: int) -> float:
        if total_steps <= 0:
            return 0.0
        if total_steps % self.period == 0:
            sparse = self.period - self.period // 2
            return self.target * sparse / self.period
        return super().average_rate(total_steps)


SCHEDULES = {
    "constant": Constant,
    "linear": Linear,
    "cosine": Cosine,
    "bar": Bar,
    "epoch_bar": EpochBar,
    "periodic_bar": PeriodicBar,
}

SCHEDULE_NAMES = tuple(SCHEDULES)


def make_schedule(
    name: str,
    *,
    target: float,
    total_steps: int = 100,
    steps_per_epoch: int = 1,
    period: int = 100,
    rate_buckets: tuple[float, ...] = _DEFAULT_BUCKETS,
) -> Schedule:
    """Build a :class:`Schedule` from its legacy string name.

    Only the shape parameter the named schedule uses is consumed
    (``total_steps`` for linear/cosine/bar, ``steps_per_epoch`` for
    epoch_bar, ``period`` for periodic_bar).
    """
    try:
        cls = SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; known: {sorted(SCHEDULES)}"
        ) from None
    kw = {"target": target, "rate_buckets": rate_buckets}
    if cls in (Linear, Cosine, Bar):
        kw["total_steps"] = total_steps
    elif cls is EpochBar:
        kw["steps_per_epoch"] = steps_per_epoch
    elif cls is PeriodicBar:
        kw["period"] = period
    return cls(**kw)


# ----------------------------------------------------------------------
# legacy functional API — thin shims over the Schedule objects
# ----------------------------------------------------------------------


def constant_schedule(progress: float, target: float) -> float:
    del progress
    return target


def linear_schedule(progress: float, target: float) -> float:
    return target * min(max(progress, 0.0), 1.0)


def cosine_schedule(progress: float, target: float) -> float:
    p = min(max(progress, 0.0), 1.0)
    return target * 0.5 * (1.0 - math.cos(math.pi * p))


def bar_schedule(progress: float, target: float) -> float:
    return target if progress >= 0.5 else 0.0


def epoch_bar_schedule(epoch: int, target: float) -> float:
    return target if (epoch % 2 == 1) else 0.0


def periodic_bar_schedule(step: int, period: int, target: float) -> float:
    if period <= 0:
        raise ValueError("period must be positive")
    return target if (step % period) >= (period // 2) else 0.0


def drop_rate_for_step(
    scheduler: str,
    *,
    step: int,
    steps_per_epoch: int,
    total_steps: int,
    target: float,
    period: int = 0,
) -> float:
    """Legacy entry point: resolve one step's rate from a string name."""
    sched = make_schedule(
        scheduler,
        target=target,
        total_steps=total_steps,
        steps_per_epoch=steps_per_epoch,
        period=period,
    )
    return sched.rate(step)


def average_rate(
    scheduler: str,
    *,
    total_steps: int,
    steps_per_epoch: int,
    target: float,
    period: int = 0,
) -> float:
    """Legacy entry point: mean drop rate over a whole run."""
    sched = make_schedule(
        scheduler,
        target=target,
        total_steps=total_steps,
        steps_per_epoch=steps_per_epoch,
        period=period,
    )
    return sched.average_rate(total_steps)
