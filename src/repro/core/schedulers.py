"""Drop-rate schedulers (paper Fig. 2(c)/(d)).

All schedulers map training progress to a drop rate in ``[0, target]``.
They run in the *host* training loop (Python floats), because the keep
count K must be static under jit (see ``policy.py``). The paper's winner
is the **bar scheduler with a 2-epoch period** (``epoch_bar``): dense on
even epochs, full target rate on odd epochs — the average rate over
training is ``target / 2`` (≈40% for the 80% target), matching the
paper's "nearly 40% computation saved".
"""
from __future__ import annotations

import math


def constant_schedule(progress: float, target: float) -> float:
    """Fixed drop rate for the whole run (paper's 'constant' baseline)."""
    del progress
    return target


def linear_schedule(progress: float, target: float) -> float:
    """Ramp 0 → target linearly from first to last epoch."""
    return target * min(max(progress, 0.0), 1.0)


def cosine_schedule(progress: float, target: float) -> float:
    """Ramp 0 → target with a cosine ease-in."""
    p = min(max(progress, 0.0), 1.0)
    return target * 0.5 * (1.0 - math.cos(math.pi * p))


def bar_schedule(progress: float, target: float) -> float:
    """Step function: 0 for the first half of training, target after."""
    return target if progress >= 0.5 else 0.0


def epoch_bar_schedule(epoch: int, target: float) -> float:
    """The paper's best config: 2-epoch period bar.

    Epoch 0, 2, 4, ... train dense; epoch 1, 3, 5, ... train at the
    target rate. (Paper numbers epochs from 1 and trains normally in
    epochs 1, 3, 5 — identical parity pattern.)
    """
    return target if (epoch % 2 == 1) else 0.0


def periodic_bar_schedule(step: int, period: int, target: float) -> float:
    """Iteration-periodic bar (paper Fig. 2(d), 30–300-iteration periods).

    First half of each period dense, second half at target rate.
    """
    if period <= 0:
        raise ValueError("period must be positive")
    return target if (step % period) >= (period // 2) else 0.0


_SCHEDULES = {
    "constant": constant_schedule,
    "linear": linear_schedule,
    "cosine": cosine_schedule,
    "bar": bar_schedule,
}


def drop_rate_for_step(
    scheduler: str,
    *,
    step: int,
    steps_per_epoch: int,
    total_steps: int,
    target: float,
    period: int = 0,
) -> float:
    """Resolve the drop rate for one training step under any scheduler.

    ``epoch_bar`` keys on the epoch index; ``periodic_bar`` on the step
    index with an explicit ``period``; the remaining schedules key on
    fractional training progress.
    """
    if scheduler == "epoch_bar":
        epoch = step // max(steps_per_epoch, 1)
        return epoch_bar_schedule(epoch, target)
    if scheduler == "periodic_bar":
        return periodic_bar_schedule(step, period, target)
    try:
        fn = _SCHEDULES[scheduler]
    except KeyError:
        raise ValueError(f"unknown scheduler {scheduler!r}") from None
    progress = step / max(total_steps - 1, 1)
    return fn(progress, target)


def average_rate(
    scheduler: str,
    *,
    total_steps: int,
    steps_per_epoch: int,
    target: float,
    period: int = 0,
) -> float:
    """Mean drop rate over a whole run (drives total-FLOPs accounting)."""
    if total_steps <= 0:
        return 0.0
    acc = 0.0
    for s in range(total_steps):
        acc += drop_rate_for_step(
            scheduler,
            step=s,
            steps_per_epoch=steps_per_epoch,
            total_steps=total_steps,
            target=target,
            period=period,
        )
    return acc / total_steps
