"""The paper's backward-FLOPs model (Eq. 6-11), plus policy-aware counts.

Counting convention (paper, "Drop Rate Lower Bound"): each Add, Sub, Mul
or Div is one FLOP; sorting is comparisons only (0 FLOPs); the importance
reduction adds ``(Bt*H_out*W_out - 1) * C_out`` FLOPs.

The ``*_ssprop`` functions take the paper's nominal drop rate; the
``*_policy`` functions take an :class:`~repro.core.policy.SsPropPolicy`
and count what the backward engine *actually* executes: block
granularity rounds the keep count to whole ``block_size`` blocks, and
the Pallas gathered kernels pay for their 128-aligned tile padding.
The ``*_site`` functions are the per-site entry points: they accept a
resolved :class:`~repro.core.policy.SitePolicies` table plus the call
site's name, so a per-site policy program's total FLOPs are summed over
the resolved site table — each layer at its *own* keep count — rather
than one global rate.

Alongside FLOPs, :func:`conv_backward_bytes_policy` models the HBM
*traffic* of one conv backward — materializing-im2col vs the fused
Pallas kernels — and is both the roofline bytes-moved column and the
gate the engine uses to decide when fusing actually wins.

These formulas drive the benchmark tables (paper Tables 4-7), the conv
roofline rows, and the property test on the drop-rate lower bound
(Eq. 10-11).
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:
    from repro.core.policy import PolicyLike, SsPropPolicy


def _roundup(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def conv_backward_flops(
    bt: int, h_out: int, w_out: int, c_in: int, c_out: int, k: int
) -> int:
    """Eq. 6: backward FLOPs of one convolution, columnized form.

    ``(Bt*H_out*W_out) * (4*C_in*K^2 + 1) * C_out``
    """
    m = bt * h_out * w_out
    return m * (4 * c_in * k * k + 1) * c_out


def conv_backward_flops_ssprop(
    bt: int, h_out: int, w_out: int, c_in: int, c_out: int, k: int, drop_rate: float
) -> int:
    """Eq. 9 RHS: conv backward FLOPs with ssProp at ``drop_rate``.

    ``[(4MN + M)(1 - D) + M] * C_out`` with ``M = Bt*H_out*W_out`` and
    ``N = C_in*K^2``; the trailing ``M*C_out`` is the importance
    reduction overhead.
    """
    m = bt * h_out * w_out
    n = c_in * k * k
    return int(((4 * m * n + m) * (1.0 - drop_rate) + m) * c_out)


def batchnorm_backward_flops(bt: int, h: int, w: int, c: int) -> int:
    """Eq. 7: ``12*(Bt*H*W*C) + 10*C``."""
    return 12 * (bt * h * w * c) + 10 * c


def dropout_backward_flops(bt: int, h: int, w: int, c: int) -> int:
    """Eq. 8: ``2*(Bt*H*W*C)``."""
    return 2 * (bt * h * w * c)


def drop_rate_lower_bound(c_in: int, k: int) -> float:
    """Eq. 10: minimum drop rate that saves computation.

    ``D > 1 / (4*C_in*K^2 + 1)``; Eq. 11 notes this is <= ~3% for K>=3.
    """
    return 1.0 / (4 * c_in * k * k + 1)


def dense_backward_flops(m: int, d_in: int, d_out: int, bias: bool = True) -> int:
    """Backward FLOPs of ``Y[M, D_out] = X[M, D_in] @ W + b``.

    dX and dW are each a ``2*M*D_in*D_out`` FLOP matmul; the bias gradient
    is an ``M*D_out`` reduction. This is Eq. 6 with K=1 (a 1x1 conv), the
    form used for the transformer-projection extension (DESIGN.md §4).
    """
    f = 4 * m * d_in * d_out
    if bias:
        f += m * d_out
    return f


def dense_backward_flops_ssprop(
    m: int, d_in: int, d_out: int, drop_rate: float, bias: bool = True
) -> int:
    """ssProp dense backward: shrunk matmuls + importance reduction."""
    f = 4 * m * d_in * d_out * (1.0 - drop_rate)
    if bias:
        f += m * d_out * (1.0 - drop_rate)
    f += m * d_out  # importance reduction (Eq. 9's +M per channel)
    return int(f)


def kept_channels(c_out: int, policy: "SsPropPolicy") -> int:
    """Output channels whose gradients the engine actually computes.

    Channel granularity: the paper's ``max(1, round((1-D)*C))``. Block
    granularity: whole blocks, ``keep_count`` blocks × ``block_size``
    channels, capped at ``C`` — an upper bound when the ragged tail
    block is among the kept (its phantom slots are masked at runtime but
    the contraction is sized for the full block).
    """
    if not policy.active:
        return c_out
    if policy.granularity == "channel":
        return policy.keep_count(c_out)
    return min(c_out, policy.keep_count(c_out) * policy.block_size)


def gather_width(
    c_out: int, policy: "SsPropPolicy", n_shards: int = 1
) -> int:
    """The engine's true gathered contraction width (``Selection.k``).

    Unlike :func:`kept_channels` this is **not** capped at ``C``: with a
    ragged tail block the engine still gathers ``keep_count * block_size``
    columns (phantom slots zeroed by the ``valid`` mask), so the matmul
    is sized for whole blocks. Sharded selection (TP / grouped convs)
    keeps ``k_loc`` channels per shard with a shard-local block size —
    mirrored from :func:`repro.core.sparsity.shard_select_width` so the
    tables count exactly what the backward traces.
    """
    if n_shards > 1:
        from repro.core.sparsity import shard_select_width

        k_loc, _ = shard_select_width(c_out, policy, n_shards)
        return n_shards * k_loc
    if policy.granularity == "channel":
        return policy.keep_count(c_out)
    return policy.keep_count(c_out) * policy.block_size


def effective_drop_rate(c_out: int, policy: "SsPropPolicy") -> float:
    """The drop rate the backward actually realizes at ``c_out`` channels
    (block rounding makes this coarser than ``policy.drop_rate``)."""
    return 1.0 - kept_channels(c_out, policy) / c_out


def conv_backward_flops_policy(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "SsPropPolicy",
) -> int:
    """Eq. 9 with the engine's real keep counts instead of the nominal D.

    ``(4MN + M) * kept + M*C_out`` with ``M = Bt*H_out*W_out``,
    ``N = C_in*K^2`` and ``kept = kept_channels(C_out, policy)``. On the
    Pallas block path the two gathered matmuls run over 128-aligned
    padded tiles (M, N padded to 128; kept padded to whole blocks), so
    the 4MN term is counted at padded sizes — the honest cost of the
    TPU lowering, visible whenever shapes are misaligned.
    """
    m = bt * h_out * w_out
    n = c_in * k * k
    sdx, sdw = policy.sparsify_dx, policy.sparsify_dw
    if not policy.active or not (sdx or sdw):
        return conv_backward_flops(bt, h_out, w_out, c_in, c_out, k)
    kept = kept_channels(c_out, policy)
    # Eq. 6 decomposes per output element as 2N (dX) + 2N (dW) + 1 (db);
    # each side shrinks only when its sparsify_* flag is on.
    if policy.use_pallas and policy.granularity == "block":
        m_pad = _roundup(m, 128)
        n_pad = _roundup(n, 128)
        kept_pad = policy.keep_count(c_out) * policy.block_size
        gathered = 2 * m_pad * n_pad * kept_pad
        dx_term = gathered if sdx else 2 * m * n * c_out
        dw_term = gathered if sdw else 2 * m * n * c_out
    else:
        dx_term = 2 * m * n * (kept if sdx else c_out)
        dw_term = 2 * m * n * (kept if sdw else c_out)
    db_term = m * (kept if sdw else c_out)
    return int(dx_term + dw_term + db_term + m * c_out)


def dense_backward_flops_policy(
    m: int, d_in: int, d_out: int, policy: "SsPropPolicy", bias: bool = True
) -> int:
    """Dense analogue of :func:`conv_backward_flops_policy` (K=1 conv)."""
    sdx, sdw = policy.sparsify_dx, policy.sparsify_dw
    if not policy.active or not (sdx or sdw):
        return dense_backward_flops(m, d_in, d_out, bias=bias)
    kept = kept_channels(d_out, policy)
    if policy.use_pallas and policy.granularity == "block":
        m_pad = _roundup(m, 128)
        d_pad = _roundup(d_in, 128)
        kept_pad = policy.keep_count(d_out) * policy.block_size
        gathered = 2 * m_pad * d_pad * kept_pad
        dx_term = gathered if sdx else 2 * m * d_in * d_out
        dw_term = gathered if sdw else 2 * m * d_in * d_out
    else:
        dx_term = 2 * m * d_in * (kept if sdx else d_out)
        dw_term = 2 * m * d_in * (kept if sdw else d_out)
    f = dx_term + dw_term
    if bias:
        f += m * (kept if sdw else d_out)
    return int(f + m * d_out)


def _conv_fused_route(
    bt: int, h_out: int, w_out: int, c_in: int, c_out: int, k: int,
    policy: "SsPropPolicy", groups: int,
) -> bool:
    """Would the engine take the fused-im2col Pallas route for this conv?

    Replicates :meth:`repro.core.conv._ConvOp.fused_backward`'s gate:
    structural conditions (``fuse_im2col``, a real patch buffer to fuse
    away, whole blocks per group) plus the traffic-model min. The
    auditor needs the routing decision statically to predict which
    kernels appear in the jaxpr.
    """
    if not (
        policy.active
        and policy.use_pallas
        and policy.granularity == "block"
        and policy.fuse_im2col
        and k > 1
    ):
        return False
    if groups > 1 and c_out % (groups * policy.block_size) != 0:
        return False
    fus = conv_backward_bytes_policy(
        bt, h_out, w_out, c_in, c_out, k, policy, fused=True, groups=groups
    )
    mat = conv_backward_bytes_policy(
        bt, h_out, w_out, c_in, c_out, k, policy, fused=False, groups=groups
    )
    return fus < mat


def conv_backward_contraction_bounds(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "SsPropPolicy",
    *,
    groups: int = 1,
    h_pad: int = None,
) -> tuple:
    """Exact ``(lo, hi)`` *contraction* FLOPs of one conv backward.

    The jaxpr-auditable core of :func:`conv_backward_flops_policy`: only
    ``conv_general_dilated`` / ``dot_general`` / Pallas-kernel work — no
    bias reduction, no importance pass (those are elementwise and the
    walker doesn't count them). Groups-aware (``N_g = (C_in/G)*K²``),
    unlike the legacy per-site tables which predate grouped convs.

    ``lo == hi`` everywhere except the fused-im2col dX kernel, whose
    grid sweeps every *padded-image* row and masks invalid taps with
    ``pl.when`` — in the jaxpr that is a ``cond``, so the walker reports
    an interval: ``lo`` counts only valid grid steps (``B*H_out`` rows),
    ``hi`` the full grid (``B*H_pad`` rows). ``h_pad`` defaults to the
    stride-1 'SAME'-ish ``H_out + K - 1`` (the bytes model's
    convention); pass the true padded height for exact bounds.

    The invariant the hook-consistency test pins: on every non-fused
    route, ``conv_backward_flops_policy == lo + db_term + M*C_out`` for
    ``groups == 1``.
    """
    m = bt * h_out * w_out
    cg = c_in // groups
    n_g = cg * k * k
    full_side = 2 * m * n_g * c_out
    sdx, sdw = policy.sparsify_dx, policy.sparsify_dw
    if not policy.active or not (sdx or sdw) or policy.mask_mode:
        return (2 * full_side, 2 * full_side)

    # selection sharding mirrors _ConvOp.selection_shards: per-group
    # balance is structural; it subsumes a TP degree it doesn't divide.
    n_shards = (
        policy.tp_shards
        if policy.tp_shards > 1 and c_out % policy.tp_shards == 0
        else 1
    )
    if groups > 1 and (n_shards < groups or n_shards % groups != 0):
        n_shards = groups
    width = gather_width(c_out, policy, n_shards)
    gathered_side = 2 * m * n_g * width

    if (
        policy.use_pallas
        and policy.granularity == "block"
    ):
        bs = policy.block_size
        nb = -(-c_out // bs)
        if _conv_fused_route(bt, h_out, w_out, c_in, c_out, k, policy, groups):
            # Every dX dot sits under the kernel's pl.when(valid) — a
            # cond in the jaxpr — so the unconditional floor is the dW
            # kernel alone (lo), and the ceiling bills the dX grid's
            # full padded-row sweep (hi). The true cost, valid steps
            # only, is 2*M*N_g*kept_dx + dw_term, inside the interval.
            if h_pad is None:
                h_pad = h_out + k - 1
            kept_dx = width if sdx else nb * bs
            kept_dw = width if sdw else nb * bs
            dw_term = 2 * m * n_g * kept_dw
            dx_hi = 2 * (bt * h_pad * w_out) * n_g * kept_dx
            return (int(dw_term), int(dx_hi + dw_term))
        if groups == 1:
            # canonical-form gathered kernels over 128-padded tiles;
            # a non-sparsified side is a plain unpadded jnp.matmul.
            # conv_general_dilated_patches (X2) and its VJP (col2im)
            # are themselves convs with K² identity output channels —
            # 2*M*N*K² FLOPs each, the honest price of materializing.
            n = c_in * k * k
            m_pad = _roundup(m, 128)
            n_pad = _roundup(n, 128)
            gathered_pad = 2 * m_pad * n_pad * width
            dx_term = gathered_pad if sdx else full_side
            dw_term = gathered_pad if sdw else full_side
            im2col_term = 2 * (2 * m * n * k * k)
            t = int(dx_term + dw_term + im2col_term)
            return (t, t)
        # groups > 1 without the fused route: the canonical lowering
        # declines grouped convs, so the engine falls back to the
        # gathered-VJP path below.

    dx_term = gathered_side if sdx else full_side
    dw_term = gathered_side if sdw else full_side
    t = int(dx_term + dw_term)
    return (t, t)


def dense_backward_contraction_bounds(
    m: int, d_in: int, d_out: int, policy: "SsPropPolicy"
) -> tuple:
    """Exact ``(lo, hi)`` contraction FLOPs of one dense backward.

    Dense analogue of :func:`conv_backward_contraction_bounds` — every
    route is unconditional, so ``lo == hi`` always; the interval form is
    kept for API symmetry. Routes mirrored from
    :func:`repro.core.backward.channel_sparse_backward` +
    :class:`repro.core.dense._DenseOp`:

    * inactive / mask_mode: two full ``2*M*D_in*D_out`` matmuls,
    * TP fast path (``tp_shards`` divides ``D_out``, both sides
      sparsified): two unpadded gathered einsums — *before* the Pallas
      branch, so padding never applies,
    * Pallas block: gathered kernel sides at 128-padded tiles, dense
      sides unpadded,
    * Pallas channel: ``kops.matmul`` pads every operand dim to 128,
    * plain gather: unpadded matmuls at the engine's *gathered* width
      (:func:`gather_width` — whole blocks, not capped at ``D_out``).
    """
    full_side = 2 * m * d_in * d_out
    sdx, sdw = policy.sparsify_dx, policy.sparsify_dw
    if not policy.active or not (sdx or sdw) or policy.mask_mode:
        return (2 * full_side, 2 * full_side)

    # selection sharding mirrors _DenseOp.selection_shards
    n_shards = (
        policy.tp_shards
        if policy.tp_shards > 1 and d_out % policy.tp_shards == 0
        else 1
    )
    width = gather_width(d_out, policy, n_shards)
    gathered_side = 2 * m * d_in * width

    if n_shards > 1 and sdx and sdw:
        # TP fast path: two unpadded shard-local einsums over the
        # (shard, kept) axes — checked before the Pallas branch.
        t = int(2 * gathered_side)
        return (t, t)
    if policy.use_pallas:
        if policy.granularity == "block":
            m_pad = _roundup(m, 128)
            d_pad = _roundup(d_in, 128)
            gathered_pad = 2 * m_pad * d_pad * width
            dx_term = gathered_pad if sdx else full_side
            dw_term = gathered_pad if sdw else full_side
        else:
            padded = (
                2 * _roundup(m, 128) * _roundup(d_in, 128) * _roundup(width, 128)
            )
            dx_term = padded if sdx else full_side
            dw_term = padded if sdw else full_side
        t = int(dx_term + dw_term)
        return (t, t)

    dx_term = gathered_side if sdx else full_side
    dw_term = gathered_side if sdw else full_side
    t = int(dx_term + dw_term)
    return (t, t)


def conv_backward_bytes_policy(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "SsPropPolicy",
    fused: bool = None,
    itemsize: int = 4,
    groups: int = 1,
) -> int:
    """HBM bytes one conv backward moves under ``policy``.

    The FLOPs model (Eq. 6/9) says what the backward *computes*; this
    says what it *transfers* — the roofline memory term and the quantity
    the Pallas im2col fusion actually attacks. Two regimes:

    * **Materializing** (``fused=False``): the canonical im2col path
      builds real patch buffers — ``X2 [M, N]`` written then read by the
      dW kernel, ``dX2 [M, N]`` written by the dX kernel then read back
      by col2im (``M = Bt*H_out*W_out``, ``N = C_in*K²``). Those four
      ``M*N`` transfers dominate and do **not** shrink with sparsity.
    * **Fused** (``fused=True``): mirrors the fused kernel grids in
      :mod:`repro.kernels.gathered_matmul` — padded-image rows and
      cotangent panels are re-fetched once per (tap × kept-block) grid
      step, the compact filter is fetched into VMEM once, and no
      ``[M, N]`` buffer exists anywhere. Traffic scales with the kept
      block count, so sparsity cuts bytes as well as FLOPs.

    ``fused=None`` routes exactly like the engine: the fused model when
    the policy's Pallas/fuse_im2col path applies to this conv and it
    moves fewer bytes, the materializing model otherwise (this min is
    the gate :meth:`repro.core.conv._ConvOp.fused_backward` applies).
    Geometry is counted at stride 1 / 'SAME'-ish padding
    (``H_pad = H_out + K - 1``) — walkers don't carry strides, and both
    regimes use the same approximation.
    """
    if fused is None:
        mat = conv_backward_bytes_policy(
            bt, h_out, w_out, c_in, c_out, k, policy,
            fused=False, itemsize=itemsize, groups=groups,
        )
        if not (
            policy.active
            and policy.use_pallas
            and policy.granularity == "block"
            and policy.fuse_im2col
            and k > 1
        ):
            return mat
        fus = conv_backward_bytes_policy(
            bt, h_out, w_out, c_in, c_out, k, policy,
            fused=True, itemsize=itemsize, groups=groups,
        )
        return min(mat, fus)

    parts = conv_backward_bytes_breakdown(
        bt, h_out, w_out, c_in, c_out, k, policy, fused=fused, groups=groups
    )
    return sum(parts.values()) * itemsize


def conv_backward_bytes_breakdown(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "SsPropPolicy",
    *,
    fused: bool,
    groups: int = 1,
) -> dict[str, int]:
    """Per-component *element* counts behind the bytes model.

    :func:`conv_backward_bytes_policy` is exactly
    ``sum(breakdown.values()) * itemsize``; this exposes the terms so the
    static checker can cross-validate the fused kernel components
    against a grid-walk traffic emulation of the kernel specs
    (:mod:`repro.analysis.pallas_check`). Fused kernel-side keys map 1:1
    onto per-operand fetch totals of the ``conv_dw_fused`` /
    ``conv_dx_fused`` grids under sequential-grid revisit elision:

    * ``dw.xg_rows`` / ``dw.dy_panels`` / ``dw.out_flush`` — the dW
      kernel's image-row fetches, cotangent fetches, output flushes;
    * ``dx.dy_rows`` / ``dx.w2k_fetch`` / ``dx.out_writes`` — the dX
      kernel's cotangent fetches, single compact-filter fetch (its
      index map is constant), padded-image writes. ``dx.w2k_gather`` is
      the wrapper-side ``jnp.take`` that builds the compact filter —
      host of the kernel's fetch, not itself a kernel term.
    """
    m = bt * h_out * w_out
    cg = c_in // groups
    n = cg * k * k
    kept = kept_channels(c_out, policy)
    sdx = policy.active and policy.sparsify_dx
    sdw = policy.active and policy.sparsify_dw
    h_pad, w_pad = h_out + k - 1, w_out + k - 1
    x_elems = bt * c_in * h_pad * w_pad

    if not fused or k == 1:
        kept_dx = kept if sdx else c_out
        kept_dw = kept if sdw else c_out
        return {
            "mat.x_read": x_elems,               # read X to extract patches
            "mat.patch_buffers": 4 * m * n * groups,  # X2 w+r, dX2 w+r
            "mat.dy_panels": m * (kept_dx + kept_dw),  # read by each matmul
            "mat.importance": m * c_out,         # dY read for importance
            "mat.w_panels": n * kept_dx,         # W2 panels read (dX side)
            "mat.dw_write": n * c_out,           # dW written
            "mat.dx_write": x_elems,             # dX written
        }

    bs = policy.block_size
    nb = -(-c_out // bs)
    kb = policy.keep_count(c_out) if policy.active else nb
    kb_dx = kb if sdx else nb
    kb_dw = kb if sdw else nb
    m2 = bt * h_out      # dY row count (dW grid's sequential axis)
    s_ax = bt * h_pad    # padded-image row count (dX grid's outer axis)
    return {
        # dW kernel: one fetch per grid step for both streaming operands
        "dw.xg_rows": k * kb_dw * m2 * (w_pad * cg),
        "dw.dy_panels": k * kb_dw * m2 * (w_out * bs),
        "dw.out_flush": k * kb_dw * (k * cg * bs),
        # dX kernel: cotangent per (row, block, tap); filter once
        "dx.dy_rows": s_ax * kb_dx * k * (w_out * bs),
        "dx.w2k_gather": k * k * cg * kb_dx * bs,
        "dx.w2k_fetch": k * k * cg * kb_dx * bs,
        "dx.out_writes": s_ax * (w_pad * cg) * groups,
        # shared wrapper traffic
        "common.pad_image": 2 * x_elems,   # build padded row-major view
        "common.importance": m * c_out,    # dY read for importance
        "common.dw_write": n * c_out,      # dW written
        "common.dx_write": x_elems,        # dX written (border sliced off)
    }


def conv_backward_bytes_site(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "PolicyLike",
    site: str = "",
    fused: bool = None,
    itemsize: int = 4,
) -> int:
    """:func:`conv_backward_bytes_policy` for one named call site."""
    from repro.core.policy import policy_for

    return conv_backward_bytes_policy(
        bt, h_out, w_out, c_in, c_out, k, policy_for(policy, site),
        fused=fused, itemsize=itemsize,
    )


def conv_backward_flops_site(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "PolicyLike",
    site: str = "",
) -> int:
    """:func:`conv_backward_flops_policy` for one *named* call site.

    ``policy`` may be a plain policy (the site name is ignored) or a
    resolved :class:`~repro.core.policy.SitePolicies` table — the conv
    then counts at its own site's policy. This is what makes whole-model
    FLOPs walks (``models/resnet.py::flops_per_iter``,
    ``models/ddpm.py::flops_per_iter``) per-site aware.
    """
    from repro.core.policy import policy_for

    return conv_backward_flops_policy(
        bt, h_out, w_out, c_in, c_out, k, policy_for(policy, site)
    )


def dense_backward_flops_site(
    m: int,
    d_in: int,
    d_out: int,
    policy: "PolicyLike",
    site: str = "",
    bias: bool = True,
) -> int:
    """:func:`dense_backward_flops_policy` for one named call site."""
    from repro.core.policy import policy_for

    return dense_backward_flops_policy(
        m, d_in, d_out, policy_for(policy, site), bias=bias
    )


def savings_fraction(
    dense_flops: int, ssprop_flops: int
) -> float:
    """Fraction of backward FLOPs saved by ssProp."""
    if dense_flops <= 0:
        return 0.0
    return 1.0 - ssprop_flops / dense_flops


def conv_layer_report(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    drop_rate: float,
    policy: "SsPropPolicy" = None,
) -> dict[str, float]:
    """Per-layer dict used by the benchmark tables.

    With ``policy`` the ssProp count uses the engine's real keep counts
    (:func:`conv_backward_flops_policy`); otherwise the paper's nominal
    Eq. 9 at ``drop_rate``.
    """
    dense = conv_backward_flops(bt, h_out, w_out, c_in, c_out, k)
    if policy is not None:
        sparse = conv_backward_flops_policy(bt, h_out, w_out, c_in, c_out, k, policy)
    else:
        sparse = conv_backward_flops_ssprop(bt, h_out, w_out, c_in, c_out, k, drop_rate)
    return {
        "dense_flops": dense,
        "ssprop_flops": sparse,
        "saved": savings_fraction(dense, sparse),
        "lower_bound": drop_rate_lower_bound(c_in, k),
    }
