"""The paper's backward-FLOPs model (Eq. 6-11).

Counting convention (paper, "Drop Rate Lower Bound"): each Add, Sub, Mul
or Div is one FLOP; sorting is comparisons only (0 FLOPs); the importance
reduction adds ``(Bt*H_out*W_out - 1) * C_out`` FLOPs.

These formulas drive the benchmark tables (paper Tables 4-7) and the
property test on the drop-rate lower bound (Eq. 10-11).
"""
from __future__ import annotations

from typing import Dict


def conv_backward_flops(
    bt: int, h_out: int, w_out: int, c_in: int, c_out: int, k: int
) -> int:
    """Eq. 6: backward FLOPs of one convolution, columnized form.

    ``(Bt*H_out*W_out) * (4*C_in*K^2 + 1) * C_out``
    """
    m = bt * h_out * w_out
    return m * (4 * c_in * k * k + 1) * c_out


def conv_backward_flops_ssprop(
    bt: int, h_out: int, w_out: int, c_in: int, c_out: int, k: int, drop_rate: float
) -> int:
    """Eq. 9 RHS: conv backward FLOPs with ssProp at ``drop_rate``.

    ``[(4MN + M)(1 - D) + M] * C_out`` with ``M = Bt*H_out*W_out`` and
    ``N = C_in*K^2``; the trailing ``M*C_out`` is the importance
    reduction overhead.
    """
    m = bt * h_out * w_out
    n = c_in * k * k
    return int(((4 * m * n + m) * (1.0 - drop_rate) + m) * c_out)


def batchnorm_backward_flops(bt: int, h: int, w: int, c: int) -> int:
    """Eq. 7: ``12*(Bt*H*W*C) + 10*C``."""
    return 12 * (bt * h * w * c) + 10 * c


def dropout_backward_flops(bt: int, h: int, w: int, c: int) -> int:
    """Eq. 8: ``2*(Bt*H*W*C)``."""
    return 2 * (bt * h * w * c)


def drop_rate_lower_bound(c_in: int, k: int) -> float:
    """Eq. 10: minimum drop rate that saves computation.

    ``D > 1 / (4*C_in*K^2 + 1)``; Eq. 11 notes this is <= ~3% for K>=3.
    """
    return 1.0 / (4 * c_in * k * k + 1)


def dense_backward_flops(m: int, d_in: int, d_out: int, bias: bool = True) -> int:
    """Backward FLOPs of ``Y[M, D_out] = X[M, D_in] @ W + b``.

    dX and dW are each a ``2*M*D_in*D_out`` FLOP matmul; the bias gradient
    is an ``M*D_out`` reduction. This is Eq. 6 with K=1 (a 1x1 conv), the
    form used for the transformer-projection extension (DESIGN.md §4).
    """
    f = 4 * m * d_in * d_out
    if bias:
        f += m * d_out
    return f


def dense_backward_flops_ssprop(
    m: int, d_in: int, d_out: int, drop_rate: float, bias: bool = True
) -> int:
    """ssProp dense backward: shrunk matmuls + importance reduction."""
    f = 4 * m * d_in * d_out * (1.0 - drop_rate)
    if bias:
        f += m * d_out * (1.0 - drop_rate)
    f += m * d_out  # importance reduction (Eq. 9's +M per channel)
    return int(f)


def savings_fraction(
    dense_flops: int, ssprop_flops: int
) -> float:
    """Fraction of backward FLOPs saved by ssProp."""
    if dense_flops <= 0:
        return 0.0
    return 1.0 - ssprop_flops / dense_flops


def conv_layer_report(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    drop_rate: float,
) -> Dict[str, float]:
    """Per-layer dict used by the benchmark tables."""
    dense = conv_backward_flops(bt, h_out, w_out, c_in, c_out, k)
    sparse = conv_backward_flops_ssprop(bt, h_out, w_out, c_in, c_out, k, drop_rate)
    return {
        "dense_flops": dense,
        "ssprop_flops": sparse,
        "saved": savings_fraction(dense, sparse),
        "lower_bound": drop_rate_lower_bound(c_in, k),
    }
