"""The paper's backward-FLOPs model (Eq. 6-11), plus policy-aware counts.

Counting convention (paper, "Drop Rate Lower Bound"): each Add, Sub, Mul
or Div is one FLOP; sorting is comparisons only (0 FLOPs); the importance
reduction adds ``(Bt*H_out*W_out - 1) * C_out`` FLOPs.

The ``*_ssprop`` functions take the paper's nominal drop rate; the
``*_policy`` functions take an :class:`~repro.core.policy.SsPropPolicy`
and count what the backward engine *actually* executes: block
granularity rounds the keep count to whole ``block_size`` blocks, and
the Pallas gathered kernels pay for their 128-aligned tile padding.
The ``*_site`` functions are the per-site entry points: they accept a
resolved :class:`~repro.core.policy.SitePolicies` table plus the call
site's name, so a per-site policy program's total FLOPs are summed over
the resolved site table — each layer at its *own* keep count — rather
than one global rate.

Alongside FLOPs, :func:`conv_backward_bytes_policy` models the HBM
*traffic* of one conv backward — materializing-im2col vs the fused
Pallas kernels — and is both the roofline bytes-moved column and the
gate the engine uses to decide when fusing actually wins.

These formulas drive the benchmark tables (paper Tables 4-7), the conv
roofline rows, and the property test on the drop-rate lower bound
(Eq. 10-11).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict

if TYPE_CHECKING:
    from repro.core.policy import PolicyLike, SsPropPolicy


def _roundup(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def conv_backward_flops(
    bt: int, h_out: int, w_out: int, c_in: int, c_out: int, k: int
) -> int:
    """Eq. 6: backward FLOPs of one convolution, columnized form.

    ``(Bt*H_out*W_out) * (4*C_in*K^2 + 1) * C_out``
    """
    m = bt * h_out * w_out
    return m * (4 * c_in * k * k + 1) * c_out


def conv_backward_flops_ssprop(
    bt: int, h_out: int, w_out: int, c_in: int, c_out: int, k: int, drop_rate: float
) -> int:
    """Eq. 9 RHS: conv backward FLOPs with ssProp at ``drop_rate``.

    ``[(4MN + M)(1 - D) + M] * C_out`` with ``M = Bt*H_out*W_out`` and
    ``N = C_in*K^2``; the trailing ``M*C_out`` is the importance
    reduction overhead.
    """
    m = bt * h_out * w_out
    n = c_in * k * k
    return int(((4 * m * n + m) * (1.0 - drop_rate) + m) * c_out)


def batchnorm_backward_flops(bt: int, h: int, w: int, c: int) -> int:
    """Eq. 7: ``12*(Bt*H*W*C) + 10*C``."""
    return 12 * (bt * h * w * c) + 10 * c


def dropout_backward_flops(bt: int, h: int, w: int, c: int) -> int:
    """Eq. 8: ``2*(Bt*H*W*C)``."""
    return 2 * (bt * h * w * c)


def drop_rate_lower_bound(c_in: int, k: int) -> float:
    """Eq. 10: minimum drop rate that saves computation.

    ``D > 1 / (4*C_in*K^2 + 1)``; Eq. 11 notes this is <= ~3% for K>=3.
    """
    return 1.0 / (4 * c_in * k * k + 1)


def dense_backward_flops(m: int, d_in: int, d_out: int, bias: bool = True) -> int:
    """Backward FLOPs of ``Y[M, D_out] = X[M, D_in] @ W + b``.

    dX and dW are each a ``2*M*D_in*D_out`` FLOP matmul; the bias gradient
    is an ``M*D_out`` reduction. This is Eq. 6 with K=1 (a 1x1 conv), the
    form used for the transformer-projection extension (DESIGN.md §4).
    """
    f = 4 * m * d_in * d_out
    if bias:
        f += m * d_out
    return f


def dense_backward_flops_ssprop(
    m: int, d_in: int, d_out: int, drop_rate: float, bias: bool = True
) -> int:
    """ssProp dense backward: shrunk matmuls + importance reduction."""
    f = 4 * m * d_in * d_out * (1.0 - drop_rate)
    if bias:
        f += m * d_out * (1.0 - drop_rate)
    f += m * d_out  # importance reduction (Eq. 9's +M per channel)
    return int(f)


def kept_channels(c_out: int, policy: "SsPropPolicy") -> int:
    """Output channels whose gradients the engine actually computes.

    Channel granularity: the paper's ``max(1, round((1-D)*C))``. Block
    granularity: whole blocks, ``keep_count`` blocks × ``block_size``
    channels, capped at ``C`` — an upper bound when the ragged tail
    block is among the kept (its phantom slots are masked at runtime but
    the contraction is sized for the full block).
    """
    if not policy.active:
        return c_out
    if policy.granularity == "channel":
        return policy.keep_count(c_out)
    return min(c_out, policy.keep_count(c_out) * policy.block_size)


def effective_drop_rate(c_out: int, policy: "SsPropPolicy") -> float:
    """The drop rate the backward actually realizes at ``c_out`` channels
    (block rounding makes this coarser than ``policy.drop_rate``)."""
    return 1.0 - kept_channels(c_out, policy) / c_out


def conv_backward_flops_policy(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "SsPropPolicy",
) -> int:
    """Eq. 9 with the engine's real keep counts instead of the nominal D.

    ``(4MN + M) * kept + M*C_out`` with ``M = Bt*H_out*W_out``,
    ``N = C_in*K^2`` and ``kept = kept_channels(C_out, policy)``. On the
    Pallas block path the two gathered matmuls run over 128-aligned
    padded tiles (M, N padded to 128; kept padded to whole blocks), so
    the 4MN term is counted at padded sizes — the honest cost of the
    TPU lowering, visible whenever shapes are misaligned.
    """
    m = bt * h_out * w_out
    n = c_in * k * k
    sdx, sdw = policy.sparsify_dx, policy.sparsify_dw
    if not policy.active or not (sdx or sdw):
        return conv_backward_flops(bt, h_out, w_out, c_in, c_out, k)
    kept = kept_channels(c_out, policy)
    # Eq. 6 decomposes per output element as 2N (dX) + 2N (dW) + 1 (db);
    # each side shrinks only when its sparsify_* flag is on.
    if policy.use_pallas and policy.granularity == "block":
        m_pad = _roundup(m, 128)
        n_pad = _roundup(n, 128)
        kept_pad = policy.keep_count(c_out) * policy.block_size
        gathered = 2 * m_pad * n_pad * kept_pad
        dx_term = gathered if sdx else 2 * m * n * c_out
        dw_term = gathered if sdw else 2 * m * n * c_out
    else:
        dx_term = 2 * m * n * (kept if sdx else c_out)
        dw_term = 2 * m * n * (kept if sdw else c_out)
    db_term = m * (kept if sdw else c_out)
    return int(dx_term + dw_term + db_term + m * c_out)


def dense_backward_flops_policy(
    m: int, d_in: int, d_out: int, policy: "SsPropPolicy", bias: bool = True
) -> int:
    """Dense analogue of :func:`conv_backward_flops_policy` (K=1 conv)."""
    sdx, sdw = policy.sparsify_dx, policy.sparsify_dw
    if not policy.active or not (sdx or sdw):
        return dense_backward_flops(m, d_in, d_out, bias=bias)
    kept = kept_channels(d_out, policy)
    if policy.use_pallas and policy.granularity == "block":
        m_pad = _roundup(m, 128)
        d_pad = _roundup(d_in, 128)
        kept_pad = policy.keep_count(d_out) * policy.block_size
        gathered = 2 * m_pad * d_pad * kept_pad
        dx_term = gathered if sdx else 2 * m * d_in * d_out
        dw_term = gathered if sdw else 2 * m * d_in * d_out
    else:
        dx_term = 2 * m * d_in * (kept if sdx else d_out)
        dw_term = 2 * m * d_in * (kept if sdw else d_out)
    f = dx_term + dw_term
    if bias:
        f += m * (kept if sdw else d_out)
    return int(f + m * d_out)


def conv_backward_bytes_policy(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "SsPropPolicy",
    fused: bool = None,
    itemsize: int = 4,
    groups: int = 1,
) -> int:
    """HBM bytes one conv backward moves under ``policy``.

    The FLOPs model (Eq. 6/9) says what the backward *computes*; this
    says what it *transfers* — the roofline memory term and the quantity
    the Pallas im2col fusion actually attacks. Two regimes:

    * **Materializing** (``fused=False``): the canonical im2col path
      builds real patch buffers — ``X2 [M, N]`` written then read by the
      dW kernel, ``dX2 [M, N]`` written by the dX kernel then read back
      by col2im (``M = Bt*H_out*W_out``, ``N = C_in*K²``). Those four
      ``M*N`` transfers dominate and do **not** shrink with sparsity.
    * **Fused** (``fused=True``): mirrors the fused kernel grids in
      :mod:`repro.kernels.gathered_matmul` — padded-image rows and
      cotangent panels are re-fetched once per (tap × kept-block) grid
      step, the compact filter is fetched into VMEM once, and no
      ``[M, N]`` buffer exists anywhere. Traffic scales with the kept
      block count, so sparsity cuts bytes as well as FLOPs.

    ``fused=None`` routes exactly like the engine: the fused model when
    the policy's Pallas/fuse_im2col path applies to this conv and it
    moves fewer bytes, the materializing model otherwise (this min is
    the gate :meth:`repro.core.conv._ConvOp.fused_backward` applies).
    Geometry is counted at stride 1 / 'SAME'-ish padding
    (``H_pad = H_out + K - 1``) — walkers don't carry strides, and both
    regimes use the same approximation.
    """
    if fused is None:
        mat = conv_backward_bytes_policy(
            bt, h_out, w_out, c_in, c_out, k, policy,
            fused=False, itemsize=itemsize, groups=groups,
        )
        if not (
            policy.active
            and policy.use_pallas
            and policy.granularity == "block"
            and policy.fuse_im2col
            and k > 1
        ):
            return mat
        fus = conv_backward_bytes_policy(
            bt, h_out, w_out, c_in, c_out, k, policy,
            fused=True, itemsize=itemsize, groups=groups,
        )
        return min(mat, fus)

    m = bt * h_out * w_out
    cg = c_in // groups
    n = cg * k * k
    kept = kept_channels(c_out, policy)
    sdx = policy.active and policy.sparsify_dx
    sdw = policy.active and policy.sparsify_dw
    h_pad, w_pad = h_out + k - 1, w_out + k - 1
    x_elems = bt * c_in * h_pad * w_pad

    if not fused or k == 1:
        kept_dx = kept if sdx else c_out
        kept_dw = kept if sdw else c_out
        elems = (
            x_elems                      # read X to extract patches
            + 4 * m * n * groups         # X2 write+read, dX2 write+read
            + m * (kept_dx + kept_dw)    # dY2 panels read by each matmul
            + m * c_out                  # dY read for importance
            + n * kept_dx                # W2 panels read (dX side)
            + n * c_out                  # dW written
            + x_elems                    # dX written
        )
        return int(elems) * itemsize

    bs = policy.block_size
    nb = -(-c_out // bs)
    kb = policy.keep_count(c_out) if policy.active else nb
    kb_dx = kb if sdx else nb
    kb_dw = kb if sdw else nb
    m2 = bt * h_out      # dY row count (dW grid's sequential axis)
    s_ax = bt * h_pad    # padded-image row count (dX grid's outer axis)
    dw_elems = (
        k * kb_dw * m2 * (w_pad * cg)    # padded-image row per (tap, block)
        + k * kb_dw * m2 * (w_out * bs)  # cotangent panel per grid step
        + k * kb_dw * (k * cg * bs)      # output tap blocks flushed
    )
    dx_elems = (
        s_ax * kb_dx * k * (w_out * bs)  # cotangent row per (row, block, tap)
        + 2 * (k * k * cg * kb_dx * bs)  # compact filter: gather + one fetch
        + s_ax * (w_pad * cg) * groups   # padded-image blocks written once
    )
    common = (
        2 * x_elems      # build the padded row-major image view
        + m * c_out      # dY read for importance
        + n * c_out      # dW written
        + x_elems        # dX written (padding border sliced off)
    )
    return int(dw_elems + dx_elems + common) * itemsize


def conv_backward_bytes_site(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "PolicyLike",
    site: str = "",
    fused: bool = None,
    itemsize: int = 4,
) -> int:
    """:func:`conv_backward_bytes_policy` for one named call site."""
    from repro.core.policy import policy_for

    return conv_backward_bytes_policy(
        bt, h_out, w_out, c_in, c_out, k, policy_for(policy, site),
        fused=fused, itemsize=itemsize,
    )


def conv_backward_flops_site(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: "PolicyLike",
    site: str = "",
) -> int:
    """:func:`conv_backward_flops_policy` for one *named* call site.

    ``policy`` may be a plain policy (the site name is ignored) or a
    resolved :class:`~repro.core.policy.SitePolicies` table — the conv
    then counts at its own site's policy. This is what makes whole-model
    FLOPs walks (``models/resnet.py::flops_per_iter``,
    ``models/ddpm.py::flops_per_iter``) per-site aware.
    """
    from repro.core.policy import policy_for

    return conv_backward_flops_policy(
        bt, h_out, w_out, c_in, c_out, k, policy_for(policy, site)
    )


def dense_backward_flops_site(
    m: int,
    d_in: int,
    d_out: int,
    policy: "PolicyLike",
    site: str = "",
    bias: bool = True,
) -> int:
    """:func:`dense_backward_flops_policy` for one named call site."""
    from repro.core.policy import policy_for

    return dense_backward_flops_policy(
        m, d_in, d_out, policy_for(policy, site), bias=bias
    )


def savings_fraction(
    dense_flops: int, ssprop_flops: int
) -> float:
    """Fraction of backward FLOPs saved by ssProp."""
    if dense_flops <= 0:
        return 0.0
    return 1.0 - ssprop_flops / dense_flops


def conv_layer_report(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    drop_rate: float,
    policy: "SsPropPolicy" = None,
) -> Dict[str, float]:
    """Per-layer dict used by the benchmark tables.

    With ``policy`` the ssProp count uses the engine's real keep counts
    (:func:`conv_backward_flops_policy`); otherwise the paper's nominal
    Eq. 9 at ``drop_rate``.
    """
    dense = conv_backward_flops(bt, h_out, w_out, c_in, c_out, k)
    if policy is not None:
        sparse = conv_backward_flops_policy(bt, h_out, w_out, c_in, c_out, k, policy)
    else:
        sparse = conv_backward_flops_ssprop(bt, h_out, w_out, c_in, c_out, k, drop_rate)
    return {
        "dense_flops": dense,
        "ssprop_flops": sparse,
        "saved": savings_fraction(dense, sparse),
        "lower_bound": drop_rate_lower_bound(c_in, k),
    }
