"""The channel-sparse backward engine (paper Fig. 1(a), one implementation).

Both ``sparse_dense`` and ``sparse_conv2d`` used to carry their own copy
of the ssProp backward pipeline; they now delegate to
:func:`channel_sparse_backward`, which owns every op-independent stage:

  1. ``bwd_dtype`` casting of the output cotangent,
  2. importance → policy-driven channel/block selection (including the
     ragged-tail ``valid`` mask and shard-balanced selection for TP /
     grouped convs),
  3. the ``mask_mode`` oracle (same selection, materialized as a mask
     over a full-size contraction),
  4. the gather of kept channels and the scatter of compact dW/db back
     into full-size zero buffers (``.add``-based, so clamped tail
     duplicates cannot overwrite the last real channel),
  5. routing to the Pallas gathered kernels when the op can lower itself
     to the canonical 2-D form (``use_pallas`` + block granularity).

Ops plug in through :class:`ChannelSparseOp`, providing only their
linear algebra: the full-size contraction, the shrunk (gathered)
contraction, and optionally a :class:`CanonicalForm` — the im2col-style
``X2 [M, D_flat] / W2 [D_flat, C_out] / dY2 [M, C_out]`` view that the
Pallas ``dx_gathered`` / ``dw_gathered_scatter`` kernels consume — and a
TP fast path for comm-free sharded gathers.

Selection consistency is the engine's core guarantee: mask mode and
gather mode share one :class:`repro.core.sparsity.Selection` per call,
so gather-mode output equals the mask-mode oracle to accumulation
tolerance across every configuration (the property the parity test grid
pins down).
"""
from __future__ import annotations

from collections.abc import Callable
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import sparsity
from repro.core.policy import SsPropPolicy


@dataclasses.dataclass
class CanonicalForm:
    """An op lowered to the 2-D matmul form the Pallas kernels speak.

    ``x2 [M, D_flat]``, ``w2 [D_flat, C_out]``, ``dy2 [M, C_out]`` with
    rows of ``x2``/``dy2`` aligned (same (batch, position) ordering).
    ``dx_from`` / ``dw_from`` lift the canonical gradients — dX2
    ``[M, D_flat]`` and full-size dW2 ``[D_flat, C_out]`` — back to the
    op's native shapes (dense: reshape; conv: col2im / OIHW reshape).
    """

    x2: jax.Array
    w2: jax.Array
    dy2: jax.Array
    dx_from: Callable[[jax.Array], jax.Array]
    dw_from: Callable[[jax.Array], jax.Array]


class ChannelSparseOp:
    """Adapter protocol: the op-specific linear algebra.

    Attributes:
      c_out: number of output channels (the sparsified axis).
      channel_axis: position of the channel axis in ``dy``.
      dw_channel_axis: position of the output-channel axis in ``dw``.

    ``__init__`` installs the shared ``bwd_dtype`` machinery: ``_acc``
    (the accumulation dtype) and ``_cast`` (casts contraction operands
    into it when ``bwd_dtype`` is set, identity otherwise — natural
    promotion is left alone for the default fp32 backward).
    """

    c_out: int
    channel_axis: int
    dw_channel_axis: int

    def __init__(self, policy: SsPropPolicy):
        self.policy = policy
        self._acc = _acc_dtype(policy)
        self._cast = (
            (lambda a: a.astype(self._acc)) if policy.bwd_dtype else (lambda a: a)
        )

    def selection_shards(self, policy: SsPropPolicy) -> int:
        """How many contiguous channel groups selection must balance over
        (1 = global top-k). Ops fold structural constraints (conv groups)
        and the policy's TP degree into this."""
        return 1

    def contract_full(self, dy_eff: jax.Array) -> tuple[jax.Array, jax.Array]:
        """(dX, dW) from a full-size (possibly masked) cotangent."""
        raise NotImplementedError

    def dx_full(self, dy_eff: jax.Array) -> jax.Array:
        """Dense dX alone (``sparsify_dx=False`` path). The default rides
        on ``contract_full``; under jit the unused dW branch is DCE'd."""
        return self.contract_full(dy_eff)[0]

    def dw_full(self, dy_eff: jax.Array) -> jax.Array:
        """Dense dW alone (``sparsify_dw=False`` path)."""
        return self.contract_full(dy_eff)[1]

    def contract_gathered(
        self, dy_k: jax.Array, sel: sparsity.Selection
    ) -> tuple[jax.Array, jax.Array]:
        """(dX, compact dW) from the gathered cotangent ``dy_k`` (kept
        channels only, phantom slots already zeroed). The compact dW has
        ``sel.k`` channels on ``dw_channel_axis``; the engine scatters."""
        raise NotImplementedError

    def contract_gathered_dx(self, dy_k: jax.Array, sel) -> jax.Array:
        """Gathered dX alone (mixed ``sparsify_dw=False`` path). The
        default discards the dW half; under jit that half is DCE'd."""
        return self.contract_gathered(dy_k, sel)[0]

    def contract_gathered_dw(self, dy_k: jax.Array, sel) -> jax.Array:
        """Gathered compact dW alone (mixed ``sparsify_dx=False`` path)."""
        return self.contract_gathered(dy_k, sel)[1]

    def canonical(self, dy_eff: jax.Array) -> CanonicalForm | None:
        """The 2-D lowering for the Pallas gathered kernels, or None when
        the op cannot (or should not) lower itself."""
        return None

    def fused_backward(
        self, dy_eff: jax.Array, sel: sparsity.Selection, sdx: bool, sdw: bool
    ) -> tuple[jax.Array, jax.Array] | None:
        """Optional fully-fused Pallas path: (dX, dW) in native shapes and
        accumulation dtype, or None to fall through to the canonical-form
        kernels. Checked first in the Pallas branch — ops that can fuse
        their data-layout transform into the kernels' index maps (conv
        im2col) skip the materialized canonical buffers entirely."""
        return None

    def tp_contract(
        self, dy_eff: jax.Array, sel: sparsity.Selection
    ) -> tuple[jax.Array, jax.Array] | None:
        """Optional comm-free sharded fast path: (dX, full dW) from the
        per-shard selection, or None to use the generic gather path."""
        return None


def scatter_channels(
    compact: jax.Array, idx: jax.Array, c: int, axis: int
) -> jax.Array:
    """Scatter a compact per-kept-channel tensor into full-size zeros.

    Accumulating (``.add``): duplicate indices — the clamped phantoms of
    a ragged block tail, whose values the engine has already zeroed —
    contribute nothing instead of overwriting.
    """
    axis = axis % compact.ndim
    shape = list(compact.shape)
    shape[axis] = c
    sl: list = [slice(None)] * compact.ndim
    sl[axis] = idx
    return jnp.zeros(shape, compact.dtype).at[tuple(sl)].add(compact)


def _acc_dtype(policy: SsPropPolicy):
    return jnp.bfloat16 if policy.bwd_dtype == "bfloat16" else jnp.float32


def _wrap_key(policy: SsPropPolicy, key32) -> jax.Array | None:
    if policy.selection == "random" and key32 is not None:
        return jax.random.wrap_key_data(key32.astype(jnp.uint32))
    return None


def channel_sparse_backward(
    policy: SsPropPolicy,
    op: ChannelSparseOp,
    dy: jax.Array,
    *,
    key32: jax.Array | None = None,
    has_bias: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Run the shared ssProp backward pipeline for one op.

    Returns ``(dX, dW, db)`` in accumulation dtype (callers cast back to
    their parameter dtypes); ``db`` is None when ``has_bias`` is False.
    """
    ca = op.channel_axis % dy.ndim
    c = op.c_out
    reduce_axes = tuple(a for a in range(dy.ndim) if a != ca)
    dy_eff = dy.astype(_acc_dtype(policy)) if policy.bwd_dtype else dy
    sdx, sdw = policy.sparsify_dx, policy.sparsify_dw

    if not policy.active or not (sdx or sdw):
        dx, dw = op.contract_full(dy_eff)
        db = dy_eff.sum(axis=reduce_axes) if has_bias else None
        return dx, dw, db

    key = _wrap_key(policy, key32)
    sel = sparsity.select(
        dy_eff,
        policy,
        channel_axis=ca,
        n_shards=op.selection_shards(policy),
        key=key,
    )

    if policy.mask_mode:
        # Reference semantics: identical selection, zeroed channels,
        # full-size contraction. The oracle every other path must match.
        # A gradient whose sparsify_* flag is off sees the raw cotangent.
        mask = sparsity.keep_mask(dy.shape, sel.idx, channel_axis=ca, dtype=dy_eff.dtype)
        dy_m = dy_eff * mask
        dx = op.dx_full(dy_m if sdx else dy_eff)
        dw = op.dw_full(dy_m if sdw else dy_eff)
        db = (dy_m if sdw else dy_eff).sum(axis=reduce_axes) if has_bias else None
        return dx, dw, db

    db = None
    if has_bias:
        # db follows the dW side (bias is a weight). With sparsify_dw
        # off it stays dense; otherwise: clamped phantom slots always
        # point into the kept tail block, so the plain keep-mask is
        # correct even when sel.valid exists.
        db = dy_eff.sum(axis=reduce_axes)
        if sdw:
            km = sparsity.keep_mask((c,), sel.idx, channel_axis=0, dtype=dy_eff.dtype)
            db = db * km

    if sel.shard_idx is not None and sdx and sdw:
        fast = op.tp_contract(dy_eff, sel)
        if fast is not None:
            dx, dw = fast
            return dx, dw, db

    if (
        policy.use_pallas
        and policy.granularity == "block"
        and sel.block_idx is not None
    ):
        fused = op.fused_backward(dy_eff, sel, sdx, sdw)
        if fused is not None:
            dx, dw = fused
            return dx, dw, db
        can = op.canonical(dy_eff)
        if can is not None:
            from repro.kernels import ops as kops

            if sdx:
                dx2 = kops.dx_gathered(can.dy2, can.w2, sel.block_idx, policy.block_size)
            else:
                dx2 = jnp.matmul(can.dy2, can.w2.T)
            if sdw:
                dw2 = kops.dw_gathered_scatter(
                    can.x2, can.dy2, sel.block_idx, c, policy.block_size
                )
            else:
                dw2 = jnp.matmul(can.x2.T, can.dy2)
            return can.dx_from(dx2), can.dw_from(dw2), db

    dy_k = jnp.take(dy_eff, sel.idx, axis=ca)
    if sel.valid is not None:
        vshape = [1] * dy.ndim
        vshape[ca] = sel.k
        dy_k = dy_k * sel.valid.reshape(vshape).astype(dy_k.dtype)
    if sdx and sdw:
        dx, dw_compact = op.contract_gathered(dy_k, sel)
    elif sdx:
        dx = op.contract_gathered_dx(dy_k, sel)
        dw_compact = None
    else:
        dx = op.dx_full(dy_eff)
        dw_compact = op.contract_gathered_dw(dy_k, sel)
    if sdw:
        dw = scatter_channels(dw_compact, sel.idx, c, op.dw_channel_axis)
    else:
        dw = op.dw_full(dy_eff)
    return dx, dw, db
