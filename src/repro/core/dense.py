"""``sparse_dense``: matmul with ssProp channel-sparse backward.

Forward is a plain ``Y = X @ W (+ b)``. Backward implements the paper's
Fig. 1(a) pipeline generalized from conv to any linear operator
(the paper's stated extension path — DESIGN.md §4):

  1. importance[c] = mean |dY[..., c]| over all leading axes,
  2. keep top-K channels (or 128-channel blocks, TPU mode),
  3. dX = dY_kept @ W[:, kept]^T        (shrunk matmul, (1-D) FLOPs)
  4. dW[:, kept] = X^T @ dY_kept, dW[:, dropped] = 0
  5. db[kept]   = sum dY_kept,          db[dropped] = 0

The pipeline itself — selection, mask-mode oracle, ``bwd_dtype``
casting, TP-local selection, Pallas routing, compact-gradient scatter —
lives in :mod:`repro.core.backward`; this module only supplies the dense
linear algebra through a :class:`~repro.core.backward.ChannelSparseOp`
adapter. ``sparse_conv2d`` plugs into the same engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backward
from repro.core.policy import SsPropPolicy

# frozen, so safe to share as the signature default
_DEFAULT_POLICY = SsPropPolicy()


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


class _DenseOp(backward.ChannelSparseOp):
    """Canonical-form op: X2 [M, D_in] @ W [D_in, D_out]."""

    channel_axis = 1
    dw_channel_axis = 1

    def __init__(self, x2: jax.Array, w: jax.Array, policy: SsPropPolicy):
        super().__init__(policy)
        self.x2 = x2
        self.w = w
        self.c_out = w.shape[1]

    def selection_shards(self, policy: SsPropPolicy) -> int:
        if policy.tp_shards > 1 and self.c_out % policy.tp_shards == 0:
            return policy.tp_shards
        return 1

    def contract_full(self, dy_eff):
        return self.dx_full(dy_eff), self.dw_full(dy_eff)

    def dx_full(self, dy_eff):
        return jnp.matmul(dy_eff, self._cast(self.w.T))

    def dw_full(self, dy_eff):
        return jnp.matmul(self._cast(self.x2.T), dy_eff)

    def contract_gathered(self, dy_k, sel):
        return self.contract_gathered_dx(dy_k, sel), self.contract_gathered_dw(dy_k, sel)

    def contract_gathered_dx(self, dy_k, sel):
        w_k = self._cast(jnp.take(self.w, sel.idx, axis=1))
        if self.policy.use_pallas:
            from repro.kernels import ops as kops

            return kops.matmul(dy_k, w_k.T)
        return jnp.matmul(dy_k, w_k.T)          # shrunk: 2*M*K*D_in

    def contract_gathered_dw(self, dy_k, sel):
        x2 = self._cast(self.x2)
        if self.policy.use_pallas:
            from repro.kernels import ops as kops

            return kops.matmul(x2.T, dy_k)
        return jnp.matmul(x2.T, dy_k)           # shrunk: 2*M*D_in*K

    def canonical(self, dy_eff):
        return backward.CanonicalForm(
            x2=self._cast(self.x2),
            w2=self._cast(self.w),
            dy2=dy_eff,
            dx_from=lambda dx2: dx2,
            dw_from=lambda dw2: dw2,
        )

    def tp_contract(self, dy_eff, sel):
        # TP-local selection: gather stays on the shard-local channel
        # axis (take_along_axis), so GSPMD never all-gathers dY. The
        # contraction over (shard, kept) for dX reduces exactly like the
        # dense row-parallel matmul (one psum of [M, D_in]).
        m = dy_eff.shape[0]
        d_in = self.w.shape[0]
        s, c_loc = sel.n_shards, self.c_out // sel.n_shards
        dy3 = dy_eff.reshape(m, s, c_loc)
        dy_k = jnp.take_along_axis(dy3, sel.shard_idx[None], axis=2)  # [M, s, k]
        w3 = self.w.reshape(d_in, s, c_loc)
        w_k = jnp.take_along_axis(w3, sel.shard_idx[None], axis=2)  # [D_in, s, k]
        dx2 = jnp.einsum(
            "msk,dsk->md", dy_k, w_k.astype(dy_k.dtype),
            preferred_element_type=self._acc,
        )
        dw_k = jnp.einsum(
            "md,msk->dsk", self.x2.astype(dy_k.dtype), dy_k,
            preferred_element_type=self._acc,
        )  # [D_in, s, k]
        dw = (
            jnp.zeros((d_in, s, c_loc), dw_k.dtype)
            .at[:, jnp.arange(s)[:, None], sel.shard_idx]
            .set(dw_k)
            .reshape(d_in, self.c_out)
        )
        return dx2, dw


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sparse_dense(policy: SsPropPolicy, has_bias: bool, x, w, b, key32):
    y = jnp.matmul(x, w)
    if has_bias:
        y = y + b
    return y


def _fwd(policy, has_bias, x, w, b, key32):
    return _sparse_dense(policy, has_bias, x, w, b, key32), (x, w, key32)


def _bwd(policy: SsPropPolicy, has_bias: bool, res, dy):
    x, w, key32 = res
    d_in, d_out = w.shape
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    op = _DenseOp(x.reshape(m, d_in), w, policy)
    dx2, dw, db = backward.channel_sparse_backward(
        policy, op, dy.reshape(m, d_out), key32=key32, has_bias=has_bias
    )
    dx = dx2.reshape(*lead, d_in).astype(x.dtype)
    dw = dw.astype(w.dtype)
    db_out = db.astype(dy.dtype) if has_bias else jnp.zeros((d_out,), dy.dtype)
    return dx, dw, db_out, _float0_like(key32)


_sparse_dense.defvjp(_fwd, _bwd)

_DUMMY_KEY = np.zeros((2,), dtype=np.uint32)


def sparse_dense(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    policy: SsPropPolicy = _DEFAULT_POLICY,
    key: jax.Array | None = None,
) -> jax.Array:
    """Linear layer with ssProp scheduled-sparse backward.

    Args:
      x: ``[..., D_in]`` activations.
      w: ``[D_in, D_out]`` weights.
      b: optional ``[D_out]`` bias.
      policy: the ssProp policy (drop rate already bucketed/static).
      key: PRNG key, only needed for ``selection="random"``.

    Returns:
      ``[..., D_out]`` output; backward follows the policy.
    """
    has_bias = b is not None
    key32 = (
        jax.random.key_data(key).astype(jnp.uint32).reshape(-1)
        if key is not None
        else jnp.asarray(_DUMMY_KEY)
    )
    if b is None:
        b = jnp.zeros((w.shape[1],), dtype=x.dtype)
    return _sparse_dense(policy, has_bias, x, w, b, key32)
