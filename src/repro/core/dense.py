"""``sparse_dense``: matmul with ssProp channel-sparse backward.

Forward is a plain ``Y = X @ W (+ b)``. Backward implements the paper's
Fig. 1(a) pipeline generalized from conv to any linear operator
(the paper's stated extension path — DESIGN.md §4):

  1. importance[c] = mean |dY[..., c]| over all leading axes,
  2. keep top-K channels (or 128-channel blocks, TPU mode),
  3. dX = dY_kept @ W[:, kept]^T        (shrunk matmul, (1-D) FLOPs)
  4. dW[:, kept] = X^T @ dY_kept, dW[:, dropped] = 0
  5. db[kept]   = sum dY_kept,          db[dropped] = 0

``mask_mode`` keeps full-size matmuls with zeroed channels — numerically
identical, used as the oracle in tests.

The PRNG key argument only matters for ``selection="random"`` (Fig. 2(b)
ablation); it is a raw uint32 array so custom_vjp can hand back a float0
cotangent.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import SsPropPolicy
from repro.core import sparsity


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _select(dy2d: jax.Array, policy: SsPropPolicy, key32: jax.Array):
    key = None
    if policy.selection == "random":
        key = jax.random.wrap_key_data(key32.astype(jnp.uint32))
    return sparsity.select_indices(dy2d, policy, channel_axis=-1, key=key)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sparse_dense(policy: SsPropPolicy, has_bias: bool, x, w, b, key32):
    y = jnp.matmul(x, w)
    if has_bias:
        y = y + b
    return y


def _fwd(policy, has_bias, x, w, b, key32):
    return _sparse_dense(policy, has_bias, x, w, b, key32), (x, w, key32)


def _bwd(policy: SsPropPolicy, has_bias: bool, res, dy):
    x, w, key32 = res
    d_in, d_out = w.shape
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(m, d_in)
    dy2 = dy.reshape(m, d_out)
    acc_t = jnp.bfloat16 if policy.bwd_dtype == "bfloat16" else jnp.float32
    if policy.bwd_dtype:
        dy2 = dy2.astype(acc_t)

    if not policy.active:
        dx2 = jnp.matmul(dy2, w.T)
        dw = jnp.matmul(x2.T, dy2)
        db = dy2.sum(axis=0) if has_bias else None
    elif policy.tp_shards > 1 and d_out % policy.tp_shards == 0:
        # TP-local selection: gather stays on the shard-local channel
        # axis (take_along_axis), so GSPMD never all-gathers dY. The
        # contraction over (shard, kept) for dX reduces exactly like the
        # dense row-parallel matmul (one psum of [M, D_in]).
        s = policy.tp_shards
        c_loc = d_out // s
        sel_key = (
            jax.random.wrap_key_data(key32.astype(jnp.uint32))
            if policy.selection == "random"
            else None
        )
        idx, k_loc = sparsity.select_indices_per_shard(
            dy2, policy, s, key=sel_key
        )  # [s, k_loc]
        dy3 = dy2.reshape(m, s, c_loc)
        dy_k = jnp.take_along_axis(dy3, idx[None], axis=2)  # [M, s, k_loc]
        w3 = w.reshape(d_in, s, c_loc)
        w_k = jnp.take_along_axis(w3, idx[None], axis=2)  # [D_in, s, k_loc]
        dx2 = jnp.einsum(
            "msk,dsk->md", dy_k, w_k.astype(dy_k.dtype),
            preferred_element_type=acc_t,
        )
        dw_k = jnp.einsum(
            "md,msk->dsk", x2.astype(dy_k.dtype), dy_k,
            preferred_element_type=acc_t,
        )  # [D_in, s, k_loc]
        dw3 = jnp.zeros((d_in, s, c_loc), dw_k.dtype)
        dw = dw3.at[:, jnp.arange(s)[:, None], idx].set(dw_k).reshape(d_in, d_out)
        db = (
            jnp.zeros((s, c_loc), dy.dtype)
            .at[jnp.arange(s)[:, None], idx]
            .set(dy_k.sum(axis=0).astype(dy.dtype))
            .reshape(d_out)
            if has_bias
            else None
        )
    elif policy.mask_mode:
        dy2m = sparsity.mask_grad(
            dy2,
            policy,
            channel_axis=-1,
            key=(
                jax.random.wrap_key_data(key32.astype(jnp.uint32))
                if policy.selection == "random"
                else None
            ),
        )
        dx2 = jnp.matmul(dy2m, w.T)
        dw = jnp.matmul(x2.T, dy2m)
        db = dy2m.sum(axis=0) if has_bias else None
    else:
        if (
            policy.use_pallas
            and policy.granularity == "block"
            and d_out % policy.block_size == 0
        ):
            # TPU-native path: kept-block indices ride in SMEM and the
            # gather is fused into the kernels' HBM→VMEM addressing.
            from repro.kernels import ops as kops

            imp = sparsity.channel_importance(dy2, channel_axis=-1)
            kb = policy.keep_count(d_out)
            sel_key = (
                jax.random.wrap_key_data(key32.astype(jnp.uint32))
                if policy.selection == "random"
                else None
            )
            bidx = sparsity.select_topk_blocks(
                imp, policy.block_size, kb, selection=policy.selection, key=sel_key
            )
            idx = sparsity.block_indices_to_channels(bidx, policy.block_size)
            dx2 = kops.dx_gathered(dy2, w, bidx, policy.block_size)
            dw = kops.dw_gathered_scatter(x2, dy2, bidx, d_out, policy.block_size)
            dy_k = jnp.take(dy2, idx, axis=1) if has_bias else None
        else:
            idx, k = _select(dy2, policy, key32)
            dy_k = jnp.take(dy2, idx, axis=1)       # [M, K]
            w_k = jnp.take(w, idx, axis=1)          # [D_in, K]
            if policy.use_pallas:
                from repro.kernels import ops as kops

                dx2 = kops.matmul(dy_k, w_k.T)
                dw_k = kops.matmul(x2.T, dy_k)
            else:
                dx2 = jnp.matmul(dy_k, w_k.T)       # shrunk: 2*M*K*D_in
                dw_k = jnp.matmul(x2.T, dy_k)       # shrunk: 2*M*D_in*K
            dw = jnp.zeros((d_in, d_out), dtype=dw_k.dtype).at[:, idx].set(dw_k)
        db = (
            jnp.zeros((d_out,), dtype=dy.dtype).at[idx].set(dy_k.sum(axis=0))
            if has_bias
            else None
        )

    dx = dx2.reshape(*lead, d_in).astype(x.dtype)
    dw = dw.astype(w.dtype)
    db_out = db.astype(dy.dtype) if has_bias else jnp.zeros((d_out,), dy.dtype)
    return dx, dw, db_out, _float0_like(key32)


_sparse_dense.defvjp(_fwd, _bwd)

_DUMMY_KEY = np.zeros((2,), dtype=np.uint32)


def sparse_dense(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    policy: SsPropPolicy = SsPropPolicy(),
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Linear layer with ssProp scheduled-sparse backward.

    Args:
      x: ``[..., D_in]`` activations.
      w: ``[D_in, D_out]`` weights.
      b: optional ``[D_out]`` bias.
      policy: the ssProp policy (drop rate already bucketed/static).
      key: PRNG key, only needed for ``selection="random"``.

    Returns:
      ``[..., D_out]`` output; backward follows the policy.
    """
    has_bias = b is not None
    key32 = (
        jax.random.key_data(key).astype(jnp.uint32).reshape(-1)
        if key is not None
        else jnp.asarray(_DUMMY_KEY)
    )
    if b is None:
        b = jnp.zeros((w.shape[1],), dtype=x.dtype)
    return _sparse_dense(policy, has_bias, x, w, b, key32)
