"""``sparse_conv2d``: convolution with ssProp channel-sparse backward.

Forward is ``jax.lax.conv_general_dilated`` (NCHW / OIHW, matching the
paper's tensor layout). Backward applies the paper's Fig. 1(a) pipeline:
select top-K output channels of dY, then compute dX and dW through the
*shrunk* convolution — we take the VJP of the conv restricted to the kept
output channels, which XLA lowers to transposed convs with ``C_out' = K``
(exactly the (1-D) FLOPs saving of Eq. 9, without img2col).

The paper's img2col exposition is replaced by the framework-native conv —
the paper itself does the same for its fast path ("PyTorch built-in
backward version"). See DESIGN.md §3.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import SsPropPolicy
from repro.core import sparsity

_DN = ("NCHW", "OIHW", "NCHW")


def _norm_pair(v) -> Tuple[int, int]:
    if isinstance(v, int):
        return (v, v)
    return tuple(v)


def _conv(x, w, stride, padding, dilation, groups):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=padding,
        rhs_dilation=dilation,
        dimension_numbers=_DN,
        feature_group_count=groups,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _sparse_conv2d(policy, has_bias, stride, padding, dilation, groups, x, w, b, key32):
    y = _conv(x, w, stride, padding, dilation, groups)
    if has_bias:
        y = y + b[None, :, None, None]
    return y


def _fwd(policy, has_bias, stride, padding, dilation, groups, x, w, b, key32):
    y = _sparse_conv2d(policy, has_bias, stride, padding, dilation, groups, x, w, b, key32)
    return y, (x, w, key32)


def _bwd(policy: SsPropPolicy, has_bias, stride, padding, dilation, groups, res, dy):
    x, w, key32 = res
    c_out = w.shape[0]

    key = None
    if policy.selection == "random":
        key = jax.random.wrap_key_data(key32.astype(jnp.uint32))

    def full_vjp(dy_eff):
        _, vjp = jax.vjp(lambda x_, w_: _conv(x_, w_, stride, padding, dilation, groups), x, w)
        dx, dw = vjp(dy_eff)
        db = dy_eff.sum(axis=(0, 2, 3)) if has_bias else None
        return dx, dw, db

    if not policy.active:
        dx, dw, db = full_vjp(dy)
    elif policy.mask_mode:
        dy_m = sparsity.mask_grad(dy, policy, channel_axis=1, key=key)
        dx, dw, db = full_vjp(dy_m)
    else:
        idx, k = sparsity.select_indices(dy, policy, channel_axis=1, key=key)
        dy_k = jnp.take(dy, idx, axis=1)          # [B, K, H, W]
        w_k = jnp.take(w, idx, axis=0)            # [K, C_in/g, Kh, Kw]
        # VJP of the conv restricted to the kept output channels — the
        # transposed convs XLA emits have C_out' = K, i.e. shrunk FLOPs.
        _, vjp_k = jax.vjp(
            lambda x_, w_: _conv(x_, w_, stride, padding, dilation, groups), x, w_k
        )
        dx, dw_k = vjp_k(dy_k)
        dw = jnp.zeros_like(w).at[idx].set(dw_k.astype(w.dtype))
        db = (
            jnp.zeros((c_out,), dtype=dy.dtype).at[idx].set(dy_k.sum(axis=(0, 2, 3)))
            if has_bias
            else None
        )

    db_out = (
        db.astype(dy.dtype) if has_bias else jnp.zeros((c_out,), dy.dtype)
    )
    return (
        dx.astype(x.dtype),
        dw.astype(w.dtype),
        db_out,
        np.zeros(key32.shape, dtype=jax.dtypes.float0),
    )


_sparse_conv2d.defvjp(_fwd, _bwd)

_DUMMY_KEY = np.zeros((2,), dtype=np.uint32)


def sparse_conv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: Union[int, Sequence[int]] = 1,
    padding: Union[str, int, Sequence[Tuple[int, int]]] = 0,
    dilation: Union[int, Sequence[int]] = 1,
    groups: int = 1,
    policy: SsPropPolicy = SsPropPolicy(),
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """2-D convolution (NCHW) with ssProp scheduled-sparse backward.

    Args:
      x: ``[B, C_in, H, W]`` input.
      w: ``[C_out, C_in // groups, Kh, Kw]`` filters (OIHW).
      b: optional ``[C_out]`` bias.
      stride / padding / dilation / groups: as in any DL framework; the
        paper's simplifying assumptions (p=0, d=1, g=1) are *not* baked in.
      policy: ssProp policy.
      key: PRNG key for ``selection="random"``.
    """
    stride = _norm_pair(stride)
    dilation = _norm_pair(dilation)
    if isinstance(padding, int):
        padding = ((padding, padding), (padding, padding))
    elif isinstance(padding, str):
        pass
    else:
        padding = tuple(tuple(p) for p in padding)
    has_bias = b is not None
    key32 = (
        jax.random.key_data(key).astype(jnp.uint32).reshape(-1)
        if key is not None
        else jnp.asarray(_DUMMY_KEY)
    )
    if b is None:
        b = jnp.zeros((w.shape[0],), dtype=x.dtype)
    return _sparse_conv2d(
        policy, has_bias, stride, padding, dilation, groups, x, w, b, key32
    )
