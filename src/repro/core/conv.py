"""``sparse_conv2d``: convolution with ssProp channel-sparse backward.

Forward is ``jax.lax.conv_general_dilated`` (NCHW / OIHW, matching the
paper's tensor layout). Backward delegates to the shared channel-sparse
engine (:mod:`repro.core.backward`), which applies the paper's Fig. 1(a)
pipeline; this module supplies only the conv linear algebra:

* **full / mask-mode contraction** — the VJP of the conv itself,
* **gathered contraction** — the VJP of the conv restricted to the kept
  output channels, which XLA lowers to transposed convs with
  ``C_out' = K`` (exactly the (1-D) FLOPs saving of Eq. 9),
* **fused Pallas backward** — the default Pallas route
  (``fuse_im2col=True``): ``kernels/ops.py::conv_dx_fused`` /
  ``conv_dw_fused_scatter`` extract im2col patches inside the kernels'
  HBM→VMEM index maps, so the ``[M, C_in*Kh*Kw]`` patch buffer is never
  materialized. Grouped convs ride the same kernels in block-diagonal
  form whenever per-group channel counts are block-aligned.
* **canonical (im2col) lowering** — ``kernels/im2col.py`` columnizes the
  conv so block-granular selection routes through the same Pallas
  ``dx_gathered`` / ``dw_gathered_scatter`` kernels as ``sparse_dense``
  when ``use_pallas=True, granularity="block"``. With ``fuse_im2col``
  on this is only the A/B baseline; it materializes ``X2`` in HBM.

Grouped convs select a balanced top-k per group (the engine's shard
mechanism): a gathered grouped conv stays well-formed only when every
group keeps the same channel count. ``bwd_dtype`` and ``tp_shards``
behave as in ``sparse_dense``.
"""
from __future__ import annotations

from collections.abc import Sequence
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backward
from repro.core.policy import SsPropPolicy

# frozen, so safe to share as the signature default
_DEFAULT_POLICY = SsPropPolicy()

_DN = ("NCHW", "OIHW", "NCHW")


def _norm_pair(v) -> tuple[int, int]:
    if isinstance(v, int):
        return (v, v)
    return tuple(v)


def _conv(x, w, stride, padding, dilation, groups):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=padding,
        rhs_dilation=dilation,
        dimension_numbers=_DN,
        feature_group_count=groups,
    )


class _ConvOp(backward.ChannelSparseOp):
    """Conv adapter: NCHW dY, OIHW dW (output channels on axis 0)."""

    channel_axis = 1
    dw_channel_axis = 0

    def __init__(self, x, w, stride, padding, dilation, groups, policy):
        super().__init__(policy)
        self.x = x
        self.w = w
        self.stride = stride
        self.padding = padding
        self.dilation = dilation
        self.groups = groups
        self.c_out = w.shape[0]

    def selection_shards(self, policy: SsPropPolicy) -> int:
        s = 1
        if policy.tp_shards > 1 and self.c_out % policy.tp_shards == 0:
            s = policy.tp_shards
        if self.groups > 1 and (s < self.groups or s % self.groups != 0):
            # per-group balance is a structural requirement for gathered
            # grouped convs; it subsumes a TP degree it doesn't divide.
            s = self.groups
        return s

    def _vjp(self, w, dy_eff):
        """VJP of the conv (over cast operands) applied to ``dy_eff``."""
        x, w = self._cast(self.x), self._cast(w)
        _, vjp = jax.vjp(
            lambda x_, w_: _conv(
                x_, w_, self.stride, self.padding, self.dilation, self.groups
            ),
            x,
            w,
        )
        return vjp(dy_eff.astype(jnp.result_type(x.dtype, w.dtype)))

    def contract_full(self, dy_eff):
        return self._vjp(self.w, dy_eff)

    def _one_sided_vjp(self, dy_eff, wrt_x: bool, w=None):
        """VJP w.r.t. a single operand — the mixed sparsify_dx/dw paths
        ask for one gradient; differentiating only that operand avoids
        the discarded-half contraction outside jit. ``w`` defaults to
        the full filters (dense side); the gathered sides pass the
        kept-channel restriction."""
        x, w = self._cast(self.x), self._cast(self.w if w is None else w)
        conv = lambda x_, w_: _conv(
            x_, w_, self.stride, self.padding, self.dilation, self.groups
        )
        if wrt_x:
            _, vjp = jax.vjp(lambda x_: conv(x_, w), x)
        else:
            _, vjp = jax.vjp(lambda w_: conv(x, w_), w)
        return vjp(dy_eff.astype(jnp.result_type(x.dtype, w.dtype)))[0]

    def dx_full(self, dy_eff):
        return self._one_sided_vjp(dy_eff, wrt_x=True)

    def dw_full(self, dy_eff):
        return self._one_sided_vjp(dy_eff, wrt_x=False)

    def contract_gathered_dx(self, dy_k, sel):
        w_k = jnp.take(self.w, sel.idx, axis=0)
        return self._one_sided_vjp(dy_k, wrt_x=True, w=w_k)

    def contract_gathered_dw(self, dy_k, sel):
        w_k = jnp.take(self.w, sel.idx, axis=0)
        return self._one_sided_vjp(dy_k, wrt_x=False, w=w_k)

    def contract_gathered(self, dy_k, sel):
        # VJP of the conv restricted to the kept output channels — the
        # transposed convs XLA emits have C_out' = K, i.e. shrunk FLOPs.
        # Balanced per-group selection keeps kept channel j in group
        # j // k_loc, so feature_group_count survives the restriction.
        w_k = jnp.take(self.w, sel.idx, axis=0)
        return self._vjp(w_k, dy_k)

    def _explicit_padding(self) -> tuple[tuple[int, int], tuple[int, int]]:
        """Resolve string padding to explicit per-dim (lo, hi) pairs.

        The fused kernels address the zero-padded image directly, so they
        need numbers; ``padtype_to_pads`` wants the *effective* (dilated)
        filter extent."""
        if isinstance(self.padding, str):
            kh, kw = self.w.shape[2:]
            eff = tuple((k - 1) * d + 1 for k, d in zip((kh, kw), self.dilation, strict=True))
            pads = jax.lax.padtype_to_pads(
                self.x.shape[2:], eff, self.stride, self.padding
            )
            return tuple(tuple(p) for p in pads)
        return tuple(tuple(p) for p in self.padding)

    def fused_backward(self, dy_eff, sel, sdx, sdw):
        if not self.policy.fuse_im2col:
            return None
        if self.w.shape[2] == self.w.shape[3] == 1:
            # 1x1: im2col is a reshape/slice, there is no patch buffer
            # to fuse away — the canonical kernels are the cheaper path.
            return None
        bs = self.policy.block_size
        c_out = self.c_out
        if self.groups > 1 and c_out % (self.groups * bs) != 0:
            # block-diagonal routing needs whole blocks per group
            return None
        # The traffic model is the routing authority: fuse only when the
        # kernels' per-(tap × kept-block) re-fetches move fewer bytes
        # than the [M, N] patch buffers they eliminate. All inputs are
        # static, so this folds away under jit.
        from repro.core import flops as F

        bt, _, h_out, w_out = dy_eff.shape
        model = functools.partial(
            F.conv_backward_bytes_policy,
            bt, h_out, w_out, self.x.shape[1], c_out, self.w.shape[2],
            self.policy, groups=self.groups,
        )
        if model(fused=True) >= model(fused=False):
            return None
        from repro.kernels import ops as kops

        pads = self._explicit_padding()
        x, w = self._cast(self.x), self._cast(self.w)
        dy_eff = dy_eff.astype(jnp.result_type(x.dtype, w.dtype))
        nb = -(-c_out // bs)
        # dense side of a mixed sparsify_dx/dw policy: every block kept
        dense_idx = jnp.arange(nb, dtype=sel.block_idx.dtype)
        kh, kw = self.w.shape[2:]
        common = dict(
            stride=self.stride, padding=pads, dilation=self.dilation,
            groups=self.groups, block_size=bs,
        )
        dx = kops.conv_dx_fused(
            dy_eff, w, sel.block_idx if sdx else dense_idx,
            hw=self.x.shape[2:], **common,
        )
        dw2 = kops.conv_dw_fused_scatter(
            x, dy_eff, sel.block_idx if sdw else dense_idx, kh=kh, kw=kw, **common,
        )  # [Cg*Kh*Kw, C_out] with (c, kh, kw) row order -> OIHW
        dw = dw2.T.reshape(c_out, self.w.shape[1], kh, kw)
        return dx.astype(self._acc), dw.astype(self._acc)

    def canonical(self, dy_eff):
        if self.groups != 1:
            return None
        from repro.kernels import im2col

        c_out, _, kh, kw = self.w.shape
        x2, col2im, _ = im2col.conv_patches(
            self._cast(self.x), kh, kw, self.stride, self.padding, self.dilation
        )
        return backward.CanonicalForm(
            x2=x2,
            w2=self._cast(im2col.flatten_filters(self.w)),
            dy2=im2col.flatten_grad(dy_eff),
            dx_from=col2im,
            dw_from=lambda dw2: im2col.unflatten_filter_grad(dw2, self.w.shape),
        )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _sparse_conv2d(policy, has_bias, stride, padding, dilation, groups, x, w, b, key32):
    y = _conv(x, w, stride, padding, dilation, groups)
    if has_bias:
        y = y + b[None, :, None, None]
    return y


def _fwd(policy, has_bias, stride, padding, dilation, groups, x, w, b, key32):
    y = _sparse_conv2d(policy, has_bias, stride, padding, dilation, groups, x, w, b, key32)
    return y, (x, w, key32)


def _bwd(policy: SsPropPolicy, has_bias, stride, padding, dilation, groups, res, dy):
    x, w, key32 = res
    c_out = w.shape[0]
    op = _ConvOp(x, w, stride, padding, dilation, groups, policy)
    dx, dw, db = backward.channel_sparse_backward(
        policy, op, dy, key32=key32, has_bias=has_bias
    )
    db_out = db.astype(dy.dtype) if has_bias else jnp.zeros((c_out,), dy.dtype)
    return (
        dx.astype(x.dtype),
        dw.astype(w.dtype),
        db_out,
        np.zeros(key32.shape, dtype=jax.dtypes.float0),
    )


_sparse_conv2d.defvjp(_fwd, _bwd)

_DUMMY_KEY = np.zeros((2,), dtype=np.uint32)


def sparse_conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int | Sequence[int] = 1,
    padding: str | int | Sequence[tuple[int, int]] = 0,
    dilation: int | Sequence[int] = 1,
    groups: int = 1,
    policy: SsPropPolicy = _DEFAULT_POLICY,
    key: jax.Array | None = None,
) -> jax.Array:
    """2-D convolution (NCHW) with ssProp scheduled-sparse backward.

    Args:
      x: ``[B, C_in, H, W]`` input.
      w: ``[C_out, C_in // groups, Kh, Kw]`` filters (OIHW).
      b: optional ``[C_out]`` bias.
      stride / padding / dilation / groups: as in any DL framework; the
        paper's simplifying assumptions (p=0, d=1, g=1) are *not* baked in.
      policy: ssProp policy.
      key: PRNG key for ``selection="random"``.
    """
    stride = _norm_pair(stride)
    dilation = _norm_pair(dilation)
    if isinstance(padding, int):
        padding = ((padding, padding), (padding, padding))
    elif isinstance(padding, str):
        pass
    else:
        padding = tuple(tuple(p) for p in padding)
    has_bias = b is not None
    key32 = (
        jax.random.key_data(key).astype(jnp.uint32).reshape(-1)
        if key is not None
        else jnp.asarray(_DUMMY_KEY)
    )
    if b is None:
        b = jnp.zeros((w.shape[0],), dtype=x.dtype)
    return _sparse_conv2d(
        policy, has_bias, stride, padding, dilation, groups, x, w, b, key32
    )
