"""Channel importance and top-k gradient selection (paper Fig. 1(a)).

Given an output gradient ``dY``, the paper computes a per-output-channel
importance — the spatial/batch mean of ``|dY|`` — sorts it, and keeps the
top-K channels' gradients for the backward matmuls.

Two granularities are provided (DESIGN.md §3):

* ``"channel"``: per-channel top-k, exactly the paper.
* ``"block"``: top-k over contiguous blocks of ``block_size`` channels —
  the TPU-native form that keeps shrunk matmuls 128-lane/MXU aligned and
  lets the Pallas kernel fuse the gather into HBM→VMEM block addressing.

All functions are jit-safe: K is static, indices are data-dependent.
Returned indices are **sorted ascending** — gathers with monotone indices
lower to cheaper HLO and keep dW scatters coalesced.
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.policy import SsPropPolicy


class Selection(NamedTuple):
    """A complete, static-shape description of one selection decision.

    ``idx`` always holds ``k`` channel indices (sorted ascending, clamped
    into ``[0, C)``). With block granularity and a ragged channel tail
    (``C % block_size != 0``) some slots are phantoms — clamped
    duplicates of ``C-1`` — and ``valid`` marks the real ones; gathers
    must zero the phantom slots and scatters must accumulate with
    ``.add`` so the duplicates cannot overwrite the last real channel.
    ``valid is None`` means every slot is real.

    ``block_idx`` carries the kept *block* indices (global, sorted
    ascending — the form the Pallas gathered kernels consume) when the
    selection was block-granular. Sharded selections populate it too,
    whenever each shard's channel count is a multiple of the policy
    block size (the shard-local block size then equals the global one,
    so per-shard blocks tile exactly into global blocks).
    ``shard_idx``/``k_loc``/``n_shards`` carry the per-shard form for
    TP-local or per-group balanced selection.
    """

    idx: jax.Array
    k: int
    valid: jax.Array | None = None
    block_idx: jax.Array | None = None
    shard_idx: jax.Array | None = None
    k_loc: int = 0
    n_shards: int = 1


def channel_importance(dy: jax.Array, channel_axis: int = -1) -> jax.Array:
    """Mean of ``|dy|`` over every axis except ``channel_axis``.

    Returns a 1-D vector of length ``dy.shape[channel_axis]`` where larger
    values mean the channel "contributes more significantly to the
    gradients of inputs and weights/biases" (paper, Method).
    """
    axis = channel_axis % dy.ndim
    reduce_axes = tuple(a for a in range(dy.ndim) if a != axis)
    # fp32 accumulation: bf16 |dy| means underflow easily at large B*S.
    return jnp.mean(jnp.abs(dy).astype(jnp.float32), axis=reduce_axes)


def block_importance(imp: jax.Array, block_size: int) -> jax.Array:
    """Aggregate per-channel importance into per-block importance.

    Channels are padded with zeros up to a multiple of ``block_size``;
    block importance is the mean over the block (zeros in a ragged tail
    only dilute that tail block, matching "smallest gradients dropped
    first" semantics).
    """
    c = imp.shape[0]
    nblocks = -(-c // block_size)
    pad = nblocks * block_size - c
    if pad:
        imp = jnp.pad(imp, (0, pad))
    return imp.reshape(nblocks, block_size).mean(axis=1)


def select_topk_channels(
    imp: jax.Array,
    k: int,
    *,
    selection: str = "topk",
    key: jax.Array | None = None,
) -> jax.Array:
    """Indices of the K most important channels, sorted ascending.

    ``selection="random"`` reproduces the paper's Fig. 2(b) ablation:
    K channels chosen uniformly at random (requires ``key``).
    """
    c = imp.shape[0]
    if not 0 < k <= c:
        raise ValueError(f"k={k} out of range for {c} channels")
    if selection == "topk":
        _, idx = jax.lax.top_k(imp, k)
    elif selection == "random":
        if key is None:
            raise ValueError("selection='random' requires a PRNG key")
        idx = jax.random.permutation(key, c)[:k]
    else:
        raise ValueError(f"bad selection {selection!r}")
    return jnp.sort(idx)


def select_topk_blocks(
    imp: jax.Array,
    block_size: int,
    k_blocks: int,
    *,
    selection: str = "topk",
    key: jax.Array | None = None,
) -> jax.Array:
    """Indices of the K most important channel *blocks*, sorted ascending."""
    bimp = block_importance(imp, block_size)
    return select_topk_channels(bimp, k_blocks, selection=selection, key=key)


def block_indices_to_channels(block_idx: jax.Array, block_size: int) -> jax.Array:
    """Expand block indices to the flat channel indices they cover."""
    offs = jnp.arange(block_size)
    return (block_idx[:, None] * block_size + offs[None, :]).reshape(-1)


def select(
    dy: jax.Array,
    policy: SsPropPolicy,
    *,
    channel_axis: int = -1,
    n_shards: int = 1,
    key: jax.Array | None = None,
) -> Selection:
    """Policy-driven selection in its full structured form.

    ``n_shards > 1`` partitions the channel axis into that many contiguous
    equal groups and selects a balanced top-k within each — the form used
    both for TP-local selection (comm-free gathers) and for grouped convs
    (a gathered grouped conv stays well-formed only when every group
    keeps the same number of channels).
    """
    c = dy.shape[channel_axis % dy.ndim]
    if n_shards > 1:
        dy2 = jnp.moveaxis(dy, channel_axis % dy.ndim, -1).reshape(-1, c)
        shard_idx, k_loc = select_indices_per_shard(dy2, policy, n_shards, key=key)
        offs = jnp.arange(n_shards)[:, None] * (c // n_shards)
        flat = jnp.sort((shard_idx + offs).reshape(-1))
        block_idx = None
        c_loc = c // n_shards
        if (
            policy.granularity == "block"
            and c_loc % policy.block_size == 0
            and k_loc % policy.block_size == 0
        ):
            # Shard-local blocks tile exactly into global blocks (the
            # per-shard block size was not shrunk), so the flat sorted
            # channel indices regroup into whole kept blocks — the form
            # the Pallas gathered kernels consume. This is what routes
            # grouped convs / TP-local selection onto the fused kernels.
            block_idx = (
                flat.reshape(-1, policy.block_size)[:, 0] // policy.block_size
            )
        return Selection(
            idx=flat,
            k=n_shards * k_loc,
            block_idx=block_idx,
            shard_idx=shard_idx,
            k_loc=k_loc,
            n_shards=n_shards,
        )
    imp = channel_importance(dy, channel_axis)
    if policy.granularity == "channel":
        k = policy.keep_count(c)
        idx = select_topk_channels(imp, k, selection=policy.selection, key=key)
        return Selection(idx=idx, k=k)
    k_blocks = policy.keep_count(c)
    bidx = select_topk_blocks(
        imp, policy.block_size, k_blocks, selection=policy.selection, key=key
    )
    raw = block_indices_to_channels(bidx, policy.block_size)
    # Ragged tail (C % block_size != 0): the tail block covers phantom
    # channels past C-1. Clamp them into range for gathers, and mark them
    # invalid so the engine zeroes their gathered values and scatters
    # with .add — otherwise the clamped duplicates double-count /
    # arbitrarily overwrite channel C-1.
    valid = None
    if c % policy.block_size != 0:
        valid = raw < c
    idx = jnp.minimum(raw, c - 1)
    return Selection(idx=idx, k=k_blocks * policy.block_size, valid=valid, block_idx=bidx)


def select_indices(
    dy: jax.Array,
    policy: SsPropPolicy,
    *,
    channel_axis: int = -1,
    key: jax.Array | None = None,
) -> tuple[jax.Array, int]:
    """Back-compat view of :func:`select`: (sorted channel indices, K).

    For block granularity the indices are the expanded channel indices of
    the kept blocks, tail phantoms clamped to ``C-1``. Safe for building
    keep-masks (a phantom only exists when the tail block was kept, so
    its clamp target is itself a kept channel); gather/scatter callers
    must use :func:`select` and honour ``Selection.valid``.
    """
    sel = select(dy, policy, channel_axis=channel_axis, key=key)
    return sel.idx, sel.k


def keep_mask(
    dy_shape: Sequence[int],
    idx: jax.Array,
    *,
    channel_axis: int = -1,
    dtype=jnp.bool_,
) -> jax.Array:
    """Boolean mask over the channel axis: True on kept channels.

    Used by ``mask_mode`` (reference semantics) and by tests.
    """
    c = dy_shape[channel_axis % len(dy_shape)]
    flat = jnp.zeros((c,), dtype=jnp.bool_).at[idx].set(True)
    shape = [1] * len(dy_shape)
    shape[channel_axis % len(dy_shape)] = c
    return flat.reshape(shape).astype(dtype)


def shard_select_width(
    c: int, policy: SsPropPolicy, n_shards: int
) -> tuple[int, int]:
    """Static ``(k_loc, bs_loc)`` of sharded selection over ``C`` channels.

    ``k_loc`` is the per-shard gathered width (channels each shard keeps)
    and ``bs_loc`` the shard-local block size (halved until it tiles the
    shard; 1 for channel granularity). This is the sizing half of
    :func:`select_indices_per_shard`, split out so the FLOPs tables
    (``core/flops.py``) model the *same* contraction widths the engine
    traces — the honest-savings audit pins them equal, so keep the two
    in one place.
    """
    c_loc = c // n_shards
    if policy.granularity == "block":
        bs = policy.block_size
        while bs > 1 and (c_loc < bs or c_loc % bs):
            bs //= 2
        nblocks_loc = c_loc // bs
        k_total = max(1, int(round((1.0 - policy.drop_rate) * (c // bs))))
        return max(1, min(nblocks_loc, k_total // n_shards)) * bs, bs
    return max(1, policy.keep_count(c) // n_shards), 1


def select_indices_per_shard(
    dy2: jax.Array,
    policy: SsPropPolicy,
    tp_shards: int,
    *,
    key: jax.Array | None = None,
) -> tuple[jax.Array, int]:
    """TP-local selection: top-k/shard within each of ``tp_shards``
    contiguous channel groups (the TP shards of the output dim).

    Returns (idx [tp_shards, k_local] of *within-shard* channel indices,
    k_local). Selection is balanced across shards by construction, so the
    shrunk matmuls stay load-balanced, and — because the gather uses
    ``take_along_axis`` on the shard-local axis — GSPMD keeps it
    communication-free (DESIGN.md §3.4; §Perf iteration 1).
    """
    m, c = dy2.shape
    assert c % tp_shards == 0, (c, tp_shards)
    c_loc = c // tp_shards
    imp = channel_importance(dy2, -1).reshape(tp_shards, c_loc)
    if policy.granularity == "block":
        # shard-local block size: small projections (e.g. kv with few
        # heads) may hold fewer than block_size channels per shard.
        k_loc, bs = shard_select_width(c, policy, tp_shards)
        nblocks_loc = c_loc // bs
        k_loc_blocks = k_loc // bs
        bimp = imp.reshape(tp_shards, nblocks_loc, bs).mean(-1)
        _, bidx = jax.lax.top_k(bimp, k_loc_blocks)  # [S, kb]
        bidx = jnp.sort(bidx, axis=-1)
        offs = jnp.arange(bs)
        idx = (bidx[:, :, None] * bs + offs[None, None, :]).reshape(tp_shards, -1)
        return idx, k_loc
    k_loc, _ = shard_select_width(c, policy, tp_shards)
    if policy.selection == "random":
        if key is None:
            raise ValueError("random selection requires key")
        noise = jax.random.uniform(key, imp.shape)
        _, idx = jax.lax.top_k(noise, k_loc)
    else:
        _, idx = jax.lax.top_k(imp, k_loc)
    return jnp.sort(idx, axis=-1), k_loc


def mask_grad(
    dy: jax.Array,
    policy: SsPropPolicy,
    *,
    channel_axis: int = -1,
    key: jax.Array | None = None,
) -> jax.Array:
    """Zero out dropped channels of ``dy`` (mask-mode sparsification)."""
    if not policy.active:
        return dy
    idx, _ = select_indices(dy, policy, channel_axis=channel_axis, key=key)
    m = keep_mask(dy.shape, idx, channel_axis=channel_axis, dtype=dy.dtype)
    return dy * m
