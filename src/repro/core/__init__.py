"""ssProp core: scheduled channel-sparse back-propagation.

The paper's contribution as a composable JAX module:

* :mod:`repro.core.sparsity`   — channel importance + top-k selection.
* :mod:`repro.core.schedulers` — drop-rate schedulers (constant, linear,
  cosine, bar, 2-epoch bar).
* :mod:`repro.core.dense`      — ``sparse_dense``: matmul with
  channel-sparse backward (custom_vjp).
* :mod:`repro.core.conv`       — ``sparse_conv2d``: convolution with
  channel-sparse backward (custom_vjp).
* :mod:`repro.core.flops`      — the paper's FLOPs model (Eq. 6-11).
* :mod:`repro.core.policy`     — ``SsPropPolicy`` configuration object.
"""
from repro.core.policy import SsPropPolicy
from repro.core.schedulers import (
    bar_schedule,
    constant_schedule,
    cosine_schedule,
    drop_rate_for_step,
    epoch_bar_schedule,
    linear_schedule,
)
from repro.core.sparsity import (
    channel_importance,
    select_topk_channels,
    select_topk_blocks,
)
from repro.core.dense import sparse_dense
from repro.core.conv import sparse_conv2d
from repro.core import flops

__all__ = [
    "SsPropPolicy",
    "sparse_dense",
    "sparse_conv2d",
    "channel_importance",
    "select_topk_channels",
    "select_topk_blocks",
    "constant_schedule",
    "linear_schedule",
    "cosine_schedule",
    "bar_schedule",
    "epoch_bar_schedule",
    "drop_rate_for_step",
    "flops",
]
