"""ssProp core: scheduled channel-sparse back-propagation.

The paper's contribution as a composable JAX module:

* :mod:`repro.core.backward`   — the unified channel-sparse backward
  engine: one pipeline (importance → selection → gather → shrunk
  contraction → compact-gradient scatter, plus the mask-mode oracle,
  ``bwd_dtype`` casting, TP-local selection, and Pallas routing) that
  both ops below plug into via :class:`~repro.core.backward.ChannelSparseOp`.
* :mod:`repro.core.sparsity`   — channel importance + top-k selection
  (:class:`~repro.core.sparsity.Selection` carries the ragged-tail
  validity mask and per-shard balanced form).
* :mod:`repro.core.schedulers` — first-class drop-rate schedules
  (constant, linear, cosine, bar, 2-epoch bar, periodic bar) with
  per-schedule ``rate(step)`` / ``average_rate`` / bucket quantization.
* :mod:`repro.core.dense`      — ``sparse_dense``: matmul adapter over
  the engine (custom_vjp).
* :mod:`repro.core.conv`       — ``sparse_conv2d``: convolution adapter
  over the engine; lowers to im2col canonical form for the Pallas
  gathered kernels (``kernels/im2col.py``).
* :mod:`repro.core.flops`      — the paper's FLOPs model (Eq. 6-11) and
  the policy-aware counts (block rounding, Pallas tile padding).
* :mod:`repro.core.policy`     — the policy program surface:
  ``SsPropPolicy`` (one site's config), ``PolicyRules`` (site-name rule
  table), ``PolicyProgram`` / ``ResolvedProgram`` (rules + schedule,
  the train loop's one control object) and ``SitePolicies`` (the
  resolved site → policy table threaded through the models).
"""
from repro.core import flops
from repro.core.backward import ChannelSparseOp, channel_sparse_backward
from repro.core.conv import sparse_conv2d
from repro.core.dense import sparse_dense
from repro.core.policy import (
    DENSE,
    PolicyProgram,
    PolicyRules,
    ResolvedProgram,
    SitePolicies,
    SsPropPolicy,
    policy_for,
)
from repro.core.schedulers import (
    SCHEDULES,
    Bar,
    Constant,
    Cosine,
    EpochBar,
    Linear,
    PeriodicBar,
    Schedule,
    bar_schedule,
    constant_schedule,
    cosine_schedule,
    drop_rate_for_step,
    epoch_bar_schedule,
    linear_schedule,
    make_schedule,
)
from repro.core.sparsity import (
    Selection,
    channel_importance,
    select_topk_blocks,
    select_topk_channels,
)

__all__ = [
    "SsPropPolicy",
    "DENSE",
    "PolicyRules",
    "PolicyProgram",
    "ResolvedProgram",
    "SitePolicies",
    "policy_for",
    "Schedule",
    "Constant",
    "Linear",
    "Cosine",
    "Bar",
    "EpochBar",
    "PeriodicBar",
    "SCHEDULES",
    "make_schedule",
    "Selection",
    "ChannelSparseOp",
    "channel_sparse_backward",
    "sparse_dense",
    "sparse_conv2d",
    "channel_importance",
    "select_topk_channels",
    "select_topk_blocks",
    "constant_schedule",
    "linear_schedule",
    "cosine_schedule",
    "bar_schedule",
    "epoch_bar_schedule",
    "drop_rate_for_step",
    "flops",
]
