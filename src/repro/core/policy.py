"""ssProp policy configuration: per-site rules + scheduled programs.

Three layers, smallest first:

* :class:`SsPropPolicy` — the static (hashable) config for ONE call
  site: *how* that site's backward gradients are sparsified.
* :class:`PolicyRules` — a name-keyed rule table (glob patterns over
  site names, the same pattern ``repro/dist/sharding.py`` uses for
  partition specs) mapping sites to per-site policies. Resolved once
  per model against the model's enumerated site names into a
  :class:`SitePolicies` table.
* :class:`PolicyProgram` — rules + a first-class
  :class:`~repro.core.schedulers.Schedule`: the one control surface the
  train loop consumes. ``program.resolve(sites).policies_for_step(step)``
  replaces the old manual ``bucketed(drop_rate_for_step(...))`` dance.

Shape-static requirement
------------------------
XLA requires static shapes, so the *keep count* K must be a Python int
at trace time. The drop-rate schedule therefore lives outside jit: the
train loop asks the resolved program for the current step's policies,
which are quantized through the schedule's ``rate_buckets`` and retrace
(cached per bucket). For the paper's 2-epoch bar scheduler this means
exactly two compiled executables: dense (scale 0) and sparse (scale 1).

Site names
----------
Each model assigns a stable name to every sparsifiable call site
(``models/model.py::site_names``, ``models/resnet.py::site_names``,
``models/ddpm.py::site_names``): transformer stacks use
``layer_{i}/{attn|self|cross}/{q,k,v,o}``, ``layer_{i}/mlp/{up,gate,
down}``, ``layer_{i}/moe/...``, ``layer_{i}/ssm/{in_proj,out_proj}``;
CNNs use ``stem``, ``block_{i}/conv1`` etc. Rule patterns are
fnmatch-style globs over those names, plus brace sets with negative
indices and ranges resolved against the model depth:
``layer_{0,-1}/*`` (first and last layer), ``layer_{2..5}/mlp/*``,
``block_*/conv{1,2}``. First matching rule wins; unmatched sites get
the table's ``default``.
"""
from __future__ import annotations

from collections.abc import Iterable, Sequence
import dataclasses
import fnmatch
import re

from repro.core.schedulers import Constant, Schedule, SCHEDULE_NAMES


@dataclasses.dataclass(frozen=True)
class SsPropPolicy:
    """Static configuration for scheduled sparse back-propagation.

    Attributes:
      drop_rate: fraction of output channels whose gradients are dropped
        in the *current* compiled step. 0.0 disables sparsification.
      granularity: ``"channel"`` = per-channel top-k (paper-faithful);
        ``"block"`` = top-k over contiguous channel blocks of
        ``block_size`` (TPU/MXU-native adaptation, see DESIGN.md §3).
      block_size: channel-block width for ``granularity="block"``.
        128 matches the TPU lane width / MXU tile.
      selection: ``"topk"`` (paper) or ``"random"`` (Fig. 2(b) ablation).
      scheduler: legacy string name of the schedule that produced this
        rate — carried for logging and FLOPs accounting only; programs
        carry a first-class :class:`~repro.core.schedulers.Schedule`
        instead. Validated against the schedule registry at
        construction so a typo fails here, not deep in the train loop.
      target_rate: the schedule's target drop rate for this site (e.g.
        0.8 for the paper's bar schedule; 0.0 pins the site dense).
      rate_buckets: allowed compiled drop rates. Scheduled rates are
        rounded to the nearest bucket so the jit cache stays small.
      mask_mode: if True, dropped channels are zeroed but matmuls stay
        full-size (reference semantics; no FLOPs saved — used by tests
        and as the XLA-autodiff-visible fallback). If False, matmuls
        shrink to the kept channels (gather mode, FLOPs actually drop).
      sparsify_dx / sparsify_dw: apply sparsity to the input-gradient /
        weight-gradient matmul. Paper uses both.
      use_pallas: route the shrunk backward matmuls through the Pallas
        gathered-matmul kernels (TPU target; interpret-mode on CPU)
        rather than plain jnp gather+dot.
      fuse_im2col: with ``use_pallas`` on a conv site, extract im2col
        patches inside the kernels' HBM→VMEM index maps (the fused
        ``conv_dx_fused`` / ``conv_dw_fused`` kernels) instead of
        materializing the ``[M, C_in*Kh*Kw]`` patch buffer in HBM
        first. Default on; turn off to A/B against the materializing
        canonical-form path (``kernels/im2col.py``).
      seed: RNG seed for ``selection="random"``.
    """

    drop_rate: float = 0.0
    granularity: str = "channel"  # "channel" | "block"
    block_size: int = 128
    selection: str = "topk"  # "topk" | "random"
    scheduler: str = "epoch_bar"  # see schedulers.SCHEDULES
    target_rate: float = 0.8
    rate_buckets: tuple[float, ...] = (0.0, 0.25, 0.5, 0.8, 0.95)
    mask_mode: bool = False
    sparsify_dx: bool = True
    sparsify_dw: bool = True
    use_pallas: bool = False
    fuse_im2col: bool = True  # conv sites: patch extraction in-kernel
    tp_shards: int = 0  # >0: TP-local per-shard top-k (comm-free gather;
    #   equal k per shard -> load-balanced shrunk matmuls). §Perf iter 1.
    bwd_dtype: str = ""  # "bfloat16": backward matmuls/psums in bf16
    #   (halves the fp32 cotangent all-reduce volume). §Perf iter 5.
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(f"drop_rate must be in [0, 1), got {self.drop_rate}")
        if self.granularity not in ("channel", "block"):
            raise ValueError(f"bad granularity {self.granularity!r}")
        if self.selection not in ("topk", "random"):
            raise ValueError(f"bad selection {self.selection!r}")
        if self.scheduler not in SCHEDULE_NAMES:
            raise ValueError(
                f"unknown scheduler {self.scheduler!r}; "
                f"known: {sorted(SCHEDULE_NAMES)}"
            )

    @property
    def active(self) -> bool:
        return self.drop_rate > 0.0

    def keep_count(self, channels: int) -> int:
        """Number of channels (or blocks) retained for ``channels`` outputs.

        Per-channel: K = max(1, round((1-D) * C)).
        Block: computed over ceil(C / block_size) blocks, at least 1 block.
        """
        if self.granularity == "channel":
            return max(1, int(round((1.0 - self.drop_rate) * channels)))
        nblocks = -(-channels // self.block_size)
        return max(1, int(round((1.0 - self.drop_rate) * nblocks)))

    def with_rate(self, rate: float) -> "SsPropPolicy":
        return dataclasses.replace(self, drop_rate=float(rate))

    def with_target(self, rate: float) -> "SsPropPolicy":
        """Same knobs, retargeted to ``rate`` (and currently at it)."""
        return dataclasses.replace(
            self, drop_rate=float(rate), target_rate=float(rate)
        )

    def bucketed(self, rate: float) -> "SsPropPolicy":
        """Round ``rate`` to the nearest allowed bucket and return a policy."""
        best = min(self.rate_buckets, key=lambda b: abs(b - rate))
        return self.with_rate(best)


DENSE = SsPropPolicy(drop_rate=0.0, target_rate=0.0)
"""The canonical "never sparsify" policy — the one definition of dense.

Use this as the default everywhere a policy parameter is optional; its
``target_rate`` is pinned to 0 so a program can never schedule it
sparse.
"""


def paper_default(drop_rate: float = 0.8) -> SsPropPolicy:
    """The paper's winning configuration: channel top-k + 2-epoch bar."""
    return SsPropPolicy(
        drop_rate=drop_rate,
        granularity="channel",
        selection="topk",
        scheduler="epoch_bar",
        target_rate=drop_rate,
    )


def tpu_default(drop_rate: float = 0.8) -> SsPropPolicy:
    """TPU-native configuration: 128-channel-block top-k (DESIGN.md §3)."""
    return SsPropPolicy(
        drop_rate=drop_rate,
        granularity="block",
        block_size=128,
        selection="topk",
        scheduler="epoch_bar",
        target_rate=drop_rate,
    )


# ----------------------------------------------------------------------
# site tables
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SitePolicies:
    """A resolved site → policy table (hashable, jit-cache-key safe).

    The per-model output of :meth:`PolicyRules.resolve`: one entry per
    enumerated call site. Lookups of names outside the table fall back
    to ``default`` — model code can therefore thread a ``SitePolicies``
    anywhere a plain :class:`SsPropPolicy` is accepted and every named
    call site picks up its own policy via :func:`policy_for`.
    """

    entries: tuple[tuple[str, SsPropPolicy], ...]
    default: SsPropPolicy = DENSE

    def __post_init__(self):
        object.__setattr__(self, "_table", dict(self.entries))

    def __getitem__(self, name: str) -> SsPropPolicy:
        return self._table.get(name, self.default)

    def __contains__(self, name: str) -> bool:
        return name in self._table

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self.entries)

    def scoped(self, prefix: str) -> "SitePolicies":
        """The sub-table under ``prefix + "/"``, names stripped of it.

        ``table.scoped("layer_3")["attn/q"] == table["layer_3/attn/q"]``.
        """
        cut = len(prefix) + 1
        sub = tuple(
            (n[cut:], p)
            for n, p in self.entries
            if n.startswith(prefix + "/")
        )
        return SitePolicies(sub, default=self.default)

    def uniform(self) -> SsPropPolicy | None:
        """The single policy if every entry (and the default) agrees."""
        pols = {p for _, p in self.entries} | {self.default}
        return next(iter(pols)) if len(pols) == 1 else None


PolicyLike = SsPropPolicy | SitePolicies


def policy_for(policy: PolicyLike, site: str) -> SsPropPolicy:
    """Resolve the policy for one named call site.

    A plain :class:`SsPropPolicy` applies to every site (the legacy
    global-policy path, bit-exact by construction); a
    :class:`SitePolicies` table looks the site up by name.
    """
    if isinstance(policy, SitePolicies):
        return policy[site]
    return policy


# ----------------------------------------------------------------------
# rule patterns
# ----------------------------------------------------------------------


_BRACE = re.compile(r"\{([^{}]*)\}")
_RANGE = re.compile(r"^(-?\d+)\.\.(-?\d+)$")
_INT = re.compile(r"^-?\d+$")


def _resolve_index(value: int, depth: int | None, pattern: str) -> int:
    if value < 0:
        if depth is None:
            raise ValueError(
                f"pattern {pattern!r} uses a negative index but the model "
                "has no depth to resolve it against"
            )
        value += depth
    return value


def expand_pattern(pattern: str, depth: int | None = None) -> tuple[str, ...]:
    """Expand brace sets into plain glob patterns.

    Items in ``{...}`` may be literals (``{conv1,conv2}``), integers —
    negative ones resolve against ``depth``, Python-style
    (``layer_{0,-1}``) — or inclusive ranges (``layer_{2..5}``,
    ``layer_{0..-2}``). Multiple groups expand as a cartesian product.
    """
    m = _BRACE.search(pattern)
    if not m:
        return (pattern,)
    head, tail = pattern[: m.start()], pattern[m.end():]
    items = []
    for part in m.group(1).split(","):
        part = part.strip()
        rm = _RANGE.match(part)
        if rm:
            lo = _resolve_index(int(rm.group(1)), depth, pattern)
            hi = _resolve_index(int(rm.group(2)), depth, pattern)
            items.extend(str(v) for v in range(lo, hi + 1))
        elif _INT.match(part):
            items.append(str(_resolve_index(int(part), depth, pattern)))
        else:
            items.append(part)
    out = []
    for it in items:
        out.extend(expand_pattern(head + it + tail, depth))
    return tuple(out)


def pattern_matches(pattern: str, site: str, depth: int | None = None) -> bool:
    """fnmatch-style match of one rule pattern against a site name."""
    return any(
        fnmatch.fnmatchcase(site, glob) for glob in expand_pattern(pattern, depth)
    )


# ----------------------------------------------------------------------
# rule table
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PolicyRules:
    """Ordered (pattern, policy) rules over site names — first match wins.

    The sparsity analogue of the ``dist/sharding.py`` partition-spec
    rule table: mesh-independent rules, resolved once per model against
    its enumerated sites. A rule's policy carries the site's *target*
    rate (``target_rate``); the schedule scales every site between 0
    and its own target in lock-step.
    """

    rules: tuple[tuple[str, SsPropPolicy], ...]
    default: SsPropPolicy = DENSE

    @classmethod
    def single(cls, policy: SsPropPolicy) -> "PolicyRules":
        """The trivial one-rule program: ``policy`` at every site."""
        return cls(rules=(("*", policy),), default=policy)

    @classmethod
    def of(cls, *rules, base: SsPropPolicy, default: SsPropPolicy | None = None):
        """Build rules from (pattern, rate-or-policy) pairs.

        A float rate becomes ``base.with_target(rate)`` — so every site
        shares ``base``'s granularity/selection knobs and differs only
        in its target rate. ``default`` falls back to dense.
        """
        rows = []
        for pattern, rule in rules:
            if not isinstance(rule, SsPropPolicy):
                rule = base.with_target(float(rule))
            rows.append((pattern, rule))
        return cls(
            rules=tuple(rows),
            default=base.with_target(0.0) if default is None else default,
        )

    @classmethod
    def parse(cls, text: str, base: SsPropPolicy) -> "PolicyRules":
        """Parse the CLI mini-grammar: ``"pattern=rate;pattern=rate"``.

        ``rate`` is a float target drop rate or the word ``dense``
        (= 0.0). Example::

            layer_{0,-1}/*=dense;*/attn/*=0.5;*=0.8
        """
        rows = []
        for clause in text.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            pattern, _, rate = clause.rpartition("=")
            if not pattern:
                raise ValueError(f"bad rule clause {clause!r} (want pattern=rate)")
            rows.append(
                (pattern, 0.0 if rate.strip() == "dense" else float(rate))
            )
        return cls.of(*rows, base=base)

    def resolve(
        self, sites: Sequence[str], *, depth: int | None = None
    ) -> SitePolicies:
        """Assign every enumerated site its policy (first match wins)."""
        entries = []
        for site in sites:
            for pattern, pol in self.rules:
                if pattern_matches(pattern, site, depth):
                    entries.append((site, pol))
                    break
            else:
                entries.append((site, self.default))
        return SitePolicies(tuple(entries), default=self.default)


# ----------------------------------------------------------------------
# programs
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PolicyProgram:
    """Rules + schedule: the one ssProp control surface.

    ``program.resolve(sites, depth=...)`` binds the rules to a concrete
    model; the :class:`ResolvedProgram` then answers
    ``policies_for_step(step)`` for the train loop and per-site FLOPs
    questions for the benchmarks.
    """

    rules: PolicyRules
    schedule: Schedule

    @classmethod
    def single(
        cls, policy: SsPropPolicy, schedule: Schedule | None = None
    ) -> "PolicyProgram":
        """The trivial program: one global policy, optionally scheduled.

        Without a schedule the program runs *exactly this policy* every
        step (a :class:`~repro.core.schedulers.Constant` at its
        ``drop_rate`` — so a dense policy stays dense regardless of its
        legacy ``target_rate`` field), which is bit-exact with threading
        the bare policy. With a schedule the policy's ``target_rate``
        is the peak the schedule modulates toward.
        """
        if schedule is None:
            policy = policy.with_target(policy.drop_rate)
            if policy.drop_rate not in policy.rate_buckets:
                # keep the bit-exactness promise for off-bucket rates:
                # the policy's own rate is always a legal bucket
                policy = dataclasses.replace(
                    policy,
                    rate_buckets=tuple(
                        sorted((*policy.rate_buckets, policy.drop_rate))
                    ),
                )
            schedule = Constant(
                target=policy.target_rate, rate_buckets=policy.rate_buckets
            )
        return cls(rules=PolicyRules.single(policy), schedule=schedule)

    def resolve(
        self, sites: Sequence[str], *, depth: int | None = None
    ) -> "ResolvedProgram":
        return ResolvedProgram(
            sites=self.rules.resolve(sites, depth=depth), schedule=self.schedule
        )


@dataclasses.dataclass(frozen=True)
class ResolvedProgram:
    """A program bound to one model's site table.

    ``sites`` holds every site at its *target* rate; per-step tables
    come from scaling each site by the schedule's (bucket-quantized)
    activation fraction. Over a whole run the number of distinct
    per-step tables — and therefore compiled executables — is bounded
    by ``len(schedule.rate_buckets)``.
    """

    sites: SitePolicies
    schedule: Schedule

    def at_scale(self, scale: float) -> SitePolicies:
        """Every site at ``site_target * scale``, bucket-quantized."""

        def mod(p: SsPropPolicy) -> SsPropPolicy:
            return p.bucketed(p.target_rate * scale)

        return SitePolicies(
            tuple((n, mod(p)) for n, p in self.sites.entries),
            default=mod(self.sites.default),
        )

    def policies_for_step(self, step: int) -> SitePolicies:
        return self.at_scale(self.schedule.scale(step))

    def peak(self) -> SitePolicies:
        """The fully-on table (scale 1): what a sparse epoch runs."""
        return self.at_scale(1.0)

    def average_scale(self, total_steps: int) -> float:
        """Mean schedule activation over a run (for FLOPs accounting)."""
        if self.schedule.target <= 0.0:
            return 0.0
        return min(
            self.schedule.average_rate(total_steps) / self.schedule.target, 1.0
        )

    def average_rates(self, total_steps: int) -> dict[str, float]:
        """Per-site mean drop rate over a run — the per-site input to
        total-FLOPs accounting (each site saves at its own rate, not one
        global number)."""
        s = self.average_scale(total_steps)
        return {n: p.target_rate * s for n, p in self.sites.entries}


def site_tables_equal(tables: Iterable[SitePolicies]) -> bool:
    """True when every table in ``tables`` is identical (used by the
    scan-layers uniformity check in ``models/transformer.py``)."""
    it = iter(tables)
    first = next(it, None)
    return all(t == first for t in it)
