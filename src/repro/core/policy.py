"""ssProp policy configuration.

A :class:`SsPropPolicy` describes *how* backward gradients are sparsified.
It is a static (hashable) config object threaded through model builders so
every ``sparse_dense`` / ``sparse_conv2d`` call site sees the same policy.

Shape-static requirement
------------------------
XLA requires static shapes, so the *keep count* K must be a Python int at
trace time. The drop-rate *schedule* therefore lives outside jit: the
train loop asks :func:`repro.core.schedulers.drop_rate_for_step` for the
current rate, quantizes it to ``rate_buckets`` and retraces (cached per
bucket). For the paper's 2-epoch bar scheduler this means exactly two
compiled executables: dense (rate 0.0) and sparse (rate 0.8).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SsPropPolicy:
    """Static configuration for scheduled sparse back-propagation.

    Attributes:
      drop_rate: fraction of output channels whose gradients are dropped
        in the *current* compiled step. 0.0 disables sparsification.
      granularity: ``"channel"`` = per-channel top-k (paper-faithful);
        ``"block"`` = top-k over contiguous channel blocks of
        ``block_size`` (TPU/MXU-native adaptation, see DESIGN.md §3).
      block_size: channel-block width for ``granularity="block"``.
        128 matches the TPU lane width / MXU tile.
      selection: ``"topk"`` (paper) or ``"random"`` (Fig. 2(b) ablation).
      scheduler: which schedule produced this rate — carried for logging
        and FLOPs accounting only; the schedule itself runs in the host
        loop (see module docstring).
      target_rate: the schedule's target drop rate (e.g. 0.8 for the
        paper's bar schedule).
      rate_buckets: allowed compiled drop rates. The host loop rounds the
        scheduled rate to the nearest bucket so the jit cache stays small.
      mask_mode: if True, dropped channels are zeroed but matmuls stay
        full-size (reference semantics; no FLOPs saved — used by tests and
        as the XLA-autodiff-visible fallback). If False, matmuls shrink to
        the kept channels (gather mode, FLOPs actually drop).
      sparsify_dx / sparsify_dw: apply sparsity to the input-gradient /
        weight-gradient matmul. Paper uses both.
      use_pallas: route the shrunk backward matmuls through the Pallas
        gathered-matmul kernels (TPU target; interpret-mode on CPU) rather
        than plain jnp gather+dot.
      seed: RNG seed for ``selection="random"``.
    """

    drop_rate: float = 0.0
    granularity: str = "channel"  # "channel" | "block"
    block_size: int = 128
    selection: str = "topk"  # "topk" | "random"
    scheduler: str = "epoch_bar"  # constant|linear|cosine|bar|epoch_bar
    target_rate: float = 0.8
    rate_buckets: Tuple[float, ...] = (0.0, 0.25, 0.5, 0.8, 0.95)
    mask_mode: bool = False
    sparsify_dx: bool = True
    sparsify_dw: bool = True
    use_pallas: bool = False
    tp_shards: int = 0  # >0: TP-local per-shard top-k (comm-free gather;
    #   equal k per shard -> load-balanced shrunk matmuls). §Perf iter 1.
    bwd_dtype: str = ""  # "bfloat16": backward matmuls/psums in bf16
    #   (halves the fp32 cotangent all-reduce volume). §Perf iter 5.
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(f"drop_rate must be in [0, 1), got {self.drop_rate}")
        if self.granularity not in ("channel", "block"):
            raise ValueError(f"bad granularity {self.granularity!r}")
        if self.selection not in ("topk", "random"):
            raise ValueError(f"bad selection {self.selection!r}")

    @property
    def active(self) -> bool:
        return self.drop_rate > 0.0

    def keep_count(self, channels: int) -> int:
        """Number of channels (or blocks) retained for ``channels`` outputs.

        Per-channel: K = max(1, round((1-D) * C)).
        Block: computed over ceil(C / block_size) blocks, at least 1 block.
        """
        if self.granularity == "channel":
            return max(1, int(round((1.0 - self.drop_rate) * channels)))
        nblocks = -(-channels // self.block_size)
        return max(1, int(round((1.0 - self.drop_rate) * nblocks)))

    def with_rate(self, rate: float) -> "SsPropPolicy":
        return dataclasses.replace(self, drop_rate=float(rate))

    def bucketed(self, rate: float) -> "SsPropPolicy":
        """Round ``rate`` to the nearest allowed bucket and return a policy."""
        best = min(self.rate_buckets, key=lambda b: abs(b - rate))
        return self.with_rate(best)


DENSE = SsPropPolicy(drop_rate=0.0)


def paper_default(drop_rate: float = 0.8) -> SsPropPolicy:
    """The paper's winning configuration: channel top-k + 2-epoch bar."""
    return SsPropPolicy(
        drop_rate=drop_rate,
        granularity="channel",
        selection="topk",
        scheduler="epoch_bar",
        target_rate=drop_rate,
    )


def tpu_default(drop_rate: float = 0.8) -> SsPropPolicy:
    """TPU-native configuration: 128-channel-block top-k (DESIGN.md §3)."""
    return SsPropPolicy(
        drop_rate=drop_rate,
        granularity="block",
        block_size=128,
        selection="topk",
        scheduler="epoch_bar",
        target_rate=drop_rate,
    )
