"""paligemma-3b [vlm] — SigLIP frontend stubbed (precomputed patch
embeddings), gemma backbone (arXiv:2407.07726)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    act="gelu",
    n_patches=256,
)
