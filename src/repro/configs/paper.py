"""The paper's own experiment configurations (Tables 1-3).

ResNet / DDPM training setups exactly as published: datasets, image
sizes, learning rates, epochs, batch sizes. Used by the benchmark tables
and the examples; the synthetic data layer substitutes the (offline-
unavailable) datasets with shape-identical deterministic streams.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperTask:
    task: str            # classification | generation
    dataset: str
    model: str           # resnet18 | resnet26 | resnet50 | ddpm
    image: tuple[int, int, int]
    n_classes: int
    lr: float
    epochs: int
    batch: int
    timesteps: int = 0   # DDPM only


CLASSIFICATION = {
    ("mnist", "resnet18"): PaperTask("classification", "mnist", "resnet18", (1, 28, 28), 10, 2e-4, 50, 128),
    ("mnist", "resnet50"): PaperTask("classification", "mnist", "resnet50", (1, 28, 28), 10, 2e-4, 50, 128),
    ("fashionmnist", "resnet18"): PaperTask("classification", "fashionmnist", "resnet18", (1, 28, 28), 10, 2e-4, 50, 128),
    ("fashionmnist", "resnet50"): PaperTask("classification", "fashionmnist", "resnet50", (1, 28, 28), 10, 2e-4, 50, 128),
    ("cifar10", "resnet18"): PaperTask("classification", "cifar10", "resnet18", (3, 32, 32), 10, 2e-4, 50, 128),
    ("cifar10", "resnet50"): PaperTask("classification", "cifar10", "resnet50", (3, 32, 32), 10, 2e-4, 250, 128),
    ("cifar100", "resnet18"): PaperTask("classification", "cifar100", "resnet18", (3, 32, 32), 100, 2e-4, 50, 128),
    ("cifar100", "resnet50"): PaperTask("classification", "cifar100", "resnet50", (3, 32, 32), 100, 2e-4, 250, 128),
    ("celeba", "resnet18"): PaperTask("classification", "celeba", "resnet18", (3, 64, 64), 40, 2e-4, 50, 128),
    ("celeba", "resnet50"): PaperTask("classification", "celeba", "resnet50", (3, 64, 64), 40, 2e-4, 50, 32),
    ("imagenet1k", "resnet18"): PaperTask("classification", "imagenet1k", "resnet18", (3, 224, 224), 1000, 2e-4, 50, 32),
    ("imagenet1k", "resnet50"): PaperTask("classification", "imagenet1k", "resnet50", (3, 224, 224), 1000, 2e-4, 50, 16),
}

GENERATION = {
    "mnist": PaperTask("generation", "mnist", "ddpm", (1, 28, 28), 0, 1e-3, 300, 128, timesteps=200),
    "fashionmnist": PaperTask("generation", "fashionmnist", "ddpm", (1, 28, 28), 0, 1e-3, 500, 128, timesteps=200),
    "celeba": PaperTask("generation", "celeba", "ddpm", (3, 64, 64), 0, 2e-4, 200, 128, timesteps=1000),
}
