"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384e top-8 + 1 shared
expert, expert d_ff=2048 (arXiv:2501.kimi2)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    n_experts=384,
    moe_topk=8,
    n_shared_experts=1,
)
