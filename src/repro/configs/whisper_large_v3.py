"""whisper-large-v3 [audio] — enc-dec backbone; conv/audio frontend is a
stub per the assignment (input_specs provides frame embeddings)
(arXiv:2212.04356). 32L = 32 encoder + 32 decoder layers; the encoder
length is Whisper's native 1500 frames, assigned seq_len is the decoder
length (DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_enc_layers=32,
    enc_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    act="gelu",
    gated_mlp=False,
)
