"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7, MoE 16e top-2 every
other layer (arXiv:2403.19887)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    n_experts=16,
    moe_topk=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    ssm_state=128,
    ssm_headdim=64,
)
