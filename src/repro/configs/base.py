"""Model / shape configuration system.

One :class:`ModelConfig` dataclass covers every assigned architecture
family (dense, MoE, hybrid SSM+attn, pure SSM, encoder-decoder, VLM).
Each ``repro/configs/<arch>.py`` exports ``CONFIG`` with the exact
constants from the assignment table and a ``reduced()`` smoke-test
variant. ``repro.configs.registry`` maps ``--arch`` ids to them.

Input shapes are global; the four assigned shape cells live in
:data:`SHAPES`.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    # global batch BELOW the multi-pod dp_size (2x16 = 32): the
    # ('pod','data') batch split cannot fit whole, so fit_spec's joint
    # placement keeps pod on batch and relocates data to the seq dim
    "train_tight": ShapeConfig("train_tight", 4_096, 8, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (see assignment table; DESIGN.md §4)."""

    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "silu"  # silu | gelu | relu2
    gated_mlp: bool = True  # False: 2-matrix MLP (nemotron relu2, whisper)
    rope_theta: float = 1e6
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    moe_topk: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1  # apply MoE on layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25
    moe_dp_groups: int = 0  # >0: DP-local MoE dispatch (§Perf iteration 2)

    # SSM / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0  # hybrid: one attention layer per this many (jamba 8)

    # encoder-decoder
    n_enc_layers: int = 0
    enc_seq: int = 0  # fixed encoder length (whisper: 1500 frames)

    # VLM
    n_patches: int = 0  # prefix length of stub patch embeddings

    # numerics / memory
    attn_q_chunk: int = 1024  # blocked-attention query chunk (memory lever)
    decode_seq_shard: bool = False  # §Perf iter 3: seq-sharded KV decode
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # ssProp integration: which projections get the sparse backward.
    ssprop_projections: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 for clean TP sharding.

        Standard practice (MaxText/Megatron pad vocab): the embedding
        table gets padded rows, logits for padded ids are masked to -inf.
        The logical vocab (targets, sampling) is unchanged.
        """
        return -(-self.vocab // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def supports_shape(self, shape: ShapeConfig) -> tuple[bool, str]:
        """Whether a shape cell applies (long_500k needs sub-quadratic)."""
        if shape.seq_len > 100_000 and self.family not in ("ssm", "hybrid"):
            return False, "long_500k skipped: pure full-attention arch (DESIGN.md §4)"
        return True, ""

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.family != "encdec" else 1) + v * d  # tok + unembed
        per_attn = (
            self.n_heads * self.head_dim * d  # q
            + 2 * self.n_kv_heads * self.head_dim * d  # kv
            + self.n_heads * self.head_dim * d  # o
        )
        per_mlp = (3 if self.gated_mlp else 2) * d * self.d_ff
        per_moe = (
            (self.n_experts + self.n_shared_experts) * 3 * d * self.d_ff
            + d * self.n_experts
        )
        per_ssm = (
            d * (2 * self.d_inner + 2 * self.ssm_state + self.n_ssm_heads)
            + self.d_inner * d
        )
        total = emb
        n_dec = self.n_layers
        for i in range(n_dec):
            is_attn = (self.attn_every == 0) or (i % self.attn_every == 0)
            if self.family in ("ssm",):
                total += per_ssm
                continue
            if self.family == "hybrid":
                total += per_attn if is_attn else per_ssm
            else:
                total += per_attn
            if self.is_moe and (i % self.moe_every == self.moe_offset):
                total += per_moe
            else:
                total += per_mlp
        for _ in range(self.n_enc_layers):
            total += per_attn + per_mlp
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full_moe = (
            (self.n_experts + self.n_shared_experts) * 3 * d * self.d_ff
            + d * self.n_experts
        )
        act_moe = (
            (self.moe_topk + self.n_shared_experts) * 3 * d * self.d_ff
            + d * self.n_experts
        )
        n_moe_layers = sum(
            1
            for i in range(self.n_layers)
            if i % self.moe_every == self.moe_offset
        )
        return self.param_count() - n_moe_layers * (full_moe - act_moe)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4 if self.attn_every else 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            moe_topk=min(self.moe_topk, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=32 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=16,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=min(self.enc_seq, 32) if self.enc_seq else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            dtype="float32",
            remat=False,
            scan_layers=self.scan_layers,
        )
        if self.attn_every:
            small["n_layers"] = self.attn_every * 2 if self.attn_every <= 2 else 4
            small["attn_every"] = 2
        small.update(overrides)
        return dataclasses.replace(self, **small)
