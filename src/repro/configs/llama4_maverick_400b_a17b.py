"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1 + shared expert,
early fusion (hf:meta-llama/Llama-4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    n_experts=128,
    moe_topk=1,
    n_shared_experts=1,
    moe_every=2,   # Maverick interleaves dense / MoE layers
    moe_offset=1,
)
