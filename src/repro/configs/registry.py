"""``--arch`` id → ModelConfig registry (assigned archs + paper models)."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig

_ARCH_MODULES = {
    "mistral-large-123b": "mistral_large_123b",
    "nemotron-4-15b": "nemotron_4_15b",
    "deepseek-67b": "deepseek_67b",
    "qwen2.5-3b": "qwen2_5_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-large-v3": "whisper_large_v3",
    "paligemma-3b": "paligemma_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mamba2-1.3b": "mamba2_1_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    try:
        mod = _ARCH_MODULES[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}") from None
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells():
    """All (arch, shape) cells with applicability flags — 50 rows."""
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = cfg.supports_shape(shape)
            rows.append((arch, sname, ok, why))
    return rows
