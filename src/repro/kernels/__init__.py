"""Pallas TPU kernels for ssProp's backward hot-spots.

* ``gathered_matmul`` — kernel bodies (pl.pallas_call + BlockSpec):
  block-gathered dX/dW matmuls (scalar-prefetch fused gather) and the
  channel-importance reduction.
* ``ops`` — jit'd public wrappers (padding, backend dispatch, scatter).
* ``ref`` — pure-jnp oracles; tests assert_allclose against these.
"""
from repro.kernels import ops, ref
from repro.kernels import gathered_matmul

__all__ = ["ops", "ref", "gathered_matmul"]
