"""Pallas TPU kernels for ssProp's backward hot-spots.

* ``gathered_matmul`` — kernel bodies (pl.pallas_call + BlockSpec):
  block-gathered dX/dW matmuls (scalar-prefetch fused gather), the
  fused-im2col conv backward kernels, and the channel-importance
  reduction.
* ``paged_attention`` — decode attention straight off the paged KV
  pool: the block table rides in SMEM and the BlockSpec index maps read
  physical pages in place (no per-layer gather).
* ``ops`` — jit'd public wrappers (padding, backend dispatch, scatter).
* ``ref`` — pure-jnp oracles; tests assert_allclose against these.
"""
from repro.kernels import ops, ref
from repro.kernels import gathered_matmul, paged_attention

__all__ = ["ops", "ref", "gathered_matmul", "paged_attention"]
