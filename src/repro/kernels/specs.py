"""Introspectable grid / BlockSpec descriptions of every Pallas kernel.

Each kernel in :mod:`repro.kernels.gathered_matmul` and
:mod:`repro.kernels.paged_attention` builds its ``pl.pallas_call`` from
a :class:`KernelSpec` returned by one of the ``*_spec`` constructors
below — and the static checker (:mod:`repro.analysis.pallas_check`)
evaluates the *same* spec objects over the full grid to prove in-bounds
access, block-shape divisibility and VMEM footprint, and to emulate HBM
traffic. Because kernel and checker consume one spec object, the two
cannot drift: an index-map change is automatically re-checked.

A spec is purely structural — grid, operand shapes, block shapes, index
maps, scratch buffers. Index maps have exactly the arity Pallas expects
(grid coordinates, plus the scalar-prefetch ref last when
``num_scalar_prefetch == 1``) and use only arithmetic/indexing, so the
checker can call them with plain Python ints and a NumPy array for the
prefetch operand.
"""
from __future__ import annotations

from collections.abc import Callable
import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BlockSpecInfo:
    """One operand's blocking: full shape, block shape, index map.

    ``index_map`` takes the grid coordinates (plus the scalar-prefetch
    array when the kernel uses one) and returns the *block* index per
    dimension — element offset = block index × block extent, exactly
    Pallas' ``BlockSpec`` contract. ``itemsize`` is the operand's bytes
    per element (for traffic/VMEM accounting).
    """

    name: str
    array_shape: tuple[int, ...]
    block_shape: tuple[int, ...]
    index_map: Callable
    itemsize: int = 4

    @property
    def block_elems(self) -> int:
        return math.prod(self.block_shape)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A kernel's full launch geometry.

    ``grid`` iterates sequentially on TPU with the *last* axis
    fastest-varying; a block whose index map returns the same indices on
    consecutive steps is fetched once and revisited in VMEM (the
    revisit-elision the traffic emulator models). ``scratch`` lists
    fp32 VMEM scratch shapes.
    """

    name: str
    grid: tuple[int, ...]
    in_specs: tuple[BlockSpecInfo, ...]
    out_specs: tuple[BlockSpecInfo, ...]
    num_scalar_prefetch: int = 0
    scratch: tuple[tuple[int, ...], ...] = ()

    @property
    def grid_size(self) -> int:
        return math.prod(self.grid)

    def grid_spec(self):
        """The ``pl.pallas_call`` grid spec this object describes."""
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        in_specs = [
            pl.BlockSpec(i.block_shape, i.index_map) for i in self.in_specs
        ]
        out_specs = [
            pl.BlockSpec(o.block_shape, o.index_map) for o in self.out_specs
        ]
        out = out_specs[0] if len(out_specs) == 1 else out_specs
        if self.num_scalar_prefetch or self.scratch:
            return dict(
                grid_spec=pltpu.PrefetchScalarGridSpec(
                    num_scalar_prefetch=self.num_scalar_prefetch,
                    grid=self.grid,
                    in_specs=in_specs,
                    out_specs=out,
                    scratch_shapes=[
                        pltpu.VMEM(s, jnp.float32) for s in self.scratch
                    ],
                )
            )
        return dict(grid=self.grid, in_specs=in_specs, out_specs=out)


# ----------------------------------------------------------------------
# gathered matmuls
# ----------------------------------------------------------------------


def dx_gathered_spec(
    m: int, n: int, d_in: int, kb: int, *, block_size: int = 128,
    bm: int = 128, bn: int = 128, itemsize: int = 4,
) -> KernelSpec:
    """dX[M, D_in] = Σ_kb dY[:, blk] @ W[:, blk]^T (see gathered_matmul)."""
    return KernelSpec(
        name="dx_gathered",
        grid=(m // bm, d_in // bn, kb),
        in_specs=(
            BlockSpecInfo(
                "dy", (m, n), (bm, block_size),
                lambda i, j, k, idx: (i, idx[k]), itemsize,
            ),
            BlockSpecInfo(
                "w", (d_in, n), (bn, block_size),
                lambda i, j, k, idx: (j, idx[k]), itemsize,
            ),
        ),
        out_specs=(
            BlockSpecInfo(
                "dx", (m, d_in), (bm, bn), lambda i, j, k, idx: (i, j), 4
            ),
        ),
        num_scalar_prefetch=1,
    )


def dw_gathered_spec(
    m: int, n: int, d_in: int, kb: int, *, block_size: int = 128,
    bm: int = 128, bk_m: int = 128, itemsize: int = 4,
) -> KernelSpec:
    """Compact dW[D_in, KB*bs] = X^T @ dY[:, kept]."""
    return KernelSpec(
        name="dw_gathered",
        grid=(d_in // bm, kb, m // bk_m),
        in_specs=(
            BlockSpecInfo(
                "x", (m, d_in), (bk_m, bm),
                lambda i, j, s, idx: (s, i), itemsize,
            ),
            BlockSpecInfo(
                "dy", (m, n), (bk_m, block_size),
                lambda i, j, s, idx: (s, idx[j]), itemsize,
            ),
        ),
        out_specs=(
            BlockSpecInfo(
                "dw", (d_in, kb * block_size), (bm, block_size),
                lambda i, j, s, idx: (i, j), 4,
            ),
        ),
        num_scalar_prefetch=1,
    )


# ----------------------------------------------------------------------
# fused-im2col conv backward
# ----------------------------------------------------------------------


def conv_dw_fused_spec(
    *, b: int, h_pad: int, w_pad: int, groups: int, cg: int, h_out: int,
    w_out: int, c_pad: int, kh_dim: int, kw_dim: int, stride, dilation,
    kb: int, block_size: int = 128, itemsize: int = 4,
) -> KernelSpec:
    """Compact conv dW ``[Kh, Kw, Cg, KB*bs]`` with fused patch gather.

    The image operand's index map holds the im2col contract checked
    against ``docs/kernels.md``: grid step ``(kh, j, s)`` reads padded
    image row ``(s // H_out) * H_pad + (s % H_out) * sh + kh * dh`` of
    the kept block's group.
    """
    sh, _ = stride
    dh, _ = dilation
    m2 = b * h_out
    bpg = (c_pad // block_size) // groups
    return KernelSpec(
        name="conv_dw_fused",
        grid=(kh_dim, kb, m2),
        in_specs=(
            BlockSpecInfo(
                "xg", (b * h_pad, groups, w_pad, cg), (1, 1, w_pad, cg),
                lambda kh, j, s, idx: (
                    (s // h_out) * h_pad + (s % h_out) * sh + kh * dh,
                    idx[j] // bpg,
                    0,
                    0,
                ),
                itemsize,
            ),
            BlockSpecInfo(
                "dy2r", (m2, w_out, c_pad), (1, w_out, block_size),
                lambda kh, j, s, idx: (s, 0, idx[j]), itemsize,
            ),
        ),
        out_specs=(
            BlockSpecInfo(
                "dw", (kh_dim, kw_dim, cg, kb * block_size),
                (1, kw_dim, cg, block_size),
                lambda kh, j, s, idx: (kh, 0, 0, j), 4,
            ),
        ),
        num_scalar_prefetch=1,
    )


def conv_dx_fused_spec(
    *, b: int, h_pad: int, w_pad: int, groups: int, cg: int, h_out: int,
    w_out: int, c_pad: int, kh_dim: int, kw_dim: int, stride, dilation,
    kb: int, block_size: int = 128, itemsize: int = 4,
) -> KernelSpec:
    """Padded-image conv dX with fused col2im scatter.

    The cotangent map inverts the dW map (clipped to a valid row — the
    kernel body masks out-of-range taps with ``pl.when``); the compact
    filter's map is *constant*, so the whole ``[Kh, Kw, Cg, KB*bs]``
    operand is fetched into VMEM exactly once across the row sweep.
    """
    sh, _ = stride
    dh, _ = dilation
    m2 = b * h_out
    bpg = (c_pad // block_size) // groups
    return KernelSpec(
        name="conv_dx_fused",
        grid=(b * h_pad, kb, kh_dim),
        in_specs=(
            BlockSpecInfo(
                "dy2r", (m2, w_out, c_pad), (1, w_out, block_size),
                lambda s, j, kh, idx: (
                    (s // h_pad) * h_out
                    + jnp.clip((s % h_pad - kh * dh) // sh, 0, h_out - 1),
                    0,
                    idx[j],
                ),
                itemsize,
            ),
            BlockSpecInfo(
                "w2k", (kh_dim, kw_dim, cg, kb * block_size),
                (kh_dim, kw_dim, cg, kb * block_size),
                lambda s, j, kh, idx: (0, 0, 0, 0), itemsize,
            ),
        ),
        out_specs=(
            BlockSpecInfo(
                "dxp", (b * h_pad, groups, w_pad, cg), (1, 1, w_pad, cg),
                lambda s, j, kh, idx: (s, idx[j] // bpg, 0, 0), 4,
            ),
        ),
        num_scalar_prefetch=1,
    )


# ----------------------------------------------------------------------
# importance / plain matmul
# ----------------------------------------------------------------------


def importance_spec(
    m: int, n: int, *, bm: int = 256, bn: int = 128, itemsize: int = 4
) -> KernelSpec:
    """imp[1, N] = Σ_row-blocks |dY| / M."""
    return KernelSpec(
        name="importance",
        grid=(n // bn, m // bm),
        in_specs=(
            BlockSpecInfo(
                "dy", (m, n), (bm, bn), lambda j, s: (s, j), itemsize
            ),
        ),
        out_specs=(
            BlockSpecInfo("imp", (1, n), (1, bn), lambda j, s: (0, j), 4),
        ),
    )


def matmul_spec(
    m: int, k: int, n: int, *, bm: int = 128, bn: int = 128, bk: int = 128,
    itemsize: int = 4,
) -> KernelSpec:
    """A[M, K] @ B[K, N] -> [M, N], MXU-tiled."""
    return KernelSpec(
        name="matmul",
        grid=(m // bm, n // bn, k // bk),
        in_specs=(
            BlockSpecInfo(
                "a", (m, k), (bm, bk), lambda i, j, s: (i, s), itemsize
            ),
            BlockSpecInfo(
                "b", (k, n), (bk, bn), lambda i, j, s: (s, j), itemsize
            ),
        ),
        out_specs=(
            BlockSpecInfo("out", (m, n), (bm, bn), lambda i, j, s: (i, j), 4),
        ),
    )


# ----------------------------------------------------------------------
# paged attention
# ----------------------------------------------------------------------


def paged_attention_spec(
    *, b: int, s: int, h: int, d: int, n_pages: int, bs_pg: int, kvh: int,
    nb: int, itemsize: int = 4,
) -> KernelSpec:
    """Decode attention over the K/V page pool via the block table.

    Grid ``(B, NB)``: batch row × logical block; the K/V maps read
    physical page ``tbl[b * NB + j]`` — the in-bounds proof over the
    full grid is exactly the "tables always address a real page" claim
    (the wrapper clips defensively; the checker proves the clip is a
    no-op for well-formed tables).
    """
    sg = s * (h // kvh)
    return KernelSpec(
        name="paged_attention",
        grid=(b, nb),
        in_specs=(
            BlockSpecInfo(
                "q", (b, s, h, d), (1, s, h, d),
                lambda bi, j, tbl: (bi, 0, 0, 0), itemsize,
            ),
            BlockSpecInfo(
                "k_pool", (n_pages, bs_pg, kvh, d), (1, bs_pg, kvh, d),
                lambda bi, j, tbl: (tbl[bi * nb + j], 0, 0, 0), itemsize,
            ),
            BlockSpecInfo(
                "v_pool", (n_pages, bs_pg, kvh, d), (1, bs_pg, kvh, d),
                lambda bi, j, tbl: (tbl[bi * nb + j], 0, 0, 0), itemsize,
            ),
            BlockSpecInfo(
                "qpos", (b, s), (1, s), lambda bi, j, tbl: (bi, 0), 4
            ),
        ),
        out_specs=(
            BlockSpecInfo(
                "out", (b, s, h, d), (1, s, h, d),
                lambda bi, j, tbl: (bi, 0, 0, 0), 4,
            ),
        ),
        num_scalar_prefetch=1,
        scratch=((kvh, sg), (kvh, sg), (kvh, sg, d)),
    )
