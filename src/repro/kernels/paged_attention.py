"""Pallas paged-attention decode kernel: K/V pages read in place.

The serving gather this kernel kills (``models/layers.py::attn_apply``,
paged branch) rebuilds a contiguous ``[B, NB*bs, KV, hd]`` K/V view from
the page pool **every layer** — ``pool[block_tables].reshape(...)`` is a
full HBM copy of the cache just to feed ``masked_attention``. Here the
block table rides in SMEM (scalar prefetch) and the ``BlockSpec`` index
map addresses physical page ``tables[b, j]`` directly during the
HBM→VMEM copy of grid step ``(b, j)`` — vLLM-style paged attention; the
pool is never re-materialized.

Addressing rules (mirrors the write path in ``attn_apply``):
  * grid = (B, NB): batch row × *logical* block; the K/V index map reads
    physical page ``tables[b*NB + j]``, so the tokens seen at step j sit
    at logical positions ``j*bs + [0, bs)``.
  * per-slot causality: a key at logical position t attends query row s
    iff ``t <= qpos[b, s]`` — identical to the gather path's mask, so
    stale pages of a slot's previous occupant and unassigned table
    entries (page 0) are fenced exactly as before.
  * softmax is *online* (flash-style running max/denominator in VMEM
    scratch) since pages stream block-by-block; all accumulation fp32.
    Masked positions contribute exp-of-masked = 0 explicitly — an
    all-masked page must not inflate the denominator.

GQA: q heads fold into their KV group (``[KV, S*G, D]``) so the batched
dot contracts per KV head without materializing the repeated K/V the
einsum path uses.
"""
from __future__ import annotations

import functools
import math

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

from repro.kernels import specs


def _paged_attn_kernel(
    tbl_ref, q_ref, k_ref, v_ref, qpos_ref, o_ref, m_ref, l_ref, acc_ref,
    *, bs_pg: int, nb: int, scale: float,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [S, H, D]
    s, h, d = q.shape
    kvh = k_ref.shape[2]
    g = h // kvh
    sg = s * g
    # head h = kv*G + g' -> group rows per KV head: [KV, S*G, D]
    qg = q.reshape(s, kvh, g, d).transpose(1, 0, 2, 3).reshape(kvh, sg, d)
    k = k_ref[0].transpose(1, 0, 2)  # [KV, bs, D] — physical page tbl[b, j]
    v = v_ref[0].transpose(1, 0, 2).astype(jnp.float32)
    scores = (
        jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        * scale
    )  # [KV, SG, bs]

    # logical positions of this page's tokens vs per-row query positions
    t_pos = j * bs_pg + jax.lax.broadcasted_iota(jnp.int32, (sg, bs_pg), 1)
    qp = jnp.repeat(qpos_ref[0], g)  # [SG] — row r is query s = r // G
    mask = t_pos <= qp[:, None]  # [SG, bs]

    m_prev = m_ref[...]  # [KV, SG]
    s_max = jnp.max(jnp.where(mask[None], scores, -1e30), axis=-1)
    m_new = jnp.maximum(m_prev, s_max)
    # exp(-1e30 - (-1e30)) = 1: masked slots must be zeroed explicitly,
    # not left to the exp — an all-masked page would corrupt l otherwise.
    p = jnp.where(mask[None], jnp.exp(scores - m_new[..., None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _done():
        out = acc_ref[...] / l_ref[...][..., None]  # [KV, SG, D]
        o_ref[0] = out.reshape(kvh, s, g, d).transpose(1, 0, 2, 3).reshape(s, h, d)


def paged_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    qpos: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Causal per-slot attention straight off the page pool.

    Args:
      q: ``[B, S, H, D]`` query rows (post-rope; S = step width).
      k_pool / v_pool: ``[n_pages, bs, KV, D]`` page pool, *after* this
        step's tokens were scattered in (same operand order as the
        gather path).
      block_tables: ``[B, NB]`` int32 logical block -> physical page.
      qpos: ``[B, S]`` int32 absolute query positions (per slot).

    Returns ``[B, S, H, D]`` fp32 (cast at the wrapper).
    """
    b, s, h, d = q.shape
    n_pages, bs_pg, kvh, d2 = k_pool.shape
    assert d == d2 and h % kvh == 0, (q.shape, k_pool.shape)
    nb = block_tables.shape[1]
    # tables are always valid page ids; clip defensively so a bad entry
    # can only read a wrong (causally fenced) page, never out of bounds
    tbl = jnp.clip(block_tables.reshape(-1).astype(jnp.int32), 0, n_pages - 1)
    spec = specs.paged_attention_spec(
        b=b, s=s, h=h, d=d, n_pages=n_pages, bs_pg=bs_pg, kvh=kvh, nb=nb,
        itemsize=q.dtype.itemsize,
    )
    return pl.pallas_call(
        functools.partial(
            _paged_attn_kernel, bs_pg=bs_pg, nb=nb, scale=1.0 / math.sqrt(d)
        ),
        **spec.grid_spec(),
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), jnp.float32),
        interpret=interpret,
    )(tbl, q, k_pool, v_pool, qpos.astype(jnp.int32))
