"""Pallas TPU kernels: channel-block-gathered backward matmuls.

The TPU-native heart of ssProp (DESIGN.md §3.2): instead of materializing
a shrunk ``dY_kept`` in HBM, the kept-block indices ride in SMEM (scalar
prefetch) and the ``BlockSpec`` index maps address the kept 128-channel
blocks of ``dY`` / ``W`` directly during the HBM→VMEM copy. The gather is
thus free — the MXU only ever sees dense, 128-aligned tiles.

Kernels:
  * ``dx_gathered``  : dX[M, D_in]  = Σ_kb dY[:, blk] @ W[:, blk]^T
  * ``dw_gathered``  : dWk[D_in, K] = X^T @ dY[:, kept]   (compact out)
  * ``conv_dx_fused`` / ``conv_dw_fused``: the conv backward with the
    im2col patch extraction *fused into the index maps* — the kernels
    read padded image rows / cotangent rows straight from HBM and never
    materialize the ``[M, C_in*Kh*Kw]`` patch buffer. The dynamic
    spatial offset (``oh*sh + kh*dh``) lands on a leading block-size-1
    axis whose index map computes the row arithmetically from the grid
    coordinates; ``kw``/stride are static strided slices of the loaded
    VMEM row. Grouped convs ride the same kernels in block-diagonal
    form: operands carry an explicit group axis and the kept output
    block's group indexes it (``block_idx[j] // blocks_per_group``).
  * ``importance``   : imp[N]       = mean_M |dY|

Grid iteration on TPU is sequential over the last axis, so accumulation
into the revisited output block (init at step 0) is the standard pattern.
All accumulation is fp32 (``preferred_element_type``).

Every kernel's grid and BlockSpecs come from the matching ``*_spec``
constructor in :mod:`repro.kernels.specs` — the introspectable launch
geometry the static checker (:mod:`repro.analysis.pallas_check`) proves
in-bounds and traffic-models. Kernel and checker share one spec object,
so the addressing documented in ``docs/kernels.md`` cannot silently
drift from what runs.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

from repro.kernels import specs


# ----------------------------------------------------------------------
# dX = dY[:, kept] @ W[:, kept]^T  — gather fused via scalar prefetch.
# ----------------------------------------------------------------------
def _dx_kernel(idx_ref, dy_ref, w_ref, out_ref, *, nk: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dy_blk = dy_ref[...]  # [bm, bk]   kept block of dY
    w_blk = w_ref[...]    # [bn, bk]   same kept block of W (D_in rows)
    out_ref[...] += jax.lax.dot_general(
        dy_blk,
        w_blk,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dx_gathered(
    dy: jax.Array,
    w: jax.Array,
    block_idx: jax.Array,
    *,
    block_size: int = 128,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """dX[M, D_in] from full dY[M, N], W[D_in, N], kept block_idx[KB].

    M, D_in must be multiples of (bm, bn) and N of block_size — callers
    (ops.py) pad. Output is fp32.
    """
    m, n = dy.shape
    d_in, n2 = w.shape
    assert n == n2, (n, n2)
    kb = block_idx.shape[0]
    assert m % bm == 0 and d_in % bn == 0 and n % block_size == 0

    spec = specs.dx_gathered_spec(
        m, n, d_in, kb, block_size=block_size, bm=bm, bn=bn,
        itemsize=dy.dtype.itemsize,
    )
    return pl.pallas_call(
        functools.partial(_dx_kernel, nk=kb),
        **spec.grid_spec(),
        out_shape=jax.ShapeDtypeStruct((m, d_in), jnp.float32),
        interpret=interpret,
    )(block_idx, dy, w)


# ----------------------------------------------------------------------
# compact dW = X^T @ dY[:, kept] — output written compact [D_in, K].
# ----------------------------------------------------------------------
def _dw_kernel(idx_ref, x_ref, dy_ref, out_ref, *, nsteps: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x_blk = x_ref[...]    # [bk_m, bm]  rows of X, D_in cols
    dy_blk = dy_ref[...]  # [bk_m, bs]  kept channel block of dY
    out_ref[...] += jax.lax.dot_general(
        x_blk,
        dy_blk,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dw_gathered(
    x: jax.Array,
    dy: jax.Array,
    block_idx: jax.Array,
    *,
    block_size: int = 128,
    bm: int = 128,
    bk_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Compact dW[D_in, KB*block_size] from X[M, D_in], dY[M, N].

    Column block j of the output corresponds to channel block
    ``block_idx[j]`` of the full dW; callers scatter it back.
    """
    m, d_in = x.shape
    m2, n = dy.shape
    assert m == m2
    kb = block_idx.shape[0]
    assert m % bk_m == 0 and d_in % bm == 0 and n % block_size == 0

    nsteps = m // bk_m
    spec = specs.dw_gathered_spec(
        m, n, d_in, kb, block_size=block_size, bm=bm, bk_m=bk_m,
        itemsize=x.dtype.itemsize,
    )
    return pl.pallas_call(
        functools.partial(_dw_kernel, nsteps=nsteps),
        **spec.grid_spec(),
        out_shape=jax.ShapeDtypeStruct((d_in, kb * block_size), jnp.float32),
        interpret=interpret,
    )(block_idx, x, dy)


# ----------------------------------------------------------------------
# fused-im2col conv backward: patch extraction in the index maps.
#
# Layouts (prepared by ops.py):
#   xg   [B*H_pad, G, W_pad, Cg]   zero-padded input, group-blocked
#   dy2r [B*H_out, W_out, C_pad]   cotangent rows, channels padded to
#                                  a block_size multiple
#   w2k  [Kh, Kw, Cg, C_pad]       filters, OIHW -> (kh, kw, c_in, c_out)
#
# The im2col row for output position (b, oh, ow) and tap (kh, kw) lives
# at padded-image row ``b*H_pad + oh*sh + kh*dh``, column ``ow*sw +
# kw*dw`` — the row part is pure index-map arithmetic on a block-size-1
# leading axis, the column part a static strided slice of the loaded
# row. Nothing [M, C_in*Kh*Kw]-shaped ever exists in HBM.
# ----------------------------------------------------------------------
def _conv_dw_kernel(idx_ref, x_ref, dy_ref, out_ref, *, kw_dim, sw, dw_, w_out):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    row = x_ref[0, 0]  # [W_pad, Cg] — padded image row oh*sh + kh*dh
    dyb = dy_ref[0]    # [W_out, bs] — cotangent row oh, kept block j
    for kw in range(kw_dim):
        lo = kw * dw_
        xs = jax.lax.slice(
            row, (lo, 0), (lo + sw * (w_out - 1) + 1, row.shape[1]), (sw, 1)
        )  # [W_out, Cg] — the (kh, kw) tap of every patch in this row
        out_ref[0, kw] += jax.lax.dot_general(
            xs, dyb, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # [Cg, bs]


def conv_dw_fused(
    xg: jax.Array,
    dy2r: jax.Array,
    block_idx: jax.Array,
    *,
    kh_dim: int,
    kw_dim: int,
    stride,
    dilation,
    h_out: int,
    block_size: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Compact conv dW with fused patch gather.

    Returns ``[Kh, Kw, Cg, KB*block_size]`` fp32 — tap-major compact
    weight gradient; column block j is output-channel block
    ``block_idx[j]``. Callers transpose to the canonical ``(c, kh, kw)``
    row order and scatter.
    """
    s_total, g, w_pad, cg = xg.shape
    m2, w_out, c_pad = dy2r.shape
    assert m2 % h_out == 0 and c_pad % block_size == 0
    b = m2 // h_out
    h_pad = s_total // b
    assert b * h_pad == s_total, (s_total, b, h_pad)
    kb = block_idx.shape[0]
    _, sw = stride
    _, dw_ = dilation

    spec = specs.conv_dw_fused_spec(
        b=b, h_pad=h_pad, w_pad=w_pad, groups=g, cg=cg, h_out=h_out,
        w_out=w_out, c_pad=c_pad, kh_dim=kh_dim, kw_dim=kw_dim,
        stride=stride, dilation=dilation, kb=kb, block_size=block_size,
        itemsize=xg.dtype.itemsize,
    )
    return pl.pallas_call(
        functools.partial(
            _conv_dw_kernel, kw_dim=kw_dim, sw=sw, dw_=dw_, w_out=w_out
        ),
        **spec.grid_spec(),
        out_shape=jax.ShapeDtypeStruct(
            (kh_dim, kw_dim, cg, kb * block_size), jnp.float32
        ),
        interpret=interpret,
    )(block_idx, xg, dy2r)


def _conv_dx_kernel(
    idx_ref, dy_ref, w_ref, out_ref, *, kw_dim, sh, sw, dh, dw_, h_out, h_pad,
    kbg, bs
):
    s = pl.program_id(0)
    j = pl.program_id(1)
    kh = pl.program_id(2)

    @pl.when((kh == 0) & (j % kbg == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Padded-image row s%h_pad receives tap kh from cotangent row oh
    # only when oh = (s%h_pad - kh*dh)/sh is a whole in-range number.
    oh_num = s % h_pad - kh * dh
    valid = (oh_num >= 0) & (oh_num < sh * h_out) & (oh_num % sh == 0)

    @pl.when(valid)
    def _acc():
        dyrow = dy_ref[0]  # [W_out, bs]
        for kw in range(kw_dim):
            wk = w_ref[kh, kw, :, pl.dslice(j * bs, bs)]  # [Cg, bs]
            part = jax.lax.dot_general(
                dyrow, wk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # [W_out, Cg]
            w_out, cg = part.shape
            if sw == 1:
                out_ref[0, 0, dw_ * kw : dw_ * kw + w_out, :] += part
            else:
                # strided scatter: interleave sw-1 zero rows, then a
                # contiguous add at the kw tap's column offset
                spread = jnp.pad(part[:, None, :], ((0, 0), (0, sw - 1), (0, 0)))
                spread = spread.reshape(w_out * sw, cg)
                n = sw * (w_out - 1) + 1
                out_ref[0, 0, dw_ * kw : dw_ * kw + n, :] += spread[:n]


def conv_dx_fused(
    dy2r: jax.Array,
    w2k: jax.Array,
    block_idx: jax.Array,
    *,
    b: int,
    h_pad: int,
    w_pad: int,
    groups: int,
    stride,
    dilation,
    block_size: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Padded-image conv dX with fused col2im scatter.

    ``w2k [Kh, Kw, Cg, KB*block_size]`` is the *compact* filter — kept
    output-channel blocks only, gathered by the caller (a tiny jnp take:
    filters are orders of magnitude smaller than activations). Its
    BlockSpec index map is constant, so the whole compact filter is
    fetched into VMEM exactly once and reused across every image row —
    re-fetching it per row would swamp the traffic the fusion saves.

    Returns ``dxp [B*H_pad, G, W_pad, Cg]`` fp32 — the input gradient on
    the zero-padded image; callers slice the padding off and restore
    NCHW. ``block_idx`` still rides in SMEM for the cotangent gather and
    the output group routing (pass ``arange(NB)`` with the full filter
    for the dense side of a mixed policy).
    """
    m2, w_out, c_pad = dy2r.shape
    kh_dim, kw_dim, cg, kbbs = w2k.shape
    assert m2 % b == 0
    h_out = m2 // b
    kb = block_idx.shape[0]
    assert kbbs == kb * block_size, (w2k.shape, kb, block_size)
    assert kb % groups == 0, (kb, groups)
    kbg = kb // groups
    sh, sw = stride
    dh, dw_ = dilation

    spec = specs.conv_dx_fused_spec(
        b=b, h_pad=h_pad, w_pad=w_pad, groups=groups, cg=cg, h_out=h_out,
        w_out=w_out, c_pad=c_pad, kh_dim=kh_dim, kw_dim=kw_dim,
        stride=stride, dilation=dilation, kb=kb, block_size=block_size,
        itemsize=dy2r.dtype.itemsize,
    )
    return pl.pallas_call(
        functools.partial(
            _conv_dx_kernel, kw_dim=kw_dim, sh=sh, sw=sw, dh=dh, dw_=dw_,
            h_out=h_out, h_pad=h_pad, kbg=kbg, bs=block_size,
        ),
        **spec.grid_spec(),
        out_shape=jax.ShapeDtypeStruct((b * h_pad, groups, w_pad, cg), jnp.float32),
        interpret=interpret,
    )(block_idx, dy2r, w2k)


# ----------------------------------------------------------------------
# importance: imp[N] = mean_M |dY| — fp32 tree of row-block partials.
# ----------------------------------------------------------------------
def _imp_kernel(dy_ref, out_ref, *, m_total: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    blk = jnp.abs(dy_ref[...].astype(jnp.float32))
    out_ref[...] += jnp.sum(blk, axis=0, keepdims=True) / m_total


def importance(
    dy: jax.Array,
    *,
    bm: int = 256,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Per-channel importance mean |dY| over rows: dy[M, N] -> [N] f32."""
    m, n = dy.shape
    assert m % bm == 0 and n % bn == 0
    spec = specs.importance_spec(m, n, bm=bm, bn=bn, itemsize=dy.dtype.itemsize)
    out = pl.pallas_call(
        functools.partial(_imp_kernel, m_total=m),
        **spec.grid_spec(),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(dy)
    return out[0]


# ----------------------------------------------------------------------
# plain blocked matmul (used for the per-channel-granularity fallback
# where the gather cannot be block-fused; also a tuning baseline).
# ----------------------------------------------------------------------
def _mm_kernel(a_ref, b_ref, out_ref):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """A[M, K] @ B[K, N] -> [M, N] f32, MXU-tiled."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    spec = specs.matmul_spec(
        m, k, n, bm=bm, bn=bn, bk=bk, itemsize=a.dtype.itemsize
    )
    return pl.pallas_call(
        _mm_kernel,
        **spec.grid_spec(),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
