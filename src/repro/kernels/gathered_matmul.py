"""Pallas TPU kernels: channel-block-gathered backward matmuls.

The TPU-native heart of ssProp (DESIGN.md §3.2): instead of materializing
a shrunk ``dY_kept`` in HBM, the kept-block indices ride in SMEM (scalar
prefetch) and the ``BlockSpec`` index maps address the kept 128-channel
blocks of ``dY`` / ``W`` directly during the HBM→VMEM copy. The gather is
thus free — the MXU only ever sees dense, 128-aligned tiles.

Kernels:
  * ``dx_gathered``  : dX[M, D_in]  = Σ_kb dY[:, blk] @ W[:, blk]^T
  * ``dw_gathered``  : dWk[D_in, K] = X^T @ dY[:, kept]   (compact out)
  * ``importance``   : imp[N]       = mean_M |dY|

Grid iteration on TPU is sequential over the last axis, so accumulation
into the revisited output block (init at step 0) is the standard pattern.
All accumulation is fp32 (``preferred_element_type``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ----------------------------------------------------------------------
# dX = dY[:, kept] @ W[:, kept]^T  — gather fused via scalar prefetch.
# ----------------------------------------------------------------------
def _dx_kernel(idx_ref, dy_ref, w_ref, out_ref, *, nk: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dy_blk = dy_ref[...]  # [bm, bk]   kept block of dY
    w_blk = w_ref[...]    # [bn, bk]   same kept block of W (D_in rows)
    out_ref[...] += jax.lax.dot_general(
        dy_blk,
        w_blk,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dx_gathered(
    dy: jax.Array,
    w: jax.Array,
    block_idx: jax.Array,
    *,
    block_size: int = 128,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """dX[M, D_in] from full dY[M, N], W[D_in, N], kept block_idx[KB].

    M, D_in must be multiples of (bm, bn) and N of block_size — callers
    (ops.py) pad. Output is fp32.
    """
    m, n = dy.shape
    d_in, n2 = w.shape
    assert n == n2, (n, n2)
    kb = block_idx.shape[0]
    assert m % bm == 0 and d_in % bn == 0 and n % block_size == 0

    grid = (m // bm, d_in // bn, kb)
    return pl.pallas_call(
        functools.partial(_dx_kernel, nk=kb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, block_size), lambda i, j, k, idx: (i, idx[k])),
                pl.BlockSpec((bn, block_size), lambda i, j, k, idx: (j, idx[k])),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, idx: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, d_in), jnp.float32),
        interpret=interpret,
    )(block_idx, dy, w)


# ----------------------------------------------------------------------
# compact dW = X^T @ dY[:, kept] — output written compact [D_in, K].
# ----------------------------------------------------------------------
def _dw_kernel(idx_ref, x_ref, dy_ref, out_ref, *, nsteps: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x_blk = x_ref[...]    # [bk_m, bm]  rows of X, D_in cols
    dy_blk = dy_ref[...]  # [bk_m, bs]  kept channel block of dY
    out_ref[...] += jax.lax.dot_general(
        x_blk,
        dy_blk,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dw_gathered(
    x: jax.Array,
    dy: jax.Array,
    block_idx: jax.Array,
    *,
    block_size: int = 128,
    bm: int = 128,
    bk_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Compact dW[D_in, KB*block_size] from X[M, D_in], dY[M, N].

    Column block j of the output corresponds to channel block
    ``block_idx[j]`` of the full dW; callers scatter it back.
    """
    m, d_in = x.shape
    m2, n = dy.shape
    assert m == m2
    kb = block_idx.shape[0]
    assert m % bk_m == 0 and d_in % bm == 0 and n % block_size == 0

    nsteps = m // bk_m
    grid = (d_in // bm, kb, nsteps)
    return pl.pallas_call(
        functools.partial(_dw_kernel, nsteps=nsteps),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bk_m, bm), lambda i, j, s, idx: (s, i)),
                pl.BlockSpec((bk_m, block_size), lambda i, j, s, idx: (s, idx[j])),
            ],
            out_specs=pl.BlockSpec((bm, block_size), lambda i, j, s, idx: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((d_in, kb * block_size), jnp.float32),
        interpret=interpret,
    )(block_idx, x, dy)


# ----------------------------------------------------------------------
# importance: imp[N] = mean_M |dY| — fp32 tree of row-block partials.
# ----------------------------------------------------------------------
def _imp_kernel(dy_ref, out_ref, *, m_total: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    blk = jnp.abs(dy_ref[...].astype(jnp.float32))
    out_ref[...] += jnp.sum(blk, axis=0, keepdims=True) / m_total


def importance(
    dy: jax.Array,
    *,
    bm: int = 256,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Per-channel importance mean |dY| over rows: dy[M, N] -> [N] f32."""
    m, n = dy.shape
    assert m % bm == 0 and n % bn == 0
    grid = (n // bn, m // bm)
    out = pl.pallas_call(
        functools.partial(_imp_kernel, m_total=m),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda j, s: (s, j))],
        out_specs=pl.BlockSpec((1, bn), lambda j, s: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(dy)
    return out[0]


# ----------------------------------------------------------------------
# plain blocked matmul (used for the per-channel-granularity fallback
# where the gather cannot be block-fused; also a tuning baseline).
# ----------------------------------------------------------------------
def _mm_kernel(a_ref, b_ref, out_ref):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """A[M, K] @ B[K, N] -> [M, N] f32, MXU-tiled."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
