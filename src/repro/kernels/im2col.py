"""im2col / patch lowering: conv backward in the canonical 2-D form.

The Pallas gathered kernels (:mod:`repro.kernels.gathered_matmul`) speak
one language — ``X2 [M, D_flat]``, ``W2 [D_flat, C_out]``, ``dY2
[M, C_out]`` — so a convolution reaches them by lowering to columnized
(im2col) form, exactly the paper's Eq. 6 exposition:

  * ``X2`` rows are the ``C_in*Kh*Kw`` receptive-field patches at each
    output position (``M = B*H_out*W_out``), via
    ``lax.conv_general_dilated_patches`` (channel ordering ``(c, kh,
    kw)`` — verified against OIHW filters).
  * ``dW2 = X2^T @ dY2_kept`` scattered, then ``dW = dW2^T`` reshaped to
    OIHW.
  * ``dX2 = dY2_kept @ W2_kept^T`` lifted back to the image by
    ``col2im`` — the exact VJP of the patch extraction, so stride,
    padding and dilation all transpose correctly for free.

This module is the *materializing* baseline: ``X2`` and ``dX2`` are
real ``[M, C_in*Kh*Kw]`` HBM buffers. The default Pallas route
(``SsPropPolicy.fuse_im2col``) skips it entirely — the fused kernels in
:mod:`repro.kernels.gathered_matmul` do the patch extraction and col2im
scatter inside their BlockSpec index maps, and their block-diagonal
canonical form covers grouped convs too. What still lowers here: the
``fuse_im2col=False`` A/B oracle, and 1x1 convs (where im2col is a
reshape and there is no patch buffer to fuse away). Grouped convs that
reach this path (only ``groups == 1`` lowers here) keep the
framework-native shrunk-VJP path in :mod:`repro.core.conv`.
"""
from __future__ import annotations

from collections.abc import Callable

import jax

_DN = ("NCHW", "OIHW", "NCHW")


def conv_patches(
    x: jax.Array,
    kh: int,
    kw: int,
    stride: tuple[int, int],
    padding,
    dilation: tuple[int, int],
) -> tuple[jax.Array, Callable[[jax.Array], jax.Array], tuple[int, int]]:
    """Extract receptive-field patches and return the col2im closure.

    Args:
      x: ``[B, C_in, H, W]`` input (NCHW).
      kh / kw: filter spatial dims.
      stride / padding / dilation: as accepted by
        ``lax.conv_general_dilated``.

    Returns:
      ``(x2, col2im, (h_out, w_out))`` where ``x2`` is
      ``[B*H_out*W_out, C_in*Kh*Kw]`` with columns ordered ``(c, kh,
      kw)`` (matching a flattened OIHW filter), and ``col2im`` lifts a
      cotangent of that shape back to ``[B, C_in, H, W]`` by
      scatter-adding each patch element to its source pixel.
    """
    b = x.shape[0]

    def patches_fn(x_):
        return jax.lax.conv_general_dilated_patches(
            x_,
            (kh, kw),
            stride,
            padding,
            rhs_dilation=dilation,
            dimension_numbers=_DN,
        )  # [B, C_in*Kh*Kw, H_out, W_out]

    p, col2im_vjp = jax.vjp(patches_fn, x)
    ckk, h_out, w_out = p.shape[1], p.shape[2], p.shape[3]
    x2 = p.transpose(0, 2, 3, 1).reshape(b * h_out * w_out, ckk)

    def col2im(dx2: jax.Array) -> jax.Array:
        dcol = dx2.reshape(b, h_out, w_out, ckk).transpose(0, 3, 1, 2)
        (dx,) = col2im_vjp(dcol.astype(p.dtype))
        return dx

    return x2, col2im, (h_out, w_out)


def flatten_filters(w: jax.Array) -> jax.Array:
    """OIHW filters → canonical ``W2 [C_in*Kh*Kw, C_out]``."""
    c_out = w.shape[0]
    return w.reshape(c_out, -1).T


def unflatten_filter_grad(dw2: jax.Array, w_shape: tuple[int, ...]) -> jax.Array:
    """Canonical ``dW2 [C_in*Kh*Kw, C_out]`` → OIHW filter gradient."""
    c_out, c_in, kh, kw = w_shape
    return dw2.T.reshape(c_out, c_in, kh, kw)


def flatten_grad(dy: jax.Array) -> jax.Array:
    """NCHW cotangent → canonical ``dY2 [B*H_out*W_out, C_out]`` (row
    order matching :func:`conv_patches`)."""
    b, c, h, w = dy.shape
    return dy.transpose(0, 2, 3, 1).reshape(b * h * w, c)
