"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` computes the exact mathematical result the kernel must
reproduce; tests sweep shapes/dtypes and ``assert_allclose`` kernel
(interpret=True) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expand_block_idx(block_idx: jax.Array, block_size: int) -> jax.Array:
    """Flat channel indices covered by the kept blocks, sorted order."""
    offs = jnp.arange(block_size)
    return (block_idx[:, None] * block_size + offs[None, :]).reshape(-1)


def dx_gathered_ref(
    dy: jax.Array, w: jax.Array, block_idx: jax.Array, block_size: int
) -> jax.Array:
    """dX = dY[:, kept] @ W[:, kept]^T with kept = expanded block_idx.

    Shapes: dy [M, N], w [D_in, N], block_idx [KB] -> out [M, D_in] f32.
    """
    cols = expand_block_idx(block_idx, block_size)
    dy_k = jnp.take(dy, cols, axis=1).astype(jnp.float32)
    w_k = jnp.take(w, cols, axis=1).astype(jnp.float32)
    return dy_k @ w_k.T


def dw_gathered_ref(
    x: jax.Array, dy: jax.Array, block_idx: jax.Array, block_size: int
) -> jax.Array:
    """Compact dW_kept = X^T @ dY[:, kept].

    Shapes: x [M, D_in], dy [M, N], block_idx [KB]
    -> out [D_in, KB*block_size] f32 (caller scatters into full dW).
    """
    cols = expand_block_idx(block_idx, block_size)
    dy_k = jnp.take(dy, cols, axis=1).astype(jnp.float32)
    return x.astype(jnp.float32).T @ dy_k


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain blocked-matmul oracle: A [M, K] @ B [K, N] in f32."""
    return a.astype(jnp.float32) @ b.astype(jnp.float32)


def importance_ref(dy: jax.Array) -> jax.Array:
    """Per-channel importance: mean |dY| over rows. dy [M, N] -> [N] f32."""
    return jnp.mean(jnp.abs(dy).astype(jnp.float32), axis=0)
