"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, backend detection (TPU → compiled
kernel, anything else → ``interpret=True`` so CPU CI exercises the same
kernel body), and the scatter of the compact dW back into the full
weight-gradient buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gathered_matmul as gm
from repro.kernels import paged_attention as pa


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_size",))
def dx_gathered(dy, w, block_idx, block_size: int = 128):
    """dX[M, D_in] = dY[:, kept] @ W[:, kept]^T, gather fused in-kernel."""
    m, n = dy.shape
    d_in = w.shape[0]
    dy_p = _pad_to(_pad_to(dy, 0, 128), 1, block_size)
    w_p = _pad_to(_pad_to(w, 0, 128), 1, block_size)
    out = gm.dx_gathered(
        dy_p, w_p, block_idx, block_size=block_size, interpret=_interpret()
    )
    return out[:m, :d_in]


@functools.partial(jax.jit, static_argnames=("block_size", "n_out"))
def dw_gathered_scatter(x, dy, block_idx, n_out: int, block_size: int = 128):
    """Full dW[D_in, N]: compact kernel output scattered into zeros."""
    m, d_in = x.shape
    x_p = _pad_to(_pad_to(x, 0, 128), 1, 128)
    dy_p = _pad_to(_pad_to(dy, 0, 128), 1, block_size)
    compact = gm.dw_gathered(
        x_p, dy_p, block_idx, block_size=block_size, interpret=_interpret()
    )  # [D_in_pad, KB*block_size]
    compact = compact[:d_in]
    kb = block_idx.shape[0]
    dw = jnp.zeros((d_in, -(-n_out // block_size), block_size), jnp.float32)
    dw = dw.at[:, block_idx, :].set(compact.reshape(d_in, kb, block_size))
    return dw.reshape(d_in, -1)[:, :n_out]


def _dy_rows(dy, block_size):
    """NCHW cotangent -> ``[B*H_out, W_out, C_pad]`` row layout."""
    b, c_out, h_out, w_out = dy.shape
    dy2r = dy.transpose(0, 2, 3, 1).reshape(b * h_out, w_out, c_out)
    return _pad_to(dy2r, 2, block_size)


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "dilation", "groups", "block_size"),
)
def conv_dw_fused_scatter(
    x, dy, block_idx, *, kh, kw, stride, padding, dilation, groups, block_size=128
):
    """Canonical conv dW2 ``[Cg*Kh*Kw, C_out]`` with fused patch gather.

    The ``[M, C_in*Kh*Kw]`` im2col buffer is never built: the kernel's
    index maps read padded image rows in place (``gathered_matmul.
    conv_dw_fused``). Compact kernel output is scattered into full-size
    zeros over the kept output-channel blocks.
    """
    b, c_in, h, w_dim = x.shape
    c_out, h_out = dy.shape[1], dy.shape[2]
    cg = c_in // groups
    (ph0, ph1), (pw0, pw1) = padding
    h_pad, w_pad = h + ph0 + ph1, w_dim + pw0 + pw1
    c_pad = c_out + (-c_out) % block_size
    dy2r = _dy_rows(dy, block_size)
    # NCHW -> group-blocked padded rows [B*H_pad, G, W_pad, Cg]
    xp = jnp.pad(x, ((0, 0), (0, 0), padding[0], padding[1]))
    xg = (
        xp.transpose(0, 2, 3, 1)
        .reshape(b, h_pad, w_pad, groups, cg)
        .transpose(0, 1, 3, 2, 4)
        .reshape(b * h_pad, groups, w_pad, cg)
    )
    compact = gm.conv_dw_fused(
        xg, dy2r, block_idx, kh_dim=kh, kw_dim=kw, stride=stride,
        dilation=dilation, h_out=h_out, block_size=block_size,
        interpret=_interpret(),
    )  # [Kh, Kw, Cg, KB*bs]
    kb = block_idx.shape[0]
    d_flat = cg * kh * kw
    compact = compact.transpose(2, 0, 1, 3).reshape(d_flat, kb * block_size)
    dw = jnp.zeros((d_flat, c_pad // block_size, block_size), jnp.float32)
    dw = dw.at[:, block_idx, :].set(compact.reshape(d_flat, kb, block_size))
    return dw.reshape(d_flat, c_pad)[:, :c_out]


@functools.partial(
    jax.jit,
    static_argnames=("hw", "stride", "padding", "dilation", "groups", "block_size"),
)
def conv_dx_fused(
    dy, w, block_idx, *, hw, stride, padding, dilation, groups, block_size=128
):
    """Conv dX ``[B, C_in, H, W]`` with fused col2im scatter.

    ``hw`` is the static (H, W) of the input. The kernel accumulates on
    the zero-padded image (``gathered_matmul.conv_dx_fused``); the
    padding border is sliced off here. The kept filter blocks are
    gathered *here* (filters are tiny next to activations) so the kernel
    can hold the whole compact filter in VMEM across the row sweep.
    """
    b = dy.shape[0]
    h, w_dim = hw
    cg = w.shape[1]
    (ph0, ph1), (pw0, pw1) = padding
    h_pad, w_pad = h + ph0 + ph1, w_dim + pw0 + pw1
    dy2r = _dy_rows(dy, block_size)
    wfull = _pad_to(w.transpose(2, 3, 1, 0), 3, block_size)  # [Kh,Kw,Cg,C_pad]
    kh, kw = wfull.shape[:2]
    nb = wfull.shape[3] // block_size
    w2k = jnp.take(
        wfull.reshape(kh, kw, cg, nb, block_size), block_idx, axis=3
    ).reshape(kh, kw, cg, -1)  # compact [Kh,Kw,Cg,KB*bs]
    dxp = gm.conv_dx_fused(
        dy2r, w2k, block_idx, b=b, h_pad=h_pad, w_pad=w_pad, groups=groups,
        stride=stride, dilation=dilation, block_size=block_size,
        interpret=_interpret(),
    )  # [B*H_pad, G, W_pad, Cg]
    dx = (
        dxp.reshape(b, h_pad, groups, w_pad, cg)
        .transpose(0, 2, 4, 1, 3)
        .reshape(b, groups * cg, h_pad, w_pad)
    )
    return dx[:, :, ph0 : ph0 + h, pw0 : pw0 + w_dim]


@jax.jit
def paged_attention(q, k_pool, v_pool, block_tables, qpos):
    """Per-slot causal attention reading K/V pages in place.

    q ``[B,S,H,D]``, pools ``[n_pages, bs, KV, D]``, block_tables
    ``[B, NB]``, qpos ``[B, S]`` -> ``[B, S, H, D]`` in q.dtype. The
    kernel-side contract (grid, addressing, online softmax) lives in
    :mod:`repro.kernels.paged_attention`.
    """
    out = pa.paged_attention(
        q, k_pool, v_pool, block_tables, qpos, interpret=_interpret()
    )
    return out.astype(q.dtype)


@jax.jit
def importance(dy):
    """Per-channel mean |dY| over all leading axes. dy [..., N] -> [N]."""
    n = dy.shape[-1]
    dy2 = dy.reshape(-1, n)
    m = dy2.shape[0]
    dy_p = _pad_to(_pad_to(dy2, 0, 256), 1, 128)
    # zero padding is |.|-neutral; rescale the mean to the true M.
    out = gm.importance(dy_p, interpret=_interpret())
    return out[:n] * (dy_p.shape[0] / m)


@jax.jit
def matmul(a, b):
    """Padded MXU-tiled matmul."""
    m, k = a.shape
    n = b.shape[1]
    a_p = _pad_to(_pad_to(a, 0, 128), 1, 128)
    b_p = _pad_to(_pad_to(b, 0, 128), 1, 128)
    return gm.matmul(a_p, b_p, interpret=_interpret())[:m, :n]
