"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, backend detection (TPU → compiled
kernel, anything else → ``interpret=True`` so CPU CI exercises the same
kernel body), and the scatter of the compact dW back into the full
weight-gradient buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gathered_matmul as gm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_size",))
def dx_gathered(dy, w, block_idx, block_size: int = 128):
    """dX[M, D_in] = dY[:, kept] @ W[:, kept]^T, gather fused in-kernel."""
    m, n = dy.shape
    d_in = w.shape[0]
    dy_p = _pad_to(_pad_to(dy, 0, 128), 1, block_size)
    w_p = _pad_to(_pad_to(w, 0, 128), 1, block_size)
    out = gm.dx_gathered(
        dy_p, w_p, block_idx, block_size=block_size, interpret=_interpret()
    )
    return out[:m, :d_in]


@functools.partial(jax.jit, static_argnames=("block_size", "n_out"))
def dw_gathered_scatter(x, dy, block_idx, n_out: int, block_size: int = 128):
    """Full dW[D_in, N]: compact kernel output scattered into zeros."""
    m, d_in = x.shape
    x_p = _pad_to(_pad_to(x, 0, 128), 1, 128)
    dy_p = _pad_to(_pad_to(dy, 0, 128), 1, block_size)
    compact = gm.dw_gathered(
        x_p, dy_p, block_idx, block_size=block_size, interpret=_interpret()
    )  # [D_in_pad, KB*block_size]
    compact = compact[:d_in]
    kb = block_idx.shape[0]
    dw = jnp.zeros((d_in, -(-n_out // block_size), block_size), jnp.float32)
    dw = dw.at[:, block_idx, :].set(compact.reshape(d_in, kb, block_size))
    return dw.reshape(d_in, -1)[:, :n_out]


@jax.jit
def importance(dy):
    """Per-channel mean |dY| over all leading axes. dy [..., N] -> [N]."""
    n = dy.shape[-1]
    dy2 = dy.reshape(-1, n)
    m = dy2.shape[0]
    dy_p = _pad_to(_pad_to(dy2, 0, 256), 1, 128)
    # zero padding is |.|-neutral; rescale the mean to the true M.
    out = gm.importance(dy_p, interpret=_interpret())
    return out[:n] * (dy_p.shape[0] / m)


@jax.jit
def matmul(a, b):
    """Padded MXU-tiled matmul."""
    m, k = a.shape
    n = b.shape[1]
    a_p = _pad_to(_pad_to(a, 0, 128), 1, 128)
    b_p = _pad_to(_pad_to(b, 0, 128), 1, 128)
    return gm.matmul(a_p, b_p, interpret=_interpret())[:m, :n]
