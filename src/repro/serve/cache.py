"""Slot-based cache manager: batch rows as an allocatable resource.

The decode cache is batch-major (``[np, B, T, ...]`` leaves), so batch
row *b* is an independent per-request resource — a **slot** — with its
own write position. This manager owns the cache pytree, the free-slot
pool and the host-side per-slot positions; ``reset`` zeroes a freed
slot's rows (mandatory for SSM/conv state, which has no position to
mask by) in one jitted call before reuse.

Under a data×model mesh the cache is placed with the production
partition rules (:func:`repro.dist.sharding.cache_shardings`), so the
engine serves sharded exactly like the lock-step driver did.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as lm


class SlotCacheManager:
    """Allocate/free cache rows per request with independent positions."""

    def __init__(
        self,
        cfg: ModelConfig,
        n_slots: int,
        max_seq: int,
        *,
        dtype=jnp.float32,
        mesh=None,
        seq_shard: bool = False,
    ):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        cache = lm.init_cache(cfg, n_slots, max_seq, dtype=dtype)
        if mesh is not None:
            from repro.dist import sharding as shd

            cache = jax.device_put(
                cache, shd.cache_shardings(mesh, cache, seq_shard=seq_shard)
            )
        self.cache = cache
        self.pos = np.zeros((n_slots,), np.int32)  # per-slot write offset
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._reset = jax.jit(lm.reset_slots)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def alloc(self) -> int:
        """Claim a free slot (lowest id first). Raises when full."""
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop()
        self.pos[slot] = 0
        return slot

    def free(self, slot: int) -> None:
        """Return a slot to the pool. The rows are zeroed lazily at the
        next :meth:`reset` (batched with other freed slots)."""
        if slot in self._free:
            raise ValueError(f"slot {slot} already free")
        self.pos[slot] = 0
        self._free.append(slot)
        self._free.sort(reverse=True)

    def reset(self, slots) -> None:
        """Zero the cache rows of ``slots`` (one fused device call)."""
        slots = list(slots)
        if not slots:
            return
        mask = np.zeros((self.n_slots,), bool)
        mask[slots] = True
        self.cache = self._reset(self.cache, jnp.asarray(mask))

