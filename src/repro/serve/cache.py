"""Cache managers: batch rows (slots) and KV pages as allocatable resources.

Two memory planes live here:

* :class:`SlotCacheManager` — the contiguous layout: the decode cache is
  batch-major (``[np, B, T, ...]`` leaves), so batch row *b* is an
  independent per-request resource — a **slot** — with its own write
  position, and every slot owns ``max_seq`` contiguous cache rows.
  Concurrency is bounded by worst-case sequence length: ``B`` slots cost
  ``B × max_seq`` rows even when most requests are short.

* :class:`PagedCacheManager` — the paged layout: attention K/V lives in
  a global pool of fixed-size **pages** (``[np, n_blocks, block_size,
  KV, hd]`` leaves) handed out by a :class:`BlockAllocator`; each slot
  maps logical block *l* to a physical page through its row of the
  **block table** (``[B, blocks_per_slot]`` int32). Slots still exist —
  they carry the positionless SSM/conv state and the activation batch
  row — but KV memory is now proportional to *actual* sequence length,
  so ``max_slots`` can exceed ``pool_tokens / max_seq``.

Both managers own the cache pytree, the free lists and the host-side
per-slot positions. Freed state is **zeroed before reuse** — mandatory
for SSM/conv state (which has no position to mask by) and enforced for
freed KV pages too (the property test reads freed pages back as zero).

The paged manager additionally supports **swap preemption**:
:meth:`PagedCacheManager.swap_out` stages one slot's KV pages and
SSM/conv rows on the host (:class:`SwappedSlot`) and
:meth:`PagedCacheManager.swap_in` restores them into a fresh slot with
remapped pages — the eviction strategy that stays correct for *sampled*
requests, where recompute-from-token-history would silently diverge.

Under a data×model mesh the cache is placed with the production
partition rules (:func:`repro.dist.sharding.cache_shardings`); the paged
pool passes ``paged=True`` (pages replicated over data, kv-heads over
model — block tables index the pool globally, so sharding the page axis
would turn every gather into a collective).
"""
from __future__ import annotations

from collections.abc import Iterable, Sequence
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as lm


@dataclasses.dataclass
class SwappedSlot:
    """One slot's cache state, staged on the host by :meth:`PagedCacheManager.swap_out`.

    ``data`` mirrors the cache pytree: K/V leaves hold the slot's pages
    (``[np, n_pages, bs, KV, hd]`` host arrays), slot-major leaves (SSM
    conv/state) hold the slot's row. ``pos`` is the slot's write
    position at eviction; ``n_pages`` the page count to re-allocate at
    swap-in. The bundle restores the request's device state exactly —
    the preemption strategy that stays correct under sampling, where the
    recompute path (``Request.preempt``) would silently diverge.
    """

    pos: int
    n_pages: int
    data: Any  # host-side pytree (np.ndarray leaves)

    @property
    def nbytes(self) -> int:
        """Host bytes staged — the swap-traffic cost the benchmark reports."""
        return int(sum(a.nbytes for a in jax.tree.leaves(self.data)))


class SlotCacheManager:
    """Allocate/free contiguous cache rows per request.

    Args:
      cfg: model config (decides the cache pytree structure).
      n_slots: batch capacity B — one cache row set per slot.
      max_seq: rows per slot (prompt + generation must fit).
      dtype: cache dtype (fp32 default, matching the lock-step driver).
      mesh: optional data×model mesh; places the cache with
        :func:`repro.dist.sharding.cache_shardings`.
      seq_shard: shard the KV seq dim over ``model`` (long decode).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        n_slots: int,
        max_seq: int,
        *,
        dtype=jnp.float32,
        mesh=None,
        seq_shard: bool = False,
    ):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        cache = lm.init_cache(cfg, n_slots, max_seq, dtype=dtype)
        if mesh is not None:
            from repro.dist import sharding as shd

            cache = jax.device_put(
                cache, shd.cache_shardings(mesh, cache, seq_shard=seq_shard)
            )
        self.cache = cache
        self.pos = np.zeros((n_slots,), np.int32)  # per-slot write offset
        self._free: list[int] = list(range(n_slots - 1, -1, -1))
        self._reset = jax.jit(lm.reset_slots)

    @property
    def n_free(self) -> int:
        """Free slots available to admission."""
        return len(self._free)

    @property
    def n_active(self) -> int:
        """Slots currently owned by running requests."""
        return self.n_slots - len(self._free)

    def alloc(self) -> int:
        """Claim a free slot (lowest id first). Raises when full."""
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop()
        self.pos[slot] = 0
        return slot

    def free(self, slot: int) -> None:
        """Return a slot to the pool. The rows are zeroed lazily at the
        next :meth:`reset` (batched with other freed slots)."""
        if slot in self._free:
            raise ValueError(f"slot {slot} already free")
        self.pos[slot] = 0
        self._free.append(slot)
        self._free.sort(reverse=True)

    def reset(self, slots: Iterable[int]) -> None:
        """Zero the cache rows of ``slots`` (one fused device call)."""
        slots = list(slots)
        if not slots:
            return
        mask = np.zeros((self.n_slots,), bool)
        mask[slots] = True
        self.cache = self._reset(self.cache, jnp.asarray(mask))


class NoFreeBlocks(RuntimeError):
    """Raised by :meth:`BlockAllocator.alloc` when the pool is exhausted.

    The engine catches this and preempts a running request back to
    WAITING (recompute on re-admission) instead of crashing."""


class BlockAllocator:
    """Host-side free list over a fixed pool of KV pages.

    Pure bookkeeping — no device state. Invariants (pinned by the
    property test in ``tests/test_serve.py``):

    * a page is owned by at most one holder at a time (no double alloc);
    * ``n_free + outstanding == n_blocks`` always (conservation);
    * double-free raises.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 1:
            raise ValueError("n_blocks must be >= 1")
        self.n_blocks = n_blocks
        # lowest ids first, matching SlotCacheManager's slot order
        self._free: list[int] = list(range(n_blocks - 1, -1, -1))
        self._held = np.zeros((n_blocks,), bool)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        """Claim ``n`` pages (all or nothing). Raises :class:`NoFreeBlocks`
        if fewer than ``n`` are free — the pool is left untouched."""
        if n < 0:
            raise ValueError("n must be >= 0")
        if n > len(self._free):
            raise NoFreeBlocks(f"need {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._held[out] = True
        return out

    def free(self, blocks: Sequence[int]) -> None:
        """Return pages to the pool. Double-free raises — including a
        duplicate id within one call (it would enter the free list
        twice and be handed to two holders)."""
        blocks = list(blocks)
        if len(set(blocks)) != len(blocks):
            raise ValueError(f"duplicate page ids in free: {blocks}")
        for b in blocks:
            if not self._held[b]:
                raise ValueError(f"page {b} already free")
        self._held[blocks] = False
        self._free.extend(blocks)
        self._free.sort(reverse=True)


class PagedCacheManager:
    """Slots + a paged KV pool behind the same interface as
    :class:`SlotCacheManager` (``alloc``/``free``/``reset``/``pos``/
    ``cache``/``n_free``), plus the block-table plane.

    The engine drives three extra paged-only operations:

    * :meth:`ensure` — grow a slot's block table to cover a target
      sequence length, allocating pages on demand (returns ``False``
      instead of raising when the pool can't cover it — the engine then
      preempts a victim and retries);
    * :meth:`block_tables` (attribute) — the ``[n_slots,
      blocks_per_slot]`` int32 table threaded through the jitted step as
      *data*; unassigned entries are 0, which is always a valid page —
      per-slot causal masking fences whatever it holds;
    * :meth:`free` — releases the slot *and* its pages, zeroing both the
      slot's SSM/conv rows and the freed pages **eagerly** (pages can be
      re-allocated to another slot within the same engine tick, so
      zero-on-free cannot be deferred the way slot resets are).

    Args mirror :class:`SlotCacheManager`; additionally:

    Args:
      block_size: tokens per KV page.
      n_blocks: pool size in pages. Equal cache memory with a contiguous
        manager of ``B`` slots means ``n_blocks * block_size == B *
        max_seq``.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        n_slots: int,
        max_seq: int,
        *,
        block_size: int,
        n_blocks: int,
        dtype=jnp.float32,
        mesh=None,
        seq_shard: bool = False,
    ):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.blocks_per_slot = -(-max_seq // block_size)
        cache = lm.init_paged_cache(
            cfg, n_slots, n_blocks, block_size, dtype=dtype
        )
        self.mesh = mesh
        self.table_sharding = None
        if mesh is not None:
            from repro.dist import sharding as shd

            cache = jax.device_put(
                cache,
                shd.cache_shardings(
                    mesh, cache, seq_shard=seq_shard, paged=True
                ),
            )
            self.table_sharding = shd.block_table_sharding(mesh)
        self.cache = cache
        self.pos = np.zeros((n_slots,), np.int32)
        self.block_tables = np.zeros((n_slots, self.blocks_per_slot), np.int32)
        self.n_table_blocks = np.zeros((n_slots,), np.int32)
        self.allocator = BlockAllocator(n_blocks)
        self._free_slots: list[int] = list(range(n_slots - 1, -1, -1))
        self._reset = jax.jit(lm.reset_paged)

    # ------------------------------------------------------------------
    # slot plane
    # ------------------------------------------------------------------

    @property
    def n_free(self) -> int:
        """Free slots available to admission."""
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    @property
    def n_free_blocks(self) -> int:
        """Free pages in the pool (the admission gate)."""
        return self.allocator.n_free

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to cache ``n_tokens`` tokens."""
        return -(-int(n_tokens) // self.block_size)

    def alloc(self) -> int:
        """Claim a free slot (lowest id first) with an empty block table.
        Pages are allocated lazily by :meth:`ensure`. Raises when full."""
        if not self._free_slots:
            raise RuntimeError("no free slots")
        slot = self._free_slots.pop()
        self.pos[slot] = 0
        self.block_tables[slot] = 0
        self.n_table_blocks[slot] = 0
        return slot

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s block table to cover ``n_tokens`` tokens.

        Allocates pages on demand; returns ``False`` (pool untouched) if
        the free list can't cover the growth — the engine preempts a
        victim and retries."""
        need = self.blocks_for(n_tokens)
        if need > self.blocks_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens need {need} pages > "
                f"blocks_per_slot {self.blocks_per_slot}"
            )
        have = int(self.n_table_blocks[slot])
        if need <= have:
            return True
        try:
            pages = self.allocator.alloc(need - have)
        except NoFreeBlocks:
            return False
        self.block_tables[slot, have:need] = pages
        self.n_table_blocks[slot] = need
        return True

    def trim(self, slot: int, n_tokens: int) -> None:
        """Shrink ``slot``'s block table to cover only ``n_tokens`` tokens.

        The speculative-rollback path: a verify step allocates pages for
        the full ``k+1``-wide chunk up front (:meth:`ensure`), but only
        the accepted prefix is committed — pages past
        ``blocks_for(n_tokens)`` hold nothing but rejected draft writes,
        so they are released back to the pool and zeroed eagerly (same
        invariant as :meth:`free`: a released page can be re-allocated
        within the same tick, and it currently holds garbage KV rows).
        A no-op when the committed length still needs every page."""
        keep = self.blocks_for(n_tokens)
        have = int(self.n_table_blocks[slot])
        if keep >= have:
            return
        pages = self.block_tables[slot, keep:have].tolist()
        self.allocator.free(pages)
        self.block_tables[slot, keep:have] = 0
        self.n_table_blocks[slot] = keep
        self._zero(slots=[], pages=pages)

    def free(self, slot: int) -> None:
        """Release ``slot`` and its pages; zero both eagerly.

        Freed pages must read back zero before any re-allocation (the
        SSM-state invariant extended to the KV pool), and re-allocation
        can happen within the same engine tick — so the zeroing device
        call happens here, not lazily at the next admission."""
        if slot in self._free_slots:
            raise ValueError(f"slot {slot} already free")
        n = int(self.n_table_blocks[slot])
        pages = self.block_tables[slot, :n].tolist()
        self.allocator.free(pages)
        self.pos[slot] = 0
        self.block_tables[slot] = 0
        self.n_table_blocks[slot] = 0
        self._free_slots.append(slot)
        self._free_slots.sort(reverse=True)
        self._zero(slots=[slot], pages=pages)

    def reset(self, slots: Iterable[int]) -> None:
        """Zero the SSM/conv rows of ``slots``. Pages are already zeroed
        at :meth:`free` time; this keeps the admission-time interface of
        :class:`SlotCacheManager` (idempotent on freshly freed slots)."""
        self._zero(slots=list(slots), pages=[])

    # ------------------------------------------------------------------
    # swap preemption (host staging)
    # ------------------------------------------------------------------

    def swap_out(self, slot: int) -> SwappedSlot:
        """Stage ``slot``'s cache state on the host and release the slot.

        Copies the slot's KV pages and SSM/conv rows to host memory,
        then frees the slot and its pages (zeroed as usual — the freed
        pages may be re-allocated this same tick). The returned
        :class:`SwappedSlot` restores the exact device state through
        :meth:`swap_in`; unlike the recompute path this is correct for
        sampled requests too (positions are preserved, so the stateless
        per-position RNG lane re-emits the identical stream).
        """
        if slot in self._free_slots:
            raise ValueError(f"slot {slot} already free")
        n = int(self.n_table_blocks[slot])
        pages = np.asarray(self.block_tables[slot, :n], np.int32)
        pos = int(self.pos[slot])
        data = jax.tree.map(
            np.asarray, lm.swap_out_slot(self.cache, slot, pages)
        )
        self.free(slot)
        return SwappedSlot(pos=pos, n_pages=n, data=data)

    def swap_in(self, slot: int, swapped: SwappedSlot) -> bool:
        """Restore a :meth:`swap_out` bundle into (freshly reset) ``slot``.

        Allocates ``swapped.n_pages`` fresh pages (the physical ids may
        differ from eviction time — contents are position-addressed
        within each page, so the block-table remap is free), scatters
        the host bundle back and restores the slot's position. Returns
        ``False`` with the pool untouched if the pages aren't free —
        admission gates on this, so a ``False`` here is an engine bug.

        Under a mesh the host bundle is first staged with
        :func:`repro.dist.sharding.swap_shardings`, so each leaf lands
        pre-sharded like its pool (kv-heads over ``model``) and the
        scatter needs no resharding collective.
        """
        try:
            pages = self.allocator.alloc(swapped.n_pages)
        except NoFreeBlocks:
            return False
        self.block_tables[slot, : swapped.n_pages] = pages
        self.n_table_blocks[slot] = swapped.n_pages
        self.pos[slot] = swapped.pos
        data = swapped.data
        if self.mesh is not None:
            from repro.dist import sharding as shd

            data = jax.device_put(data, shd.swap_shardings(self.mesh, data))
        self.cache = lm.swap_in_slot(
            self.cache, data, slot, np.asarray(pages, np.int32)
        )
        return True

    def _zero(self, *, slots: Sequence[int], pages: Sequence[int]) -> None:
        if not slots and not pages:
            return
        slot_mask = np.zeros((self.n_slots,), bool)
        slot_mask[list(slots)] = True
        page_mask = np.zeros((self.n_blocks,), bool)
        if pages:
            page_mask[list(pages)] = True
        self.cache = self._reset(
            self.cache, jnp.asarray(slot_mask), jnp.asarray(page_mask)
        )

    def page_view(self, page: int) -> list | None:
        """Device readback of one page's K leaves (tests/debug only)."""
        out = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.cache)[0]:
            keys = [str(k.key) for k in path if hasattr(k, "key")]
            if keys and keys[-1] in ("k", "v"):
                out.append(np.asarray(leaf[:, page]))
        return out
