"""Synthetic serving workloads: staggered (Poisson) arrivals with
heterogeneous prompt/generation lengths — the traffic shape that makes
continuous batching win over a static lock-step batch — and a
**long-tail** variant (mostly short generations, a few near-``max_seq``
ones) — the shape that makes the *paged* cache win over contiguous
slots: a contiguous layout must reserve worst-case rows for every slot,
while pages let the many short requests share the memory the few long
ones actually use."""
from __future__ import annotations


import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.request import Request, SamplingParams


def poisson_workload(
    cfg: ModelConfig,
    *,
    n_requests: int,
    arrival_rate: float = 1.0,  # mean arrivals per engine tick
    prompt_len=(4, 12),  # int or (lo, hi) inclusive
    gen_len=(4, 24),  # int or (lo, hi) inclusive
    seed: int = 0,
    uniform_prompts: bool = False,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> list[Request]:
    """Build a staggered request list for ``cfg``.

    Arrivals are a Poisson process (exponential inter-arrival, mean
    ``1/arrival_rate`` ticks, floored to integer ticks); prompt and
    generation lengths draw uniformly from their ranges.
    ``uniform_prompts=True`` fixes every prompt at ``prompt_len``'s max
    so the lock-step baseline (which needs a rectangular prompt batch)
    can run the identical workload.

    ``temperature`` > 0 makes every request sampled (with the given
    ``top_k``/``top_p``) under a per-request seed drawn from the
    workload generator — so the whole workload, including each
    request's sampled stream, is reproducible from ``seed``.
    """
    rng = np.random.default_rng(seed)

    def _range(v):
        return (v, v) if isinstance(v, int) else (int(v[0]), int(v[1]))

    plo, phi = _range(prompt_len)
    glo, ghi = _range(gen_len)
    if uniform_prompts:
        plo = phi
    arrivals = np.floor(
        np.cumsum(rng.exponential(1.0 / max(arrival_rate, 1e-9), n_requests))
    ).astype(int)
    reqs = []
    for i in range(n_requests):
        p = int(rng.integers(plo, phi + 1))
        g = int(rng.integers(glo, ghi + 1))
        prompt = rng.integers(0, cfg.vocab, size=p).astype(np.int32)
        frames: np.ndarray | None = None
        if cfg.family == "encdec":
            frames = rng.standard_normal((cfg.enc_seq, cfg.d_model)).astype(
                np.float32
            )
        sp = SamplingParams()
        if temperature > 0:
            sp = SamplingParams(
                temperature=temperature,
                top_k=top_k,
                top_p=top_p,
                seed=int(rng.integers(2**31)),
            )
        reqs.append(
            Request(
                rid=i,
                prompt=prompt,
                max_new_tokens=g,
                arrival=int(arrivals[i]),
                frames=frames,
                sampling=sp,
            )
        )
    return reqs


def longtail_workload(
    cfg: ModelConfig,
    *,
    n_requests: int,
    arrival_rate: float = 1.0,
    prompt_len=(4, 8),  # int or (lo, hi) inclusive
    gen_short=(3, 6),  # generation range for the short majority
    gen_long=(24, 32),  # generation range for the long tail
    tail_frac: float = 0.2,  # fraction of requests in the tail
    seed: int = 0,
    uniform_prompts: bool = False,
) -> list[Request]:
    """Long-tail workload: ~``1 - tail_frac`` short requests plus a few
    long ones. A contiguous cache must budget every slot for the tail's
    worst case; the paged cache only spends pages on the tail requests
    that actually grow — the benchmark workload for the paged-vs-
    contiguous concurrency comparison at equal cache memory."""
    rng = np.random.default_rng(seed)
    reqs = poisson_workload(
        cfg,
        n_requests=n_requests,
        arrival_rate=arrival_rate,
        prompt_len=prompt_len,
        gen_len=gen_short,
        seed=seed,
        uniform_prompts=uniform_prompts,
    )
    n_tail = max(1, int(round(tail_frac * n_requests)))
    glo, ghi = (gen_long, gen_long) if isinstance(gen_long, int) else gen_long
    for i in rng.choice(n_requests, size=n_tail, replace=False):
        reqs[i].max_new_tokens = int(rng.integers(glo, ghi + 1))
    return reqs
