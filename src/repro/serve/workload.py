"""Synthetic serving workloads: staggered (Poisson) arrivals with
heterogeneous prompt/generation lengths — the traffic shape that makes
continuous batching win over a static lock-step batch."""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.request import Request


def poisson_workload(
    cfg: ModelConfig,
    *,
    n_requests: int,
    arrival_rate: float = 1.0,  # mean arrivals per engine tick
    prompt_len=(4, 12),  # int or (lo, hi) inclusive
    gen_len=(4, 24),  # int or (lo, hi) inclusive
    seed: int = 0,
    uniform_prompts: bool = False,
) -> List[Request]:
    """Build a staggered request list for ``cfg``.

    Arrivals are a Poisson process (exponential inter-arrival, mean
    ``1/arrival_rate`` ticks, floored to integer ticks); prompt and
    generation lengths draw uniformly from their ranges.
    ``uniform_prompts=True`` fixes every prompt at ``prompt_len``'s max
    so the lock-step baseline (which needs a rectangular prompt batch)
    can run the identical workload.
    """
    rng = np.random.default_rng(seed)

    def _range(v):
        return (v, v) if isinstance(v, int) else (int(v[0]), int(v[1]))

    plo, phi = _range(prompt_len)
    glo, ghi = _range(gen_len)
    if uniform_prompts:
        plo = phi
    arrivals = np.floor(
        np.cumsum(rng.exponential(1.0 / max(arrival_rate, 1e-9), n_requests))
    ).astype(int)
    reqs = []
    for i in range(n_requests):
        p = int(rng.integers(plo, phi + 1))
        g = int(rng.integers(glo, ghi + 1))
        prompt = rng.integers(0, cfg.vocab, size=p).astype(np.int32)
        frames: Optional[np.ndarray] = None
        if cfg.family == "encdec":
            frames = rng.standard_normal((cfg.enc_seq, cfg.d_model)).astype(
                np.float32
            )
        reqs.append(
            Request(
                rid=i,
                prompt=prompt,
                max_new_tokens=g,
                arrival=int(arrivals[i]),
                frames=frames,
            )
        )
    return reqs
