"""Token-budget scheduler: interleave chunked prefill with decode.

Each engine step the scheduler packs work into the batch under a token
budget (the compute envelope of one step):

* every decoding slot gets 1 token — decode latency is the product, so
  running requests are never starved by arrivals;
* the remaining budget goes to prefilling slots (oldest arrival first)
  in chunks of up to ``prefill_chunk`` prompt tokens.

Admission is FIFO by (arrival, rid): a waiting request joins whenever a
slot is free and its arrival tick has passed. The plan is pure host
logic over per-slot request state — the jitted step consumes only the
resulting (tokens, count, pos) arrays, which is why one compiled step
serves any occupancy the scheduler produces.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.serve.request import Request


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine/scheduler configuration.

    Attributes:
      max_slots: batch capacity B — concurrent requests in flight.
      max_seq: cache rows per slot (prompt + generation must fit).
      prefill_chunk: max prompt tokens one slot absorbs per step (the
        chunked-prefill width; also the compiled mixed-step width C).
      token_budget: max total tokens processed per engine step;
        0 means ``max_slots + prefill_chunk`` (all decodes plus one
        full prefill chunk).
    """

    max_slots: int
    max_seq: int
    prefill_chunk: int = 8
    token_budget: int = 0

    def __post_init__(self):
        if self.max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.token_budget < 0:
            raise ValueError("token_budget must be >= 0 (0 = default)")

    @property
    def budget(self) -> int:
        return self.token_budget or (self.max_slots + self.prefill_chunk)


class Scheduler:
    """Pure planning: no device state, unit-testable in isolation."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self._rr = 0  # round-robin offset for budget-limited decode

    def admit(self, waiting: List[Request], n_free: int, clock: int) -> List[Request]:
        """FIFO admission: arrived requests, up to the free-slot count.

        ``waiting`` must be sorted by (arrival, rid); returns the prefix
        to admit (the caller assigns slots and removes them from the
        queue).
        """
        out = []
        for req in waiting:
            if len(out) >= n_free or req.arrival > clock:
                break
            out.append(req)
        return out

    def plan(self, by_slot: Dict[int, Request]) -> Dict[int, int]:
        """Token counts per slot for one step, under the budget.

        Decode slots first (1 token each, round-robin so a budget
        smaller than the decode count rotates fairly instead of
        starving high slot ids), then prefill chunks by arrival order.
        Slots that don't fit this step's budget are left out (count 0)
        and move to the front of the rotation next tick.
        """
        budget = self.cfg.budget
        plan: Dict[int, int] = {}
        decoding = [s for s in sorted(by_slot) if by_slot[s].remaining_prompt == 0]
        if decoding:
            off = self._rr % len(decoding)
            decoding = decoding[off:] + decoding[:off]
            self._rr += max(1, min(self.cfg.budget, len(decoding)))
        prefilling = sorted(
            (s for s in by_slot if by_slot[s].remaining_prompt > 0),
            key=lambda s: (by_slot[s].arrival, by_slot[s].rid),
        )
        for s in decoding:
            if budget < 1:
                break
            plan[s] = 1
            budget -= 1
        for s in prefilling:
            if budget < 1:
                break
            n = min(self.cfg.prefill_chunk, by_slot[s].remaining_prompt, budget)
            plan[s] = n
            budget -= n
        return plan
