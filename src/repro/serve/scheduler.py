"""Token-budget scheduler: interleave chunked prefill with decode.

Each engine step the scheduler packs work into the batch under a token
budget (the compute envelope of one step):

* every decoding slot gets 1 token — decode latency is the product, so
  running requests are never starved by arrivals;
* the remaining budget goes to prefilling slots (oldest arrival first)
  in chunks of up to ``prefill_chunk`` prompt tokens.

Admission is FIFO by (arrival, rid): a waiting request joins whenever a
slot is free and its arrival tick has passed. Under the **paged** cache
admission is additionally gated on the free-page count: a request is
admitted only while the pool still holds enough free pages to cover its
prefill context, and a shortfall blocks the whole queue (FIFO-honest —
later, smaller requests don't starve the head of the line). Generation
growth beyond the prefill context is *not* reserved; the engine handles
pool exhaustion by preempting the youngest running request back to
WAITING (see ``repro.serve.engine``).

The plan is pure host logic over per-slot request state — the jitted
step consumes only the resulting (tokens, count, pos[, block_tables])
arrays, which is why one compiled step serves any occupancy the
scheduler produces.
"""
from __future__ import annotations

import dataclasses

from repro.serve.request import Request


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine/scheduler configuration.

    Attributes:
      max_slots: batch capacity B — concurrent requests in flight.
      max_seq: cache tokens per slot (prompt + generation must fit).
      prefill_chunk: max prompt tokens one slot absorbs per step (the
        chunked-prefill width; also the widest compiled mixed-step
        width C).
      token_budget: max total tokens processed per engine step;
        0 means ``max_slots + prefill_chunk`` (all decodes plus one
        full prefill chunk).
      block_size: tokens per KV page. 0 (default) keeps the contiguous
        per-slot cache; > 0 switches the engine to the paged cache.
      n_blocks: page-pool size. 0 (default) sizes the pool to match the
        contiguous layout exactly (``max_slots * ceil(max_seq /
        block_size)`` pages) — set it smaller to serve more slots than
        the worst case fits, relying on preemption under pressure.
      decode_widths: extra compiled step widths below ``prefill_chunk``.
        The engine picks the smallest compiled width that fits the
        step's largest per-slot token count, so a mixed step whose
        biggest chunk is 3 runs at width 4 instead of padding every
        row to ``prefill_chunk``. Default ``(1, 4)`` gives the ladder
        {1, 4, prefill_chunk}; ``(1,)`` reproduces the old two-width
        behaviour. Entries above ``prefill_chunk`` or duplicated are
        rejected at construction (a width above the chunk would never
        be picked; silently dropping it hid config typos).
      attn_kernel: route decode attention through the Pallas
        paged-attention kernel — K/V pages read in place from the pool
        via the block table instead of the per-layer
        ``pool[block_tables]`` gather. Requires the paged cache
        (``block_size > 0``); token-parity with the gather path is the
        invariant the serve tests pin.
      preempt: pool-exhaustion eviction strategy (paged engine).
        ``"recompute"`` drops the victim's cache and re-prefills its
        token history on re-admission — cheapest, but bit-exact only
        for greedy requests (``Request.preempt`` enforces this);
        ``"swap"`` stages the victim's KV pages + SSM/conv rows on the
        host and restores them — correct for any request; ``"auto"``
        (default) swaps sampled requests and recomputes greedy ones.
      spec_k: draft tokens proposed per decode slot per step
        (speculative decoding; 0 = off). A decoding slot is planned a
        ``1 + spec_k``-token chunk (the last committed token plus k
        draft proposals) which the target model verifies in one step;
        the accepted prefix plus one target token is emitted. The
        verify chunk must fit a compiled width, so ``spec_k + 1 <=
        prefill_chunk`` (add ``spec_k + 1`` to ``decode_widths`` to
        avoid padding up to the next ladder width). Output is
        bit-identical to ``spec_k=0`` — same tokens at the same folds,
        fewer steps.
    """

    max_slots: int
    max_seq: int
    prefill_chunk: int = 8
    token_budget: int = 0
    block_size: int = 0
    n_blocks: int = 0
    decode_widths: tuple[int, ...] = (1, 4)
    attn_kernel: bool = False
    preempt: str = "auto"
    spec_k: int = 0

    def __post_init__(self):
        if self.max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.token_budget < 0:
            raise ValueError("token_budget must be >= 0 (0 = default)")
        if self.block_size < 0:
            raise ValueError("block_size must be >= 0 (0 = contiguous)")
        if self.n_blocks < 0:
            raise ValueError("n_blocks must be >= 0 (0 = default pool)")
        if self.n_blocks and not self.block_size:
            raise ValueError("n_blocks requires block_size > 0")
        if self.attn_kernel and not self.block_size:
            raise ValueError(
                "attn_kernel requires the paged cache (block_size > 0): "
                "the kernel addresses K/V through the block table"
            )
        if any(w < 1 for w in self.decode_widths):
            raise ValueError("decode_widths must be >= 1")
        if len(set(self.decode_widths)) != len(self.decode_widths):
            raise ValueError(
                f"decode_widths {self.decode_widths} contains duplicates — "
                "each compiled width should appear once"
            )
        too_wide = [w for w in self.decode_widths if w > self.prefill_chunk]
        if too_wide:
            raise ValueError(
                f"decode_widths {too_wide} exceed prefill_chunk "
                f"{self.prefill_chunk}: no step is ever planned wider than "
                "the chunk, so these widths would never be picked — drop "
                "them or raise prefill_chunk"
            )
        if self.preempt not in ("auto", "swap", "recompute"):
            raise ValueError(
                f"unknown preemption policy {self.preempt!r}: expected "
                "'auto', 'swap' or 'recompute'"
            )
        if self.spec_k < 0:
            raise ValueError("spec_k must be >= 0 (0 = speculation off)")
        if self.spec_k and self.spec_k + 1 > self.prefill_chunk:
            raise ValueError(
                f"spec_k={self.spec_k} needs a {self.spec_k + 1}-wide verify "
                f"chunk but prefill_chunk={self.prefill_chunk} is the widest "
                "compiled width — lower spec_k or raise prefill_chunk"
            )

    @property
    def budget(self) -> int:
        """Effective per-step token budget."""
        return self.token_budget or (self.max_slots + self.prefill_chunk)

    @property
    def paged(self) -> bool:
        """Whether the paged KV cache is enabled."""
        return self.block_size > 0

    @property
    def blocks_per_slot(self) -> int:
        """Block-table length: pages covering ``max_seq`` tokens."""
        return -(-self.max_seq // self.block_size) if self.paged else 0

    @property
    def total_blocks(self) -> int:
        """Page-pool size (0 when contiguous)."""
        if not self.paged:
            return 0
        return self.n_blocks or (self.max_slots * self.blocks_per_slot)

    @property
    def widths(self) -> tuple[int, ...]:
        """Ascending compiled step widths (always ends at prefill_chunk)."""
        ws = {w for w in self.decode_widths if w <= self.prefill_chunk}
        ws.add(self.prefill_chunk)
        return tuple(sorted(ws))


class Scheduler:
    """Pure planning: no device state, unit-testable in isolation."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self._rr = 0  # round-robin offset for budget-limited decode

    def admit(
        self,
        waiting: list[Request],
        n_free: int,
        clock: int,
        *,
        n_free_blocks: int | None = None,
    ) -> list[Request]:
        """FIFO admission: arrived requests, up to the free-slot count.

        ``waiting`` must be sorted by (arrival, rid); returns the prefix
        to admit (the caller assigns slots and removes them from the
        queue). With the paged cache, ``n_free_blocks`` additionally
        gates each candidate on the pages it needs up front — its
        prefill context, or for a swapped-out request the exact page
        count of its staged cache — the free count is debited as
        candidates are accepted, and the first shortfall stops admission
        (FIFO head-of-line).
        """
        out = []
        blocks = n_free_blocks
        for req in waiting:
            if len(out) >= n_free or req.arrival > clock:
                break
            if self.cfg.paged and blocks is not None:
                if req.swap is not None:
                    need = req.swap.n_pages
                else:
                    need = -(-req.context_len // self.cfg.block_size)
                if need > blocks:
                    break
                blocks -= need
            out.append(req)
        return out

    def plan(self, by_slot: dict[int, Request]) -> dict[int, int]:
        """Token counts per slot for one step, under the budget.

        Decode slots first (round-robin so a budget smaller than the
        decode count rotates fairly instead of starving high slot ids),
        then prefill chunks by arrival order. Slots that don't fit this
        step's budget are left out (count 0) and move to the front of
        the rotation next tick.

        With ``spec_k > 0`` a decoding slot is allotted ``1 + spec_k``
        tokens (last committed token + k draft proposals), clamped to
        the request's remaining generation budget (proposing past
        ``max_new_tokens`` is wasted verify width), its per-request
        opt-out (``no_spec`` slots stay at 1), and the step budget
        (a tight budget truncates the chunk rather than starving the
        slot).
        """
        budget = self.cfg.budget
        plan: dict[int, int] = {}
        decoding = [s for s in sorted(by_slot) if by_slot[s].remaining_prompt == 0]
        if decoding:
            off = self._rr % len(decoding)
            decoding = decoding[off:] + decoding[:off]
            self._rr += max(1, min(self.cfg.budget, len(decoding)))
        prefilling = sorted(
            (s for s in by_slot if by_slot[s].remaining_prompt > 0),
            key=lambda s: (by_slot[s].arrival, by_slot[s].rid),
        )
        for s in decoding:
            if budget < 1:
                break
            req = by_slot[s]
            n = 1
            if self.cfg.spec_k and not req.no_spec:
                remaining = req.max_new_tokens - len(req.generated)
                n = 1 + max(0, min(self.cfg.spec_k, remaining - 1))
            plan[s] = min(n, budget)
            budget -= plan[s]
        for s in prefilling:
            if budget < 1:
                break
            n = min(self.cfg.prefill_chunk, by_slot[s].remaining_prompt, budget)
            plan[s] = n
            budget -= n
        return plan
