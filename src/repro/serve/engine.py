"""The continuous-batching engine: slot-scheduled sampling-safe serving.

One engine iteration (:meth:`ContinuousBatchingEngine.step`):

1. **admission** — freed slots are handed to arrived waiting requests
   (FIFO; under the paged cache also gated on free pages); each new
   occupant's cache rows are zeroed and, for encdec families, its
   encoder output is written into the slot's row. A request returning
   from a **swap** preemption has its staged KV pages and SSM/conv rows
   restored instead of re-prefilling.
2. **planning** — the :class:`~repro.serve.scheduler.Scheduler` packs
   decode tokens (1 per running slot) and chunked-prefill tokens under
   the token budget. With the paged cache the engine then grows each
   planned slot's block table to cover the step; if the pool runs dry
   it **preempts** the youngest running request back to WAITING and
   retries. The eviction strategy is ``ServeConfig.preempt``:
   ``recompute`` (re-prefill the token history — bit-exact for greedy
   only, and ``Request.preempt`` enforces that), ``swap`` (stage the
   cache state on the host), or ``auto`` (swap sampled requests,
   recompute greedy ones).
3. **one jitted mixed step** — :func:`repro.launch.steps.make_slot_step`
   runs prefill chunks and decode tokens together; per-slot cache
   positions (and, when paged, per-slot block tables) mean no slot
   waits for another. Per-request
   :class:`~repro.serve.request.SamplingParams` ride in the step state
   as per-slot data arrays (temperature / top-k / top-p plus a
   ``[B, 2]`` PRNG-lane array), so one compiled executable per width
   serves any mix of greedy and sampled slots. The step width is the
   smallest compiled width in ``ServeConfig.widths`` that fits the
   largest per-slot count.
4. **completion** — slots that consumed their last prompt token emit
   their first generated token; slots that hit ``max_new_tokens`` finish
   and release their slot (and pages) for the next waiting request.
   Each emitted token is **streamed** out of :meth:`step` as a
   :class:`TokenEvent` ``(rid, token, is_last)``; :meth:`run` forwards
   them to an ``on_token`` callback and :meth:`stream` yields them.

With ``ServeConfig.spec_k > 0`` the engine adds **speculative
decoding**: before the target step, a small drafter (own per-slot
cache rows, state advisory — dropped on preemption, re-prefilled on
resume) proposes up to ``k`` tokens per decoding slot; the target
verifies the chunk in one ``k+1``-wide step (the spec variant of
``make_slot_step``) with per-position folds, emits the exactly-matching
draft prefix plus its own next token, and rolls ``pos`` (and, paged,
the tail pages) back past the first mismatch. Output is bit-identical
to ``spec_k=0`` — same tokens at the same folds, fewer target steps.

Requests therefore join and leave the batch mid-flight: throughput is
bounded by slot capacity — and with the paged cache by *actual* cache
use rather than worst-case sequence length. Greedy outputs are
identical per request to lock-step decode of the same prompt, and
seeded sampled outputs are identical to the lock-step sampling path —
with and without preemption (`repro.serve.lockstep` is the reference;
`tests/test_serve.py` pins paged ≡ contiguous ≡ lock-step across all
model families, greedy and sampled).
"""
from __future__ import annotations

from collections.abc import Callable, Iterator
import math
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as steps_lib
from repro.models import model as lm
from repro.serve import request as rq
from repro.serve.cache import PagedCacheManager, SlotCacheManager
from repro.serve.scheduler import Scheduler, ServeConfig


class TokenEvent(NamedTuple):
    """One streamed token: emitted by :meth:`ContinuousBatchingEngine.step`
    the tick it is generated, in slot order. ``is_last`` marks the
    request's final token (its slot is already released)."""

    rid: int
    token: int
    is_last: bool


class ContinuousBatchingEngine:
    """Slot-based request scheduler over one model replica.

    Args:
      cfg: model config.
      params: model params (already sharded when serving under a mesh).
      serve_cfg: slot/chunk/budget configuration. ``block_size > 0``
        switches the KV cache to the paged layout (pool of fixed-size
        pages + per-slot block tables) with preempt-to-WAITING on pool
        exhaustion.
      cache_dtype: decode-cache dtype (fp32 default, matching the
        lock-step driver).
      mesh: optional data×model mesh; the cache is placed with the
        production ``cache_shardings`` rules. Callers run the engine
        inside ``jax.set_mesh(mesh)``.
      draft_cfg / draft_params: the drafter for speculative decoding
        (``ServeConfig.spec_k > 0``) — a same-family model, typically a
        reduced-depth config. Both default to the target model
        (self-drafting: every proposal is accepted, the degenerate
        sanity case). The drafter keeps its own contiguous per-slot
        cache rows; its state is **advisory** — dropped on preemption
        and re-prefilled from the request's token history on resume —
        so it never affects correctness, only the acceptance rate.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        serve_cfg: ServeConfig,
        *,
        cache_dtype=jnp.float32,
        mesh=None,
        seq_shard: bool = False,
        draft_cfg: ModelConfig | None = None,
        draft_params=None,
    ):
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        if serve_cfg.paged:
            self.slots = PagedCacheManager(
                cfg, serve_cfg.max_slots, serve_cfg.max_seq,
                block_size=serve_cfg.block_size,
                n_blocks=serve_cfg.total_blocks,
                dtype=cache_dtype, mesh=mesh, seq_shard=seq_shard,
            )
        else:
            self.slots = SlotCacheManager(
                cfg, serve_cfg.max_slots, serve_cfg.max_seq,
                dtype=cache_dtype, mesh=mesh, seq_shard=seq_shard,
            )
        self.scheduler = Scheduler(serve_cfg)
        self._spec = serve_cfg.spec_k > 0
        self._step_fn = jax.jit(
            steps_lib.make_slot_step(
                cfg, paged_kernel=serve_cfg.attn_kernel, spec=self._spec
            )
        )
        # --- speculative drafter plane (spec_k > 0) ---
        # Its own per-slot cache rows, always contiguous (the drafter is
        # cheap and advisory — paging it would buy nothing); slot ids
        # mirror the target's. The rows are sized past max_seq because
        # proposal steps write up to spec_k draft tokens beyond the
        # committed history before the snapshot is rolled back.
        self._draft = None
        if self._spec:
            self.draft_cfg = draft_cfg or cfg
            self.draft_params = draft_params if draft_params is not None else params
            self._draft = SlotCacheManager(
                self.draft_cfg, serve_cfg.max_slots,
                serve_cfg.max_seq + serve_cfg.spec_k,
                dtype=cache_dtype, mesh=mesh,
            )
            self._draft_step_fn = jax.jit(
                steps_lib.make_slot_step(self.draft_cfg)
            )
            # committed tokens (prompt + generated prefix) the drafter
            # has consumed per slot; 0 forces a full catch-up re-prefill
            self._draft_sync = np.zeros((serve_cfg.max_slots,), np.int64)
        self.waiting: list[rq.Request] = []
        self._known_rids = set()
        self.by_slot: dict[int, rq.Request] = {}
        self.finished: dict[int, rq.Request] = {}
        self.clock = 0
        # stats
        self.compute_steps = 0
        self.idle_steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.preemptions = 0
        self.swap_preemptions = 0
        self.recompute_preemptions = 0
        self.swapped_bytes = 0
        self.peak_concurrency = 0
        self.spec_proposed = 0  # draft tokens offered for verification
        self.spec_accepted = 0  # draft tokens the target confirmed
        self.draft_steps = 0  # drafter model invocations
        self.padded_tokens = 0  # B × width summed over compute steps
        self.step_times: list[float] = []
        self._occupancy_sum = 0
        self.enc_out = None
        self._encode = None
        self._draft_enc_out = None
        self._draft_encode = None
        if cfg.family == "encdec":
            self.enc_out = jnp.zeros(
                (serve_cfg.max_slots, cfg.enc_seq, cfg.d_model),
                jnp.dtype(cfg.dtype),
            )
            self._encode = jax.jit(
                lambda p, f: lm.encode(cfg, p, f.astype(jnp.dtype(cfg.dtype)))
            )
            if self._spec:
                dcfg = self.draft_cfg
                self._draft_enc_out = jnp.zeros(
                    (serve_cfg.max_slots, dcfg.enc_seq, dcfg.d_model),
                    jnp.dtype(dcfg.dtype),
                )
                self._draft_encode = jax.jit(
                    lambda p, f: lm.encode(
                        dcfg, p, f.astype(jnp.dtype(dcfg.dtype))
                    )
                )

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def submit(self, req: rq.Request) -> None:
        """Queue a request. Raises if it can never fit the cache, or if
        its rid is already known (waiting, running or finished) — a
        duplicate would silently overwrite the first request's output in
        :attr:`finished`. Known rids live in a set, so bulk submission
        stays O(n) instead of re-scanning every queue per call."""
        if req.rid in self._known_rids:
            raise ValueError(
                f"request {req.rid}: duplicate rid — already "
                "waiting, running or finished in this engine"
            )
        need = req.prompt_len + req.max_new_tokens - 1  # last token not cached
        if need > self.serve_cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+generation ({need}) exceeds "
                f"max_seq {self.serve_cfg.max_seq}"
            )
        if self.serve_cfg.paged:
            need_blocks = -(-need // self.serve_cfg.block_size)
            if need_blocks > self.serve_cfg.total_blocks:
                raise ValueError(
                    f"request {req.rid}: needs {need_blocks} pages, pool "
                    f"has {self.serve_cfg.total_blocks}"
                )
        if self.cfg.family == "encdec" and req.frames is None:
            raise ValueError(f"request {req.rid}: encdec family needs frames")
        self._known_rids.add(req.rid)
        req.state = rq.WAITING
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))

    def _admit(self) -> None:
        admitted = self.scheduler.admit(
            self.waiting, self.slots.n_free, self.clock,
            n_free_blocks=(
                self.slots.n_free_blocks if self.serve_cfg.paged else None
            ),
        )
        if not admitted:
            return
        new_slots = []
        swapped_in = []
        for req in admitted:
            self.waiting.remove(req)
            slot = self.slots.alloc()
            req.slot = slot
            req.state = rq.PREFILL
            self.by_slot[slot] = req
            new_slots.append(slot)
            if req.swap is not None:
                swapped_in.append(req)
            if self._encode is not None:
                enc = self._encode(self.params, jnp.asarray(req.frames)[None])
                self.enc_out = self.enc_out.at[slot].set(enc[0])
            if self._draft_encode is not None:
                denc = self._draft_encode(
                    self.draft_params, jnp.asarray(req.frames)[None]
                )
                self._draft_enc_out = self._draft_enc_out.at[slot].set(denc[0])
        self.slots.reset(new_slots)  # clear the previous occupants' state
        if self._draft is not None:
            # drafter state is advisory: a new occupant (fresh request,
            # or one returning from swap/recompute preemption) starts
            # from a zeroed drafter row and a full catch-up re-prefill
            self._draft.reset(new_slots)
            for slot in new_slots:
                self._draft.pos[slot] = 0
                self._draft_sync[slot] = 0
        for req in swapped_in:
            # restore the staged cache state (after the reset above);
            # admission already reserved the page count, so a failed
            # swap-in is an accounting bug, not a recoverable state
            if not self.slots.swap_in(req.slot, req.swap):
                raise RuntimeError(
                    f"request {req.rid}: swap-in failed for "
                    f"{req.swap.n_pages} pages despite admission gate"
                )
            req.resume_from_swap()

    # ------------------------------------------------------------------
    # paged-cache block management
    # ------------------------------------------------------------------

    def _pick_victim(self, keep: int) -> int | None:
        """Youngest running slot other than ``keep`` (max arrival, rid)."""
        cands = [s for s in self.by_slot if s != keep]
        if not cands:
            return None
        return max(
            cands, key=lambda s: (self.by_slot[s].arrival, self.by_slot[s].rid)
        )

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s request back to WAITING and free its pages.

        The strategy is ``ServeConfig.preempt``: **swap** stages the
        slot's KV pages and SSM/conv rows on the host (restored at
        re-admission — correct for any request), **recompute** drops the
        cache and re-prefills the token history (``Request.preempt``
        raises for sampled requests, whose resumed stream would be
        re-sampled and silently diverge), **auto** picks swap for
        sampled and recompute for greedy requests. Freed pages are
        zeroed eagerly either way (they may be re-allocated within this
        same tick)."""
        req = self.by_slot.pop(slot)
        mode = self.serve_cfg.preempt
        use_swap = mode == "swap" or (mode == "auto" and not req.sampling.greedy)
        if use_swap:
            swapped = self.slots.swap_out(slot)  # frees slot + pages
            req.preempt_swap(swapped)
            self.swap_preemptions += 1
            self.swapped_bytes += swapped.nbytes
        else:
            req.preempt()  # validates the greedy-recompute invariant
            self.slots.free(slot)
            self.recompute_preemptions += 1
        self.preemptions += 1
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))

    def _ensure_blocks(self, plan: dict[int, int]) -> dict[int, int]:
        """Grow block tables to cover this step's writes, oldest request
        first; preempt the youngest running request on pool exhaustion
        (evicting it from the plan) and retry."""
        order = sorted(
            plan, key=lambda s: (self.by_slot[s].arrival, self.by_slot[s].rid)
        )
        for slot in order:
            if slot not in plan:
                continue  # preempted as a victim earlier in this loop
            need = int(self.slots.pos[slot]) + plan[slot]
            while not self.slots.ensure(slot, need):
                victim = self._pick_victim(keep=slot)
                if victim is None:
                    raise RuntimeError(
                        f"slot {slot}: page pool exhausted with no victim "
                        "(request larger than the pool?)"
                    )
                self._preempt(victim)
                plan.pop(victim, None)
        return plan

    # ------------------------------------------------------------------
    # speculative drafting
    # ------------------------------------------------------------------

    def _run_draft(self, tokens: np.ndarray, count: np.ndarray) -> np.ndarray:
        """One drafter step over per-slot chunks; returns emitted tokens.

        The drafter samples with each request's own controls and PRNG
        lane at the same folds the target would use — a draft is a bet
        on the *exact* token the target will emit at that position, so
        for self-drafting (draft = target) every bet wins.
        """
        b = self.serve_cfg.max_slots
        temps = np.zeros((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        top_ps = np.ones((b,), np.float32)
        rng = np.zeros((b, 2), np.uint32)
        for slot, req in self.by_slot.items():
            sp = req.sampling
            temps[slot] = sp.temperature
            top_ks[slot] = sp.top_k
            top_ps[slot] = sp.top_p
            rng[slot] = sp.key_data()
        state = {
            "tokens": jnp.asarray(tokens),
            "count": jnp.asarray(count),
            "pos": jnp.asarray(self._draft.pos),
            "cache": self._draft.cache,
            "temps": jnp.asarray(temps),
            "top_ks": jnp.asarray(top_ks),
            "top_ps": jnp.asarray(top_ps),
            "rng": jnp.asarray(rng),
        }
        if self._draft_enc_out is not None:
            state["enc_out"] = self._draft_enc_out
        nxt, new_state = self._draft_step_fn(self.draft_params, state)
        self._draft.cache = new_state["cache"]
        self._draft.pos = self._draft.pos + count
        self.draft_steps += 1
        return np.asarray(nxt)

    def _draft_propose(self, plan: dict[int, int]) -> dict[int, list[int]]:
        """Draft ``n-1`` proposal tokens for each speculative decode slot.

        The drafter-never-commits-speculative-state protocol, per tick:

        1. **catch-up** — feed each slot the committed tokens (prompt +
           generated) the drafter hasn't consumed yet, in prefill-width
           chunks. In steady state that is the previous tick's accepted
           tokens (≤ spec_k + 1); after admission or any preemption it
           is the full history (``_draft_sync`` was reset — drafter
           state is advisory and is simply re-prefilled). The step that
           consumes a slot's last committed token emits its first
           proposal ``d1``. These cache writes are committed state and
           are kept.
        2. **snapshot** — the drafter cache/pos are captured (free:
           JAX arrays are immutable, a snapshot is a reference).
        3. **propose** — ``k-1`` width-1 steps, each feeding the
           previous proposal, yield ``d2..dk``; slots wanting fewer
           proposals freeze (count 0).
        4. **restore** — the snapshot is put back: proposal writes are
           speculative and must not contaminate the committed drafter
           state (next tick's catch-up re-feeds whatever the target
           actually accepted).
        """
        spec_slots = [
            s for s, n in plan.items()
            if n > 1 and self.by_slot[s].remaining_prompt == 0
        ]
        if not spec_slots:
            return {}
        b = self.serve_cfg.max_slots
        chunk = self.serve_cfg.prefill_chunk
        hist = {
            s: np.concatenate(
                [
                    self.by_slot[s].prompt,
                    np.asarray(self.by_slot[s].generated, np.int32),
                ]
            )
            for s in spec_slots
        }
        pending = {s: hist[s][int(self._draft_sync[s]):] for s in spec_slots}
        # A slot with nothing pending has no fresh logits to draft from.
        # The engine loop never produces one (every verified tick leaves
        # >= 1 newly committed token unseen by the drafter), but demote
        # it to plain decode rather than propose from stale state.
        for s in [s for s in spec_slots if len(pending[s]) == 0]:
            plan[s] = 1
            spec_slots.remove(s)
            pending.pop(s)
        if not spec_slots:
            return {}
        proposals: dict[int, list[int]] = {s: [] for s in spec_slots}
        while any(len(p) for p in pending.values()):
            tokens = np.zeros((b, chunk), np.int32)
            count = np.zeros((b,), np.int32)
            for s in spec_slots:
                seg = pending[s][:chunk]
                tokens[s, : len(seg)] = seg
                count[s] = len(seg)
            nxt = self._run_draft(tokens, count)
            for s in spec_slots:
                pending[s] = pending[s][int(count[s]):]
                if count[s] and not len(pending[s]) and not proposals[s]:
                    proposals[s].append(int(nxt[s]))
        for s in spec_slots:
            self._draft_sync[s] = len(hist[s])
        snap_cache, snap_pos = self._draft.cache, self._draft.pos.copy()
        for _ in range(max(plan[s] - 1 for s in spec_slots) - 1):
            live = [s for s in spec_slots if len(proposals[s]) < plan[s] - 1]
            if not live:
                break
            tokens = np.zeros((b, 1), np.int32)
            count = np.zeros((b,), np.int32)
            for s in live:
                tokens[s, 0] = proposals[s][-1]
                count[s] = 1
            nxt = self._run_draft(tokens, count)
            for s in live:
                proposals[s].append(int(nxt[s]))
        self._draft.cache = snap_cache
        self._draft.pos = snap_pos
        return proposals

    # ------------------------------------------------------------------
    # one engine iteration
    # ------------------------------------------------------------------

    def _pick_width(self, plan: dict[int, int]) -> int:
        """Smallest compiled step width fitting the largest chunk — the
        decode-width ladder (mixed steps stop padding to prefill_chunk)."""
        need = max(plan.values())
        for w in self.serve_cfg.widths:
            if w >= need:
                return w
        return self.serve_cfg.prefill_chunk

    def step(self) -> list[TokenEvent]:
        """Run one engine tick. Returns the tokens emitted this tick (in
        slot order) — empty on an idle tick or a pure-prefill step."""
        self._admit()
        self.peak_concurrency = max(self.peak_concurrency, len(self.by_slot))
        plan = self.scheduler.plan(self.by_slot)
        if plan and self.serve_cfg.paged:
            plan = self._ensure_blocks(plan)
        if not plan:
            self.clock += 1
            self.idle_steps += 1
            return []
        proposals = self._draft_propose(plan) if self._spec else {}

        b = self.serve_cfg.max_slots
        width = self._pick_width(plan)
        tokens = np.zeros((b, width), np.int32)
        count = np.zeros((b,), np.int32)
        is_spec = np.zeros((b,), bool)
        temps = np.zeros((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        top_ps = np.ones((b,), np.float32)
        rng = np.zeros((b, 2), np.uint32)
        n_prefill = 0
        for slot, n in plan.items():
            req = self.by_slot[slot]
            if req.remaining_prompt > 0:
                seg = req.context[req.prefilled : req.prefilled + n]
                tokens[slot, : len(seg)] = seg
                count[slot] = len(seg)
                n_prefill += len(seg)
            else:
                # decode: the last committed token, plus — speculating —
                # the drafter's proposals, verified as one chunk
                prop = proposals.get(slot, [])
                tokens[slot, 0] = req.generated[-1]
                if prop:
                    tokens[slot, 1 : 1 + len(prop)] = prop
                    is_spec[slot] = True
                count[slot] = 1 + len(prop)
            sp = req.sampling
            temps[slot] = sp.temperature
            top_ks[slot] = sp.top_k
            top_ps[slot] = sp.top_p
            rng[slot] = sp.key_data()

        state = {
            "tokens": jnp.asarray(tokens),
            "count": jnp.asarray(count),
            "pos": jnp.asarray(self.slots.pos),
            "cache": self.slots.cache,
            # sampling is data: per-slot controls + PRNG lanes, so the
            # same executable serves any greedy/sampled mix
            "temps": jnp.asarray(temps),
            "top_ks": jnp.asarray(top_ks),
            "top_ps": jnp.asarray(top_ps),
            "rng": jnp.asarray(rng),
        }
        if self._spec:
            state["is_spec"] = jnp.asarray(is_spec)
        if self.serve_cfg.paged:
            # host table -> device, replicated under a mesh (every pool
            # shard needs the full logical->physical map)
            state["block_tables"] = (
                jax.device_put(self.slots.block_tables, self.slots.table_sharding)
                if self.slots.table_sharding is not None
                else jnp.asarray(self.slots.block_tables)
            )
        if self.enc_out is not None:
            state["enc_out"] = self.enc_out
        t0 = time.perf_counter()
        if self._spec:
            (tok, keep), new_state = self._step_fn(self.params, state)
            tok, keep = np.asarray(tok), np.asarray(keep)
            consumed = keep
        else:
            nxt, new_state = self._step_fn(self.params, state)
            nxt = np.asarray(nxt)
            consumed = count
        dt = time.perf_counter() - t0
        self.slots.cache = new_state["cache"]
        self.slots.pos = self.slots.pos + consumed
        if self._spec and self.serve_cfg.paged:
            # page rollback: pages ensured for the full verify chunk but
            # reaching past the committed position hold only rejected
            # draft writes — release (and zero) them
            for slot in plan:
                if is_spec[slot] and consumed[slot] < count[slot]:
                    self.slots.trim(slot, int(self.slots.pos[slot]))

        events: list[TokenEvent] = []
        done_slots = []
        for slot, _n in sorted(plan.items()):
            req = self.by_slot[slot]
            emitted: list[int] = []
            if req.state == rq.PREFILL:
                req.prefilled += int(count[slot])
                if req.remaining_prompt == 0:
                    req.state = rq.DECODE
                    if req.first_token_step < 0:
                        req.first_token_step = self.clock
                    # A resumed (recompute-preempted) request's
                    # re-prefill ends on generated[-2]; the logits there
                    # re-predict the already-known generated[-1] — don't
                    # emit it twice.
                    if not req.generated:
                        emitted = [
                            int(tok[slot, count[slot] - 1])
                            if self._spec
                            else int(nxt[slot])
                        ]
            elif self._spec:
                # accepted drafts + the target's token past them —
                # keep[slot] tokens, bit-identical to keep[slot]
                # non-speculative decode steps (same folds)
                emitted = [int(t) for t in tok[slot, : keep[slot]]]
                if is_spec[slot]:
                    self.spec_proposed += int(count[slot]) - 1
                    self.spec_accepted += int(keep[slot]) - 1
            else:
                emitted = [int(nxt[slot])]
            for e in emitted:
                req.generated.append(e)
                req.token_steps.append(self.clock)
                req.token_latencies.append(dt)
                if req.done:
                    req.state = rq.FINISHED
                    req.finish_step = self.clock
                    self.finished[req.rid] = req
                    done_slots.append(slot)
                events.append(TokenEvent(req.rid, e, req.done))
        for slot in done_slots:
            del self.by_slot[slot]
            self.slots.free(slot)

        self.compute_steps += 1
        self.step_times.append(dt)
        self.padded_tokens += b * width
        n_total = int(consumed.sum())
        self.prefill_tokens += n_prefill
        self.decode_tokens += n_total - n_prefill
        # mixed steps: apportion wall time by token share so the
        # prefill/decode split stays comparable to the lock-step baseline
        frac = n_prefill / max(n_total, 1)
        self.prefill_s += dt * frac
        self.decode_s += dt * (1.0 - frac)
        self._occupancy_sum += len(plan)
        self.clock += 1
        return events

    def run(
        self,
        max_ticks: int | None = None,
        *,
        on_token: Callable[[TokenEvent], None] | None = None,
    ) -> dict[int, np.ndarray]:
        """Drive to completion (incl. future arrivals). rid -> tokens.

        ``on_token`` is called with each :class:`TokenEvent` the tick it
        is generated — the callback flavour of the streaming API (use
        :meth:`stream` for the iterator flavour)."""
        ticks = 0
        while self.waiting or self.by_slot:
            for ev in self.step():
                if on_token is not None:
                    on_token(ev)
            ticks += 1
            if max_ticks is not None and ticks >= max_ticks:
                break
        return {rid: r.tokens() for rid, r in sorted(self.finished.items())}

    def stream(self, max_ticks: int | None = None) -> Iterator[TokenEvent]:
        """Drive to completion, yielding each token as it is generated.

        The iterator flavour of the streaming API: yields
        :class:`TokenEvent` tuples in generation order (slot order
        within a tick). Finished outputs accumulate in
        :attr:`finished` as usual."""
        ticks = 0
        while self.waiting or self.by_slot:
            yield from self.step()
            ticks += 1
            if max_ticks is not None and ticks >= max_ticks:
                break

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> dict[str, float]:
        """Aggregate serving metrics for the finished (or partial) run.

        Keys cover throughput (``tokens_per_step``, ``tokens_per_s``),
        latency percentiles, slot economics (``slot_utilization``,
        ``peak_concurrency``), step-padding efficiency
        (``padded_tokens``, ``padding_efficiency`` — the decode-width
        ladder's metric), paged-cache health (``preemptions``) and
        speculative decoding (``spec_proposed`` / ``spec_accepted`` /
        ``acceptance_rate`` — accepted over proposed draft tokens — and
        ``draft_steps``, the drafter invocations those savings cost).
        """
        total_tokens = self.prefill_tokens + self.decode_tokens
        steps = max(self.compute_steps, 1)
        gen = sum(len(r.generated) for r in self.finished.values())
        lat = sorted(
            t for r in self.finished.values() for t in r.token_latencies
        )

        def pct(p):
            # nearest-rank percentile: the ceil(p*n/100)-th smallest
            # sample (1-indexed), clamped into range — int(p/100*n)
            # indexed one element too high (p50 of 2 samples returned
            # the max)
            if not lat:
                return 0.0
            n = len(lat)
            return lat[min(n - 1, max(0, math.ceil(p * n / 100.0) - 1))]

        wall = sum(self.step_times)
        return {
            "compute_steps": self.compute_steps,
            "idle_steps": self.idle_steps,
            "total_tokens": total_tokens,
            "generated_tokens": gen,
            "tokens_per_step": total_tokens / steps,
            "generated_per_step": gen / steps,
            "slot_utilization": self._occupancy_sum
            / (steps * self.serve_cfg.max_slots),
            "peak_concurrency": self.peak_concurrency,
            "preemptions": self.preemptions,
            "swap_preemptions": self.swap_preemptions,
            "recompute_preemptions": self.recompute_preemptions,
            "swapped_bytes": self.swapped_bytes,
            "padded_tokens": self.padded_tokens,
            "padding_efficiency": total_tokens / max(self.padded_tokens, 1),
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
            "acceptance_rate": self.spec_accepted / max(self.spec_proposed, 1),
            "draft_steps": self.draft_steps,
            "wall_s": wall,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "tokens_per_s": total_tokens / max(wall, 1e-9),
            "p50_token_latency_s": pct(50),
            "p99_token_latency_s": pct(99),
        }
