"""The continuous-batching engine: slot-scheduled greedy serving.

One engine iteration (:meth:`ContinuousBatchingEngine.step`):

1. **admission** — freed slots are handed to arrived waiting requests
   (FIFO); each new occupant's cache rows are zeroed and, for encdec
   families, its encoder output is written into the slot's row.
2. **planning** — the :class:`~repro.serve.scheduler.Scheduler` packs
   decode tokens (1 per running slot) and chunked-prefill tokens under
   the token budget.
3. **one jitted mixed step** — :func:`repro.launch.steps.make_slot_step`
   runs prefill chunks and decode tokens together; per-slot cache
   positions mean no slot waits for another.
4. **completion** — slots that consumed their last prompt token emit
   their first generated token; slots that hit ``max_new_tokens`` finish
   and release their slot for the next waiting request.

Requests therefore join and leave the batch mid-flight: throughput is
bounded by slot capacity, not by the slowest request of a static batch.
Greedy outputs are identical per request to lock-step decode of the same
prompt (`repro.serve.lockstep` is the reference; `tests/test_serve.py`
pins the parity across all model families).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as steps_lib
from repro.models import model as lm
from repro.serve import request as rq
from repro.serve.cache import SlotCacheManager
from repro.serve.scheduler import Scheduler, ServeConfig


class ContinuousBatchingEngine:
    """Slot-based request scheduler over one model replica.

    Args:
      cfg: model config.
      params: model params (already sharded when serving under a mesh).
      serve_cfg: slot/chunk/budget configuration.
      cache_dtype: decode-cache dtype (fp32 default, matching the
        lock-step driver).
      mesh: optional data×model mesh; the cache is placed with the
        production ``cache_shardings`` rules. Callers run the engine
        inside ``jax.set_mesh(mesh)``.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        serve_cfg: ServeConfig,
        *,
        cache_dtype=jnp.float32,
        mesh=None,
        seq_shard: bool = False,
    ):
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self.slots = SlotCacheManager(
            cfg, serve_cfg.max_slots, serve_cfg.max_seq,
            dtype=cache_dtype, mesh=mesh, seq_shard=seq_shard,
        )
        self.scheduler = Scheduler(serve_cfg)
        self._step_fn = jax.jit(steps_lib.make_slot_step(cfg))
        self.waiting: List[rq.Request] = []
        self.by_slot: Dict[int, rq.Request] = {}
        self.finished: Dict[int, rq.Request] = {}
        self.clock = 0
        # stats
        self.compute_steps = 0
        self.idle_steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.step_times: List[float] = []
        self._occupancy_sum = 0
        self.enc_out = None
        self._encode = None
        if cfg.family == "encdec":
            self.enc_out = jnp.zeros(
                (serve_cfg.max_slots, cfg.enc_seq, cfg.d_model),
                jnp.dtype(cfg.dtype),
            )
            self._encode = jax.jit(
                lambda p, f: lm.encode(cfg, p, f.astype(jnp.dtype(cfg.dtype)))
            )

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def submit(self, req: rq.Request) -> None:
        need = req.prompt_len + req.max_new_tokens - 1  # last token not cached
        if need > self.serve_cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+generation ({need}) exceeds "
                f"max_seq {self.serve_cfg.max_seq}"
            )
        if self.cfg.family == "encdec" and req.frames is None:
            raise ValueError(f"request {req.rid}: encdec family needs frames")
        req.state = rq.WAITING
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))

    def _admit(self) -> None:
        admitted = self.scheduler.admit(self.waiting, self.slots.n_free, self.clock)
        if not admitted:
            return
        new_slots = []
        for req in admitted:
            self.waiting.remove(req)
            slot = self.slots.alloc()
            req.slot = slot
            req.state = rq.PREFILL
            self.by_slot[slot] = req
            new_slots.append(slot)
            if self._encode is not None:
                enc = self._encode(self.params, jnp.asarray(req.frames)[None])
                self.enc_out = self.enc_out.at[slot].set(enc[0])
        self.slots.reset(new_slots)  # clear the previous occupants' state

    # ------------------------------------------------------------------
    # one engine iteration
    # ------------------------------------------------------------------

    def step(self) -> bool:
        """Run one engine tick. Returns True when compute happened."""
        self._admit()
        plan = self.scheduler.plan(self.by_slot)
        if not plan:
            self.clock += 1
            self.idle_steps += 1
            return False

        b = self.serve_cfg.max_slots
        width = 1 if max(plan.values()) <= 1 else self.serve_cfg.prefill_chunk
        tokens = np.zeros((b, width), np.int32)
        count = np.zeros((b,), np.int32)
        n_prefill = 0
        for slot, n in plan.items():
            req = self.by_slot[slot]
            if req.remaining_prompt > 0:
                seg = req.prompt[req.prefilled : req.prefilled + n]
                tokens[slot, : len(seg)] = seg
                count[slot] = len(seg)
                n_prefill += len(seg)
            else:
                tokens[slot, 0] = req.generated[-1]
                count[slot] = 1

        state = {
            "tokens": jnp.asarray(tokens),
            "count": jnp.asarray(count),
            "pos": jnp.asarray(self.slots.pos),
            "cache": self.slots.cache,
        }
        if self.enc_out is not None:
            state["enc_out"] = self.enc_out
        t0 = time.perf_counter()
        nxt, new_state = self._step_fn(self.params, state)
        nxt = np.asarray(nxt)
        dt = time.perf_counter() - t0
        self.slots.cache = new_state["cache"]
        self.slots.pos = self.slots.pos + count

        done_slots = []
        for slot, n in sorted(plan.items()):
            req = self.by_slot[slot]
            emitted = None
            if req.state == rq.PREFILL:
                req.prefilled += int(count[slot])
                if req.remaining_prompt == 0:
                    req.state = rq.DECODE
                    req.first_token_step = self.clock
                    emitted = int(nxt[slot])
            else:
                emitted = int(nxt[slot])
            if emitted is not None:
                req.generated.append(emitted)
                req.token_steps.append(self.clock)
                req.token_latencies.append(dt)
                if req.done:
                    req.state = rq.FINISHED
                    req.finish_step = self.clock
                    self.finished[req.rid] = req
                    done_slots.append(slot)
        for slot in done_slots:
            del self.by_slot[slot]
            self.slots.free(slot)

        self.compute_steps += 1
        self.step_times.append(dt)
        n_total = int(count.sum())
        self.prefill_tokens += n_prefill
        self.decode_tokens += n_total - n_prefill
        # mixed steps: apportion wall time by token share so the
        # prefill/decode split stays comparable to the lock-step baseline
        frac = n_prefill / max(n_total, 1)
        self.prefill_s += dt * frac
        self.decode_s += dt * (1.0 - frac)
        self._occupancy_sum += len(plan)
        self.clock += 1
        return True

    def run(self, max_ticks: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drive to completion (incl. future arrivals). rid -> tokens."""
        ticks = 0
        while self.waiting or self.by_slot:
            self.step()
            ticks += 1
            if max_ticks is not None and ticks >= max_ticks:
                break
        return {rid: r.tokens() for rid, r in sorted(self.finished.items())}

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        total_tokens = self.prefill_tokens + self.decode_tokens
        steps = max(self.compute_steps, 1)
        gen = sum(len(r.generated) for r in self.finished.values())
        lat = sorted(
            t for r in self.finished.values() for t in r.token_latencies
        )

        def pct(p):
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p / 100.0 * len(lat)))]

        wall = sum(self.step_times)
        return {
            "compute_steps": self.compute_steps,
            "idle_steps": self.idle_steps,
            "total_tokens": total_tokens,
            "generated_tokens": gen,
            "tokens_per_step": total_tokens / steps,
            "generated_per_step": gen / steps,
            "slot_utilization": self._occupancy_sum
            / (steps * self.serve_cfg.max_slots),
            "wall_s": wall,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "tokens_per_s": total_tokens / max(wall, 1e-9),
            "p50_token_latency_s": pct(50),
            "p99_token_latency_s": pct(99),
        }
