"""The continuous-batching engine: slot-scheduled greedy serving.

One engine iteration (:meth:`ContinuousBatchingEngine.step`):

1. **admission** — freed slots are handed to arrived waiting requests
   (FIFO; under the paged cache also gated on free pages); each new
   occupant's cache rows are zeroed and, for encdec families, its
   encoder output is written into the slot's row.
2. **planning** — the :class:`~repro.serve.scheduler.Scheduler` packs
   decode tokens (1 per running slot) and chunked-prefill tokens under
   the token budget. With the paged cache the engine then grows each
   planned slot's block table to cover the step; if the pool runs dry
   it **preempts** the youngest running request back to WAITING
   (its pages freed and zeroed, its cache recomputed on re-admission —
   greedy decode makes the recompute bit-exact) and retries.
3. **one jitted mixed step** — :func:`repro.launch.steps.make_slot_step`
   runs prefill chunks and decode tokens together; per-slot cache
   positions (and, when paged, per-slot block tables) mean no slot
   waits for another. The step width is the smallest compiled width in
   ``ServeConfig.widths`` that fits the largest per-slot count, so
   mixed steps don't pad every row to the full prefill chunk.
4. **completion** — slots that consumed their last prompt token emit
   their first generated token; slots that hit ``max_new_tokens`` finish
   and release their slot (and pages) for the next waiting request.

Requests therefore join and leave the batch mid-flight: throughput is
bounded by slot capacity — and with the paged cache by *actual* cache
use rather than worst-case sequence length. Greedy outputs are
identical per request to lock-step decode of the same prompt
(`repro.serve.lockstep` is the reference; `tests/test_serve.py` pins
paged ≡ contiguous ≡ lock-step across all model families).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as steps_lib
from repro.models import model as lm
from repro.serve import request as rq
from repro.serve.cache import PagedCacheManager, SlotCacheManager
from repro.serve.scheduler import Scheduler, ServeConfig


class ContinuousBatchingEngine:
    """Slot-based request scheduler over one model replica.

    Args:
      cfg: model config.
      params: model params (already sharded when serving under a mesh).
      serve_cfg: slot/chunk/budget configuration. ``block_size > 0``
        switches the KV cache to the paged layout (pool of fixed-size
        pages + per-slot block tables) with preempt-to-WAITING on pool
        exhaustion.
      cache_dtype: decode-cache dtype (fp32 default, matching the
        lock-step driver).
      mesh: optional data×model mesh; the cache is placed with the
        production ``cache_shardings`` rules. Callers run the engine
        inside ``jax.set_mesh(mesh)``.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        serve_cfg: ServeConfig,
        *,
        cache_dtype=jnp.float32,
        mesh=None,
        seq_shard: bool = False,
    ):
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        if serve_cfg.paged:
            self.slots = PagedCacheManager(
                cfg, serve_cfg.max_slots, serve_cfg.max_seq,
                block_size=serve_cfg.block_size,
                n_blocks=serve_cfg.total_blocks,
                dtype=cache_dtype, mesh=mesh, seq_shard=seq_shard,
            )
        else:
            self.slots = SlotCacheManager(
                cfg, serve_cfg.max_slots, serve_cfg.max_seq,
                dtype=cache_dtype, mesh=mesh, seq_shard=seq_shard,
            )
        self.scheduler = Scheduler(serve_cfg)
        self._step_fn = jax.jit(steps_lib.make_slot_step(cfg))
        self.waiting: List[rq.Request] = []
        self.by_slot: Dict[int, rq.Request] = {}
        self.finished: Dict[int, rq.Request] = {}
        self.clock = 0
        # stats
        self.compute_steps = 0
        self.idle_steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.preemptions = 0
        self.peak_concurrency = 0
        self.padded_tokens = 0  # B × width summed over compute steps
        self.step_times: List[float] = []
        self._occupancy_sum = 0
        self.enc_out = None
        self._encode = None
        if cfg.family == "encdec":
            self.enc_out = jnp.zeros(
                (serve_cfg.max_slots, cfg.enc_seq, cfg.d_model),
                jnp.dtype(cfg.dtype),
            )
            self._encode = jax.jit(
                lambda p, f: lm.encode(cfg, p, f.astype(jnp.dtype(cfg.dtype)))
            )

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def submit(self, req: rq.Request) -> None:
        """Queue a request. Raises if it can never fit the cache."""
        need = req.prompt_len + req.max_new_tokens - 1  # last token not cached
        if need > self.serve_cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+generation ({need}) exceeds "
                f"max_seq {self.serve_cfg.max_seq}"
            )
        if self.serve_cfg.paged:
            need_blocks = -(-need // self.serve_cfg.block_size)
            if need_blocks > self.serve_cfg.total_blocks:
                raise ValueError(
                    f"request {req.rid}: needs {need_blocks} pages, pool "
                    f"has {self.serve_cfg.total_blocks}"
                )
        if self.cfg.family == "encdec" and req.frames is None:
            raise ValueError(f"request {req.rid}: encdec family needs frames")
        req.state = rq.WAITING
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))

    def _admit(self) -> None:
        admitted = self.scheduler.admit(
            self.waiting, self.slots.n_free, self.clock,
            n_free_blocks=(
                self.slots.n_free_blocks if self.serve_cfg.paged else None
            ),
        )
        if not admitted:
            return
        new_slots = []
        for req in admitted:
            self.waiting.remove(req)
            slot = self.slots.alloc()
            req.slot = slot
            req.state = rq.PREFILL
            self.by_slot[slot] = req
            new_slots.append(slot)
            if self._encode is not None:
                enc = self._encode(self.params, jnp.asarray(req.frames)[None])
                self.enc_out = self.enc_out.at[slot].set(enc[0])
        self.slots.reset(new_slots)  # clear the previous occupants' state

    # ------------------------------------------------------------------
    # paged-cache block management
    # ------------------------------------------------------------------

    def _pick_victim(self, keep: int) -> Optional[int]:
        """Youngest running slot other than ``keep`` (max arrival, rid)."""
        cands = [s for s in self.by_slot if s != keep]
        if not cands:
            return None
        return max(
            cands, key=lambda s: (self.by_slot[s].arrival, self.by_slot[s].rid)
        )

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s request back to WAITING and free its pages.

        The freed pages are zeroed eagerly (they may be re-allocated
        within this same tick); the request's cache is recomputed on
        re-admission (greedy decode makes the recompute bit-exact)."""
        req = self.by_slot.pop(slot)
        self.slots.free(slot)
        req.preempt()
        self.preemptions += 1
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))

    def _ensure_blocks(self, plan: Dict[int, int]) -> Dict[int, int]:
        """Grow block tables to cover this step's writes, oldest request
        first; preempt the youngest running request on pool exhaustion
        (evicting it from the plan) and retry."""
        order = sorted(
            plan, key=lambda s: (self.by_slot[s].arrival, self.by_slot[s].rid)
        )
        for slot in order:
            if slot not in plan:
                continue  # preempted as a victim earlier in this loop
            need = int(self.slots.pos[slot]) + plan[slot]
            while not self.slots.ensure(slot, need):
                victim = self._pick_victim(keep=slot)
                if victim is None:
                    raise RuntimeError(
                        f"slot {slot}: page pool exhausted with no victim "
                        "(request larger than the pool?)"
                    )
                self._preempt(victim)
                plan.pop(victim, None)
        return plan

    # ------------------------------------------------------------------
    # one engine iteration
    # ------------------------------------------------------------------

    def _pick_width(self, plan: Dict[int, int]) -> int:
        """Smallest compiled step width fitting the largest chunk — the
        decode-width ladder (mixed steps stop padding to prefill_chunk)."""
        need = max(plan.values())
        for w in self.serve_cfg.widths:
            if w >= need:
                return w
        return self.serve_cfg.prefill_chunk

    def step(self) -> bool:
        """Run one engine tick. Returns True when compute happened."""
        self._admit()
        self.peak_concurrency = max(self.peak_concurrency, len(self.by_slot))
        plan = self.scheduler.plan(self.by_slot)
        if plan and self.serve_cfg.paged:
            plan = self._ensure_blocks(plan)
        if not plan:
            self.clock += 1
            self.idle_steps += 1
            return False

        b = self.serve_cfg.max_slots
        width = self._pick_width(plan)
        tokens = np.zeros((b, width), np.int32)
        count = np.zeros((b,), np.int32)
        n_prefill = 0
        for slot, n in plan.items():
            req = self.by_slot[slot]
            if req.remaining_prompt > 0:
                seg = req.context[req.prefilled : req.prefilled + n]
                tokens[slot, : len(seg)] = seg
                count[slot] = len(seg)
                n_prefill += len(seg)
            else:
                tokens[slot, 0] = req.generated[-1]
                count[slot] = 1

        state = {
            "tokens": jnp.asarray(tokens),
            "count": jnp.asarray(count),
            "pos": jnp.asarray(self.slots.pos),
            "cache": self.slots.cache,
        }
        if self.serve_cfg.paged:
            # host table -> device, replicated under a mesh (every pool
            # shard needs the full logical->physical map)
            state["block_tables"] = (
                jax.device_put(self.slots.block_tables, self.slots.table_sharding)
                if self.slots.table_sharding is not None
                else jnp.asarray(self.slots.block_tables)
            )
        if self.enc_out is not None:
            state["enc_out"] = self.enc_out
        t0 = time.perf_counter()
        nxt, new_state = self._step_fn(self.params, state)
        nxt = np.asarray(nxt)
        dt = time.perf_counter() - t0
        self.slots.cache = new_state["cache"]
        self.slots.pos = self.slots.pos + count

        done_slots = []
        for slot, n in sorted(plan.items()):
            req = self.by_slot[slot]
            emitted = None
            if req.state == rq.PREFILL:
                req.prefilled += int(count[slot])
                if req.remaining_prompt == 0:
                    req.state = rq.DECODE
                    if req.first_token_step < 0:
                        req.first_token_step = self.clock
                    # A resumed (preempted) request's re-prefill ends on
                    # generated[-2]; the logits there re-predict the
                    # already-known generated[-1] — don't emit it twice.
                    if not req.generated:
                        emitted = int(nxt[slot])
            else:
                emitted = int(nxt[slot])
            if emitted is not None:
                req.generated.append(emitted)
                req.token_steps.append(self.clock)
                req.token_latencies.append(dt)
                if req.done:
                    req.state = rq.FINISHED
                    req.finish_step = self.clock
                    self.finished[req.rid] = req
                    done_slots.append(slot)
        for slot in done_slots:
            del self.by_slot[slot]
            self.slots.free(slot)

        self.compute_steps += 1
        self.step_times.append(dt)
        self.padded_tokens += b * width
        n_total = int(count.sum())
        self.prefill_tokens += n_prefill
        self.decode_tokens += n_total - n_prefill
        # mixed steps: apportion wall time by token share so the
        # prefill/decode split stays comparable to the lock-step baseline
        frac = n_prefill / max(n_total, 1)
        self.prefill_s += dt * frac
        self.decode_s += dt * (1.0 - frac)
        self._occupancy_sum += len(plan)
        self.clock += 1
        return True

    def run(self, max_ticks: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drive to completion (incl. future arrivals). rid -> tokens."""
        ticks = 0
        while self.waiting or self.by_slot:
            self.step()
            ticks += 1
            if max_ticks is not None and ticks >= max_ticks:
                break
        return {rid: r.tokens() for rid, r in sorted(self.finished.items())}

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Aggregate serving metrics for the finished (or partial) run.

        Keys cover throughput (``tokens_per_step``, ``tokens_per_s``),
        latency percentiles, slot economics (``slot_utilization``,
        ``peak_concurrency``), step-padding efficiency
        (``padded_tokens``, ``padding_efficiency`` — the decode-width
        ladder's metric) and paged-cache health (``preemptions``).
        """
        total_tokens = self.prefill_tokens + self.decode_tokens
        steps = max(self.compute_steps, 1)
        gen = sum(len(r.generated) for r in self.finished.values())
        lat = sorted(
            t for r in self.finished.values() for t in r.token_latencies
        )

        def pct(p):
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p / 100.0 * len(lat)))]

        wall = sum(self.step_times)
        return {
            "compute_steps": self.compute_steps,
            "idle_steps": self.idle_steps,
            "total_tokens": total_tokens,
            "generated_tokens": gen,
            "tokens_per_step": total_tokens / steps,
            "generated_per_step": gen / steps,
            "slot_utilization": self._occupancy_sum
            / (steps * self.serve_cfg.max_slots),
            "peak_concurrency": self.peak_concurrency,
            "preemptions": self.preemptions,
            "padded_tokens": self.padded_tokens,
            "padding_efficiency": total_tokens / max(self.padded_tokens, 1),
            "wall_s": wall,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "tokens_per_s": total_tokens / max(wall, 1e-9),
            "p50_token_latency_s": pct(50),
            "p99_token_latency_s": pct(99),
        }
