"""Request lifecycle for the continuous-batching engine.

A request moves through::

    WAITING --admit--> PREFILL --last prompt token--> DECODE --max_new--> FINISHED
    (arrival queue)    (chunked)                      (1 tok/step)       (slot freed)
        ^                                               |
        +----------------- preempt (paged engine) ------+

The engine owns the transitions; this module just holds the record and
its bookkeeping (slot assignment, prefill progress, generated tokens,
and per-token step/latency traces for the latency benchmark).

**Preemption** (paged engine only): when the block pool is exhausted the
engine evicts a running request back to WAITING and frees its pages.
Because decode is greedy (deterministic), the evicted request's cache
contents can be *recomputed* instead of swapped out: on re-admission it
re-prefills :attr:`Request.context` — the prompt plus every generated
token except the newest — after which the newest generated token is fed
as the next decode input, restoring exactly the state it was evicted
from. The transition is :meth:`Request.preempt`; ``context`` and
``remaining_prompt`` make the resume transparent to the scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One serving request.

    Args:
      rid: unique id.
      prompt: ``[P]`` int32 token ids (P >= 1).
      max_new_tokens: generation budget (>= 1); greedy decode stops there.
      arrival: engine tick at which the request becomes visible to
        admission (staggered/Poisson workloads).
      frames: optional ``[enc_seq, d_model]`` encoder input (encdec
        families); encoded once at admission.
    """

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: int = 0
    frames: Optional[np.ndarray] = None

    # --- engine-owned lifecycle state ---
    state: str = WAITING
    slot: int = -1
    prefilled: int = 0  # context tokens already fed to the model
    generated: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0  # times evicted back to WAITING (paged engine)
    # recompute context after a preemption (None = plain prompt)
    _resume: Optional[np.ndarray] = None
    # traces (engine ticks / seconds) for latency accounting
    first_token_step: int = -1
    finish_step: int = -1
    token_steps: List[int] = dataclasses.field(default_factory=list)
    token_latencies: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def context(self) -> np.ndarray:
        """Tokens to prefill: the prompt, or — after a preemption — the
        prompt plus all generated tokens but the newest (the newest is
        the next decode input, so it is never cached ahead of time)."""
        return self.prompt if self._resume is None else self._resume

    @property
    def context_len(self) -> int:
        return int(self.context.size)

    @property
    def remaining_prompt(self) -> int:
        return self.context_len - self.prefilled

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def preempt(self) -> None:
        """Evict back to WAITING (paged engine, block-pool exhaustion).

        Drops all cache progress; records the recompute context so
        re-admission restores the cache bit-exactly under greedy decode.
        """
        if self.generated:
            self._resume = np.concatenate(
                [self.prompt, np.asarray(self.generated[:-1], np.int32)]
            )
        else:
            self._resume = None
        self.state = WAITING
        self.slot = -1
        self.prefilled = 0
        self.preemptions += 1

    def tokens(self) -> np.ndarray:
        return np.asarray(self.generated, np.int32)
