"""Request lifecycle for the continuous-batching engine.

A request moves through::

    WAITING --admit--> PREFILL --last prompt token--> DECODE --max_new--> FINISHED
    (arrival queue)    (chunked)                      (1 tok/step)       (slot freed)
        ^                                               |
        +----------------- preempt (paged engine) ------+

The engine owns the transitions; this module just holds the record and
its bookkeeping (slot assignment, prefill progress, generated tokens,
sampling parameters, and per-token step/latency traces for the latency
benchmark).

**Sampling** is data carried on the request (:class:`SamplingParams`):
temperature 0 is greedy decode, temperature > 0 samples with per-request
top-k / top-p truncation from a per-request PRNG lane derived from
``seed``. The lane is *stateless*: the subkey for the token emitted at
absolute cache position ``p`` is ``fold_in(key_data(seed), p)``, so the
sampled stream is a pure function of (seed, position) — invariant to
chunking, slot assignment, batch composition and preemption.

**Preemption** (paged engine only): when the block pool is exhausted the
engine evicts a running request back to WAITING and frees its pages.
Two strategies exist:

* **recompute** (:meth:`Request.preempt`) — drop the cache and
  re-prefill :attr:`Request.context` (prompt plus every generated token
  except the newest) on re-admission. Bit-exact **only for greedy
  requests**: re-prefill replays argmax decisions exactly, but a sampled
  request's cache would be rebuilt from tokens whose logits are then
  *re-sampled* on the resumed decode path, so :meth:`Request.preempt`
  raises on a sampled request rather than silently corrupting output.
* **swap** (:meth:`Request.preempt_swap`) — the engine swaps the slot's
  KV pages and SSM/conv rows to host memory
  (:meth:`repro.serve.cache.PagedCacheManager.swap_out`) and restores
  them on re-admission; positions are preserved so the stateless RNG
  lane emits the identical token stream. Safe for any request.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.launch.steps import TOP_K_CAP

WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"
FINISHED = "finished"


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode controls, carried on :class:`Request` as data.

    Attributes:
      temperature: 0 (default) is greedy argmax decode; > 0 divides the
        logits before sampling.
      top_k: keep only the k highest logits before sampling (0 = off;
        bounded by ``repro.launch.steps.TOP_K_CAP`` — the jitted step
        computes the top ``TOP_K_CAP`` logits once instead of sorting
        the whole vocabulary, so k must fit under the static cap).
      top_p: keep the smallest prefix of the sorted distribution with
        cumulative probability >= top_p (1.0 = off). Applied after
        top-k, matching the usual serving convention.
      seed: PRNG lane seed. Two concurrent requests with the same seed
        share a lane (their draws at equal positions coincide) — give
        each request its own seed unless that is what you want.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {self.top_k}")
        if self.top_k > TOP_K_CAP:
            raise ValueError(
                f"top_k must be <= {TOP_K_CAP} (the static lax.top_k bound "
                f"in the jitted step), got {self.top_k}"
            )
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        """Greedy decode — deterministic without a PRNG lane."""
        return self.temperature == 0.0

    def key_data(self) -> np.ndarray:
        """The request's base PRNG lane as raw ``uint32[2]`` key data.

        Matches the threefry ``PRNGKey`` layout (hi word, lo word) so it
        can ride in the jitted step state as a plain ``[B, 2]`` array
        and be ``fold_in``-ed per emitted token on device.
        """
        return np.array(
            [(self.seed >> 32) & 0xFFFFFFFF, self.seed & 0xFFFFFFFF],
            np.uint32,
        )


@dataclasses.dataclass
class Request:
    """One serving request.

    Args:
      rid: unique id (the engine rejects duplicates at submit time).
      prompt: ``[P]`` int32 token ids (P >= 1).
      max_new_tokens: generation budget (>= 1); decode stops there.
      arrival: engine tick at which the request becomes visible to
        admission (staggered/Poisson workloads).
      frames: optional ``[enc_seq, d_model]`` encoder input (encdec
        families); encoded once at admission.
      sampling: per-request :class:`SamplingParams` (greedy default).
      no_spec: opt this request out of speculative decoding — it decodes
        one token per step even when the engine runs with
        ``ServeConfig.spec_k > 0`` (output is identical either way;
        the opt-out only trades steps for verify width).
    """

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: int = 0
    frames: np.ndarray | None = None
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    no_spec: bool = False

    # --- engine-owned lifecycle state ---
    state: str = WAITING
    slot: int = -1
    prefilled: int = 0  # context tokens already fed to the model
    generated: list[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0  # times evicted back to WAITING (paged engine)
    # recompute context after a preemption (None = plain prompt)
    _resume: np.ndarray | None = None
    # host-swapped cache state (SwappedSlot) awaiting re-admission
    swap: object | None = None
    # traces (engine ticks / seconds) for latency accounting
    first_token_step: int = -1
    finish_step: int = -1
    token_steps: list[int] = dataclasses.field(default_factory=list)
    token_latencies: list[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def context(self) -> np.ndarray:
        """Tokens to prefill: the prompt, or — after a recompute
        preemption — the prompt plus all generated tokens but the newest
        (the newest is the next decode input, so it is never cached
        ahead of time)."""
        return self.prompt if self._resume is None else self._resume

    @property
    def context_len(self) -> int:
        return int(self.context.size)

    @property
    def remaining_prompt(self) -> int:
        return self.context_len - self.prefilled

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def preempt(self) -> None:
        """Evict back to WAITING with **recompute** on re-admission.

        Drops all cache progress; records the recompute context so
        re-admission restores the cache bit-exactly under greedy decode.
        Raises for a sampled request — re-sampling the resumed decode
        stream would silently diverge from the unpreempted run; the
        engine must swap sampled requests instead
        (:meth:`preempt_swap`).
        """
        if not self.sampling.greedy:
            raise RuntimeError(
                f"request {self.rid}: recompute preemption requested for a "
                f"sampled request (temperature={self.sampling.temperature}); "
                "recompute is only bit-exact under greedy decode — use swap "
                "preemption (ServeConfig.preempt='swap' or 'auto')"
            )
        if self.generated:
            self._resume = np.concatenate(
                [self.prompt, np.asarray(self.generated[:-1], np.int32)]
            )
        else:
            self._resume = None
        self.state = WAITING
        self.slot = -1
        self.prefilled = 0
        self.preemptions += 1

    def preempt_swap(self, swapped) -> None:
        """Evict back to WAITING with the cache **swapped** to host.

        ``swapped`` is the :class:`repro.serve.cache.SwappedSlot` bundle
        the engine got from ``swap_out``; prefill progress and positions
        are preserved, so re-admission restores the exact device state
        (and the stateless RNG lane re-emits the identical sampled
        stream). Safe for greedy and sampled requests alike.
        """
        self.swap = swapped
        self.state = WAITING
        self.slot = -1
        self.preemptions += 1

    def resume_from_swap(self) -> None:
        """Called by the engine after ``swap_in``: drop the host bundle
        and restore the state the request was evicted in."""
        self.swap = None
        self.state = DECODE if self.remaining_prompt == 0 else PREFILL

    def tokens(self) -> np.ndarray:
        return np.asarray(self.generated, np.int32)
