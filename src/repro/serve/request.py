"""Request lifecycle for the continuous-batching engine.

A request moves through::

    WAITING --admit--> PREFILL --last prompt token--> DECODE --max_new--> FINISHED
    (arrival queue)    (chunked)                      (1 tok/step)       (slot freed)

The engine owns the transitions; this module just holds the record and
its bookkeeping (slot assignment, prefill progress, generated tokens,
and per-token step/latency traces for the latency benchmark).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One serving request.

    Args:
      rid: unique id.
      prompt: ``[P]`` int32 token ids (P >= 1).
      max_new_tokens: generation budget (>= 1); greedy decode stops there.
      arrival: engine tick at which the request becomes visible to
        admission (staggered/Poisson workloads).
      frames: optional ``[enc_seq, d_model]`` encoder input (encdec
        families); encoded once at admission.
    """

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: int = 0
    frames: Optional[np.ndarray] = None

    # --- engine-owned lifecycle state ---
    state: str = WAITING
    slot: int = -1
    prefilled: int = 0  # prompt tokens already fed to the model
    generated: List[int] = dataclasses.field(default_factory=list)
    # traces (engine ticks / seconds) for latency accounting
    first_token_step: int = -1
    finish_step: int = -1
    token_steps: List[int] = dataclasses.field(default_factory=list)
    token_latencies: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def remaining_prompt(self) -> int:
        return self.prompt_len - self.prefilled

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def tokens(self) -> np.ndarray:
        return np.asarray(self.generated, np.int32)
