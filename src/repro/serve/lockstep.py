"""The static lock-step baseline (and parity reference).

This is the serving loop `repro.launch.serve` used to hard-code: all
requests arrive together, prefill is teacher-forced token-by-token, and
the whole batch decodes in lock-step until the *longest* generation
finishes — finished requests burn decode slots as padding. It survives
as (a) the reference the continuous engine must match token-for-token,
and (b) the baseline `benchmarks/serve_latency.py` beats.

The oracle covers sampling too: pass per-request
:class:`~repro.serve.request.SamplingParams` and the lock-step decode
draws through the same stateless per-position PRNG lanes as the
continuous engine (subkey = ``fold_in(key_data(seed), position)``), so
a seeded sampled continuous run must match the lock-step sampled run
token-for-token — the property that makes sampling testable at all.
"""
from __future__ import annotations

from collections.abc import Sequence
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as steps_lib
from repro.models import model as lm
from repro.serve.request import SamplingParams


def generate_lockstep(
    cfg: ModelConfig,
    params,
    prompts: np.ndarray,  # [B, P] int32 (uniform prompt length)
    gen_lens: Sequence[int],  # per-request generation lengths
    *,
    max_seq: int,
    frames: np.ndarray | None = None,  # [B, enc_seq, d_model] (encdec)
    cache_dtype=jnp.float32,
    sampling: Sequence[SamplingParams] | None = None,
) -> dict[str, object]:
    """Lock-step decode of one static batch (greedy by default).

    ``sampling`` (one :class:`SamplingParams` per request, or None for
    all-greedy) routes decode through the same per-position PRNG lanes
    as the continuous engine, making this the sampled parity oracle.

    Returns dict with ``tokens`` (list of per-request arrays, sliced to
    each request's gen_len), ``steps`` (model invocations: P-1 teacher
    steps + max(gen_lens) decode steps), and wall-time splits.
    """
    prompts = np.asarray(prompts, np.int32)
    b, p = prompts.shape
    gen_lens = [int(g) for g in gen_lens]
    assert len(gen_lens) == b and min(gen_lens) >= 1
    max_gen = max(gen_lens)
    if p + max_gen - 1 > max_seq:
        raise ValueError(f"prompt+generation ({p + max_gen - 1}) exceeds max_seq {max_seq}")

    serve_step = jax.jit(steps_lib.make_serve_step(cfg))
    cache = lm.init_cache(cfg, b, max_seq, dtype=cache_dtype)
    state = {
        "tokens": jnp.asarray(prompts[:, :1]),
        "pos": jnp.int32(0),
        "cache": cache,
    }
    if sampling is not None:
        sampling = list(sampling)
        if len(sampling) != b:
            raise ValueError(
                f"sampling has {len(sampling)} entries for batch {b}"
            )
        state["temps"] = jnp.asarray(
            [s.temperature for s in sampling], jnp.float32
        )
        state["top_ks"] = jnp.asarray([s.top_k for s in sampling], jnp.int32)
        state["top_ps"] = jnp.asarray([s.top_p for s in sampling], jnp.float32)
        state["rng"] = jnp.asarray(
            np.stack([s.key_data() for s in sampling]), jnp.uint32
        )
    if cfg.family == "encdec":
        if frames is None:
            raise ValueError("encdec lock-step needs frames")
        state["enc_out"] = lm.encode(
            cfg, params, jnp.asarray(frames).astype(jnp.dtype(cfg.dtype))
        )

    t0 = time.perf_counter()
    for t in range(1, p):
        state = serve_step(params, state)
        state["tokens"] = jnp.asarray(prompts[:, t : t + 1])  # teacher-forced
    jax.block_until_ready(state["cache"])
    prefill_s = time.perf_counter() - t0

    generated = []
    t0 = time.perf_counter()
    for _ in range(max_gen):
        state = serve_step(params, state)
        generated.append(np.asarray(state["tokens"])[:, 0])
    decode_s = time.perf_counter() - t0

    gen = np.stack(generated, axis=1)  # [B, max_gen]
    tokens = [gen[i, : gen_lens[i]] for i in range(b)]
    return {
        "tokens": tokens,
        "steps": (p - 1) + max_gen,
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "generated_tokens": int(sum(gen_lens)),
    }


def generate_reference(
    cfg: ModelConfig,
    params,
    prompt: np.ndarray,  # [P] int32
    gen_len: int,
    *,
    max_seq: int,
    frames: np.ndarray | None = None,  # [enc_seq, d_model]
    cache_dtype=jnp.float32,
    sampling: SamplingParams | None = None,
) -> np.ndarray:
    """Single-request lock-step decode (greedy, or sampled via
    ``sampling``) — the per-request oracle the continuous engine must
    reproduce token-for-token."""
    out = generate_lockstep(
        cfg,
        params,
        np.asarray(prompt, np.int32)[None],
        [gen_len],
        max_seq=max_seq,
        frames=None if frames is None else np.asarray(frames)[None],
        cache_dtype=cache_dtype,
        sampling=None if sampling is None else [sampling],
    )
    return out["tokens"][0]


def lockstep_waves(
    requests,
    capacity: int,
) -> list[list]:
    """Split a request list into static batches ("waves") of ``capacity``
    in arrival order — how a lock-step server has to run a staggered
    workload. Used by the latency benchmark for the steps comparison."""
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
    return [reqs[i : i + capacity] for i in range(0, len(reqs), capacity)]
