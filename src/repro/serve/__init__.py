"""Continuous-batching serving subsystem.

Layout::

  request.py    request record + lifecycle states
  cache.py      SlotCacheManager — cache rows as allocatable slots
  scheduler.py  ServeConfig + token-budget prefill/decode packing
  engine.py     ContinuousBatchingEngine — the serving loop
  lockstep.py   static lock-step baseline + per-request parity oracle
  workload.py   Poisson staggered-arrival workload generator

The engine rides on the per-slot cache API in ``repro.models.model``
(``decode_slots`` / ``reset_slots``) and the jitted mixed step in
``repro.launch.steps.make_slot_step``; under a data×model mesh the cache
uses ``repro.dist.sharding.cache_shardings``. `repro.launch.serve` is
the CLI.
"""
from repro.serve.cache import SlotCacheManager
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.lockstep import (
    generate_lockstep,
    generate_reference,
    lockstep_waves,
)
from repro.serve.request import DECODE, FINISHED, PREFILL, WAITING, Request
from repro.serve.scheduler import Scheduler, ServeConfig
from repro.serve.workload import poisson_workload

__all__ = [
    "ContinuousBatchingEngine",
    "SlotCacheManager",
    "Scheduler",
    "ServeConfig",
    "Request",
    "WAITING",
    "PREFILL",
    "DECODE",
    "FINISHED",
    "generate_lockstep",
    "generate_reference",
    "lockstep_waves",
    "poisson_workload",
]
