"""Continuous-batching serving subsystem: slots, pages, and the engine.

Layout::

  request.py    request record + lifecycle states + SamplingParams
  cache.py      SlotCacheManager (contiguous rows) / PagedCacheManager
                (page pool + block tables + swap_out/swap_in) /
                BlockAllocator (free list) / SwappedSlot (host bundle)
  scheduler.py  ServeConfig + token-budget prefill/decode packing,
                free-page-gated admission, preemption policy
  engine.py     ContinuousBatchingEngine — the serving loop + streaming
  lockstep.py   static lock-step baseline + per-request parity oracle
                (greedy and sampled)
  workload.py   Poisson staggered-arrival + long-tail workload generators

Request lifecycle (the engine owns every transition)::

  WAITING --admit--> PREFILL --last context token--> DECODE --max_new--> FINISHED
  (arrival queue,    (chunked, up to               (1 tok/step, or a    (slot and
   slot + pages       prefill_chunk/step)           1+k verify chunk     pages freed,
   available)                ^                      when spec_k > 0)     zeroed)
                             |                        |
                             +------- preempt --------+
                              (paged engine, pool exhausted: pages freed
                               + zeroed; cache recomputed on re-admission,
                               or swap-staged on the host and restored;
                               drafter state is dropped either way and
                               rebuilt by catch-up on resume)

Speculative decoding (``ServeConfig.spec_k > 0``): a drafter — its own
per-slot cache rows; ``draft_cfg``/``draft_params`` on the engine, the
target itself by default — proposes up to k tokens per decode slot, and
the target verifies the ``1 + k`` chunk in one pass exactly as chunked
prefill (per-position logits). Acceptance is exact-match at each
position's fold, so the emitted stream is bit-identical to ``spec_k=0``
for greedy and sampled requests alike — same tokens, fewer steps.
Rejected positions cost nothing to undo: KV writes beyond the committed
position are causally fenced, SSM state is rolled back by per-position
selection, and (paged) pages holding only rejected tokens are trimmed
back to the pool. Per-request opt-out via ``Request.no_spec``;
acceptance telemetry in ``engine.stats()``. See
``docs/serving.md`` for the full design note.

Sampling (per-request ``SamplingParams`` on ``Request.sampling``)::

  temperature   0.0 = greedy argmax (default); > 0 scales logits
  top_k         0 = off; keep only the k largest logits
  top_p         1.0 = off; nucleus — smallest prefix with mass >= p
  seed          per-request PRNG lane (uint32[2] via ``key_data()``)

All controls are per-slot *data* in the jitted step — one compiled
executable per width serves any mix of greedy and sampled slots. The
subkey for the token emitted at absolute cache position p is
``fold_in(key_data(seed), p)``: a pure function of (seed, position),
so the sampled stream is invariant to chunking, slot assignment, batch
composition and preemption, and the continuous engine matches the
lock-step oracle token-for-token even when sampling.

Preemption policy (``ServeConfig.preempt``) — what happens to the
victim's cache when the page pool runs dry:

  ============  =====================  ================================
  policy        greedy request         sampled request
  ============  =====================  ================================
  "recompute"   drop pages, re-prefill  **rejected** (``Request.preempt``
                token history (cheap,   raises — replayed prefill does
                bit-exact)              not re-fold the sampled draws)
  "swap"        stage KV pages +        same — host round-trip, exact
                SSM/conv rows on host   for any request
  "auto"        recompute               swap
  ============  =====================  ================================

Streaming: ``engine.step()`` returns ``TokenEvent(rid, token,
is_last)`` tuples as tokens are emitted; ``engine.run(on_token=...)``
invokes a callback per event, and ``engine.stream()`` is a generator
yielding events as ticks execute.

Block-table protocol (paged cache, ``ServeConfig.block_size > 0``):

  ==========================  =============================================
  object                      meaning
  ==========================  =============================================
  page pool                   cache K/V leaves ``[np, n_blocks, block_size,
                              KV, hd]`` — page id *p* addresses the same
                              pool index at every layer
  block table                 ``[max_slots, blocks_per_slot]`` int32; row
                              *b*, entry *l* = physical page holding slot
                              b's tokens ``[l*bs, (l+1)*bs)``; unassigned
                              entries are 0 (valid page, causally fenced)
  write                       token at absolute position p scatters to
                              ``(table[b, p // bs], p % bs)``; invalid
                              tokens route to page ``n_blocks`` (dropped)
  read                        attention gathers ``pool[table[b]]`` into the
                              same ``[B, blocks_per_slot*bs, KV, hd]`` view
                              the contiguous path uses
  grow                        engine calls ``ensure(slot, pos+count)``
                              before each step; pages allocate on demand
  exhaustion                  youngest running request preempts to WAITING
                              (pages freed + zeroed); greedy decode makes
                              the re-admission recompute bit-exact
  admission gate              scheduler admits only while free pages cover
                              the candidate's prefill context (FIFO
                              head-of-line on shortfall)
  zero-on-free                freed pages and freed slots' SSM/conv rows
                              are zeroed before reuse (the SSM-state
                              invariant extended to the KV pool)
  ==========================  =============================================

SSM/conv state is O(1) per slot and stays slot-major (``[np, B, ...]``)
in both layouts — only attention K/V pages.

The engine rides on the per-slot cache API in ``repro.models.model``
(``decode_slots`` / ``reset_slots`` / ``reset_paged``) and the jitted
mixed step in ``repro.launch.steps.make_slot_step``; under a data×model
mesh the cache uses ``repro.dist.sharding.cache_shardings`` (pass
``paged=True`` for the pool layout). `repro.launch.serve` is the CLI
(``--engine paged|continuous|lockstep``, ``--block-size``).
"""
from repro.serve.cache import (
    BlockAllocator,
    NoFreeBlocks,
    PagedCacheManager,
    SlotCacheManager,
    SwappedSlot,
)
from repro.serve.engine import ContinuousBatchingEngine, TokenEvent
from repro.serve.lockstep import (
    generate_lockstep,
    generate_reference,
    lockstep_waves,
)
from repro.serve.request import (
    DECODE,
    FINISHED,
    PREFILL,
    WAITING,
    Request,
    SamplingParams,
)
from repro.serve.scheduler import Scheduler, ServeConfig
from repro.serve.workload import longtail_workload, poisson_workload

__all__ = [
    "BlockAllocator",
    "ContinuousBatchingEngine",
    "NoFreeBlocks",
    "PagedCacheManager",
    "SlotCacheManager",
    "SwappedSlot",
    "Scheduler",
    "ServeConfig",
    "Request",
    "SamplingParams",
    "TokenEvent",
    "WAITING",
    "PREFILL",
    "DECODE",
    "FINISHED",
    "generate_lockstep",
    "generate_reference",
    "lockstep_waves",
    "longtail_workload",
    "poisson_workload",
]
