"""Continuous-batching serving subsystem: slots, pages, and the engine.

Layout::

  request.py    request record + lifecycle states
  cache.py      SlotCacheManager (contiguous rows) / PagedCacheManager
                (page pool + block tables) / BlockAllocator (free list)
  scheduler.py  ServeConfig + token-budget prefill/decode packing,
                free-page-gated admission
  engine.py     ContinuousBatchingEngine — the serving loop
  lockstep.py   static lock-step baseline + per-request parity oracle
  workload.py   Poisson staggered-arrival + long-tail workload generators

Request lifecycle (the engine owns every transition)::

  WAITING --admit--> PREFILL --last context token--> DECODE --max_new--> FINISHED
  (arrival queue,    (chunked, up to               (1 tok/step)        (slot and
   slot + pages       prefill_chunk/step)             |                 pages freed,
   available)                ^                        |                 zeroed)
                             +------- preempt --------+
                              (paged engine, pool exhausted: pages freed
                               + zeroed, cache recomputed on re-admission)

Block-table protocol (paged cache, ``ServeConfig.block_size > 0``):

  ==========================  =============================================
  object                      meaning
  ==========================  =============================================
  page pool                   cache K/V leaves ``[np, n_blocks, block_size,
                              KV, hd]`` — page id *p* addresses the same
                              pool index at every layer
  block table                 ``[max_slots, blocks_per_slot]`` int32; row
                              *b*, entry *l* = physical page holding slot
                              b's tokens ``[l*bs, (l+1)*bs)``; unassigned
                              entries are 0 (valid page, causally fenced)
  write                       token at absolute position p scatters to
                              ``(table[b, p // bs], p % bs)``; invalid
                              tokens route to page ``n_blocks`` (dropped)
  read                        attention gathers ``pool[table[b]]`` into the
                              same ``[B, blocks_per_slot*bs, KV, hd]`` view
                              the contiguous path uses
  grow                        engine calls ``ensure(slot, pos+count)``
                              before each step; pages allocate on demand
  exhaustion                  youngest running request preempts to WAITING
                              (pages freed + zeroed); greedy decode makes
                              the re-admission recompute bit-exact
  admission gate              scheduler admits only while free pages cover
                              the candidate's prefill context (FIFO
                              head-of-line on shortfall)
  zero-on-free                freed pages and freed slots' SSM/conv rows
                              are zeroed before reuse (the SSM-state
                              invariant extended to the KV pool)
  ==========================  =============================================

SSM/conv state is O(1) per slot and stays slot-major (``[np, B, ...]``)
in both layouts — only attention K/V pages.

The engine rides on the per-slot cache API in ``repro.models.model``
(``decode_slots`` / ``reset_slots`` / ``reset_paged``) and the jitted
mixed step in ``repro.launch.steps.make_slot_step``; under a data×model
mesh the cache uses ``repro.dist.sharding.cache_shardings`` (pass
``paged=True`` for the pool layout). `repro.launch.serve` is the CLI
(``--engine paged|continuous|lockstep``, ``--block-size``).
"""
from repro.serve.cache import (
    BlockAllocator,
    NoFreeBlocks,
    PagedCacheManager,
    SlotCacheManager,
)
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.lockstep import (
    generate_lockstep,
    generate_reference,
    lockstep_waves,
)
from repro.serve.request import DECODE, FINISHED, PREFILL, WAITING, Request
from repro.serve.scheduler import Scheduler, ServeConfig
from repro.serve.workload import longtail_workload, poisson_workload

__all__ = [
    "BlockAllocator",
    "ContinuousBatchingEngine",
    "NoFreeBlocks",
    "PagedCacheManager",
    "SlotCacheManager",
    "Scheduler",
    "ServeConfig",
    "Request",
    "WAITING",
    "PREFILL",
    "DECODE",
    "FINISHED",
    "generate_lockstep",
    "generate_reference",
    "lockstep_waves",
    "longtail_workload",
    "poisson_workload",
]
