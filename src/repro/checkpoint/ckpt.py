"""Checkpointing: msgpack tensor store, async save, restart discovery.

Layout: ``<dir>/step_<N>/{manifest.json, shard_<i>.msgpack}``. Tensors
are serialized host-side (numpy + msgpack) with dtype/shape metadata;
a ``COMMITTED`` marker file makes partially-written checkpoints invisible
to restart discovery (crash-safe). ``save_async`` snapshots to host
memory synchronously (cheap) and writes on a daemon thread so the train
loop never blocks on disk.

Elastic restore: tensors are loaded host-side and re-placed with
``jax.device_put(..., sharding)`` for whatever mesh the restarted job
has — resharding across a different device count is automatic.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_COMMIT = "COMMITTED"


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(k), v) for k, v in flat]
    return items, treedef


def _encode(arr: np.ndarray) -> Dict[str, Any]:
    return {
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    }


def _decode(obj) -> np.ndarray:
    return np.frombuffer(obj["data"], dtype=obj["dtype"]).reshape(obj["shape"])


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous checkpoint write. Returns the checkpoint path."""
    items, _ = _flatten(tree)
    host = [(k, np.asarray(jax.device_get(v))) for k, v in items]
    return _write(ckpt_dir, step, host, keep)


def _write(ckpt_dir: str, step: int, host_items, keep: int) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "keys": [k for k, _ in host_items]}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    payload = {k: _encode(v) for k, v in host_items}
    with open(os.path.join(tmp, "shard_0.msgpack"), "wb") as f:
        f.write(msgpack.packb(payload))
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _gc(ckpt_dir, keep)
    return path


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a daemon thread.

    At most one in-flight save; a new save waits for the previous write
    (bounded memory). ``wait()`` drains before exit/restore.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: Any):
        items, _ = _flatten(tree)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in items]
        self.wait()
        self._thread = threading.Thread(
            target=self._run, args=(step, host), daemon=True
        )
        self._thread.start()

    def _run(self, step, host):
        self.last_path = _write(self.ckpt_dir, step, host, self.keep)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


Saver = AsyncCheckpointer


def list_steps(ckpt_dir: str) -> List[int]:
    """Committed checkpoint steps, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(ckpt_dir, name)
            if os.path.exists(os.path.join(full, _COMMIT)):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def _shardings_by_key(items, shardings) -> List[Any]:
    """Per-leaf shardings aligned to ``items`` by pytree path.

    ``shardings`` may be a single Sharding (applied everywhere), a full
    pytree, or a PARTIAL pytree — any subtree it omits (or sets to None)
    restores unsharded. Path-keyed matching (not positional zip) is what
    makes the partial case safe: a ``{"params": p_sh}`` pytree must not
    leak param shardings onto the optimizer leaves.
    """
    if shardings is None or hasattr(shardings, "device_set"):
        return [shardings] * len(items)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        shardings, is_leaf=lambda x: hasattr(x, "device_set")
    )
    by_key = {jax.tree_util.keystr(k): v for k, v in flat}
    leaf_keys = {k for k, _ in items}
    unmatched = sorted(set(by_key) - leaf_keys)
    if unmatched:
        # a typo'd key would otherwise silently restore the whole tree
        # unsharded onto the default device
        raise ValueError(
            f"shardings entries match no checkpoint leaf: {unmatched[:5]}"
            f" (leaves look like: {sorted(leaf_keys)[:3]})"
        )
    return [by_key.get(k) for k, _ in items]


def restore(
    ckpt_dir: str,
    step: int,
    like: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``like``.

    ``shardings``: optional pytree of jax.sharding.Sharding (or a single
    sharding) — enables elastic restore onto any mesh. May be partial:
    leaves without a matching entry are restored unsharded.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "shard_0.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), strict_map_key=False)
    items, treedef = _flatten(like)
    flat_sh = _shardings_by_key(items, shardings)
    out = []
    for (k, proto), sh in zip(items, flat_sh):
        arr = _decode(payload[k])
        if hasattr(proto, "dtype"):
            arr = arr.astype(proto.dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
