"""Checkpointing: msgpack tensor store, async save, restart discovery,
per-host sharded checkpoints with partial-read restore.

Layout: ``<dir>/step_<N>/{manifest.json, shard_<r>.msgpack}``. Tensors
are serialized host-side (numpy + msgpack) with dtype/shape metadata;
a ``COMMITTED`` marker file makes partially-written checkpoints invisible
to restart discovery (crash-safe). ``AsyncCheckpointer`` snapshots to
host memory synchronously (cheap) and writes on a daemon thread so the
train loop never blocks on disk.

**Per-host sharding.** In a multi-host run each rank writes its own
``shard_<r>.msgpack`` covering only the array *pieces* it owns — either
FSDP-style balanced slices (:func:`make_shard_plan`) or the slices its
devices actually hold under the production partition specs
(:func:`plan_from_specs`, the addressable-shards addressing). A single
``manifest.json`` (written by the leader, derived from the same
deterministic plan every rank computes) records key → piece → shard
placement plus global dtype/shape; ``COMMITTED`` is written only after
**every** shard named in the manifest exists, so a writer killed
mid-save leaves a torn step that restart discovery skips.

**Partial-read restore.** :func:`restore` reads the manifest, loads
*only the shard files containing pieces of the keys in ``like``*, and
re-lands each tensor with ``jax.device_put(..., sharding)`` on whatever
mesh the restarted job has — a reshaped mesh (different host count,
different axis split) restores bit-exactly because assembly happens in
index space, not device space. Restoring a subtree touches only the
shards that cover it; a shard file required by the request but missing
on disk is a hard, actionable error — never a silently partial tree.
"""
from __future__ import annotations

from collections.abc import Sequence
import dataclasses
import json
import os
import shutil
import threading
from typing import Any
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_COMMIT = "COMMITTED"


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(k), v) for k, v in flat]
    return items, treedef


def _encode(arr: np.ndarray) -> dict[str, Any]:
    return {
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    }


def _decode(obj) -> np.ndarray:
    return np.frombuffer(obj["data"], dtype=obj["dtype"]).reshape(obj["shape"])


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous checkpoint write. Returns the checkpoint path."""
    items, _ = _flatten(tree)
    host = [(k, np.asarray(jax.device_get(v))) for k, v in items]
    return _write(ckpt_dir, step, host, keep)


def _write(ckpt_dir: str, step: int, host_items, keep: int) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "keys": [k for k, _ in host_items]}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    payload = {k: _encode(v) for k, v in host_items}
    with open(os.path.join(tmp, "shard_0.msgpack"), "wb") as f:
        f.write(msgpack.packb(payload))
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _gc(ckpt_dir, keep)
    return path


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a daemon thread.

    At most one in-flight save; a new save waits for the previous write
    (bounded memory). ``wait()`` drains before exit/restore.

    **Sharded mode**: construct with ``rank=`` and ``ranks=`` (the
    active fleet) and each rank's checkpointer writes only its own
    ``shard_<r>.msgpack``; the leader (lowest active rank) writes the
    manifest and commits once every peer's shard lands, all on the
    background thread so a slow peer never blocks the train loop. A
    commit that times out (a peer died mid-save) leaves the step torn —
    restart discovery skips it and the fleet falls back to the previous
    committed step. Reassign ``.ranks`` after a membership change; the
    next save's plan spans the new fleet.
    """

    def __init__(
        self,
        ckpt_dir: str,
        keep: int = 3,
        *,
        rank: int = 0,
        ranks: Sequence[int] | None = None,
        commit_timeout_s: float = 60.0,
    ):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.rank = rank
        self.ranks = list(ranks) if ranks is not None else None
        self.commit_timeout_s = commit_timeout_s
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None
        self.last_error: BaseException | None = None

    def _sharded(self) -> bool:
        return self.ranks is not None and len(self.ranks) > 1

    def save(self, step: int, tree: Any):
        items, _ = _flatten(tree)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in items]
        self.wait()
        ranks = list(self.ranks) if self.ranks is not None else None
        self._thread = threading.Thread(
            target=self._run, args=(step, host, ranks), daemon=True
        )
        self._thread.start()

    def _run(self, step, host, ranks):
        try:
            if ranks is not None and len(ranks) > 1:
                plan = make_shard_plan(host, ranks)
                self.last_path = write_shard(
                    self.ckpt_dir, step, host, rank=self.rank, plan=plan
                )
                if self.rank == min(ranks):
                    write_sharded_manifest(
                        self.ckpt_dir, step, host, plan=plan, ranks=ranks
                    )
                    commit_sharded(
                        self.ckpt_dir,
                        step,
                        timeout_s=self.commit_timeout_s,
                        keep=self.keep,
                    )
            else:
                self.last_path = _write(self.ckpt_dir, step, host, self.keep)
            self.last_error = None
        except BaseException as e:  # surfaced via .last_error on wait()
            self.last_error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


Saver = AsyncCheckpointer


def list_steps(ckpt_dir: str) -> list[int]:
    """Committed checkpoint steps, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(ckpt_dir, name)
            if os.path.exists(os.path.join(full, _COMMIT)):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


# ----------------------------------------------------------------------
# per-host shard plans
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Piece:
    """One rank's slice of one tensor: ``index`` is a per-dim
    ``(start, stop)`` tuple covering the full rank of the array."""

    shard: int
    index: tuple[tuple[int, int], ...]

    def slices(self) -> tuple[slice, ...]:
        return tuple(slice(s, e) for s, e in self.index)


Plan = dict[str, list[Piece]]


def _owner(key: str, eligible: Sequence[int]) -> int:
    """Deterministic owner pick (crc32, NOT the salted builtin hash —
    every process must compute the identical plan)."""
    return sorted(eligible)[zlib.crc32(key.encode()) % len(eligible)]


def make_shard_plan(items, ranks: Sequence[int]) -> Plan:
    """FSDP-style balanced ownership: each tensor is sliced along its
    largest ``len(ranks)``-divisible axis, one contiguous slice per
    rank; tensors with no divisible axis are owned whole by a
    deterministic rank (crc32 spread, so small norms/biases balance
    across shards instead of piling onto rank 0).

    ``items`` is ``[(key, array_or_shapedtype)]`` as produced by the
    flattener; the plan is a pure function of (keys, shapes, ranks), so
    every rank derives the same plan independently — no coordination.
    """
    ranks = sorted(ranks)
    n = len(ranks)
    plan: Plan = {}
    for key, leaf in items:
        shape = tuple(int(d) for d in leaf.shape)
        axis = None
        if n > 1 and shape:
            divisible = [i for i, d in enumerate(shape) if d % n == 0 and d > 0]
            if divisible:
                axis = max(divisible, key=lambda i: (shape[i], -i))
        if axis is None:
            full = tuple((0, d) for d in shape)
            plan[key] = [Piece(_owner(key, ranks), full)]
            continue
        per = shape[axis] // n
        pieces = []
        for j, r in enumerate(ranks):
            idx = tuple(
                (j * per, (j + 1) * per) if i == axis else (0, d)
                for i, d in enumerate(shape)
            )
            pieces.append(Piece(r, idx))
        plan[key] = pieces
    return plan


class _DictMesh:
    """Shape-only stand-in accepted by ``fit_spec`` (no devices)."""

    def __init__(self, shape: dict[str, int]):
        self.shape = dict(shape)


def plan_from_specs(
    items,
    specs,
    mesh_shape: dict[str, int],
    ranks: Sequence[int],
) -> Plan:
    """Addressable-shards addressing: the pieces each host's devices own.

    Mirrors ``Array.addressable_shards`` arithmetic without allocating:
    the mesh is ``mesh_shape`` (ordered axis → size, row-major device
    enumeration), hosts are ``ranks`` holding equal contiguous device
    blocks, and each tensor's partition spec (a
    ``jax.sharding.PartitionSpec``-like per-dim assignment, repaired
    with ``fit_spec`` against the mesh first) determines which index
    block each device holds. A block replicated across several hosts is
    written by exactly ONE deterministic owner (crc32 pick among the
    holders), so the union of all per-host shards covers every tensor
    exactly once — no gap, no overlap.

    ``specs`` is a list aligned with ``items`` (one spec per leaf).
    """
    from repro.dist.sharding import fit_spec  # local: avoid import cycle

    ranks = sorted(ranks)
    n_hosts = len(ranks)
    axis_names = list(mesh_shape)
    sizes = [int(mesh_shape[a]) for a in axis_names]
    n_dev = 1
    for s in sizes:
        n_dev *= s
    if n_dev % n_hosts:
        raise ValueError(
            f"{n_dev} mesh devices not divisible by {n_hosts} hosts"
        )
    per_host = n_dev // n_hosts

    def device_coords(d: int) -> dict[str, int]:
        out = {}
        rem = d
        for name, size in zip(reversed(axis_names), reversed(sizes), strict=True):
            out[name] = rem % size
            rem //= size
        return out

    mesh = _DictMesh(mesh_shape)
    plan: Plan = {}
    for (key, leaf), spec in zip(items, specs, strict=True):
        shape = tuple(int(d) for d in leaf.shape)
        spec = fit_spec(spec, shape, mesh)
        entries = list(spec) + [None] * (len(shape) - len(spec))
        # block → set of hosts whose devices hold it
        holders: dict[tuple[tuple[int, int], ...], set] = {}
        for d in range(n_dev):
            coords = device_coords(d)
            host = ranks[d // per_host]
            idx = []
            for dim, entry in zip(shape, entries, strict=True):
                if entry is None:
                    idx.append((0, dim))
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                nblk, blk = 1, 0
                for a in axes:
                    nblk *= mesh_shape[a]
                    blk = blk * mesh_shape[a] + coords[a]
                per = dim // nblk
                idx.append((blk * per, (blk + 1) * per))
            holders.setdefault(tuple(idx), set()).add(host)
        plan[key] = [
            Piece(_owner(f"{key}{idx}", sorted(hosts)), idx)
            for idx, hosts in sorted(holders.items())
        ]
    return plan


def validate_plan(plan: Plan, shapes: dict[str, Sequence[int]]) -> None:
    """Assert the plan partitions every key: pieces pairwise disjoint
    and their volumes sum to the full array (⇒ no gap, no overlap)."""
    for key, shape in shapes.items():
        pieces = plan.get(key)
        if not pieces:
            raise AssertionError(f"plan has no pieces for {key}")
        total = 1
        for d in shape:
            total *= int(d)
        vol = 0
        for p in pieces:
            if len(p.index) != len(shape):
                raise AssertionError(f"{key}: piece rank mismatch {p}")
            v = 1
            for (s, e), d in zip(p.index, shape, strict=True):
                if not (0 <= s <= e <= d):
                    raise AssertionError(f"{key}: piece out of bounds {p}")
                v *= e - s
            vol += v
        for i, a in enumerate(pieces):
            for b in pieces[i + 1:]:
                if all(
                    a.index[k][0] < b.index[k][1] and b.index[k][0] < a.index[k][1]
                    for k in range(len(shape))
                ):
                    raise AssertionError(f"{key}: overlapping pieces {a} / {b}")
        if vol != total:
            raise AssertionError(
                f"{key}: pieces cover {vol} of {total} elements (gap)"
            )


# ----------------------------------------------------------------------
# sharded save / commit / restore
# ----------------------------------------------------------------------


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _atomic_bytes(path: str, data: bytes) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)


def _shard_name(rank: int) -> str:
    return f"shard_{rank}.msgpack"


def write_shard(ckpt_dir: str, step: int, host_items, *, rank: int, plan: Plan) -> str:
    """Write this rank's pieces (crash-atomic). ``host_items`` must hold
    host (numpy) arrays. Returns the shard path."""
    path = _step_dir(ckpt_dir, step)
    os.makedirs(path, exist_ok=True)
    payload: dict[str, list[dict[str, Any]]] = {}
    for key, arr in host_items:
        own = [p for p in plan.get(key, ()) if p.shard == rank]
        if not own:
            continue
        pieces = []
        for p in own:
            # np.ascontiguousarray promotes 0-d to shape (1,) (ndmin=1),
            # which would round-trip scalars as 1-element vectors
            sub = np.asarray(arr[p.slices()])
            if sub.ndim:
                sub = np.ascontiguousarray(sub)
            pieces.append(
                dict(_encode(sub), index=[list(se) for se in p.index])
            )
        payload[key] = pieces
    shard_path = os.path.join(path, _shard_name(rank))
    _atomic_bytes(shard_path, msgpack.packb(payload))
    return shard_path


def write_sharded_manifest(
    ckpt_dir: str, step: int, host_items, *, plan: Plan, ranks: Sequence[int]
) -> str:
    """Leader-side: publish key → piece → shard placement (atomic)."""
    path = _step_dir(ckpt_dir, step)
    os.makedirs(path, exist_ok=True)
    keys = {
        key: {
            "dtype": str(arr.dtype),
            "shape": [int(d) for d in arr.shape],
            "pieces": [
                {"shard": p.shard, "index": [list(se) for se in p.index]}
                for p in plan[key]
            ],
        }
        for key, arr in host_items
    }
    manifest = {
        "step": step,
        "format": "sharded",
        "ranks": sorted(ranks),
        "keys": keys,
    }
    mpath = os.path.join(path, "manifest.json")
    tmp = f"{mpath}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, mpath)
    return mpath


def commit_sharded(
    ckpt_dir: str,
    step: int,
    *,
    timeout_s: float = 60.0,
    poll_s: float = 0.02,
    keep: int = 3,
) -> str:
    """Wait until every shard the manifest names exists, then write
    ``COMMITTED``. A peer that died mid-save makes this time out and
    the step stays torn (invisible to restart discovery) — that is the
    crash-atomicity contract, not an error to paper over."""
    import time as _time

    path = _step_dir(ckpt_dir, step)
    mpath = os.path.join(path, "manifest.json")
    deadline = _time.monotonic() + timeout_s
    while not os.path.exists(mpath):
        if _time.monotonic() > deadline:
            raise TimeoutError(f"commit: no manifest at {path}")
        _time.sleep(poll_s)
    with open(mpath) as f:
        manifest = json.load(f)
    needed = sorted(
        {p["shard"] for meta in manifest["keys"].values() for p in meta["pieces"]}
    )
    while True:
        missing = [
            r for r in needed
            if not os.path.exists(os.path.join(path, _shard_name(r)))
        ]
        if not missing:
            break
        if _time.monotonic() > deadline:
            raise TimeoutError(
                f"commit: step {step} still missing shards from ranks "
                f"{missing} after {timeout_s}s — leaving the step torn"
            )
        _time.sleep(poll_s)
    with open(os.path.join(path, _COMMIT), "w") as f:
        f.write("ok")
    _gc(ckpt_dir, keep)
    return path


def save_sharded(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    rank: int,
    ranks: Sequence[int],
    plan: Plan | None = None,
    commit: bool | None = None,
    commit_timeout_s: float = 60.0,
    keep: int = 3,
) -> str:
    """One rank's synchronous sharded save.

    Every rank calls this with the same ``tree``/``ranks``; each writes
    only its own pieces. The leader (lowest rank) also writes the
    manifest and — unless ``commit=False`` — waits for its peers'
    shards and commits. Returns the shard path.
    """
    items, _ = _flatten(tree)
    host = [(k, np.asarray(jax.device_get(v))) for k, v in items]
    if plan is None:
        plan = make_shard_plan(host, ranks)
    shard_path = write_shard(ckpt_dir, step, host, rank=rank, plan=plan)
    if rank == min(ranks):
        write_sharded_manifest(ckpt_dir, step, host, plan=plan, ranks=ranks)
        if commit is None or commit:
            commit_sharded(
                ckpt_dir, step, timeout_s=commit_timeout_s, keep=keep
            )
    return shard_path


class MissingShardError(FileNotFoundError):
    """A restore needs a shard file that is not on disk."""


def _restore_sharded(path: str, manifest, items, flat_sh) -> list[Any]:
    """Assemble the leaves of ``items`` from a sharded checkpoint,
    reading ONLY the shard files their pieces live in."""
    by_key = manifest["keys"]
    missing_keys = [k for k, _ in items if k not in by_key]
    if missing_keys:
        raise KeyError(
            f"checkpoint {path} has no entry for {missing_keys[:5]} "
            f"(manifest keys look like: {sorted(by_key)[:3]})"
        )
    needed = sorted(
        {p["shard"] for k, _ in items for p in by_key[k]["pieces"]}
    )
    missing = [
        r for r in needed
        if not os.path.exists(os.path.join(path, _shard_name(r)))
    ]
    if missing:
        covered = [
            k for k, _ in items
            if any(p["shard"] in missing for p in by_key[k]["pieces"])
        ]
        raise MissingShardError(
            f"checkpoint {path} is missing "
            f"{[_shard_name(r) for r in missing]} covering "
            f"{len(covered)} requested tensors (e.g. {covered[:3]}); the "
            f"save was torn or the files were lost — restore an earlier "
            f"committed step, or restrict `like` to the keys you need"
        )
    shards: dict[int, Any] = {}
    for r in needed:
        with open(os.path.join(path, _shard_name(r)), "rb") as f:
            shards[r] = msgpack.unpackb(f.read(), strict_map_key=False)
    out = []
    for (k, proto), sh in zip(items, flat_sh, strict=True):
        meta = by_key[k]
        arr = np.empty(tuple(meta["shape"]), dtype=meta["dtype"])
        for p in meta["pieces"]:
            stored = next(
                (
                    e
                    for e in shards[p["shard"]].get(k, [])
                    if [list(se) for se in e["index"]] == p["index"]
                ),
                None,
            )
            if stored is None:
                raise MissingShardError(
                    f"{_shard_name(p['shard'])} in {path} has no piece "
                    f"{p['index']} of {k} — shard/manifest mismatch "
                    f"(mixed-up save?); restore an earlier committed step"
                )
            sl = tuple(slice(s, e) for s, e in p["index"])
            arr[sl] = _decode(stored)
        if hasattr(proto, "dtype"):
            arr = arr.astype(proto.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return out


def _shardings_by_key(items, shardings) -> list[Any]:
    """Per-leaf shardings aligned to ``items`` by pytree path.

    ``shardings`` may be a single Sharding (applied everywhere), a full
    pytree, or a PARTIAL pytree — any subtree it omits (or sets to None)
    restores unsharded. Path-keyed matching (not positional zip) is what
    makes the partial case safe: a ``{"params": p_sh}`` pytree must not
    leak param shardings onto the optimizer leaves.
    """
    if shardings is None or hasattr(shardings, "device_set"):
        return [shardings] * len(items)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        shardings, is_leaf=lambda x: hasattr(x, "device_set")
    )
    by_key = {jax.tree_util.keystr(k): v for k, v in flat}
    leaf_keys = {k for k, _ in items}
    unmatched = sorted(set(by_key) - leaf_keys)
    if unmatched:
        # a typo'd key would otherwise silently restore the whole tree
        # unsharded onto the default device
        raise ValueError(
            f"shardings entries match no checkpoint leaf: {unmatched[:5]}"
            f" (leaves look like: {sorted(leaf_keys)[:3]})"
        )
    return [by_key.get(k) for k, _ in items]


def restore(
    ckpt_dir: str,
    step: int,
    like: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``like``.

    ``shardings``: optional pytree of jax.sharding.Sharding (or a single
    sharding) — enables elastic restore onto any mesh. May be partial:
    leaves without a matching entry are restored unsharded.

    ``like`` may itself be a *partial* tree (e.g. only ``{"params":
    ...}`` out of a params/m/v checkpoint): only its leaves are
    restored, and on a sharded checkpoint only the shard files covering
    those leaves are read (partial-read restore).
    """
    path = _step_dir(ckpt_dir, step)
    items, treedef = _flatten(like)
    flat_sh = _shardings_by_key(items, shardings)
    manifest = None
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError):
        pass  # legacy layout: monolithic shard_0 with no/old manifest
    if manifest is not None and manifest.get("format") == "sharded":
        out = _restore_sharded(path, manifest, items, flat_sh)
        return jax.tree_util.tree_unflatten(treedef, out)
    with open(os.path.join(path, "shard_0.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), strict_map_key=False)
    out = []
    for (k, proto), sh in zip(items, flat_sh, strict=True):
        arr = _decode(payload[k])
        if hasattr(proto, "dtype"):
            arr = arr.astype(proto.dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
