"""DDPM (Ho et al. 2020) with a compact UNet — the paper's generation task.

UNet: conv stem, 3 resolution levels (down/up) of GroupNorm+SiLU residual
blocks with sinusoidal time embeddings, bottleneck self-attention. Every
convolution routes through ``sparse_conv2d`` so ssProp applies (the paper
notes conv modules dominate DDPM FLOPs to 99.7%).

Training objective: epsilon-prediction MSE with the standard linear beta
schedule; ``sample`` runs ancestral sampling for the generation example.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.policy import DENSE, PolicyLike
from repro.models import layers


# ----------------------------------------------------------------------
# diffusion schedule
# ----------------------------------------------------------------------


def make_schedule(timesteps: int, beta_start=1e-4, beta_end=2e-2):
    betas = jnp.linspace(beta_start, beta_end, timesteps, dtype=jnp.float32)
    alphas = 1.0 - betas
    acp = jnp.cumprod(alphas)
    return {
        "betas": betas,
        "alphas": alphas,
        "acp": acp,
        "sqrt_acp": jnp.sqrt(acp),
        "sqrt_1macp": jnp.sqrt(1.0 - acp),
    }


def q_sample(sched, x0, t, noise):
    return (
        sched["sqrt_acp"][t][:, None, None, None] * x0
        + sched["sqrt_1macp"][t][:, None, None, None] * noise
    )


def time_embedding(t, dim):
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# UNet
# ----------------------------------------------------------------------


def _conv_init(key, c_out, c_in, k=3):
    return layers.conv2d_init(key, c_out, c_in, k, bias=True)


def _lin_init(key, d_in, d_out):
    return {
        "w": jax.random.normal(key, (d_in, d_out), jnp.float32) / math.sqrt(d_in),
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def _gn(x, groups=8):
    b, c, h, w = x.shape
    g = min(groups, c)
    xg = x.reshape(b, g, c // g, h, w)
    mean = xg.mean(axis=(2, 3, 4), keepdims=True)
    var = xg.var(axis=(2, 3, 4), keepdims=True)
    return ((xg - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(b, c, h, w)


def _resblock_init(key, c_in, c_out, t_dim):
    ks = jax.random.split(key, 4)
    p = {
        "conv1": _conv_init(ks[0], c_out, c_in),
        "temb": _lin_init(ks[1], t_dim, c_out),
        "conv2": _conv_init(ks[2], c_out, c_out),
    }
    if c_in != c_out:
        p["skip"] = _conv_init(ks[3], c_out, c_in, 1)
    return p


def _resblock_apply(p, x, temb, policy, prefix):
    h = layers.conv_apply(
        p["conv1"], jax.nn.silu(_gn(x)), policy, padding=1, site=f"{prefix}/conv1"
    )
    h = h + (jax.nn.silu(temb) @ p["temb"]["w"] + p["temb"]["b"])[:, :, None, None]
    h = layers.conv_apply(
        p["conv2"], jax.nn.silu(_gn(h)), policy, padding=1, site=f"{prefix}/conv2"
    )
    if "skip" in p:
        x = layers.conv_apply(p["skip"], x, policy, site=f"{prefix}/skip")
    return x + h


def init_params(key, *, channels: int = 1, base: int = 64, t_dim: int = 256):
    ks = jax.random.split(key, 16)
    c1, c2, c3 = base, base * 2, base * 2
    return {
        "t1": _lin_init(ks[0], t_dim, t_dim),
        "t2": _lin_init(ks[1], t_dim, t_dim),
        "stem": _conv_init(ks[2], c1, channels),
        "down1": _resblock_init(ks[3], c1, c1, t_dim),
        "down2": _resblock_init(ks[4], c1, c2, t_dim),
        "down3": _resblock_init(ks[5], c2, c3, t_dim),
        "mid1": _resblock_init(ks[6], c3, c3, t_dim),
        "mid2": _resblock_init(ks[7], c3, c3, t_dim),
        "up3": _resblock_init(ks[8], c3 + c3, c2, t_dim),
        "up2": _resblock_init(ks[9], c2 + c2, c1, t_dim),
        "up1": _resblock_init(ks[10], c1 + c1, c1, t_dim),
        "out": _conv_init(ks[11], channels, c1),
    }


def _down(x):
    return -jax.lax.reduce_window(-x, jnp.inf, jax.lax.min, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def _up(x):
    b, c, h, w = x.shape
    return jnp.repeat(jnp.repeat(x, 2, axis=2), 2, axis=3)


BLOCKS = ("down1", "down2", "down3", "mid1", "mid2", "up3", "up2", "up1")


def site_names(base: int = 64):
    """Enumerate the UNet's conv sites for policy-program resolution.

    ``(sites, depth)`` with depth = number of resblocks in forward
    order, so ``{down1,up1}/*``-style rules address the outer levels.
    """
    c1, c2, c3 = base, base * 2, base * 2
    chans = {
        "down1": (c1, c1), "down2": (c1, c2), "down3": (c2, c3),
        "mid1": (c3, c3), "mid2": (c3, c3),
        "up3": (c3 + c3, c2), "up2": (c2 + c2, c1), "up1": (c1 + c1, c1),
    }
    sites = ["stem"]
    for blk in BLOCKS:
        ci, co = chans[blk]
        sites += [f"{blk}/conv1", f"{blk}/conv2"]
        if ci != co:
            sites.append(f"{blk}/skip")
    sites.append("out")
    return tuple(sites), len(BLOCKS)


def forward(params, x, t, policy: PolicyLike = DENSE):
    """Predict epsilon. x [B, C, H, W], t [B] int32."""
    td = params["t1"]["w"].shape[0]
    temb = time_embedding(t, td)
    temb = jax.nn.silu(temb @ params["t1"]["w"] + params["t1"]["b"])
    temb = temb @ params["t2"]["w"] + params["t2"]["b"]

    h0 = layers.conv_apply(params["stem"], x, policy, padding=1, site="stem")
    d1 = _resblock_apply(params["down1"], h0, temb, policy, "down1")
    d2 = _resblock_apply(params["down2"], _down(d1), temb, policy, "down2")
    d3 = _resblock_apply(params["down3"], _down(d2), temb, policy, "down3")
    m = _resblock_apply(params["mid1"], d3, temb, policy, "mid1")
    m = _resblock_apply(params["mid2"], m, temb, policy, "mid2")
    u3 = _resblock_apply(params["up3"], jnp.concatenate([m, d3], 1), temb, policy, "up3")
    u2 = _resblock_apply(params["up2"], jnp.concatenate([_up(u3), d2], 1), temb, policy, "up2")
    u1 = _resblock_apply(params["up1"], jnp.concatenate([_up(u2), d1], 1), temb, policy, "up1")
    return layers.conv_apply(
        params["out"], jax.nn.silu(_gn(u1)), policy, padding=1, site="out"
    )


def loss_fn(params, sched, x0, rng, policy: PolicyLike = DENSE):
    """Epsilon-prediction MSE at uniformly sampled t."""
    kt, kn = jax.random.split(rng)
    b = x0.shape[0]
    t = jax.random.randint(kt, (b,), 0, sched["betas"].shape[0])
    noise = jax.random.normal(kn, x0.shape)
    xt = q_sample(sched, x0, t, noise)
    pred = forward(params, xt, t, policy)
    return jnp.mean((pred - noise) ** 2)


def sample(params, sched, rng, shape, policy: PolicyLike = DENSE):
    """Ancestral sampling x_T -> x_0 (used by the generation example)."""
    timesteps = sched["betas"].shape[0]
    x = jax.random.normal(rng, shape)

    def body(i, carry):
        x, rng = carry
        t = timesteps - 1 - i
        tb = jnp.full((shape[0],), t, jnp.int32)
        eps = forward(params, x, tb, policy)
        alpha = sched["alphas"][t]
        acp = sched["acp"][t]
        coef = (1 - alpha) / jnp.sqrt(1 - acp)
        mean = (x - coef * eps) / jnp.sqrt(alpha)
        rng, kn = jax.random.split(rng)
        noise = jnp.where(t > 0, 1.0, 0.0) * jax.random.normal(kn, shape)
        x = mean + jnp.sqrt(sched["betas"][t]) * noise
        return (x, rng)

    x, _ = jax.lax.fori_loop(0, timesteps, body, (x, rng))
    return x


def iter_conv_shapes(image, base: int = 64):
    """Yield ``(site, c_in, c_out, k, h_out, w_out)`` for every conv.

    Single source of the UNet's conv geometry on ``image`` (C, H, W) —
    shared by :func:`flops_per_iter` and the benchmark bytes-moved walks.
    """
    c, hh, ww = image
    c1, c2, c3 = base, base * 2, base * 2
    yield ("stem", c, c1, 3, hh, ww)
    for blk, (ci, co, h) in zip(
        ("down1", "down2", "down3"),
        [(c1, c1, hh), (c1, c2, hh // 2), (c2, c3, hh // 4)],
        strict=True,
    ):
        yield (f"{blk}/conv1", ci, co, 3, h, h)
        yield (f"{blk}/conv2", co, co, 3, h, h)
        if ci != co:
            yield (f"{blk}/skip", ci, co, 1, h, h)
    for blk in ("mid1", "mid2"):
        yield (f"{blk}/conv1", c3, c3, 3, hh // 4, hh // 4)
        yield (f"{blk}/conv2", c3, c3, 3, hh // 4, hh // 4)
    for blk, (ci, co, h) in zip(
        ("up3", "up2", "up1"),
        [(c3 + c3, c2, hh // 4), (c2 + c2, c1, hh // 2), (c1 + c1, c1, hh)],
        strict=True,
    ):
        yield (f"{blk}/conv1", ci, co, 3, h, h)
        yield (f"{blk}/conv2", co, co, 3, h, h)
        yield (f"{blk}/skip", ci, co, 1, h, h)
    yield ("out", c1, c, 3, hh, ww)


def flops_per_iter(batch: int, image, base: int = 64, drop_rate: float = 0.0, policy=None):
    """Backward-FLOPs (Eq. 6) walk over the UNet's conv layers.

    Pass ``policy`` to count the engine's real keep counts (block
    rounding, Pallas tile padding) instead of the nominal Eq. 9 rate;
    a resolved :class:`~repro.core.policy.SitePolicies` table over
    :func:`site_names` counts each conv at its own site's policy.
    """
    from repro.core import flops as F

    dense = sparse = 0
    for site, c_in, c_out, k, h, w in iter_conv_shapes(image, base):
        dense += F.conv_backward_flops(batch, h, w, c_in, c_out, k)
        if policy is not None:
            sparse += F.conv_backward_flops_site(
                batch, h, w, c_in, c_out, k, policy, site
            )
        else:
            sparse += F.conv_backward_flops_ssprop(
                batch, h, w, c_in, c_out, k, drop_rate
            )
    return dense, sparse
