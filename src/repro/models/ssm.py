"""Mamba-2 SSD (state-space duality) block — chunked scan + O(1) decode.

Implements the chunk-parallel SSD algorithm (arXiv:2405.21060): within a
chunk the recurrence is computed as a masked attention-like matmul
(MXU-friendly); across chunks a small ``lax.scan`` carries the
``[B, H, N, P]`` state. Decode is a single-token state update.

Projections route through ``sparse_dense`` so ssProp applies; the scan
itself has no output-channel matmul to shrink (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import PolicyLike
from repro.models import layers

_CONV_K = 4  # depthwise causal conv width (mamba default)


def ssm_init(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.n_ssm_heads
    conv_ch = di + 2 * n
    return {
        # in_proj -> [z, x, B, C, dt]
        "in_proj": layers.dense_init(ks[0], d, 2 * di + 2 * n + h, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (_CONV_K, conv_ch), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": layers.rmsnorm_init(di, dtype),
        "out_proj": layers.dense_init(ks[5], di, d, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x [B, L, C], w [K, C] -> [B, L, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return out + b


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * n], axis=-1)
    return z, xbc, dt


def ssd_chunked(x, dt, a_log, b_mat, c_mat, chunk):
    """SSD chunk-parallel scan.

    x [B, L, H, P], dt [B, L, H] (post-softplus), a_log [H],
    b_mat/c_mat [B, L, N] (single group broadcast over heads).
    Returns y [B, L, H, P] fp32.
    """
    bsz, slen, h, p = x.shape
    n = b_mat.shape[-1]
    nc = slen // chunk
    a = -jnp.exp(a_log)  # [H], negative

    xr = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    dtr = dt.reshape(bsz, nc, chunk, h)
    br = b_mat.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cr = c_mat.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    da = dtr * a  # [B, nc, Q, H]
    cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative decay

    # ---- intra-chunk (masked attention-like) ----
    # decay[i, j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # Mask the exponent (not the result): exp of a masked +large diff is
    # inf, and where(mask, inf, 0) back-propagates inf*0 = NaN.
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    decay = jnp.exp(diff)
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br)  # [B,nc,Q,Q]
    scores = cb[..., None] * decay * dtr[:, :, None, :, :]  # [B,nc,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xr)

    # ---- chunk-final states ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,Q,H]
    weighted = xr * (decay_to_end * dtr)[..., None]  # [B,nc,Q,H,P]
    s_local = jnp.einsum("bcqn,bcqhp->bchnp", br, weighted)  # [B,nc,H,N,P]

    # ---- cross-chunk recurrence ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]

    def step(s_prev, args):
        s_loc, cdec = args  # [B,H,N,P], [B,H]
        s_out = s_prev
        s_next = cdec[..., None, None] * s_prev + s_loc
        return s_next, s_out

    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, s_prevs = jax.lax.scan(
        step,
        s0,
        (s_local.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # [B,nc,H,N,P]

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(cum)  # decay from chunk start to position i
    y_inter = jnp.einsum("bcqn,bchnp->bcqhp", cr, s_prevs) * in_decay[..., None]

    y = (y_intra + y_inter).reshape(bsz, slen, h, p)
    return y


def ssm_apply(
    p, x, cfg, policy: PolicyLike, cache=None, token_valid=None, spec_states=False
):
    """Mamba-2 block. x [B, S, d].

    cache (decode): {"conv": [B, K-1, conv_ch], "state": [B, H, N, P]}.
    Decode handles any S >= 1 as a scan of single-token recurrence steps
    (chunked prefill); ``token_valid [B,S]`` freezes the conv/SSM state
    on rows whose token is padding (continuous batching: slots advance
    independently). Returns (out [B, S, d], new_cache or None).

    ``spec_states=True`` (decode only) returns the *per-position* state
    stack instead of the final state: cache leaves gain a position axis
    ``{"conv": [B, S, K-1, C], "state": [B, S, H, N, P]}`` so a
    speculative verifier can commit the state as of any accepted prefix
    (the recurrence is not position-addressed like KV, so rollback must
    select, not mask). Frozen (invalid) positions carry the previous
    state forward, making prefix selection safe for idle rows.
    """
    bsz, s, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    pd = cfg.ssm_headdim

    proj = layers.dense_apply(p["in_proj"], x, policy, site="ssm/in_proj")
    z, xbc, dt = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    new_cache = None
    if cache is None:
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xbc = jax.nn.silu(xbc)
        xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
        xh = xs.reshape(bsz, s, h, pd)
        pad = (-s) % cfg.ssm_chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            bm = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
            cm = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        else:
            dt_p, bm, cm = dt, bmat, cmat
        y = ssd_chunked(xh, dt_p, p["A_log"], bm, cm, cfg.ssm_chunk)[:, :s]
        y = y + xh[:, :s] * p["D"][None, None, :, None]
    else:
        # O(1)-state decode: scan single-token recurrence steps over the
        # chunk (S=1 is the classic decode). Invalid tokens leave the
        # conv window and SSM state untouched.
        a = -jnp.exp(p["A_log"])
        if token_valid is None:
            token_valid = jnp.ones((bsz, s), bool)

        def step(carry, inp):
            conv_state, state = carry  # [B,K-1,C], [B,H,N,P]
            xbc_t, dt_t, valid_t = inp  # [B,C], [B,H], [B]
            conv_cat = jnp.concatenate([conv_state, xbc_t[:, None]], axis=1)
            xc = (conv_cat * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
            xc = jax.nn.silu(xc)
            xs_t, bm_t, cm_t = jnp.split(xc, [di, di + n], axis=-1)
            xh_t = xs_t.reshape(bsz, h, pd).astype(jnp.float32)
            da = jnp.exp(dt_t * a)  # [B,H]
            s_new = da[..., None, None] * state + jnp.einsum(
                "bn,bhp->bhnp",
                bm_t.astype(jnp.float32),
                dt_t[..., None] * xh_t,
            )
            y_t = jnp.einsum("bn,bhnp->bhp", cm_t.astype(jnp.float32), s_new)
            y_t = y_t + xh_t * p["D"][None, :, None]
            conv_next = jnp.where(
                valid_t[:, None, None], conv_cat[:, 1:], conv_state
            )
            state_next = jnp.where(valid_t[:, None, None, None], s_new, state)
            ys_t = (y_t, conv_next, state_next) if spec_states else y_t
            return (conv_next, state_next), ys_t

        (conv_f, state_f), ys = jax.lax.scan(
            step,
            (cache["conv"], cache["state"]),
            (
                xbc.transpose(1, 0, 2),
                dt.transpose(1, 0, 2),
                token_valid.transpose(1, 0),
            ),
        )
        if spec_states:
            ys, convs, states = ys
            new_cache = {
                "conv": convs.transpose(1, 0, 2, 3),  # [B,S,K-1,C]
                "state": states.transpose(1, 0, 2, 3, 4),  # [B,S,H,N,P]
            }
        else:
            new_cache = {"conv": conv_f, "state": state_f}
        y = ys.transpose(1, 0, 2, 3)  # [B,S,H,P]

    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = layers.rmsnorm_apply(p["norm"], y, cfg.norm_eps)
    out = layers.dense_apply(p["out_proj"], y, policy, site="ssm/out_proj")
    return out, new_cache


def ssm_cache_init(cfg, batch, dtype=jnp.float32):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, _CONV_K - 1, conv_ch), dtype),
        "state": jnp.zeros(
            (batch, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_headdim), jnp.float32
        ),
    }
