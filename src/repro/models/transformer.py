"""Unified decoder/encoder-decoder stack for all assigned architectures.

Layer heterogeneity (Jamba's 1:7 attn:mamba interleave, MoE-every-other,
Whisper's enc-dec) is handled with a **period** abstraction: the layer
pattern repeats every ``period_len`` layers; parameters are stacked
``[n_periods, ...]`` per period-slot and the stack is a single
``lax.scan`` over periods whose body unrolls the slots. This keeps the
HLO one-period-sized (compile time sane at 512 devices) and composes
with ``jax.checkpoint`` for activation memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import SsPropPolicy
from repro.models import layers, moe, ssm


# ----------------------------------------------------------------------
# period pattern
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Slot:
    mixer: str  # "attn" | "ssm"
    ffn: Optional[str]  # "mlp" | "moe" | None


def period_pattern(cfg: ModelConfig) -> List[Slot]:
    """The repeating layer pattern for one period."""
    if cfg.family == "ssm":
        return [Slot("ssm", None)]
    plen = 1
    if cfg.attn_every:
        plen = cfg.attn_every
    if cfg.is_moe and cfg.moe_every > 1:
        while plen % cfg.moe_every:
            plen += cfg.attn_every or 1
    slots = []
    for i in range(plen):
        if cfg.attn_every and (i % cfg.attn_every != 0):
            mixer = "ssm"
        else:
            mixer = "attn"
        if cfg.is_moe and (i % cfg.moe_every == cfg.moe_offset):
            ffn = "moe"
        else:
            ffn = "mlp"
        slots.append(Slot(mixer, ffn))
    return slots


def n_periods(cfg: ModelConfig) -> int:
    plen = len(period_pattern(cfg))
    if cfg.n_layers % plen:
        raise ValueError(f"{cfg.name}: n_layers={cfg.n_layers} not divisible by period {plen}")
    return cfg.n_layers // plen


# ----------------------------------------------------------------------
# per-slot init / apply
# ----------------------------------------------------------------------


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _slot_init(key, cfg: ModelConfig, slot: Slot):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": layers.rmsnorm_init(cfg.d_model, dt)}
    if slot.mixer == "attn":
        p["attn"] = layers.attn_init(ks[0], cfg, dt)
    else:
        p["ssm"] = ssm.ssm_init(ks[0], cfg, dt)
    if slot.ffn is not None:
        p["norm2"] = layers.rmsnorm_init(cfg.d_model, dt)
        if slot.ffn == "moe":
            p["moe"] = moe.moe_init(ks[1], cfg, dt)
        else:
            p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, gated=cfg.gated_mlp)
    return p


def _slot_apply(
    p,
    x,
    cfg: ModelConfig,
    slot: Slot,
    policy: SsPropPolicy,
    *,
    positions=None,
    cache=None,
    cache_pos=None,
    token_valid=None,
    block_tables=None,
):
    h = layers.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    new_cache = None
    if slot.mixer == "attn":
        out, new_cache = layers.attn_apply(
            p["attn"],
            h,
            cfg,
            policy,
            causal=True,
            positions=positions,
            kv_cache=cache,
            cache_pos=cache_pos,
            token_valid=token_valid,
            block_tables=block_tables,
        )
    else:
        out, new_cache = ssm.ssm_apply(
            p["ssm"], h, cfg, policy, cache=cache, token_valid=token_valid
        )
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if slot.ffn is not None:
        h2 = layers.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        if slot.ffn == "moe":
            out2, metrics = moe.moe_apply(
                p["moe"], h2, cfg, policy, full_capacity=cache is not None,
                dp_groups=cfg.moe_dp_groups,
            )
            aux = metrics["aux_loss"]
        else:
            out2 = layers.mlp_apply(p["mlp"], h2, cfg.act, policy)
        x = x + out2
    return x, new_cache, aux


# ----------------------------------------------------------------------
# decoder stack
# ----------------------------------------------------------------------


def stack_init(key, cfg: ModelConfig):
    """Stacked params: one entry per period-slot, leading axis n_periods."""
    slots = period_pattern(cfg)
    np_ = n_periods(cfg)
    keys = jax.random.split(key, np_ * len(slots)).reshape(np_, len(slots), -1)
    out = []
    for s, slot in enumerate(slots):
        init_one = lambda k, slot=slot: _slot_init(k, cfg, slot)
        out.append(jax.vmap(init_one)(keys[:, s].reshape(np_, 2)))
    return {"slots": out}


def _slot_cache_init(cfg, slot: Slot, batch, max_seq, dtype, n_pages=None):
    if slot.mixer == "attn":
        # contiguous: per-slot rows [B, T, KV, hd]; paged: a global page
        # pool [n_pages, block_size, KV, hd] addressed via block tables.
        lead = batch if n_pages is None else n_pages
        return {
            "k": jnp.zeros((lead, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((lead, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    return ssm.ssm_cache_init(cfg, batch, dtype)


def stack_cache_init(cfg: ModelConfig, batch, max_seq, dtype=jnp.bfloat16, *, n_pages=None):
    """Decode cache pytree. ``n_pages`` switches attention leaves to the
    paged pool layout (``max_seq`` is then the block size); SSM leaves
    are per-slot either way."""
    slots = period_pattern(cfg)
    np_ = n_periods(cfg)
    caches = []
    for slot in slots:
        one = _slot_cache_init(cfg, slot, batch, max_seq, dtype, n_pages=n_pages)
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (np_,) + a.shape), one))
    return tuple(caches)  # matches the tuple structure scan ys produce


def stack_apply(
    params,
    x,
    cfg: ModelConfig,
    policy: SsPropPolicy,
    *,
    positions=None,
    caches=None,
    cache_pos=None,
    token_valid=None,
    block_tables=None,
):
    """Run the full stack. Returns (x, new_caches, total_aux)."""
    slots = period_pattern(cfg)
    decode = caches is not None

    def period_body(carry, xs):
        h, aux = carry
        slot_params, slot_caches = xs
        new_slot_caches = []
        for i, slot in enumerate(slots):
            cache_i = slot_caches[i] if decode else None
            h, nc, a = _slot_apply(
                slot_params[i],
                h,
                cfg,
                slot,
                policy,
                positions=positions,
                cache=cache_i,
                cache_pos=cache_pos,
                token_valid=token_valid,
                block_tables=block_tables,
            )
            aux = aux + a
            new_slot_caches.append(nc if decode else None)
        return (h, aux), tuple(new_slot_caches)

    body = period_body
    if cfg.remat and not decode:
        body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    xs = (params["slots"], caches if decode else None)
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    else:
        aux = jnp.zeros((), jnp.float32)
        np_ = n_periods(cfg)
        ys = []
        for pi in range(np_):
            sp = jax.tree.map(lambda a: a[pi], params["slots"])
            sc = jax.tree.map(lambda a: a[pi], caches) if decode else None
            (x, aux), nc = body((x, aux), (sp, sc))
            ys.append(nc)
        new_caches = (
            jax.tree.map(lambda *a: jnp.stack(a), *ys) if decode else None
        )
    return x, (new_caches if decode else None), aux


# ----------------------------------------------------------------------
# encoder (Whisper) — plain non-causal attn+mlp stack
# ----------------------------------------------------------------------


def encoder_init(key, cfg: ModelConfig):
    dt = _dtype(cfg)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": layers.rmsnorm_init(cfg.d_model, dt),
            "attn": layers.attn_init(k1, cfg, dt),
            "norm2": layers.rmsnorm_init(cfg.d_model, dt),
            "mlp": layers.mlp_init(k2, cfg.d_model, cfg.d_ff, dt, gated=cfg.gated_mlp),
        }

    keys = jax.random.split(key, cfg.n_enc_layers)
    return jax.vmap(one)(keys)


def encoder_apply(params, x, cfg, policy):
    def body(h, p):
        a, _ = layers.attn_apply(
            p["attn"], layers.rmsnorm_apply(p["norm1"], h, cfg.norm_eps), cfg, policy,
            causal=False,
        )
        h = h + a
        m = layers.mlp_apply(
            p["mlp"], layers.rmsnorm_apply(p["norm2"], h, cfg.norm_eps), cfg.act, policy
        )
        return h + m, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params)
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params))
    return x


def cross_decoder_init(key, cfg: ModelConfig):
    dt = _dtype(cfg)

    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": layers.rmsnorm_init(cfg.d_model, dt),
            "self": layers.attn_init(k1, cfg, dt),
            "norm_x": layers.rmsnorm_init(cfg.d_model, dt),
            "cross": layers.attn_init(k2, cfg, dt),
            "norm2": layers.rmsnorm_init(cfg.d_model, dt),
            "mlp": layers.mlp_init(k3, cfg.d_model, cfg.d_ff, dt, gated=cfg.gated_mlp),
        }

    keys = jax.random.split(key, cfg.n_layers)
    return jax.vmap(one)(keys)


def cross_decoder_apply(
    params, x, enc_out, cfg, policy, *, positions=None, caches=None, cache_pos=None,
    token_valid=None, block_tables=None,
):
    decode = caches is not None

    def body(carry, xs):
        h = carry
        p, cache = xs
        a, nc = layers.attn_apply(
            p["self"], layers.rmsnorm_apply(p["norm1"], h, cfg.norm_eps), cfg, policy,
            causal=True, positions=positions,
            kv_cache=cache if decode else None, cache_pos=cache_pos,
            token_valid=token_valid, block_tables=block_tables,
        )
        h = h + a
        c, _ = layers.attn_apply(
            p["cross"], layers.rmsnorm_apply(p["norm_x"], h, cfg.norm_eps), cfg, policy,
            causal=False, x_kv=enc_out, use_rope=False,
        )
        h = h + c
        m = layers.mlp_apply(
            p["mlp"], layers.rmsnorm_apply(p["norm2"], h, cfg.norm_eps), cfg.act, policy
        )
        return h + m, (nc if decode else 0.0)

    if cfg.remat and not decode:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params, caches if decode else None))
    else:
        ys = []
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda a: a[i], params)
            c_i = jax.tree.map(lambda a: a[i], caches) if decode else None
            x, nc = body(x, (p_i, c_i))
            ys.append(nc)
        new_caches = jax.tree.map(lambda *a: jnp.stack(a), *ys) if decode else None
    return x, (new_caches if decode else None)
