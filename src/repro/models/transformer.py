"""Unified decoder/encoder-decoder stack for all assigned architectures.

Layer heterogeneity (Jamba's 1:7 attn:mamba interleave, MoE-every-other,
Whisper's enc-dec) is handled with a **period** abstraction: the layer
pattern repeats every ``period_len`` layers; parameters are stacked
``[n_periods, ...]`` per period-slot and the stack is a single
``lax.scan`` over periods whose body unrolls the slots. This keeps the
HLO one-period-sized (compile time sane at 512 devices) and composes
with ``jax.checkpoint`` for activation memory.
Per-site policies: every projection carries a site name
``layer_{li}/{role}/{proj}`` (see :func:`stack_sites`). A
:class:`~repro.core.policy.SitePolicies` table threads through
``stack_apply`` exactly like a plain policy; the table is scoped to
each layer before the slot bodies run. With ``scan_layers=True`` the
whole stack shares one trace, so the resolved policies must be
depth-uniform (same table at every layer) — depth-varying programs
require ``scan_layers=False`` (the unrolled path traces each period
separately and so supports a different policy per layer).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import PolicyLike, SitePolicies, site_tables_equal
from repro.models import layers, moe, ssm


# ----------------------------------------------------------------------
# period pattern
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Slot:
    mixer: str  # "attn" | "ssm"
    ffn: str | None  # "mlp" | "moe" | None


def period_pattern(cfg: ModelConfig) -> list[Slot]:
    """The repeating layer pattern for one period."""
    if cfg.family == "ssm":
        return [Slot("ssm", None)]
    plen = 1
    if cfg.attn_every:
        plen = cfg.attn_every
    if cfg.is_moe and cfg.moe_every > 1:
        while plen % cfg.moe_every:
            plen += cfg.attn_every or 1
    slots = []
    for i in range(plen):
        if cfg.attn_every and (i % cfg.attn_every != 0):
            mixer = "ssm"
        else:
            mixer = "attn"
        if cfg.is_moe and (i % cfg.moe_every == cfg.moe_offset):
            ffn = "moe"
        else:
            ffn = "mlp"
        slots.append(Slot(mixer, ffn))
    return slots


def n_periods(cfg: ModelConfig) -> int:
    plen = len(period_pattern(cfg))
    if cfg.n_layers % plen:
        raise ValueError(f"{cfg.name}: n_layers={cfg.n_layers} not divisible by period {plen}")
    return cfg.n_layers // plen


def slot_sites(cfg: ModelConfig, slot: Slot) -> tuple[str, ...]:
    """Layer-relative site names of one period slot's projections."""
    if slot.mixer == "attn":
        sites = ["attn/q", "attn/k", "attn/v", "attn/o"]
    else:
        sites = ["ssm/in_proj", "ssm/out_proj"]
    if slot.ffn == "moe":
        sites += ["moe/gate", "moe/up", "moe/down"]
        if cfg.n_shared_experts:
            sites += ["moe/shared/up", "moe/shared/gate", "moe/shared/down"]
    elif slot.ffn == "mlp":
        sites += ["mlp/up"] + (["mlp/gate"] if cfg.gated_mlp else []) + ["mlp/down"]
    return tuple(sites)


def stack_sites(cfg: ModelConfig) -> tuple[str, ...]:
    """Every sparsifiable site of the decoder stack, ``layer_{li}/...``."""
    slots = period_pattern(cfg)
    plen = len(slots)
    out = []
    for li in range(cfg.n_layers):
        out.extend(f"layer_{li}/{s}" for s in slot_sites(cfg, slots[li % plen]))
    return tuple(out)


def _mlp_sites(cfg) -> tuple[str, ...]:
    return ("mlp/up",) + (("mlp/gate",) if cfg.gated_mlp else ()) + ("mlp/down",)


def encoder_sites(cfg: ModelConfig) -> tuple[str, ...]:
    """Whisper encoder sites, ``enc/layer_{i}/...``."""
    per = ("attn/q", "attn/k", "attn/v", "attn/o") + _mlp_sites(cfg)
    return tuple(
        f"enc/layer_{i}/{s}" for i in range(cfg.n_enc_layers) for s in per
    )


def cross_decoder_sites(cfg: ModelConfig) -> tuple[str, ...]:
    """Cross-decoder sites: self- and cross-attention plus the MLP."""
    per = tuple(
        f"{role}/{proj}" for role in ("self", "cross") for proj in ("q", "k", "v", "o")
    ) + _mlp_sites(cfg)
    return tuple(f"layer_{li}/{s}" for li in range(cfg.n_layers) for s in per)


def _layer_scopes(policy: PolicyLike, n_layers: int):
    """Per-layer policy tables (or the plain policy broadcast)."""
    if not isinstance(policy, SitePolicies):
        return [policy] * n_layers
    return [policy.scoped(f"layer_{li}") for li in range(n_layers)]


def _check_scan_uniform(per_layer, plen: int, what: str):
    """Under ``scan_layers=True`` every period shares one trace, so the
    resolved policies must agree across periods slot-by-slot; reject a
    depth-varying program with an actionable error instead of silently
    applying the first period's policies everywhere."""
    if not any(isinstance(p, SitePolicies) for p in per_layer):
        return
    for si in range(plen):
        if not site_tables_equal(per_layer[si::plen]):
            raise ValueError(
                f"{what}: policy program varies with depth but "
                "scan_layers=True shares one trace across layers; set "
                "scan_layers=False (the unrolled path) to use per-depth "
                "rules"
            )


# ----------------------------------------------------------------------
# per-slot init / apply
# ----------------------------------------------------------------------


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _slot_init(key, cfg: ModelConfig, slot: Slot):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": layers.rmsnorm_init(cfg.d_model, dt)}
    if slot.mixer == "attn":
        p["attn"] = layers.attn_init(ks[0], cfg, dt)
    else:
        p["ssm"] = ssm.ssm_init(ks[0], cfg, dt)
    if slot.ffn is not None:
        p["norm2"] = layers.rmsnorm_init(cfg.d_model, dt)
        if slot.ffn == "moe":
            p["moe"] = moe.moe_init(ks[1], cfg, dt)
        else:
            p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, gated=cfg.gated_mlp)
    return p


def _slot_apply(
    p,
    x,
    cfg: ModelConfig,
    slot: Slot,
    policy: PolicyLike,
    *,
    positions=None,
    cache=None,
    cache_pos=None,
    token_valid=None,
    block_tables=None,
    paged_kernel=False,
    spec_states=False,
):
    h = layers.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    new_cache = None
    if slot.mixer == "attn":
        out, new_cache = layers.attn_apply(
            p["attn"],
            h,
            cfg,
            policy,
            causal=True,
            positions=positions,
            kv_cache=cache,
            cache_pos=cache_pos,
            token_valid=token_valid,
            block_tables=block_tables,
            paged_kernel=paged_kernel,
        )
    else:
        out, new_cache = ssm.ssm_apply(
            p["ssm"], h, cfg, policy, cache=cache, token_valid=token_valid,
            spec_states=spec_states,
        )
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if slot.ffn is not None:
        h2 = layers.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        if slot.ffn == "moe":
            out2, metrics = moe.moe_apply(
                p["moe"], h2, cfg, policy, full_capacity=cache is not None,
                dp_groups=cfg.moe_dp_groups,
            )
            aux = metrics["aux_loss"]
        else:
            out2 = layers.mlp_apply(p["mlp"], h2, cfg.act, policy)
        x = x + out2
    return x, new_cache, aux


# ----------------------------------------------------------------------
# decoder stack
# ----------------------------------------------------------------------


def stack_init(key, cfg: ModelConfig):
    """Stacked params: one entry per period-slot, leading axis n_periods."""
    slots = period_pattern(cfg)
    np_ = n_periods(cfg)
    keys = jax.random.split(key, np_ * len(slots)).reshape(np_, len(slots), -1)
    out = []
    for s, slot in enumerate(slots):
        init_one = lambda k, slot=slot: _slot_init(k, cfg, slot)
        out.append(jax.vmap(init_one)(keys[:, s].reshape(np_, 2)))
    return {"slots": out}


def _slot_cache_init(cfg, slot: Slot, batch, max_seq, dtype, n_pages=None):
    if slot.mixer == "attn":
        # contiguous: per-slot rows [B, T, KV, hd]; paged: a global page
        # pool [n_pages, block_size, KV, hd] addressed via block tables.
        lead = batch if n_pages is None else n_pages
        return {
            "k": jnp.zeros((lead, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((lead, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    return ssm.ssm_cache_init(cfg, batch, dtype)


def stack_cache_init(cfg: ModelConfig, batch, max_seq, dtype=jnp.bfloat16, *, n_pages=None):
    """Decode cache pytree. ``n_pages`` switches attention leaves to the
    paged pool layout (``max_seq`` is then the block size); SSM leaves
    are per-slot either way."""
    slots = period_pattern(cfg)
    np_ = n_periods(cfg)
    caches = []
    for slot in slots:
        one = _slot_cache_init(cfg, slot, batch, max_seq, dtype, n_pages=n_pages)
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (np_,) + a.shape), one))
    return tuple(caches)  # matches the tuple structure scan ys produce


def stack_apply(
    params,
    x,
    cfg: ModelConfig,
    policy: PolicyLike,
    *,
    positions=None,
    caches=None,
    cache_pos=None,
    token_valid=None,
    block_tables=None,
    paged_kernel=False,
    spec_states=False,
):
    """Run the full stack. Returns (x, new_caches, total_aux).

    ``policy`` is a plain :class:`SsPropPolicy` (every site) or a
    resolved :class:`SitePolicies` table over :func:`stack_sites` names;
    the table is scoped per layer here. Depth-varying tables require
    ``scan_layers=False`` (see :func:`_check_scan_uniform`).

    ``spec_states=True`` (decode only) makes SSM cache leaves come back
    with a per-position axis (see :func:`repro.models.ssm.ssm_apply`) so
    a speculative verifier can commit any accepted prefix; KV leaves are
    position-addressed already and return unchanged.
    """
    slots = period_pattern(cfg)
    plen = len(slots)
    decode = caches is not None
    per_layer = _layer_scopes(policy, cfg.n_layers)

    def period_body(carry, xs, slot_pols):
        h, aux = carry
        slot_params, slot_caches = xs
        new_slot_caches = []
        for i, slot in enumerate(slots):
            cache_i = slot_caches[i] if decode else None
            h, nc, a = _slot_apply(
                slot_params[i],
                h,
                cfg,
                slot,
                slot_pols[i],
                positions=positions,
                cache=cache_i,
                cache_pos=cache_pos,
                token_valid=token_valid,
                block_tables=block_tables,
                paged_kernel=paged_kernel,
                spec_states=spec_states,
            )
            aux = aux + a
            new_slot_caches.append(nc if decode else None)
        return (h, aux), tuple(new_slot_caches)

    def make_body(slot_pols):
        body = functools.partial(period_body, slot_pols=slot_pols)
        if cfg.remat and not decode:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        return body

    xs = (params["slots"], caches if decode else None)
    if cfg.scan_layers:
        _check_scan_uniform(per_layer, plen, "stack_apply")
        body = make_body(tuple(per_layer[:plen]))
        (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    else:
        aux = jnp.zeros((), jnp.float32)
        np_ = n_periods(cfg)
        ys = []
        for pi in range(np_):
            sp = jax.tree.map(lambda a, pi=pi: a[pi], params["slots"])
            sc = (
                jax.tree.map(lambda a, pi=pi: a[pi], caches)
                if decode
                else None
            )
            body = make_body(tuple(per_layer[pi * plen : (pi + 1) * plen]))
            (x, aux), nc = body((x, aux), (sp, sc))
            ys.append(nc)
        new_caches = (
            jax.tree.map(lambda *a: jnp.stack(a), *ys) if decode else None
        )
    return x, (new_caches if decode else None), aux


# ----------------------------------------------------------------------
# encoder (Whisper) — plain non-causal attn+mlp stack
# ----------------------------------------------------------------------


def encoder_init(key, cfg: ModelConfig):
    dt = _dtype(cfg)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": layers.rmsnorm_init(cfg.d_model, dt),
            "attn": layers.attn_init(k1, cfg, dt),
            "norm2": layers.rmsnorm_init(cfg.d_model, dt),
            "mlp": layers.mlp_init(k2, cfg.d_model, cfg.d_ff, dt, gated=cfg.gated_mlp),
        }

    keys = jax.random.split(key, cfg.n_enc_layers)
    return jax.vmap(one)(keys)


def encoder_apply(params, x, cfg, policy: PolicyLike):
    enc_scope = policy.scoped("enc") if isinstance(policy, SitePolicies) else policy
    per_layer = _layer_scopes(enc_scope, cfg.n_enc_layers)

    def body(h, p, pol):
        a, _ = layers.attn_apply(
            p["attn"], layers.rmsnorm_apply(p["norm1"], h, cfg.norm_eps), cfg, pol,
            causal=False,
        )
        h = h + a
        m = layers.mlp_apply(
            p["mlp"], layers.rmsnorm_apply(p["norm2"], h, cfg.norm_eps), cfg.act, pol
        )
        return h + m, None

    def make_body(pol):
        b = functools.partial(body, pol=pol)
        return jax.checkpoint(b) if cfg.remat else b

    if cfg.scan_layers:
        _check_scan_uniform(per_layer, 1, "encoder_apply")
        x, _ = jax.lax.scan(make_body(per_layer[0] if per_layer else policy), x, params)
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = make_body(per_layer[i])(
                x, jax.tree.map(lambda a, i=i: a[i], params)
            )
    return x


def cross_decoder_init(key, cfg: ModelConfig):
    dt = _dtype(cfg)

    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": layers.rmsnorm_init(cfg.d_model, dt),
            "self": layers.attn_init(k1, cfg, dt),
            "norm_x": layers.rmsnorm_init(cfg.d_model, dt),
            "cross": layers.attn_init(k2, cfg, dt),
            "norm2": layers.rmsnorm_init(cfg.d_model, dt),
            "mlp": layers.mlp_init(k3, cfg.d_model, cfg.d_ff, dt, gated=cfg.gated_mlp),
        }

    keys = jax.random.split(key, cfg.n_layers)
    return jax.vmap(one)(keys)


def cross_decoder_apply(
    params, x, enc_out, cfg, policy: PolicyLike, *, positions=None, caches=None,
    cache_pos=None, token_valid=None, block_tables=None, paged_kernel=False,
):
    decode = caches is not None
    per_layer = _layer_scopes(policy, cfg.n_layers)

    def body(carry, xs, pol):
        h = carry
        p, cache = xs
        a, nc = layers.attn_apply(
            p["self"], layers.rmsnorm_apply(p["norm1"], h, cfg.norm_eps), cfg, pol,
            causal=True, positions=positions,
            kv_cache=cache if decode else None, cache_pos=cache_pos,
            token_valid=token_valid, block_tables=block_tables,
            paged_kernel=paged_kernel, site="self",
        )
        h = h + a
        c, _ = layers.attn_apply(
            p["cross"], layers.rmsnorm_apply(p["norm_x"], h, cfg.norm_eps), cfg, pol,
            causal=False, x_kv=enc_out, use_rope=False, site="cross",
        )
        h = h + c
        m = layers.mlp_apply(
            p["mlp"], layers.rmsnorm_apply(p["norm2"], h, cfg.norm_eps), cfg.act, pol
        )
        return h + m, (nc if decode else 0.0)

    def make_body(pol):
        b = functools.partial(body, pol=pol)
        if cfg.remat and not decode:
            b = jax.checkpoint(b)
        return b

    if cfg.scan_layers:
        _check_scan_uniform(per_layer, 1, "cross_decoder_apply")
        x, new_caches = jax.lax.scan(
            make_body(per_layer[0]), x, (params, caches if decode else None)
        )
    else:
        ys = []
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda a, i=i: a[i], params)
            c_i = (
                jax.tree.map(lambda a, i=i: a[i], caches)
                if decode
                else None
            )
            x, nc = make_body(per_layer[i])(x, (p_i, c_i))
            ys.append(nc)
        new_caches = jax.tree.map(lambda *a: jnp.stack(a), *ys) if decode else None
    return x, (new_caches if decode else None)


# ----------------------------------------------------------------------
# static geometry walk (for the program auditor / roofline)
# ----------------------------------------------------------------------


def iter_dense_shapes(cfg: ModelConfig, batch: int, seq: int):
    """Yield ``(site, m, d_in, d_out, count)`` for every sparsifiable
    projection of the model at one training shape.

    ``site`` is a representative full site path (``layer_{si}/...`` for
    the first period, ``enc/layer_0/...`` for the encoder) so callers
    can resolve per-site policies against the same names
    :func:`stack_sites` produces; ``count`` is how many layers share
    that exact geometry (depth-uniform policies assumed — the same
    restriction ``scan_layers=True`` already imposes). ``m`` is the
    total contraction row count: ``batch*seq`` for sequence sites,
    ``E*capacity`` for the batched expert matmuls.

    Only ``sparse_dense`` projection sites appear — attention scores,
    the SSM scan, embeddings and the logits head are not ssProp sites.
    """
    tokens = batch * seq
    hd = cfg.head_dim

    def _attn_sites(prefix, m_q, m_kv):
        return [
            (f"{prefix}/q", m_q, cfg.d_model, cfg.n_heads * hd),
            (f"{prefix}/k", m_kv, cfg.d_model, cfg.n_kv_heads * hd),
            (f"{prefix}/v", m_kv, cfg.d_model, cfg.n_kv_heads * hd),
            (f"{prefix}/o", m_q, cfg.n_heads * hd, cfg.d_model),
        ]

    def _mlp_shapes(m, d_ff, gated):
        out = [("mlp/up", m, cfg.d_model, d_ff)]
        if gated:
            out.append(("mlp/gate", m, cfg.d_model, d_ff))
        out.append(("mlp/down", m, d_ff, cfg.d_model))
        return out

    if cfg.family == "encdec":
        m_enc = batch * cfg.enc_seq
        enc_per = _attn_sites("attn", m_enc, m_enc) + _mlp_shapes(
            m_enc, cfg.d_ff, cfg.gated_mlp
        )
        for site, m, d_in, d_out in enc_per:
            yield f"enc/layer_0/{site}", m, d_in, d_out, cfg.n_enc_layers
        dec_per = (
            _attn_sites("self", tokens, tokens)
            + _attn_sites("cross", tokens, m_enc)
            + _mlp_shapes(tokens, cfg.d_ff, cfg.gated_mlp)
        )
        for site, m, d_in, d_out in dec_per:
            yield f"layer_0/{site}", m, d_in, d_out, cfg.n_layers
        return

    slots = period_pattern(cfg)
    reps = n_periods(cfg)
    for si, slot in enumerate(slots):
        per = []
        if slot.mixer == "attn":
            per += _attn_sites("attn", tokens, tokens)
        else:
            d_in_proj = 2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.n_ssm_heads
            per += [
                ("ssm/in_proj", tokens, cfg.d_model, d_in_proj),
                ("ssm/out_proj", tokens, cfg.d_inner, cfg.d_model),
            ]
        if slot.ffn == "moe":
            cap = max(
                1, int(tokens * cfg.moe_topk / cfg.n_experts * cfg.capacity_factor)
            )
            rows = cfg.n_experts * cap
            per += [
                ("moe/gate", rows, cfg.d_model, cfg.d_ff),
                ("moe/up", rows, cfg.d_model, cfg.d_ff),
                ("moe/down", rows, cfg.d_ff, cfg.d_model),
            ]
            if cfg.n_shared_experts:
                ffs = cfg.d_ff * cfg.n_shared_experts
                per += [
                    ("moe/shared/up", tokens, cfg.d_model, ffs),
                    ("moe/shared/gate", tokens, cfg.d_model, ffs),
                    ("moe/shared/down", tokens, ffs, cfg.d_model),
                ]
        elif slot.ffn == "mlp":
            per += _mlp_shapes(tokens, cfg.d_ff, cfg.gated_mlp)
        for site, m, d_in, d_out in per:
            yield f"layer_{si}/{site}", m, d_in, d_out, reps
