"""Top-level LM model: embeddings + stack + loss + decode.

Public functional API (used by the train/serve step builders, the smoke
tests and the dry-run):

  * ``init_params(cfg, rng)``
  * ``forward(cfg, params, batch, policy)``          -> logits
  * ``loss_fn(cfg, params, batch, policy)``          -> (loss, metrics)
  * ``init_cache(cfg, batch, max_seq)``              -> cache pytree
  * ``decode_step(cfg, params, tokens, cache, pos)`` -> (logits, cache)

Batch dict keys by family:
  * LM/MoE/hybrid/ssm: ``tokens [B,S]``, ``targets [B,S]``
  * vlm:   + ``patches [B, n_patches, d_model]`` (SigLIP stub output)
  * encdec:+ ``frames  [B, enc_seq, d_model]``   (audio frontend stub)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import SsPropPolicy
from repro.models import layers, transformer


def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    k_emb, k_stack, k_enc, k_out = jax.random.split(rng, 4)
    params: Dict[str, Any] = {
        "embed": layers.embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.family == "encdec":
        params["encoder"] = transformer.encoder_init(k_enc, cfg)
        params["enc_norm"] = layers.rmsnorm_init(cfg.d_model, dt)
        params["decoder"] = transformer.cross_decoder_init(k_stack, cfg)
    else:
        params["stack"] = transformer.stack_init(k_stack, cfg)
    return params


def _embed_inputs(cfg, params, batch):
    """Token embeddings, with the VLM patch prefix fused in."""
    x = layers.embed_apply(params["embed"], batch["tokens"])
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    return x


def forward(
    cfg: ModelConfig,
    params,
    batch: Dict[str, jax.Array],
    policy: SsPropPolicy = SsPropPolicy(),
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits fp32 [B, S, V], aux_loss)."""
    x = _embed_inputs(cfg, params, batch)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "encdec":
        enc = transformer.encoder_apply(params["encoder"], batch["frames"].astype(x.dtype), cfg, policy)
        enc = layers.rmsnorm_apply(params["enc_norm"], enc, cfg.norm_eps)
        x, _ = transformer.cross_decoder_apply(params["decoder"], x, enc, cfg, policy)
    else:
        x, _, aux = transformer.stack_apply(params["stack"], x, cfg, policy)
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.family == "vlm":
        x = x[:, cfg.n_patches :]
    logits = layers.unembed_apply(params["embed"], x, valid=cfg.vocab)
    return logits, aux


def loss_fn(
    cfg: ModelConfig,
    params,
    batch: Dict[str, jax.Array],
    policy: SsPropPolicy = SsPropPolicy(),
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy (+0.01·MoE aux)."""
    logits, aux = forward(cfg, params, batch, policy)
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
    loss = (nll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


# ----------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        def one(k):
            del k
            return {
                "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt),
            }
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one(None)
        )
    return transformer.stack_cache_init(cfg, batch, max_seq, dt)


def decode_step(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,  # [B, 1]
    cache,
    pos: jax.Array,  # scalar int32: current write position
    *,
    enc_out: Optional[jax.Array] = None,
    policy: SsPropPolicy = SsPropPolicy(),
):
    """One decode step with KV/SSM caches. Returns (logits [B,V], cache)."""
    x = layers.embed_apply(params["embed"], tokens)
    positions = (pos + jnp.arange(1))[None, :]
    if cfg.family == "encdec":
        x, new_cache = transformer.cross_decoder_apply(
            params["decoder"], x, enc_out, cfg, policy,
            positions=positions, caches=cache, cache_pos=pos,
        )
    else:
        x, new_cache, _ = transformer.stack_apply(
            params["stack"], x, cfg, policy,
            positions=positions, caches=cache, cache_pos=pos,
        )
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = layers.unembed_apply(params["embed"], x, valid=cfg.vocab)[:, 0]
    return logits, new_cache


def encode(cfg: ModelConfig, params, frames: jax.Array, policy=SsPropPolicy()):
    """Whisper encoder pass (used once before decode)."""
    enc = transformer.encoder_apply(params["encoder"], frames, cfg, policy)
    return layers.rmsnorm_apply(params["enc_norm"], enc, cfg.norm_eps)
