"""Top-level LM model: embeddings + stack + loss + decode.

Public functional API (used by the train/serve step builders, the smoke
tests and the dry-run):

  * ``init_params(cfg, rng)``
  * ``forward(cfg, params, batch, policy)``          -> logits
  * ``loss_fn(cfg, params, batch, policy)``          -> (loss, metrics)
  * ``init_cache(cfg, batch, max_seq)``              -> cache pytree
  * ``decode_step(cfg, params, tokens, cache, pos)`` -> (logits, cache)

Batch dict keys by family:
  * LM/MoE/hybrid/ssm: ``tokens [B,S]``, ``targets [B,S]``
  * vlm:   + ``patches [B, n_patches, d_model]`` (SigLIP stub output)
  * encdec:+ ``frames  [B, enc_seq, d_model]``   (audio frontend stub)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import DENSE, PolicyLike
from repro.models import layers, transformer


def init_params(cfg: ModelConfig, rng: jax.Array) -> dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    k_emb, k_stack, k_enc, k_out = jax.random.split(rng, 4)
    params: dict[str, Any] = {
        "embed": layers.embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.family == "encdec":
        params["encoder"] = transformer.encoder_init(k_enc, cfg)
        params["enc_norm"] = layers.rmsnorm_init(cfg.d_model, dt)
        params["decoder"] = transformer.cross_decoder_init(k_stack, cfg)
    else:
        params["stack"] = transformer.stack_init(k_stack, cfg)
    return params


def site_names(cfg: ModelConfig):
    """Enumerate every sparsifiable call site of this model.

    Returns ``(sites, depth)`` — the inputs
    :meth:`repro.core.policy.PolicyProgram.resolve` needs: stable site
    names (``layer_{li}/attn/q`` …, see
    :func:`repro.models.transformer.stack_sites`) plus the depth that
    negative layer indices in rule patterns resolve against.
    """
    if cfg.family == "encdec":
        sites = transformer.encoder_sites(cfg) + transformer.cross_decoder_sites(cfg)
    else:
        sites = transformer.stack_sites(cfg)
    return sites, cfg.n_layers


def _embed_inputs(cfg, params, batch):
    """Token embeddings, with the VLM patch prefix fused in."""
    x = layers.embed_apply(params["embed"], batch["tokens"])
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    return x


def forward(
    cfg: ModelConfig,
    params,
    batch: dict[str, jax.Array],
    policy: PolicyLike = DENSE,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits fp32 [B, S, V], aux_loss)."""
    x = _embed_inputs(cfg, params, batch)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "encdec":
        enc = transformer.encoder_apply(params["encoder"], batch["frames"].astype(x.dtype), cfg, policy)
        enc = layers.rmsnorm_apply(params["enc_norm"], enc, cfg.norm_eps)
        x, _ = transformer.cross_decoder_apply(params["decoder"], x, enc, cfg, policy)
    else:
        x, _, aux = transformer.stack_apply(params["stack"], x, cfg, policy)
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.family == "vlm":
        x = x[:, cfg.n_patches :]
    logits = layers.unembed_apply(params["embed"], x, valid=cfg.vocab)
    return logits, aux


def loss_fn(
    cfg: ModelConfig,
    params,
    batch: dict[str, jax.Array],
    policy: PolicyLike = DENSE,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Next-token cross-entropy (+0.01·MoE aux)."""
    logits, aux = forward(cfg, params, batch, policy)
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
    loss = (nll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


# ----------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        def one(k):
            del k
            return {
                "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt),
            }
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one(None)
        )
    return transformer.stack_cache_init(cfg, batch, max_seq, dt)


def init_paged_cache(
    cfg: ModelConfig, batch: int, n_blocks: int, block_size: int, dtype=None
):
    """Paged decode cache: K/V in a global page pool, SSM state per slot.

    Attention leaves are ``[np, n_blocks, block_size, KV, hd]`` — page
    id *p* addresses the same pool index at every layer, so one block
    table serves the whole stack. SSM conv/state leaves stay batch-major
    ``[np, batch, ...]`` (they are O(1) per slot — nothing to page).
    """
    dt = dtype or jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        def one(k):
            del k
            return {
                "k": jnp.zeros((n_blocks, block_size, cfg.n_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((n_blocks, block_size, cfg.n_kv_heads, cfg.head_dim), dt),
            }
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one(None)
        )
    return transformer.stack_cache_init(
        cfg, batch, block_size, dt, n_pages=n_blocks
    )


def decode_slots(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,  # [B, C] — up to C tokens per slot this step
    cache,
    slot_pos: jax.Array,  # [B] int32: per-slot cache write position
    token_count: jax.Array,  # [B] int32: real tokens per slot (0 = idle slot)
    *,
    enc_out: jax.Array | None = None,
    block_tables: jax.Array | None = None,  # [B, NB] int32 (paged cache)
    paged_kernel: bool = False,
    policy: PolicyLike = DENSE,
    all_logits: bool = False,
    spec_states: bool = False,
):
    """Mixed prefill/decode step over independently positioned slots.

    The per-slot cache API for continuous batching: every batch row is a
    *slot* with its own write position — decode slots feed 1 token,
    prefilling slots feed a chunk of up to C prompt tokens, idle slots
    feed 0 — all in one call. KV writes are vectorized scatters at
    ``slot_pos[b] + c`` (invalid tokens dropped); SSM states freeze on
    invalid tokens; attention is causally masked per slot, which also
    fences any stale cache a previous occupant of the slot left behind.

    With ``block_tables`` the cache is the *paged* layout
    (:func:`init_paged_cache`): slot *b*'s token at logical position
    ``p`` lives in page ``block_tables[b, p // block_size]`` at offset
    ``p % block_size``; KV scatters become page-indexed and attention
    gathers K/V through the table. Block tables are data, not shape —
    the same compiled step serves any page assignment. ``paged_kernel``
    replaces that per-layer gather with the Pallas paged-attention
    kernel, which reads the pages in place (same mask semantics).

    Returns ``(logits [B, V] at each slot's last real token, new_cache)``.
    Rows with ``token_count == 0`` carry garbage logits the caller must
    ignore.

    ``all_logits=True`` returns the full chunk's logits ``[B, C, V]``
    instead of the last real token's row — the speculative verifier
    needs every position to compare draft tokens against.
    ``spec_states=True`` additionally makes SSM cache leaves come back
    with a per-position axis (``[np, B, C, ...]``) so
    :func:`commit_spec_cache` can select the state as of an accepted
    prefix; KV leaves are position-addressed and unchanged.
    """
    b, c = tokens.shape
    positions = slot_pos[:, None] + jnp.arange(c)[None, :]  # [B, C]
    valid = jnp.arange(c)[None, :] < token_count[:, None]  # [B, C]
    x = layers.embed_apply(params["embed"], tokens)
    if cfg.family == "encdec":
        x, new_cache = transformer.cross_decoder_apply(
            params["decoder"], x, enc_out, cfg, policy,
            positions=positions, caches=cache, cache_pos=slot_pos,
            token_valid=valid, block_tables=block_tables,
            paged_kernel=paged_kernel,
        )
    else:
        x, new_cache, _ = transformer.stack_apply(
            params["stack"], x, cfg, policy,
            positions=positions, caches=cache, cache_pos=slot_pos,
            token_valid=valid, block_tables=block_tables,
            paged_kernel=paged_kernel, spec_states=spec_states,
        )
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if all_logits:
        logits = layers.unembed_apply(params["embed"], x, valid=cfg.vocab)
        return logits, new_cache
    last = jnp.clip(token_count - 1, 0, c - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)  # [B, 1, d]
    logits = layers.unembed_apply(params["embed"], x_last, valid=cfg.vocab)[:, 0]
    return logits, new_cache


def commit_spec_cache(cache, keep: jax.Array):
    """Collapse a ``spec_states=True`` cache to the accepted prefix.

    ``keep [B]`` is how many of the chunk's tokens each slot actually
    consumed (``accepted + 1`` for a speculative slot, ``token_count``
    otherwise). SSM leaves carry a per-position axis
    (``conv [np, B, C, ...]`` / ``state [np, B, C, ...]``) — select
    position ``clip(keep - 1, 0)``; a slot with ``keep == 0`` read index
    0, whose state equals the pre-step state because invalid positions
    are frozen in the decode scan. KV leaves are position-addressed
    (rejected writes sit beyond the committed ``pos`` and are fenced by
    the per-slot causal mask, then overwritten) and pass through, so the
    result matches the non-speculative cache pytree exactly.
    """
    idx = jnp.clip(keep - 1, 0)

    def one(path, a):
        keys = [str(k.key) for k in path if hasattr(k, "key")]
        if keys and keys[-1] in ("conv", "state"):
            ix = idx.reshape((1, -1, 1) + (1,) * (a.ndim - 3))
            return jnp.take_along_axis(a, ix, axis=2)[:, :, 0]
        return a

    return jax.tree_util.tree_map_with_path(one, cache)


def reset_slots(cache, free_mask: jax.Array):
    """Zero the cache rows of the slots in ``free_mask [B]`` (bool).

    Every cache leaf is batch-major on axis 1 (``[np, B, ...]`` for the
    period stacks, ``[L, B, ...]`` for the encdec cache), so one
    ``where`` per leaf clears a slot for its next occupant. Mandatory
    for SSM/conv states (they carry no position to mask by); hygienic
    for KV rows.
    """

    def one(a):
        m = free_mask.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, jnp.zeros((), a.dtype), a)

    return jax.tree.map(one, cache)


def reset_paged(cache, slot_mask: jax.Array, page_mask: jax.Array):
    """Zero freed state in a paged cache (:func:`init_paged_cache`).

    K/V leaves (``[np, n_blocks, bs, KV, hd]``) are zeroed by
    ``page_mask [n_blocks]`` on the page axis; everything else (SSM
    conv/state, ``[np, B, ...]``) by ``slot_mask [B]`` on the slot axis.
    One fused device call — the paged analogue of :func:`reset_slots`.
    """

    def one(path, a):
        keys = [str(k.key) for k in path if hasattr(k, "key")]
        mask = page_mask if (keys and keys[-1] in ("k", "v")) else slot_mask
        m = mask.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, jnp.zeros((), a.dtype), a)

    return jax.tree_util.tree_map_with_path(one, cache)


def swap_out_slot(cache, slot: int, pages):
    """Gather one slot's swappable decode state from a paged cache.

    Returns a pytree mirroring ``cache`` where each K/V leaf holds only
    the slot's ``pages`` (``[np, n_pages, bs, KV, hd]``) and every
    slot-major leaf (SSM conv/state) holds only the slot's row
    (``[np, ...]``). The bundle plus the slot's position is everything a
    swap preemption needs to restore the request's device state exactly
    — the host-swap counterpart of the recompute path in
    ``repro.serve.request``.
    """

    def one(path, a):
        keys = [str(k.key) for k in path if hasattr(k, "key")]
        if keys and keys[-1] in ("k", "v"):
            return a[:, pages]
        return a[:, slot]

    return jax.tree_util.tree_map_with_path(one, cache)


def swap_in_slot(cache, data, slot: int, pages):
    """Scatter a :func:`swap_out_slot` bundle back into a paged cache.

    ``pages`` are the freshly allocated physical pages (same count as at
    swap-out; the ids may differ — block tables are remapped by the
    cache manager, the page *contents* are position-addressed within
    each page so they relocate freely).
    """

    def one(path, a, d):
        keys = [str(k.key) for k in path if hasattr(k, "key")]
        if keys and keys[-1] in ("k", "v"):
            return a.at[:, pages].set(jnp.asarray(d, a.dtype))
        return a.at[:, slot].set(jnp.asarray(d, a.dtype))

    return jax.tree_util.tree_map_with_path(one, cache, data)


def decode_step(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,  # [B, S]
    cache,
    pos: jax.Array,  # scalar int32: current write position
    *,
    enc_out: jax.Array | None = None,
    policy: PolicyLike = DENSE,
):
    """One lock-step decode step (all rows at the same ``pos``).

    The uniform-position special case of :func:`decode_slots`: the
    scalar ``pos`` keeps the cheaper ``dynamic_update_slice`` cache
    write and the batch-shared attention mask. Returns
    (logits [B,V] at the last position, cache).
    """
    b, s = tokens.shape
    x = layers.embed_apply(params["embed"], tokens)
    positions = (pos + jnp.arange(s))[None, :]
    if cfg.family == "encdec":
        x, new_cache = transformer.cross_decoder_apply(
            params["decoder"], x, enc_out, cfg, policy,
            positions=positions, caches=cache, cache_pos=pos,
        )
    else:
        x, new_cache, _ = transformer.stack_apply(
            params["stack"], x, cfg, policy,
            positions=positions, caches=cache, cache_pos=pos,
        )
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = layers.unembed_apply(params["embed"], x[:, -1:], valid=cfg.vocab)[:, 0]
    return logits, new_cache


def encode(cfg: ModelConfig, params, frames: jax.Array, policy: PolicyLike = DENSE):
    """Whisper encoder pass (used once before decode)."""
    enc = transformer.encoder_apply(params["encoder"], frames, cfg, policy)
    return layers.rmsnorm_apply(params["enc_norm"], enc, cfg.norm_eps)
