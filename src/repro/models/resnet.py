"""ResNet-18/26/50 (He et al. 2016) with ssProp convolutions.

Paper-faithful reproduction substrate: every convolution routes through
:func:`repro.models.layers.conv_apply` (and via it the unified
channel-sparse backward engine); BatchNorm follows the paper's FLOPs
model (Eq. 7). ResNet-26 is the paper's Q2 control: BasicBlocks in a
(2, 3, 5, 2) layout, FLOPs-matched to a sparsely-trained ResNet-50.

Functional pytree-params style, NCHW. BatchNorm runs in training mode
with batch statistics (the paper trains from scratch; no EMA eval path is
needed for the reproduction benchmarks, but running stats are kept).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import DENSE, PolicyLike
from repro.models import layers

LAYOUTS = {
    # name: (block_kind, stage_sizes)
    "resnet18": ("basic", (2, 2, 2, 2)),
    "resnet26": ("basic", (2, 3, 5, 2)),  # paper Table 7 control
    "resnet50": ("bottleneck", (3, 4, 6, 3)),
}


def conv_init(key, c_out, c_in, k):
    return layers.conv2d_init(key, c_out, c_in, k)


def bn_init(c):
    return {
        "scale": jnp.ones((c,), jnp.float32),
        "bias": jnp.zeros((c,), jnp.float32),
        "mean": jnp.zeros((c,), jnp.float32),
        "var": jnp.ones((c,), jnp.float32),
    }


def bn_apply(p, x, train: bool = True, momentum: float = 0.9):
    """BatchNorm (NCHW). Returns (y, updated_stats)."""
    if train:
        mean = x.mean(axis=(0, 2, 3))
        var = x.var(axis=(0, 2, 3))
        new_stats = {
            "mean": momentum * p["mean"] + (1 - momentum) * mean,
            "var": momentum * p["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = p["mean"], p["var"]
        new_stats = {"mean": p["mean"], "var": p["var"]}
    inv = jax.lax.rsqrt(var + 1e-5)
    y = (x - mean[None, :, None, None]) * inv[None, :, None, None]
    return y * p["scale"][None, :, None, None] + p["bias"][None, :, None, None], new_stats


def _basic_block_init(key, c_in, c_out, stride):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": conv_init(ks[0], c_out, c_in, 3),
        "bn1": bn_init(c_out),
        "conv2": conv_init(ks[1], c_out, c_out, 3),
        "bn2": bn_init(c_out),
    }
    if stride != 1 or c_in != c_out:
        p["down_conv"] = conv_init(ks[2], c_out, c_in, 1)
        p["down_bn"] = bn_init(c_out)
    return p


def _bottleneck_init(key, c_in, c_mid, stride):
    ks = jax.random.split(key, 4)
    c_out = c_mid * 4
    p = {
        "conv1": conv_init(ks[0], c_mid, c_in, 1),
        "bn1": bn_init(c_mid),
        "conv2": conv_init(ks[1], c_mid, c_mid, 3),
        "bn2": bn_init(c_mid),
        "conv3": conv_init(ks[2], c_out, c_mid, 1),
        "bn3": bn_init(c_out),
    }
    if stride != 1 or c_in != c_out:
        p["down_conv"] = conv_init(ks[3], c_out, c_in, 1)
        p["down_bn"] = bn_init(c_out)
    return p


def init_params(
    name: str, key, num_classes: int = 10, in_channels: int = 3, small_stem: bool = True
):
    """small_stem: 3x3/s1 stem for CIFAR-scale inputs; 7x7/s2 for ImageNet."""
    kind, stages = LAYOUTS[name]
    keys = jax.random.split(key, 64)
    ki = iter(keys)
    stem_k = 3 if small_stem else 7
    p: dict[str, Any] = {
        "stem": conv_init(next(ki), 64, in_channels, stem_k),
        "stem_bn": bn_init(64),
        "blocks": [],
    }
    widths = (64, 128, 256, 512)
    c_in = 64
    for si, (n, w) in enumerate(zip(stages, widths, strict=True)):
        for b in range(n):
            stride = 2 if (b == 0 and si > 0) else 1
            if kind == "basic":
                blk = _basic_block_init(next(ki), c_in, w, stride)
                c_in = w
            else:
                blk = _bottleneck_init(next(ki), c_in, w, stride)
                c_in = w * 4
            p["blocks"].append(blk)
    p["head"] = {
        "w": jax.random.normal(next(ki), (c_in, num_classes), jnp.float32)
        * math.sqrt(2.0 / c_in),
        "b": jnp.zeros((num_classes,), jnp.float32),
    }
    return p


def block_strides(name: str):
    """Static stride list matching init_params' block order."""
    _, stages = LAYOUTS[name]
    out = []
    for si, n in enumerate(stages):
        for b in range(n):
            out.append(2 if (b == 0 and si > 0) else 1)
    return out


def site_names(name: str):
    """Enumerate this ResNet's conv sites for policy-program resolution.

    ``(sites, depth)`` with depth = number of residual blocks, so rule
    patterns like ``block_{0,-1}/*`` address the first/last block.
    """
    kind, stages = LAYOUTS[name]
    widths = (64, 128, 256, 512)
    sites = ["stem"]
    c_in, bi = 64, 0
    for si, (n, w) in enumerate(zip(stages, widths, strict=True)):
        for b in range(n):
            stride = 2 if (b == 0 and si > 0) else 1
            convs = ("conv1", "conv2") if kind == "basic" else ("conv1", "conv2", "conv3")
            sites.extend(f"block_{bi}/{c}" for c in convs)
            c_out = w if kind == "basic" else w * 4
            if stride != 1 or c_in != c_out:
                sites.append(f"block_{bi}/down")
            c_in = c_out
            bi += 1
    return tuple(sites), bi


def _conv(p, x, stride, padding, policy, site, key=None):
    return layers.conv_apply(
        p, x, policy, stride=stride, padding=padding, key=key, site=site
    )


def _basic_apply(p, x, stride, policy, train, prefix):
    h, _ = bn_apply(p["bn1"], _conv(p["conv1"], x, stride, 1, policy, f"{prefix}/conv1"), train)
    h = jax.nn.relu(h)
    h, _ = bn_apply(p["bn2"], _conv(p["conv2"], h, 1, 1, policy, f"{prefix}/conv2"), train)
    if "down_conv" in p:
        x, _ = bn_apply(
            p["down_bn"], _conv(p["down_conv"], x, stride, 0, policy, f"{prefix}/down"), train
        )
    return jax.nn.relu(h + x)


def _bottleneck_apply(p, x, stride, policy, train, prefix):
    h, _ = bn_apply(p["bn1"], _conv(p["conv1"], x, 1, 0, policy, f"{prefix}/conv1"), train)
    h = jax.nn.relu(h)
    h, _ = bn_apply(p["bn2"], _conv(p["conv2"], h, stride, 1, policy, f"{prefix}/conv2"), train)
    h = jax.nn.relu(h)
    h, _ = bn_apply(p["bn3"], _conv(p["conv3"], h, 1, 0, policy, f"{prefix}/conv3"), train)
    if "down_conv" in p:
        x, _ = bn_apply(
            p["down_bn"], _conv(p["down_conv"], x, stride, 0, policy, f"{prefix}/down"), train
        )
    return jax.nn.relu(h + x)


def forward(
    name: str,
    params,
    x: jax.Array,
    policy: PolicyLike = DENSE,
    *,
    train: bool = True,
    small_stem: bool = True,
    dropout_rate: float = 0.0,
    dropout_key: jax.Array | None = None,
) -> jax.Array:
    """x [B, C, H, W] -> logits [B, num_classes]."""
    kind, _ = LAYOUTS[name]
    stem_stride = 1 if small_stem else 2
    stem_pad = 1 if small_stem else 3
    h, _ = bn_apply(
        params["stem_bn"], _conv(params["stem"], x, stem_stride, stem_pad, policy, "stem"), train
    )
    h = jax.nn.relu(h)
    if not small_stem:
        h = -jax.lax.reduce_window(
            -h, jnp.inf, jax.lax.min, (1, 1, 3, 3), (1, 1, 2, 2), "SAME"
        )
    dk = dropout_key
    for bi, (blk, stride) in enumerate(zip(params["blocks"], block_strides(name), strict=True)):
        if kind == "basic":
            h = _basic_apply(blk, h, stride, policy, train, f"block_{bi}")
        else:
            h = _bottleneck_apply(blk, h, stride, policy, train, f"block_{bi}")
        if dropout_rate > 0.0 and train:
            dk, sub = jax.random.split(dk)
            keep = jax.random.bernoulli(sub, 1.0 - dropout_rate, h.shape)
            h = h * keep / (1.0 - dropout_rate)
    h = h.mean(axis=(2, 3))
    return h @ params["head"]["w"] + params["head"]["b"]


def iter_conv_shapes(name: str, image: tuple[int, int, int]):
    """Yield ``(site, c_in, c_out, k, h_out, w_out)`` for every conv.

    The single source of the ResNet's layer geometry on ``image``
    (C, H, W) — :func:`flops_per_iter` and the benchmark bytes-moved
    walks both consume it, so the two accountings can never drift.
    """
    kind, stages = LAYOUTS[name]
    c, hh, ww = image
    small = hh <= 64
    if small:
        yield ("stem", c, 64, 3, hh, ww)
        h_cur, w_cur = hh, ww
    else:
        yield ("stem", c, 64, 7, hh // 2, ww // 2)
        h_cur, w_cur = hh // 4, ww // 4  # stem stride + maxpool
    c_in = 64
    widths = (64, 128, 256, 512)
    bi = 0
    for si, (n, w) in enumerate(zip(stages, widths, strict=True)):
        for b in range(n):
            stride = 2 if (b == 0 and si > 0) else 1
            h_cur2, w_cur2 = h_cur // stride, w_cur // stride
            if kind == "basic":
                yield (f"block_{bi}/conv1", c_in, w, 3, h_cur2, w_cur2)
                yield (f"block_{bi}/conv2", w, w, 3, h_cur2, w_cur2)
                if stride != 1 or c_in != w:
                    yield (f"block_{bi}/down", c_in, w, 1, h_cur2, w_cur2)
                c_out = w
            else:
                yield (f"block_{bi}/conv1", c_in, w, 1, h_cur, w_cur)
                yield (f"block_{bi}/conv2", w, w, 3, h_cur2, w_cur2)
                yield (f"block_{bi}/conv3", w, w * 4, 1, h_cur2, w_cur2)
                if stride != 1 or c_in != w * 4:
                    yield (f"block_{bi}/down", c_in, w * 4, 1, h_cur2, w_cur2)
                c_out = w * 4
            c_in = c_out
            h_cur, w_cur = h_cur2, w_cur2
            bi += 1


def flops_per_iter(
    name: str,
    batch: int,
    image: tuple[int, int, int],
    drop_rate: float = 0.0,
    policy: PolicyLike | None = None,
):
    """Backward FLOPs per iteration from the paper's Eq. 6/7 model.

    Walks the actual layer shapes of this ResNet on ``image`` (C, H, W)
    via :func:`iter_conv_shapes`. Returns (dense_flops, ssprop_flops).
    The ssProp count uses the nominal Eq. 9 at ``drop_rate``; pass
    ``policy`` instead to count the engine's real keep counts (block
    rounding, Pallas tile padding). ``policy`` may be a resolved
    :class:`~repro.core.policy.SitePolicies` table over
    :func:`site_names` — each conv then counts at its *own* site's keep
    count, so per-site programs get honest per-layer accounting instead
    of one global rate.
    """
    from repro.core import flops as F

    dense = sparse = 0
    for site, c_in, c_out, k, h_out, w_out in iter_conv_shapes(name, image):
        dense += F.conv_backward_flops(batch, h_out, w_out, c_in, c_out, k)
        if policy is not None:
            sparse += F.conv_backward_flops_site(
                batch, h_out, w_out, c_in, c_out, k, policy, site
            )
        else:
            sparse += F.conv_backward_flops_ssprop(
                batch, h_out, w_out, c_in, c_out, k, drop_rate
            )
        bn = F.batchnorm_backward_flops(batch, h_out, w_out, c_out)
        dense += bn
        sparse += bn
    return dense, sparse
