"""Shared model layers (pure-JAX, functional, pytree params).

Every projection matmul routes through :func:`repro.core.sparse_dense`
and every convolution through :func:`repro.core.sparse_conv2d` — via
:func:`dense_apply` / :func:`conv_apply` below — so the ssProp policy
(and the unified backward engine behind it) applies uniformly across
architectures: transformers, ResNets and the DDPM UNet all sparsify
through the same ``repro.core.backward`` pipeline. Attention is
memory-blocked (scan over query chunks with full-K masked scores) so
32k-prefill fits HBM without materializing the full S×S score tensor.

Every call site carries a *site name* (``site=``): with a plain
:class:`~repro.core.policy.SsPropPolicy` the name is ignored (the
legacy global-policy path), while a resolved
:class:`~repro.core.policy.SitePolicies` table gives each named site
its own policy — the per-site control surface of a
:class:`~repro.core.policy.PolicyProgram`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import sparse_conv2d, sparse_dense
from repro.core.policy import PolicyLike, policy_for

# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------


def dense_init(key, d_in, d_out, *, bias=False, dtype=jnp.bfloat16, scale=None):
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x, policy: PolicyLike, key=None, site: str = ""):
    return sparse_dense(
        x, p["w"], p.get("b"), policy=policy_for(policy, site), key=key
    )


def conv2d_init(key, c_out, c_in, k, *, bias=False, dtype=jnp.float32):
    """Kaiming-normal OIHW conv params: ``{"w"[, "b"]}``."""
    fan_in = c_in * k * k
    w = jax.random.normal(key, (c_out, c_in, k, k), jnp.float32) * math.sqrt(
        2.0 / fan_in
    )
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def conv_apply(
    p,
    x,
    policy: PolicyLike,
    *,
    stride=1,
    padding=0,
    dilation=1,
    groups=1,
    key=None,
    site: str = "",
):
    """The single conv call site the CNN models share (mirrors
    :func:`dense_apply`): params dict in, ssProp-backward conv out."""
    return sparse_conv2d(
        x,
        p["w"],
        p.get("b"),
        stride=stride,
        padding=padding,
        dilation=dilation,
        groups=groups,
        policy=policy_for(policy, site),
        key=key,
    )


def rmsnorm_init(d, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] or [S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------


def attn_init(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "q": dense_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "k": dense_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "v": dense_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "o": dense_init(
            ks[3], cfg.n_heads * hd, d, dtype=dtype, scale=1.0 / math.sqrt(2 * cfg.n_layers * cfg.n_heads * hd)
        ),
    }


def _gqa_scores(q, k):
    """q [B,S,H,D], k [B,T,KV,D] -> scores [B,H,S,T] with GQA grouping.

    Implemented as repeat-to-full-heads + plain batched dot: the repeat
    fuses into the dot, and — unlike a [KV, H/KV] reshape of the sharded
    head axis — it keeps a TP-sharded q-head axis local when k/v are
    replicated (§Perf iteration 4: kv-heads < TP degree otherwise forces
    GSPMD to reshard the S×T score tensor every layer).
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    k_full = jnp.repeat(k, h // kv, axis=2)  # [B,T,H,D]
    return jnp.einsum(
        "bshd,bthd->bhst", q, k_full, preferred_element_type=jnp.float32
    )


def _gqa_out(probs, v):
    """probs [B,H,S,T], v [B,T,KV,D] -> [B,S,H,D]."""
    b, h, s, t = probs.shape
    kv = v.shape[2]
    v_full = jnp.repeat(v, h // kv, axis=2)  # [B,T,H,D]
    return jnp.einsum("bhst,bthd->bshd", probs, v_full.astype(jnp.float32))


def masked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_len: jax.Array | None = None,
    seq_shard_hint: bool = False,
    qpos: jax.Array | None = None,
) -> jax.Array:
    """Blocked attention: scan over query chunks, full-K masked scores.

    q [B,S,H,D], k/v [B,T,KV,D]. ``q_offset`` is the absolute position of
    q[0] (decode). ``kv_len`` optionally masks positions >= kv_len
    (padded KV caches). ``qpos [B,S]`` gives *per-slot* absolute query
    positions (continuous batching: each batch row decodes at its own
    offset); it supersedes ``q_offset``/``kv_len`` and the mask gains a
    batch dim. Returns [B,S,H,D] in q.dtype.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, s)
    nchunks = max(1, -(-s // q_chunk))
    pad = nchunks * q_chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if qpos is not None:
            qpos = jnp.pad(qpos, ((0, 0), (0, pad)))
    qs = q.reshape(b, nchunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    per_slot = qpos is not None
    if per_slot:
        qpos_chunks = qpos.reshape(b, nchunks, q_chunk).transpose(1, 0, 2)
    else:
        qpos_chunks = jnp.arange(nchunks)

    kv_positions = jnp.arange(t)

    def body(carry, args):
        qc, qp = args
        scores = _gqa_scores(qc, k) * scale  # [B,H,qc,T] fp32
        if seq_shard_hint:
            # §Perf iter 3: keep decode scores sharded on the KV-seq dim
            # (partial-softmax); stops GSPMD gathering the whole cache.
            scores = jax.lax.with_sharding_constraint(
                scores, jax.sharding.PartitionSpec(None, None, None, "model")
            )
        if per_slot:
            # qp [B,qc] absolute per-slot positions -> mask [B,qc,T].
            # Causality alone fences stale cache rows from an evicted
            # request: every live kv row sits at a position <= its qpos.
            mask = jnp.ones((b, q_chunk, t), bool)
            if causal:
                mask &= kv_positions[None, None, :] <= qp[:, :, None]
            scores = jnp.where(mask[:, None], scores, -1e30)
        else:
            qpos_c = q_offset + qp * q_chunk + jnp.arange(q_chunk)
            mask = jnp.ones((q_chunk, t), bool)
            if causal:
                mask &= kv_positions[None, :] <= qpos_c[:, None]
            if kv_len is not None:
                mask &= kv_positions[None, :] < kv_len
            scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        return carry, _gqa_out(probs, v)

    _, outs = jax.lax.scan(body, None, (qs, qpos_chunks))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * q_chunk, h, d)
    return out[:, :s].astype(q.dtype)


def attn_apply(
    p,
    x,
    cfg,
    policy: PolicyLike,
    *,
    causal=True,
    positions=None,
    kv_cache=None,
    cache_pos=None,
    token_valid=None,
    block_tables=None,
    paged_kernel=False,
    x_kv=None,
    use_rope=True,
    site: str = "attn",
):
    """Self- or cross-attention. ``site`` prefixes the per-projection
    policy lookups (``{site}/q`` … ``{site}/o`` — "attn" in the decoder
    stack and encoder, "self"/"cross" in the cross-decoder).

    x [B,S,d]. ``x_kv`` switches to cross-attention (no cache, no rope on
    kv source positions beyond its own). ``kv_cache`` = dict(k, v) of
    shape [B, T, KV, D] for decode; ``cache_pos`` is the write offset —
    a scalar (lock-step: every row writes at the same position) or a
    ``[B]`` array (continuous batching: each slot writes at its own
    position, a vectorized scatter). ``token_valid [B,S]`` masks which
    tokens are real per slot; invalid tokens' k/v are dropped instead of
    written (their query outputs are garbage the caller never reads).

    ``block_tables [B, NB]`` switches the cache to the *paged* layout:
    ``kv_cache`` leaves are a page pool ``[n_pages, bs, KV, D]`` shared
    by all slots, and slot b's token at absolute position p lives in
    page ``block_tables[b, p // bs]`` at offset ``p % bs``. Writes
    become page-indexed scatters (invalid tokens routed to page index
    ``n_pages`` and dropped); attention gathers K/V back through the
    table into the same ``[B, NB*bs, KV, D]`` view the contiguous path
    uses. Unassigned table entries are 0 — a valid page whose contents
    sit at masked (future) positions, so per-slot causality fences them
    exactly like stale rows in the contiguous layout.

    ``paged_kernel=True`` (paged layout only) replaces that per-layer
    gather with the Pallas paged-attention kernel
    (:mod:`repro.kernels.paged_attention`): K/V pages are read *in
    place* from the pool during the kernel's HBM→VMEM copies, so the
    contiguous ``[B, NB*bs, KV, D]`` view is never materialized.
    Returns (out [B,S,d], new_cache or None).
    """
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = dense_apply(p["q"], x, policy, site=f"{site}/q").reshape(b, s, cfg.n_heads, hd)
    src = x if x_kv is None else x_kv
    k = dense_apply(p["k"], src, policy, site=f"{site}/k").reshape(
        b, src.shape[1], cfg.n_kv_heads, hd
    )
    v = dense_apply(p["v"], src, policy, site=f"{site}/v").reshape(
        b, src.shape[1], cfg.n_kv_heads, hd
    )

    per_slot = cache_pos is not None and getattr(cache_pos, "ndim", 0) >= 1
    if positions is None:
        positions = jnp.arange(s)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if x_kv is None:
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    q_offset = 0
    kv_len = None
    qpos = None
    if kv_cache is not None and block_tables is not None:
        # Paged cache: pool leaves [n_pages, bs, KV, D], no batch dim.
        n_pages, bs_pg = kv_cache["k"].shape[:2]
        nb = block_tables.shape[1]
        logical = cache_pos[:, None] + jnp.arange(s)[None, :]  # [B,S]
        blk = jnp.clip(logical // bs_pg, 0, nb - 1)
        off = logical % bs_pg
        page = jnp.take_along_axis(block_tables, blk, axis=1)  # [B,S]
        if token_valid is not None:
            page = jnp.where(token_valid, page, n_pages)  # OOB -> dropped
        ck = kv_cache["k"].at[page, off].set(k, mode="drop")
        cv = kv_cache["v"].at[page, off].set(v, mode="drop")
        new_cache = {"k": ck, "v": cv}
        qpos = positions if positions.ndim == 2 else logical
        if paged_kernel:
            from repro.kernels import ops as kops

            out = kops.paged_attention(q, ck, cv, block_tables, qpos)
            out = out.reshape(b, s, cfg.n_heads * hd)
            return dense_apply(p["o"], out, policy, site=f"{site}/o"), new_cache
        # Gather each slot's pages into the [B, NB*bs, KV, D] view the
        # masked attention consumes (T = NB*bs = max_seq rounded up).
        k = ck[block_tables].reshape(b, nb * bs_pg, *ck.shape[2:])
        v = cv[block_tables].reshape(b, nb * bs_pg, *cv.shape[2:])
    elif kv_cache is not None:
        t = kv_cache["k"].shape[1]
        if per_slot:
            # Vectorized per-slot write: row b's token c lands at
            # cache_pos[b] + c; invalid tokens are routed out of range
            # and dropped by the scatter.
            tgt = cache_pos[:, None] + jnp.arange(s)[None, :]  # [B,S]
            if token_valid is not None:
                tgt = jnp.where(token_valid, tgt, t)
            bidx = jnp.arange(b)[:, None]
            ck = kv_cache["k"].at[bidx, tgt].set(k, mode="drop")
            cv = kv_cache["v"].at[bidx, tgt].set(v, mode="drop")
            qpos = (
                positions
                if positions.ndim == 2
                else cache_pos[:, None] + jnp.arange(s)[None, :]
            )
        else:
            ck = jax.lax.dynamic_update_slice(kv_cache["k"], k, (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(kv_cache["v"], v, (0, cache_pos, 0, 0))
            q_offset = cache_pos
            kv_len = cache_pos + s
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv

    out = masked_attention(
        q, k, v, causal=causal and x_kv is None, q_offset=q_offset, kv_len=kv_len,
        q_chunk=getattr(cfg, "attn_q_chunk", 1024),
        seq_shard_hint=(
            kv_cache is not None and getattr(cfg, "decode_seq_shard", False)
        ),
        qpos=qpos,
    )
    out = out.reshape(b, s, cfg.n_heads * hd)
    return dense_apply(p["o"], out, policy, site=f"{site}/o"), new_cache


# ----------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def mlp_init(key, d_model, d_ff, dtype=jnp.bfloat16, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {
        "up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
        "down": dense_init(ks[2], d_ff, d_model, dtype=dtype),
    }
    if gated:
        p["gate"] = dense_init(ks[1], d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(p, x, act: str, policy: PolicyLike, site: str = "mlp"):
    if "gate" in p:
        h = _ACTS[act](dense_apply(p["gate"], x, policy, site=f"{site}/gate")) * dense_apply(
            p["up"], x, policy, site=f"{site}/up"
        )
    else:
        h = _ACTS[act](dense_apply(p["up"], x, policy, site=f"{site}/up"))
    return dense_apply(p["down"], h, policy, site=f"{site}/down")


# ----------------------------------------------------------------------
# embeddings
# ----------------------------------------------------------------------


def embed_init(key, vocab, d_model, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)}


def embed_apply(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed_apply(p, x, valid: int | None = None):
    """Tied unembedding: x [B,S,d] @ table^T -> logits fp32.

    ``valid``: logical vocab size — logits of padded table rows (vocab
    rounded up for TP sharding) are masked to -inf so softmax/argmax
    never see them.
    """
    logits = jnp.einsum(
        "bsd,vd->bsv", x, p["table"], preferred_element_type=jnp.float32
    )
    v = p["table"].shape[0]
    if valid is not None and valid < v:
        mask = jnp.arange(v) < valid
        logits = jnp.where(mask, logits, -1e30)
    return logits
