"""Mixture-of-Experts layer: top-k router + sort-based static dispatch.

MaxText-style capacity dispatch: token→expert assignments are sorted by
expert id, packed into a ``[E, capacity, d]`` buffer (overflow dropped),
experts run as one batched matmul (vmapped ``sparse_dense`` so ssProp's
channel-sparse backward applies per expert), and outputs are combined
with router weights. All shapes static; EP shards the expert axis over
the ``model`` mesh axis (see dist/sharding.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sparse_dense
from repro.core.policy import DENSE, PolicyLike, policy_for
from repro.models import layers


def moe_init(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    p = {
        "router": layers.dense_init(ks[0], d, e, dtype=jnp.float32),
        "gate": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) * scale).astype(dtype),
        "up": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) * scale).astype(dtype),
        "down": (
            jax.random.normal(ks[3], (e, ff, d), jnp.float32) / jnp.sqrt(ff)
        ).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.mlp_init(
            ks[4], d, cfg.d_ff * cfg.n_shared_experts, dtype=dtype
        )
    return p


def _expert_ffn(gate_w, up_w, down_w, xb, act, pols):
    """One expert's gated FFN on its [capacity, d] buffer (vmapped).

    ``pols`` = (gate, up, down) per-site policies, resolved before the
    vmap (sites ``moe/gate``, ``moe/up``, ``moe/down``).
    """
    h = layers._ACTS[act](sparse_dense(xb, gate_w, policy=pols[0])) * sparse_dense(
        xb, up_w, policy=pols[1]
    )
    return sparse_dense(h, down_w, policy=pols[2])


def _expert_policies(policy: PolicyLike):
    return (
        policy_for(policy, "moe/gate"),
        policy_for(policy, "moe/up"),
        policy_for(policy, "moe/down"),
    )


def moe_apply(
    p, x, cfg, policy: PolicyLike, *, full_capacity: bool = False,
    dp_groups: int = 0,
):
    """x [B, S, d] -> ([B, S, d], aux_metrics).

    Router in fp32; dispatch by stable sort over expert ids; per-expert
    capacity ``C = ceil(B*S*topk/E * capacity_factor)``; overflow dropped
    (weight zeroed). Aux load-balance loss returned for logging/training.
    ``full_capacity=True`` (decode/serving) sizes the buffer so no token
    can ever be dropped (C = tokens).

    ``dp_groups > 0`` (§Perf iteration 2): dispatch is performed
    independently within ``dp_groups`` token groups (the DP shards).
    Every sort/scatter/gather then carries a leading group axis that
    GSPMD keeps local to the data shard — the only cross-shard traffic
    left is the compact ``[G, E, C/G, d]`` expert-buffer all-to-all,
    instead of replicated token-sized scatters (which showed up as
    ~0.5 TB all-reduces in the baseline dry-run of the 1M-token MoE
    prefill cells).
    """
    if dp_groups and (x.shape[0] * x.shape[1]) % dp_groups == 0 and not full_capacity:
        return _moe_apply_grouped(p, x, cfg, policy, dp_groups)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_topk
    tokens = b * s
    xf = x.reshape(tokens, d)

    logits = layers.dense_apply(p["router"], xf.astype(jnp.float32), DENSE)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    topw, topi = jax.lax.top_k(probs, k)  # [T, k]
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux (Switch-style) ----
    me = probs.mean(0)
    ce = jnp.zeros((e,)).at[topi.reshape(-1)].add(1.0) / (tokens * k)
    aux_loss = e * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    if full_capacity:
        cap = tokens  # an expert can receive at most one slot per token
    else:
        cap = max(1, int(tokens * k / e * cfg.capacity_factor))
    flat_e = topi.reshape(-1)  # [T*k]
    order = jnp.argsort(flat_e, stable=True)  # group by expert
    sorted_e = flat_e[order]
    sorted_tok = order // k  # source token of each slot
    # position within expert group
    grp_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos = jnp.arange(tokens * k) - grp_start[sorted_e]
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_e, pos_c].set(
        jnp.where(keep[:, None], xf[sorted_tok], 0).astype(x.dtype)
    )

    out_buf = jax.vmap(_expert_ffn, in_axes=(0, 0, 0, 0, None, None))(
        p["gate"], p["up"], p["down"], buf, cfg.act, _expert_policies(policy)
    )  # [E, cap, d]

    # ---- combine ----
    gathered = out_buf[sorted_e, pos_c]  # [T*k, d]
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = jnp.zeros((tokens * k, d), jnp.float32).at[order].set(
        gathered.astype(jnp.float32)
    )
    contrib = contrib.reshape(tokens, k, d) * topw[..., None]
    y = contrib.sum(axis=1).astype(x.dtype)

    if "shared" in p:
        y = y + layers.mlp_apply(p["shared"], xf, cfg.act, policy, site="moe/shared")

    frac_dropped = 1.0 - keep.mean()
    return y.reshape(b, s, d), {"aux_loss": aux_loss, "dropped": frac_dropped}


def _moe_apply_grouped(p, x, cfg, policy: PolicyLike, groups: int):
    """DP-local dispatch: all index ops carry a leading [G] group axis.

    Token groups correspond to the data shards (G = dp size), so sorts,
    scatters and combines never cross shards; the expert einsum contracts
    the group-sharded buffer against model-sharded expert weights, which
    GSPMD lowers to the canonical EP all-to-all.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_topk
    tokens = b * s
    g = groups
    tg = tokens // g
    xf = x.reshape(g, tg, d)

    logits = jnp.einsum(
        "gtd,de->gte", xf.astype(jnp.float32), p["router"]["w"]
    )
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # [G, tg, k]
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)

    me = probs.mean((0, 1))
    ce = jnp.zeros((e,)).at[topi.reshape(-1)].add(1.0) / (tokens * k)
    aux_loss = e * jnp.sum(me * ce)

    cap = max(1, int(tg * k / e * cfg.capacity_factor))
    flat_e = topi.reshape(g, tg * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)  # [G, tg*k]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    sorted_tok = order // k
    grp_start = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(
        sorted_e
    )  # [G, E]
    pos = jnp.arange(tg * k)[None, :] - jnp.take_along_axis(grp_start, sorted_e, axis=1)
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    gidx = jnp.arange(g)[:, None]
    buf = jnp.zeros((g, e, cap, d), x.dtype)
    src = jnp.where(
        keep[..., None], jnp.take_along_axis(xf, sorted_tok[..., None], axis=1), 0
    ).astype(x.dtype)
    buf = buf.at[gidx, sorted_e, pos_c].set(src)

    # per-expert FFN, vmapped over (group, expert) — sparse_dense keeps
    # the ssProp backward on every expert matmul.
    per_expert = jax.vmap(_expert_ffn, in_axes=(0, 0, 0, 0, None, None))
    out_buf = jax.vmap(per_expert, in_axes=(None, None, None, 0, None, None))(
        p["gate"], p["up"], p["down"], buf, cfg.act, _expert_policies(policy)
    )  # [G, E, cap, d]

    gathered = out_buf[gidx, sorted_e, pos_c]  # [G, tg*k, d]
    gathered = jnp.where(keep[..., None], gathered, 0)
    contrib = jnp.zeros((g, tg * k, d), jnp.float32).at[
        gidx, order
    ].set(gathered.astype(jnp.float32))
    contrib = contrib.reshape(g, tg, k, d) * topw[..., None]
    y = contrib.sum(axis=2).astype(x.dtype)

    if "shared" in p:
        y = y + layers.mlp_apply(p["shared"], xf, cfg.act, policy, site="moe/shared")

    frac_dropped = 1.0 - keep.mean()
    return y.reshape(b, s, d), {"aux_loss": aux_loss, "dropped": frac_dropped}
