"""jax version compatibility for the distributed layer.

The production code targets the modern jax mesh API (``jax.set_mesh``,
``jax.sharding.AxisType``); CI pins an older jax where a ``Mesh`` is
itself the context manager and meshes have no axis types. ``install()``
backfills the small API surface we rely on so the same driver code runs
on both. It is idempotent and never overwrites a real jax symbol.
"""
from __future__ import annotations

import contextlib

import jax


@contextlib.contextmanager
def _set_mesh(mesh):
    """Old-jax stand-in for ``jax.set_mesh``: enter the physical mesh.

    ``with``-usage ONLY. Modern jax also allows the bare-call global
    setter form ``jax.set_mesh(mesh)``; old jax has no global mesh to
    set, so on the shim that form would be a silent no-op — always
    write ``with jax.set_mesh(mesh):`` in this codebase.
    """
    with mesh:
        yield mesh


def install() -> None:
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _set_mesh


install()
