"""jax version compatibility + multi-process init for the distributed layer.

Two concerns live here:

1. **jax shims** — the production code targets the modern jax mesh API
   (``jax.set_mesh``, ``jax.sharding.AxisType``); CI pins an older jax
   where a ``Mesh`` is itself the context manager and meshes have no
   axis types. ``install()`` backfills the small API surface we rely on
   so the same driver code runs on both. It is idempotent and never
   overwrites a real jax symbol.

2. **``jax.distributed``-style multi-process init** — ``initialize()``
   is the coordinator entry point every rank calls before training,
   mirroring ``jax.distributed.initialize(coordinator_address,
   num_processes, process_id)`` but coordinated through a *shared
   filesystem directory* instead of a gRPC service. That is the same
   substrate the fault protocol already uses (heartbeat files on the
   checkpoint filesystem), needs no ports, and lets the multi-process
   test harness spawn N real ranks as plain subprocesses sharing a
   tmpdir. The returned :class:`ProcessGroup` carries the collective
   primitives the control plane needs (``barrier``, ``put``/``gather``,
   ``broadcast``) — *control-plane only*: scalars and JSON metadata,
   never tensors. Tensor resharding stays on the checkpoint layer
   (per-host shards + partial-read restore, ``repro.checkpoint.ckpt``).
"""
from __future__ import annotations

from collections.abc import Sequence
import contextlib
import json
import os
import time
from typing import Any

import jax


@contextlib.contextmanager
def _set_mesh(mesh):
    """Old-jax stand-in for ``jax.set_mesh``: enter the physical mesh.

    ``with``-usage ONLY. Modern jax also allows the bare-call global
    setter form ``jax.set_mesh(mesh)``; old jax has no global mesh to
    set, so on the shim that form would be a silent no-op — always
    write ``with jax.set_mesh(mesh):`` in this codebase.
    """
    with mesh:
        yield mesh


def install() -> None:
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _set_mesh


install()


# ----------------------------------------------------------------------
# multi-process init (jax.distributed-style, filesystem-coordinated)
# ----------------------------------------------------------------------


class ProcessGroupTimeout(TimeoutError):
    """A collective did not complete within its deadline (a peer is
    missing or dead). The caller decides whether that is fatal — the
    fault protocol treats it as an eviction signal, not a crash."""


def _atomic_write_json(path: str, obj: Any) -> None:
    """Crash-atomic publish: a reader never observes a torn file."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def _read_json(path: str) -> Any | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None  # not yet published, or racing the atomic rename


class ProcessGroup:
    """Control-plane collectives over a shared directory.

    Every primitive is **tagged**: a tag names one logical collective
    and must be unique per use (callers include the step/epoch in it,
    e.g. ``f"commit.{step}"``) so reuse across restarts never aliases a
    stale file. Participants default to all ranks but every call takes
    ``ranks=`` — after an eviction the survivors synchronize among
    themselves without waiting on the dead.

    Payload writes are crash-atomic (tmp + rename), so a peer killed
    mid-``put`` is indistinguishable from one that never wrote: the
    collective times out instead of reading garbage.
    """

    def __init__(
        self,
        coord_dir: str,
        rank: int,
        num_processes: int,
        *,
        poll_s: float = 0.01,
        timeout_s: float = 60.0,
    ):
        if not (0 <= rank < num_processes):
            raise ValueError(f"rank {rank} outside world of {num_processes}")
        self.coord_dir = coord_dir
        self.rank = rank
        self.num_processes = num_processes
        self.poll_s = poll_s
        self.timeout_s = timeout_s
        self._kv = os.path.join(coord_dir, "kv")
        os.makedirs(self._kv, exist_ok=True)

    # -- point-to-point publish / read ---------------------------------

    def _path(self, tag: str, rank: int) -> str:
        safe = tag.replace(os.sep, "_")
        return os.path.join(self._kv, f"{safe}.{rank:05d}.json")

    def put(self, tag: str, payload: Any = None) -> None:
        """Publish this rank's payload for one tagged collective."""
        _atomic_write_json(self._path(tag, self.rank), payload)

    def try_get(self, tag: str, rank: int) -> Any | None:
        """Non-blocking read of one peer's payload (None if absent)."""
        path = self._path(tag, rank)
        if not os.path.exists(path):
            return None
        return _read_json(path)

    def get(self, tag: str, rank: int, timeout_s: float | None = None) -> Any:
        deadline = time.monotonic() + (
            self.timeout_s if timeout_s is None else timeout_s
        )
        while True:
            if os.path.exists(self._path(tag, rank)):
                out = _read_json(self._path(tag, rank))
                if out is not None or self._exists_nonempty(tag, rank):
                    return out
            if time.monotonic() > deadline:
                raise ProcessGroupTimeout(
                    f"get({tag!r}) from rank {rank} timed out"
                )
            time.sleep(self.poll_s)

    def _exists_nonempty(self, tag: str, rank: int) -> bool:
        try:
            return os.path.getsize(self._path(tag, rank)) > 0
        except OSError:
            return False

    # -- collectives ---------------------------------------------------

    def gather(
        self,
        tag: str,
        payload: Any = None,
        *,
        ranks: Sequence[int] | None = None,
        timeout_s: float | None = None,
    ) -> dict[int, Any]:
        """All-gather of JSON payloads among ``ranks``; returns
        rank → payload once every participant has published."""
        ranks = list(range(self.num_processes)) if ranks is None else list(ranks)
        self.put(tag, payload)
        return {r: self.get(tag, r, timeout_s) for r in ranks}

    def barrier(
        self,
        tag: str,
        *,
        ranks: Sequence[int] | None = None,
        timeout_s: float | None = None,
    ) -> None:
        self.gather(f"bar.{tag}", None, ranks=ranks, timeout_s=timeout_s)

    def broadcast(
        self,
        tag: str,
        payload: Any = None,
        *,
        src: int = 0,
        timeout_s: float | None = None,
    ) -> Any:
        """One rank publishes, everyone reads (src returns its own)."""
        if self.rank == src:
            self.put(tag, payload)
        return self.get(tag, src, timeout_s)


def initialize(
    coord_dir: str,
    *,
    process_id: int,
    num_processes: int,
    timeout_s: float = 60.0,
) -> ProcessGroup:
    """``jax.distributed.initialize``-style entry point, filesystem-backed.

    Registers this process (pid + local device count) under
    ``<coord_dir>/ranks/`` and blocks until all ``num_processes`` peers
    have registered, so by the time it returns every rank's heartbeat
    file can be expected to exist (missing ⇒ dead, no startup grace
    logic needed downstream). Safe to call again after a restart of the
    same rank: registration is overwritten in place.
    """
    os.makedirs(os.path.join(coord_dir, "ranks"), exist_ok=True)
    pg = ProcessGroup(
        coord_dir, process_id, num_processes, timeout_s=timeout_s
    )
    reg = {
        "pid": os.getpid(),
        "local_devices": jax.local_device_count(),
        "registered_at": time.time(),
    }
    _atomic_write_json(
        os.path.join(coord_dir, "ranks", f"rank_{process_id:05d}.json"), reg
    )
    deadline = time.monotonic() + timeout_s
    want = {f"rank_{r:05d}.json" for r in range(num_processes)}
    while not want.issubset(set(os.listdir(os.path.join(coord_dir, "ranks")))):
        if time.monotonic() > deadline:
            missing = sorted(
                want - set(os.listdir(os.path.join(coord_dir, "ranks")))
            )
            raise ProcessGroupTimeout(
                f"initialize: peers never registered: {missing}"
            )
        time.sleep(pg.poll_s)
    return pg


def registered_ranks(coord_dir: str) -> list[int]:
    """Ranks that have ever registered with :func:`initialize`."""
    d = os.path.join(coord_dir, "ranks")
    if not os.path.isdir(d):
        return []
    out = []
    for name in os.listdir(d):
        if name.startswith("rank_") and name.endswith(".json"):
            try:
                out.append(int(name[5:-5]))
            except ValueError:
                continue
    return sorted(out)
