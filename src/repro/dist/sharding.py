"""Production partition-spec rules over abstract param/input pytrees.

Rules are assigned by parameter name (path in the pytree) and expressed
as mesh-independent :class:`~jax.sharding.PartitionSpec` trees
(:func:`param_specs`); the mesh-aware entry points
(:func:`param_shardings`, :func:`opt_state_shardings`,
:func:`batch_shardings`, :func:`cache_shardings`) turn them into
``NamedSharding``s after repairing illegal placements with
:func:`fit_spec`. See ``repro/dist/__init__.py`` for the rule table.
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes

# attention module names across the decoder / encoder / cross-decoder
_ATTN_KEYS = ("attn", "self", "cross")
# kernels sharded on their LAST dim (output features)
_COL_PARALLEL = ("q", "k", "v", "up", "gate", "in_proj")
# kernels sharded on dim -2 (input features)
_ROW_PARALLEL = ("o", "down", "out_proj")


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def _axis_size(mesh, axis) -> int:
    """Size of one spec entry: a mesh axis name or a tuple of them."""
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def fit_spec(spec: P, shape: Sequence[int], mesh) -> P:
    """Repair ``spec`` so every assignment divides its dim on ``mesh``.

    An axis assigned to a non-divisible dim is relocated to the nearest
    free (None) dim that IS divisible — e.g. 16-way ``model`` on an
    8-head kv dim moves to the adjacent head_dim; ``data`` on a batch=1
    decode moves to the seq dim. Ties prefer the later (inner) dim.
    With no legal dim the axis is dropped (replicated) — always safe,
    never wrong, just less parallel. A spec longer than the shape is
    truncated (its extra axes are dropped the same way).

    **Joint placement** — when a *tuple* of mesh axes contends for one
    dim and their product does not divide it (the multi-pod
    ``("pod", "data")`` batch split at ``batch < dp_size``), the tuple
    is SPLIT rather than moved whole: the largest-product sub-tuple
    that does divide stays on the dim, and each remaining axis is
    relocated independently by the single-axis rule. A 2×16 pod×data
    fleet with global batch 8 keeps ``pod`` (2 | 8) on the batch dim
    and moves ``data`` (16) to the sequence dim, instead of giving up
    all 32-way data parallelism on the batch at once.
    """
    entries = list(spec)[: len(shape)] + [None] * (len(shape) - len(spec))

    def relocate_one(i, axis):
        n = _axis_size(mesh, axis)
        cands = [
            j
            for j, e in enumerate(entries)
            if e is None and shape[j] % n == 0
        ]
        if cands:
            best = min(cands, key=lambda j: (abs(j - i), 0 if j > i else 1))
            entries[best] = axis

    for i, axis in enumerate(list(entries)):
        if axis is None:
            continue
        n = _axis_size(mesh, axis)
        if n <= 1 or shape[i] % n == 0:
            continue
        entries[i] = None
        if isinstance(axis, tuple) and len(axis) > 1:
            # joint placement: keep the biggest divisible sub-tuple on
            # this dim, relocate the leftover axes one by one
            best_sub, best_n = (), 1
            for mask in range(1, 1 << len(axis)):
                sub = tuple(
                    a for k, a in enumerate(axis) if mask & (1 << k)
                )
                sn = _axis_size(mesh, sub)
                if shape[i] % sn == 0 and sn > best_n:
                    best_sub, best_n = sub, sn
            if best_sub:
                entries[i] = best_sub if len(best_sub) > 1 else best_sub[0]
            for a in axis:
                if a not in best_sub:
                    relocate_one(i, a)
        else:
            relocate_one(i, axis)
    return P(*entries)


def _rule_for(path, leaf) -> P:
    """Mesh-independent spec for one named parameter leaf."""
    ndim = getattr(leaf, "ndim", None)
    if ndim is None:
        ndim = len(getattr(leaf, "shape", ()))
    none = [None] * ndim
    keys = [
        str(k.key)
        for k in path
        if hasattr(k, "key")  # DictKey; skip SequenceKey indices
    ]

    if "embed" in keys and keys[-1] == "table":
        # [V, d]: vocab-sharded embedding + tied unembedding
        sp = list(none)
        sp[0] = "model"
        return P(*sp)

    if keys and keys[-1] == "w" and "router" not in keys:
        name = keys[-2] if len(keys) >= 2 else ""
        if name in _COL_PARALLEL and ndim >= 2:
            sp = list(none)
            sp[-1] = "model"
            return P(*sp)
        if name in _ROW_PARALLEL and ndim >= 2:
            sp = list(none)
            sp[-2] = "model"
            return P(*sp)

    if "moe" in keys and keys[-1] in ("gate", "up", "down") and ndim >= 3:
        # stacked expert tensors [np, E, d, ff] / [np, E, ff, d]:
        # expert-parallel over the model axis
        sp = list(none)
        sp[1] = "model"
        return P(*sp)

    # norms, biases, router, ssm conv/A/dt/D scalars: replicated
    return P(*none)


def param_specs(a_params: Any, *, replicate_kv: bool = False) -> Any:
    """PartitionSpec pytree matching ``a_params`` (abstract or concrete).

    ``replicate_kv=True`` replicates the k/v projection kernels —
    serving configs keep kv-heads < TP degree, and replicated kv avoids
    GSPMD resharding the score tensor every layer (§Perf iteration 4).
    """

    def one(path, leaf):
        sp = _rule_for(path, leaf)
        if replicate_kv:
            keys = [str(k.key) for k in path if hasattr(k, "key")]
            in_attn = any(k in _ATTN_KEYS for k in keys)
            if in_attn and len(keys) >= 2 and keys[-2] in ("k", "v"):
                return P(*([None] * len(sp)))
        return sp

    return jax.tree_util.tree_map_with_path(one, a_params)


def param_shardings(
    mesh, a_params: Any, *, replicate_kv: bool = False
) -> Any:
    """NamedSharding pytree for the params of one model on ``mesh``."""
    specs = param_specs(a_params, replicate_kv=replicate_kv)
    return jax.tree.map(
        lambda a, s: NamedSharding(mesh, fit_spec(s, a.shape, mesh)),
        a_params,
        specs,
    )


def opt_state_shardings(mesh, a_params: Any, **kw) -> Any:
    """Adam m/v mirror the param layout (same shapes, fp32)."""
    return param_shardings(mesh, a_params, **kw)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _batch_axis(mesh):
    dpax = dp_axes(mesh)
    if not dpax:
        return None
    return dpax if len(dpax) > 1 else dpax[0]


def batch_shardings(mesh, batch: Any) -> Any:
    """Inputs: leading (batch) dim over the data-parallel axes."""
    baxis = _batch_axis(mesh)

    def one(a):
        ndim = getattr(a, "ndim", 0)
        if not ndim:
            return replicated(mesh)
        spec = P(*([baxis] + [None] * (ndim - 1)))
        return NamedSharding(mesh, fit_spec(spec, a.shape, mesh))

    return jax.tree.map(one, batch)


def cache_shardings(
    mesh, a_cache: Any, *, seq_shard: bool = False, paged: bool = False
) -> Any:
    """Decode caches: batch over dp; kv-heads (or seq) over model.

    Cache leaves are period-stacked ``[np, B, ...]``. Attention k/v
    ``[np, B, T, KV, hd]`` put ``model`` on the kv-head dim, or on the
    seq dim with ``seq_shard=True`` (long-context decode: partial
    softmax over a seq-sharded cache, §Perf iteration 3). SSM states
    ``[np, B, H, N, P]`` shard the head dim; conv buffers shard their
    channel dim.

    ``paged=True`` declares the paged layout
    (:func:`repro.models.model.init_paged_cache`): attention k/v leaves
    are a page pool ``[np, n_blocks, bs, KV, hd]`` whose page axis is
    **replicated** — block tables index the pool globally, so sharding
    pages over ``data`` would turn every table gather into a
    cross-replica collective. ``model`` stays on the kv-head dim
    (``seq_shard`` moves it to the within-page dim, which only helps
    when ``block_size`` spans the model axis — rarely what you want; the
    kv-head default is right for paged serving). SSM/conv leaves are
    still slot-major and shard exactly as the contiguous layout.
    """
    baxis = _batch_axis(mesh)

    def one(path, a):
        ndim = getattr(a, "ndim", 0)
        entries = [None] * ndim
        keys = [str(k.key) for k in path if hasattr(k, "key")]
        name = keys[-1] if keys else ""
        kv_leaf = name in ("k", "v") and ndim >= 5
        if ndim >= 2 and not (paged and kv_leaf):
            entries[1] = baxis
        if kv_leaf:
            entries[2 if seq_shard else 3] = "model"
        elif name == "state" and ndim >= 3:
            entries[2] = "model"
        elif name == "conv" and ndim >= 3:
            entries[-1] = "model"
        return NamedSharding(mesh, fit_spec(P(*entries), a.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, a_cache)


def swap_shardings(mesh, a_swapped: Any) -> Any:
    """Staging shardings for one slot's swapped-out cache bundle.

    Swap preemption stages a slot's cache state through the host
    (:meth:`repro.serve.cache.PagedCacheManager.swap_out` /
    ``swap_in``); on the way back in, each leaf should land on the mesh
    already laid out like the pool it is scattered into, so the
    ``.at[...].set`` needs no resharding collective. Bundle leaves have
    the slot/batch dim removed relative to :func:`cache_shardings`:

    * K/V page bundles ``[np, n_pages, bs, KV, hd]`` — ``model`` on the
      kv-head dim, page axis replicated (matching the paged pool rule);
    * SSM state rows ``[np, H, N, P]`` — ``model`` on the head dim;
    * conv rows ``[np, K-1, C]`` — ``model`` on the channel dim.

    Anything else is replicated. Illegal placements are repaired with
    :func:`fit_spec` like every other rule table.
    """

    def one(path, a):
        ndim = getattr(a, "ndim", 0)
        entries = [None] * ndim
        keys = [str(k.key) for k in path if hasattr(k, "key")]
        name = keys[-1] if keys else ""
        if name in ("k", "v") and ndim >= 5:
            entries[3] = "model"
        elif name == "state" and ndim >= 2:
            entries[1] = "model"
        elif name == "conv" and ndim >= 2:
            entries[-1] = "model"
        return NamedSharding(mesh, fit_spec(P(*entries), a.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, a_swapped)


def block_table_sharding(mesh) -> NamedSharding:
    """Block tables are small int32 host state — replicated everywhere
    (every shard of the pool needs the full logical→physical map)."""
    return replicated(mesh)
