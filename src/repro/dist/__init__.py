"""Distributed training layer: sharding rules + fault tolerance.

``repro.dist.sharding`` holds the production partition-spec rules that
train.py / dryrun.py / serve paths all share. Specs are assigned by
parameter *name* over the abstract param pytree and then repaired
against the concrete mesh by :func:`sharding.fit_spec`, so one rule
table covers every registry architecture at every mesh size.

Sharding rule table (tensor → mesh axis placement):

  ===========================  ==========================  ============
  tensor                       shape                       spec
  ===========================  ==========================  ============
  embed table                  [V, d]                      ("model", -)
  attn q/k/v kernel            [np, d, H*hd]               (-, -, "model")
  attn o kernel                [np, H*hd, d]               (-, "model", -)
  mlp up/gate kernel           [np, d, ff]                 (-, -, "model")
  mlp down kernel              [np, ff, d]                 (-, "model", -)
  MoE expert gate/up           [np, E, d, ff]              (-, "model", -, -)
  MoE expert down              [np, E, ff, d]              (-, "model", -, -)
  ssm in_proj kernel           [np, d, X]                  (-, -, "model")
  ssm out_proj kernel          [np, di, d]                 (-, "model", -)
  norms / biases / router      any                         replicated
  batch inputs                 [B, ...]                    (dp, -, ...)
  KV cache k/v                 [np, B, T, KV, hd]          (-, dp, -, "model", -)
    (seq_shard=True moves "model" to the T dim for long decode)
  paged KV pool k/v            [np, NB, bs, KV, hd]        (-, -, -, "model", -)
    (paged=True: page axis replicated — block tables index the
     pool globally, so dp-sharding pages would make every gather
     a collective; block tables themselves are replicated)
  swap-staged KV pages         [np, n, bs, KV, hd]         (-, -, -, "model", -)
  swap-staged ssm state row    [np, H, N, P]               (-, "model", -, -)
  swap-staged conv row         [np, K-1, C]                (-, -, "model")
    (``swap_shardings``: host-staged swap-preemption bundles land
     pre-sharded like the pool they scatter into)
  ===========================  ==========================  ============

``dp`` is the data-parallel axis group — ``("pod", "data")`` on the
multi-pod mesh, ``"data"`` otherwise. Any placement whose dim is not
divisible by the mesh axis size is relocated by ``fit_spec`` to the
nearest divisible free dim (ties prefer the later dim), falling back to
replication when no dim is legal. A *tuple* of axes whose product does
not divide its dim is split jointly: the largest divisible sub-tuple
stays put and the leftover axes relocate one by one (the multi-pod
``("pod", "data")`` batch split at ``batch < dp_size`` keeps ``pod``
on batch and moves ``data`` to the seq dim — see the ``train_tight``
shape cell).

``repro.dist.compat`` provides ``initialize()`` — the
``jax.distributed``-style multi-process entry point, coordinated
through a shared filesystem directory instead of a gRPC service — and
the :class:`~repro.dist.compat.ProcessGroup` control-plane collectives
(barrier / gather / broadcast of JSON payloads, never tensors).

``repro.dist.fault`` implements the file-based **rank-complete**
fault-tolerance protocol used by the training driver:

  * ``Heartbeat`` — EVERY rank touches ``<dir>/rank_<r>`` at most
    every ``interval_s`` seconds; the file mtime IS the liveness
    signal (no server, works on any shared filesystem).
  * ``HeartbeatMonitor.dead_ranks()`` — ranks whose heartbeat file
    mtime is older than ``timeout_s``, judged against the monitor's
    own same-filesystem sentinel mtime (clock-skew safe).
  * ``FleetSupervisor`` — aggregates all heartbeats into membership
    *epochs* (atomically-published ``membership.json``): stale beat ⇒
    evict, rejoin request + fresh beat ⇒ un-evict; each bumps the
    epoch. The supervisor seat is the lowest active rank and fails
    over deterministically. Workers guard each step with
    ``check_epoch`` and abort with ``MembershipChanged`` on drift;
    the restart layer reshards them around the new active set, and a
    recovered rank re-enters through ``request_rejoin`` +
    ``wait_active``. See ``docs/distributed.md`` for the state
    machine.
  * ``StragglerTracker`` — per-rank step-time EWMA; a rank is a
    straggler when its EWMA exceeds ``slack`` × the median EWMA of
    the other ranks (leave-one-out, so it can't shift its own
    baseline).
  * ``StragglerSupervisor`` — detection → response: after ``patience``
    consecutive straggler verdicts it raises ``StragglerEvicted`` to
    abort the attempt.
  * ``RestartPolicy.run(attempt)`` — bounded-restart supervisor with
    exponential backoff; the driver resumes from the latest committed
    checkpoint on each attempt. ``StragglerEvicted`` aborts add the
    rank to ``RestartPolicy.excluded_ranks`` and restart immediately
    (no backoff, no budget slot); the attempt function reads the
    excluded-rank list on entry and reshards around the survivors.
"""
from repro.dist import compat as _compat  # noqa: F401  (installs jax shims)
