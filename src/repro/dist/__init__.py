"""Distributed training layer: sharding rules + fault tolerance.

``repro.dist.sharding`` holds the production partition-spec rules that
train.py / dryrun.py / serve paths all share. Specs are assigned by
parameter *name* over the abstract param pytree and then repaired
against the concrete mesh by :func:`sharding.fit_spec`, so one rule
table covers every registry architecture at every mesh size.

Sharding rule table (tensor → mesh axis placement):

  ===========================  ==========================  ============
  tensor                       shape                       spec
  ===========================  ==========================  ============
  embed table                  [V, d]                      ("model", -)
  attn q/k/v kernel            [np, d, H*hd]               (-, -, "model")
  attn o kernel                [np, H*hd, d]               (-, "model", -)
  mlp up/gate kernel           [np, d, ff]                 (-, -, "model")
  mlp down kernel              [np, ff, d]                 (-, "model", -)
  MoE expert gate/up           [np, E, d, ff]              (-, "model", -, -)
  MoE expert down              [np, E, ff, d]              (-, "model", -, -)
  ssm in_proj kernel           [np, d, X]                  (-, -, "model")
  ssm out_proj kernel          [np, di, d]                 (-, "model", -)
  norms / biases / router      any                         replicated
  batch inputs                 [B, ...]                    (dp, -, ...)
  KV cache k/v                 [np, B, T, KV, hd]          (-, dp, -, "model", -)
    (seq_shard=True moves "model" to the T dim for long decode)
  paged KV pool k/v            [np, NB, bs, KV, hd]        (-, -, -, "model", -)
    (paged=True: page axis replicated — block tables index the
     pool globally, so dp-sharding pages would make every gather
     a collective; block tables themselves are replicated)
  swap-staged KV pages         [np, n, bs, KV, hd]         (-, -, -, "model", -)
  swap-staged ssm state row    [np, H, N, P]               (-, "model", -, -)
  swap-staged conv row         [np, K-1, C]                (-, -, "model")
    (``swap_shardings``: host-staged swap-preemption bundles land
     pre-sharded like the pool they scatter into)
  ===========================  ==========================  ============

``dp`` is the data-parallel axis group — ``("pod", "data")`` on the
multi-pod mesh, ``"data"`` otherwise. Any placement whose dim is not
divisible by the mesh axis size is relocated by ``fit_spec`` to the
nearest divisible free dim (ties prefer the later dim), falling back to
replication when no dim is legal.

``repro.dist.fault`` implements the file-based fault-tolerance
protocol used by the training driver:

  * ``Heartbeat`` — each rank touches ``<dir>/rank_<r>`` at most every
    ``interval_s`` seconds; the file mtime IS the liveness signal (no
    server, works on any shared filesystem).
  * ``HeartbeatMonitor.dead_ranks()`` — ranks whose heartbeat file
    mtime is older than ``timeout_s``.
  * ``StragglerTracker`` — per-rank step-time EWMA; a rank is a
    straggler when its EWMA exceeds ``slack`` × the median EWMA of
    the other ranks (leave-one-out, so it can't shift its own
    baseline).
  * ``StragglerSupervisor`` — detection → response: after ``patience``
    consecutive straggler verdicts it raises ``StragglerEvicted`` to
    abort the attempt.
  * ``RestartPolicy.run(attempt)`` — bounded-restart supervisor with
    exponential backoff; the driver resumes from the latest committed
    checkpoint on each attempt. ``StragglerEvicted`` aborts add the
    rank to ``RestartPolicy.excluded_ranks`` and restart immediately
    (no backoff, no budget slot); the attempt function reads the
    excluded-rank list on entry and reshards around the survivors.
"""
from repro.dist import compat as _compat  # noqa: F401  (installs jax shims)
