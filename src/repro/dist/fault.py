"""File-based fault tolerance: heartbeats, stragglers, bounded restart.

The protocol needs nothing but a shared filesystem (the checkpoint
directory): each rank touches ``<dir>/rank_<r>``; a monitor reads the
mtimes. See the module docstring of ``repro.dist`` for the full
contract.
"""
from __future__ import annotations

import dataclasses
import os
import statistics
import time
from typing import Callable, Dict, List, Optional, Sequence

_PREFIX = "rank_"


class Heartbeat:
    """One rank's liveness signal: touch ``<dir>/rank_<r>`` on beat().

    ``interval_s`` throttles filesystem traffic from the train loop —
    ``beat()`` is a no-op until the interval has elapsed (``force=True``
    bypasses the throttle, e.g. the first beat after (re)start).
    """

    def __init__(self, hb_dir: str, rank: int, interval_s: float = 5.0):
        self.hb_dir = hb_dir
        self.rank = rank
        self.interval_s = interval_s
        self.path = os.path.join(hb_dir, f"{_PREFIX}{rank:05d}")
        self._last = 0.0

    def beat(self, *, force: bool = False) -> bool:
        now = time.time()
        if not force and now - self._last < self.interval_s:
            return False
        os.makedirs(self.hb_dir, exist_ok=True)
        with open(self.path, "w") as f:
            f.write(str(now))
        self._last = now
        return True


class HeartbeatMonitor:
    """Reads every rank's heartbeat mtime; stale ⇒ dead.

    Mtimes are compared against the monitor's ``time.time()``. On a
    network filesystem whose server clock is skewed from the monitor
    host, pass an explicit ``now`` to ``dead_ranks`` (e.g. the mtime
    of a file the monitor itself just touched on the same filesystem)
    so both sides of the comparison share one clock.
    """

    def __init__(self, hb_dir: str, timeout_s: float = 60.0):
        self.hb_dir = hb_dir
        self.timeout_s = timeout_s

    def last_seen(self) -> Dict[int, float]:
        """rank → heartbeat file mtime (empty when no dir/beats yet)."""
        out: Dict[int, float] = {}
        if not os.path.isdir(self.hb_dir):
            return out
        for name in os.listdir(self.hb_dir):
            if not name.startswith(_PREFIX):
                continue
            try:
                rank = int(name[len(_PREFIX):])
                out[rank] = os.path.getmtime(os.path.join(self.hb_dir, name))
            except (ValueError, OSError):
                continue  # foreign file, or beat racing the scan
        return out

    def dead_ranks(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return sorted(
            r for r, t in self.last_seen().items() if now - t > self.timeout_s
        )


class StragglerTracker:
    """Per-rank step-time EWMA; a rank is a straggler when its EWMA
    exceeds ``slack`` × the median EWMA of the *other* ranks.

    The leave-one-out median keeps a slow rank from shifting the
    baseline it is judged against (decisive at 2-3 ranks, where a
    fleet-wide median would absorb the outlier). Ranks with fewer than
    ``min_records`` observations are not judged (warmup/compile steps).
    """

    def __init__(self, slack: float = 2.0, alpha: float = 0.2, min_records: int = 3):
        self.slack = slack
        self.alpha = alpha
        self.min_records = min_records
        self._ewma: Dict[int, float] = {}
        self._n: Dict[int, int] = {}

    def record(self, rank: int, step_time_s: float) -> None:
        prev = self._ewma.get(rank)
        self._ewma[rank] = (
            step_time_s
            if prev is None
            else (1.0 - self.alpha) * prev + self.alpha * step_time_s
        )
        self._n[rank] = self._n.get(rank, 0) + 1

    def ewma(self, rank: int) -> Optional[float]:
        return self._ewma.get(rank)

    def forget(self, rank: int) -> None:
        """Drop a rank's history (evicted ranks must not keep inflating
        the leave-one-out baseline the survivors are judged against)."""
        self._ewma.pop(rank, None)
        self._n.pop(rank, None)

    def stragglers(self) -> List[int]:
        judged = {
            r: t
            for r, t in self._ewma.items()
            if self._n.get(r, 0) >= self.min_records
        }
        if len(judged) < 2:
            return []  # a lone rank is its own baseline
        out = []
        for r, t in judged.items():
            # leave-one-out baseline: a slow rank must not shift the
            # median it is judged against (matters most at 2-3 ranks)
            others = [v for q, v in judged.items() if q != r]
            if t > self.slack * statistics.median(others):
                out.append(r)
        return sorted(out)


class StragglerEvicted(RuntimeError):
    """Abort signal: a persistently slow rank must be resharded around.

    Raised from inside a training attempt (by
    :class:`StragglerSupervisor`); :meth:`RestartPolicy.run` catches it,
    records the rank on its excluded-rank list, and restarts the attempt
    immediately — the attempt function re-reads
    ``RestartPolicy.excluded_ranks`` and builds its mesh/data split
    around the survivors.
    """

    def __init__(self, rank: int, ewma_s: float, baseline_s: float):
        super().__init__(
            f"rank {rank} straggling (EWMA {ewma_s:.3f}s vs baseline "
            f"{baseline_s:.3f}s) — evicting for reshard"
        )
        self.rank = rank
        self.ewma_s = ewma_s
        self.baseline_s = baseline_s


class StragglerSupervisor:
    """Detection → response: turns :class:`StragglerTracker` verdicts
    into :class:`StragglerEvicted` aborts.

    A rank is evicted only after it has been flagged on ``patience``
    *consecutive* checks (one transient slow step — GC, checkpoint
    flush, preemption notice — must not shrink the fleet), and never if
    it is already on the caller's excluded list.
    """

    def __init__(
        self, tracker: Optional[StragglerTracker] = None, patience: int = 3
    ):
        self.tracker = tracker if tracker is not None else StragglerTracker()
        self.patience = patience
        self._streak: Dict[int, int] = {}

    def record(self, rank: int, step_time_s: float) -> None:
        self.tracker.record(rank, step_time_s)

    def check(self, excluded: Sequence[int] = ()) -> None:
        """Raise :class:`StragglerEvicted` for the worst persistent
        straggler, if any. Call once per step after ``record``."""
        # Excluded ranks must not linger in the tracker: a stale slow
        # EWMA would inflate the median baseline and mask real
        # stragglers among the survivors.
        for r in excluded:
            self.tracker.forget(r)
            self._streak.pop(r, None)
        flagged = self.tracker.stragglers()
        for r in list(self._streak):
            if r not in flagged:
                self._streak.pop(r)
        worst: Optional[int] = None
        for r in flagged:
            self._streak[r] = self._streak.get(r, 0) + 1
            if self._streak[r] >= self.patience:
                if worst is None or self.tracker.ewma(r) > self.tracker.ewma(worst):
                    worst = r
        if worst is not None:
            judged = {
                q: t for q, t in self.tracker._ewma.items() if q != worst
            }
            baseline = statistics.median(judged.values()) if judged else 0.0
            ewma = self.tracker.ewma(worst)
            self._streak.pop(worst, None)
            self.tracker.forget(worst)
            raise StragglerEvicted(worst, ewma, baseline)


@dataclasses.dataclass
class RestartPolicy:
    """Bounded-restart supervisor with exponential backoff.

    ``run(attempt)`` calls ``attempt(attempt_idx)`` until it returns;
    on an exception it backs off and retries up to ``max_restarts``
    times, then re-raises. The driver's attempt function restores from
    the latest committed checkpoint, so each retry resumes rather than
    recomputes.

    Straggler response: a :class:`StragglerEvicted` raised from inside
    the attempt adds its rank to ``excluded_ranks`` and restarts
    *immediately* (no backoff — the fleet just shrank, there is nothing
    to wait out) without consuming a restart budget slot. The attempt
    function reads ``excluded_ranks`` on entry to reshard around the
    evicted ranks. Evictions are bounded by ``max_evictions`` (a fleet
    cannot shrink forever), and a rank that is already excluded cannot
    be evicted twice — either overrun degrades the signal to an
    ordinary bounded restart (backoff included), so ``run`` always
    terminates.
    """

    max_restarts: int = 3
    backoff_s: float = 1.0
    backoff_mult: float = 2.0
    max_evictions: int = 16
    excluded_ranks: List[int] = dataclasses.field(default_factory=list)

    def run(
        self,
        attempt: Callable[[int], object],
        *,
        on_restart: Optional[Callable[[int, BaseException], None]] = None,
        on_evict: Optional[Callable[[int, "StragglerEvicted"], None]] = None,
    ):
        delay = self.backoff_s
        restarts = 0
        evictions = 0
        i = 0
        while True:
            try:
                return attempt(i)
            except StragglerEvicted as e:
                fresh = e.rank not in self.excluded_ranks
                if fresh:
                    self.excluded_ranks.append(e.rank)
                    if on_evict is not None:
                        on_evict(e.rank, e)
                if fresh and evictions < self.max_evictions:
                    evictions += 1
                else:
                    # double eviction (supervisor misuse) or an eviction
                    # storm: degrade to an ordinary bounded restart so
                    # the loop stays finite and backs off.
                    if restarts >= self.max_restarts:
                        raise
                    if on_restart is not None:
                        on_restart(restarts, e)
                    time.sleep(delay)
                    delay *= self.backoff_mult
                    restarts += 1
            except Exception as e:
                if restarts >= self.max_restarts:
                    raise
                if on_restart is not None:
                    on_restart(restarts, e)
                time.sleep(delay)
                delay *= self.backoff_mult
                restarts += 1
            i += 1
