"""File-based fault tolerance: heartbeats, stragglers, bounded restart.

The protocol needs nothing but a shared filesystem (the checkpoint
directory): each rank touches ``<dir>/rank_<r>``; a monitor reads the
mtimes. See the module docstring of ``repro.dist`` for the full
contract.
"""
from __future__ import annotations

import dataclasses
import os
import statistics
import time
from typing import Callable, Dict, List, Optional

_PREFIX = "rank_"


class Heartbeat:
    """One rank's liveness signal: touch ``<dir>/rank_<r>`` on beat().

    ``interval_s`` throttles filesystem traffic from the train loop —
    ``beat()`` is a no-op until the interval has elapsed (``force=True``
    bypasses the throttle, e.g. the first beat after (re)start).
    """

    def __init__(self, hb_dir: str, rank: int, interval_s: float = 5.0):
        self.hb_dir = hb_dir
        self.rank = rank
        self.interval_s = interval_s
        self.path = os.path.join(hb_dir, f"{_PREFIX}{rank:05d}")
        self._last = 0.0

    def beat(self, *, force: bool = False) -> bool:
        now = time.time()
        if not force and now - self._last < self.interval_s:
            return False
        os.makedirs(self.hb_dir, exist_ok=True)
        with open(self.path, "w") as f:
            f.write(str(now))
        self._last = now
        return True


class HeartbeatMonitor:
    """Reads every rank's heartbeat mtime; stale ⇒ dead.

    Mtimes are compared against the monitor's ``time.time()``. On a
    network filesystem whose server clock is skewed from the monitor
    host, pass an explicit ``now`` to ``dead_ranks`` (e.g. the mtime
    of a file the monitor itself just touched on the same filesystem)
    so both sides of the comparison share one clock.
    """

    def __init__(self, hb_dir: str, timeout_s: float = 60.0):
        self.hb_dir = hb_dir
        self.timeout_s = timeout_s

    def last_seen(self) -> Dict[int, float]:
        """rank → heartbeat file mtime (empty when no dir/beats yet)."""
        out: Dict[int, float] = {}
        if not os.path.isdir(self.hb_dir):
            return out
        for name in os.listdir(self.hb_dir):
            if not name.startswith(_PREFIX):
                continue
            try:
                rank = int(name[len(_PREFIX):])
                out[rank] = os.path.getmtime(os.path.join(self.hb_dir, name))
            except (ValueError, OSError):
                continue  # foreign file, or beat racing the scan
        return out

    def dead_ranks(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return sorted(
            r for r, t in self.last_seen().items() if now - t > self.timeout_s
        )


class StragglerTracker:
    """Per-rank step-time EWMA; a rank is a straggler when its EWMA
    exceeds ``slack`` × the median EWMA of the *other* ranks.

    The leave-one-out median keeps a slow rank from shifting the
    baseline it is judged against (decisive at 2-3 ranks, where a
    fleet-wide median would absorb the outlier). Ranks with fewer than
    ``min_records`` observations are not judged (warmup/compile steps).
    """

    def __init__(self, slack: float = 2.0, alpha: float = 0.2, min_records: int = 3):
        self.slack = slack
        self.alpha = alpha
        self.min_records = min_records
        self._ewma: Dict[int, float] = {}
        self._n: Dict[int, int] = {}

    def record(self, rank: int, step_time_s: float) -> None:
        prev = self._ewma.get(rank)
        self._ewma[rank] = (
            step_time_s
            if prev is None
            else (1.0 - self.alpha) * prev + self.alpha * step_time_s
        )
        self._n[rank] = self._n.get(rank, 0) + 1

    def ewma(self, rank: int) -> Optional[float]:
        return self._ewma.get(rank)

    def stragglers(self) -> List[int]:
        judged = {
            r: t
            for r, t in self._ewma.items()
            if self._n.get(r, 0) >= self.min_records
        }
        if len(judged) < 2:
            return []  # a lone rank is its own baseline
        out = []
        for r, t in judged.items():
            # leave-one-out baseline: a slow rank must not shift the
            # median it is judged against (matters most at 2-3 ranks)
            others = [v for q, v in judged.items() if q != r]
            if t > self.slack * statistics.median(others):
                out.append(r)
        return sorted(out)


@dataclasses.dataclass
class RestartPolicy:
    """Bounded-restart supervisor with exponential backoff.

    ``run(attempt)`` calls ``attempt(attempt_idx)`` until it returns;
    on an exception it backs off and retries up to ``max_restarts``
    times, then re-raises. The driver's attempt function restores from
    the latest committed checkpoint, so each retry resumes rather than
    recomputes.
    """

    max_restarts: int = 3
    backoff_s: float = 1.0
    backoff_mult: float = 2.0

    def run(
        self,
        attempt: Callable[[int], object],
        *,
        on_restart: Optional[Callable[[int, BaseException], None]] = None,
    ):
        delay = self.backoff_s
        for i in range(self.max_restarts + 1):
            try:
                return attempt(i)
            except Exception as e:
                if i >= self.max_restarts:
                    raise
                if on_restart is not None:
                    on_restart(i, e)
                time.sleep(delay)
                delay *= self.backoff_mult
