"""File-based fault tolerance: heartbeats, membership, bounded restart.

The protocol needs nothing but a shared filesystem (the checkpoint
directory): each rank touches ``<dir>/rank_<r>``; a monitor reads the
mtimes. On top of the per-rank signals sits the **rank-complete**
supervisor layer:

* every rank beats (not just rank 0); :class:`HeartbeatMonitor`
  aggregates all of them against its own filesystem-clock sentinel;
* :class:`FleetSupervisor` turns stale heartbeats into *membership
  epochs* — an atomically-published ``membership.json`` that names the
  active and evicted ranks. Evicting and un-evicting both bump the
  epoch; workers that observe a new epoch abort their attempt with
  :class:`MembershipChanged` and reshard around the new active set;
* a recovered rank **rejoins**: it touches its heartbeat again, files a
  rejoin request, and waits; the supervisor un-evicts it on the next
  poll, the epoch bumps, and every rank (the rejoiner included)
  restarts on the grown mesh from the last committed checkpoint.

See the module docstring of ``repro.dist`` for the full contract.
"""
from __future__ import annotations

from collections.abc import Callable, Sequence
import dataclasses
import json
import os
import statistics
import time

_PREFIX = "rank_"
_SENTINEL = "monitor.sentinel"


class Heartbeat:
    """One rank's liveness signal: touch ``<dir>/rank_<r>`` on beat().

    ``interval_s`` throttles filesystem traffic from the train loop —
    ``beat()`` is a no-op until the interval has elapsed (``force=True``
    bypasses the throttle, e.g. the first beat after (re)start).
    """

    def __init__(self, hb_dir: str, rank: int, interval_s: float = 5.0):
        self.hb_dir = hb_dir
        self.rank = rank
        self.interval_s = interval_s
        self.path = os.path.join(hb_dir, f"{_PREFIX}{rank:05d}")
        self._last = 0.0

    def beat(self, *, force: bool = False) -> bool:
        now = time.time()
        if not force and now - self._last < self.interval_s:
            return False
        os.makedirs(self.hb_dir, exist_ok=True)
        with open(self.path, "w") as f:
            f.write(str(now))
        self._last = now
        return True


class HeartbeatThread:
    """Background beater: keeps a rank's heartbeat fresh through long
    main-thread stalls — multi-second XLA compiles, blocking checkpoint
    commits, restore replays. The heartbeat then signals *process
    liveness*, which is the contract the eviction protocol wants: a
    SIGKILL takes the thread down with the process (detected within
    ``timeout_s``), while a rank that is merely busy compiling is NOT
    falsely evicted. Slow-but-alive ranks are the straggler layer's
    job, not the heartbeat's.

    Daemon thread; ``stop()`` is graceful but optional.
    """

    def __init__(self, hb: Heartbeat):
        import threading

        self.hb = hb
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        while not self._stop.is_set():
            self.hb.beat(force=True)
            self._stop.wait(self.hb.interval_s)

    def start(self) -> "HeartbeatThread":
        self.hb.beat(force=True)  # visible before the thread spins up
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2 * self.hb.interval_s + 1.0)


class HeartbeatMonitor:
    """Reads every rank's heartbeat mtime; stale ⇒ dead.

    Heartbeat mtimes are stamped by the *filesystem* (an NFS server's
    clock), so comparing them against the monitor host's ``time.time()``
    invites clock skew: a monitor running ahead of the file server
    falsely evicts live ranks, one running behind never evicts dead
    ones. By default ``dead_ranks`` therefore touches its **own
    sentinel file** on the same filesystem and uses that file's mtime as
    ``now`` — both sides of the comparison then share the one clock that
    stamped them. Pass an explicit ``now`` to override (tests, or a
    caller that already holds a same-filesystem timestamp).
    """

    def __init__(self, hb_dir: str, timeout_s: float = 60.0):
        self.hb_dir = hb_dir
        self.timeout_s = timeout_s
        self._sentinel = os.path.join(hb_dir, _SENTINEL)

    def last_seen(self) -> dict[int, float]:
        """rank → heartbeat file mtime (empty when no dir/beats yet)."""
        out: dict[int, float] = {}
        if not os.path.isdir(self.hb_dir):
            return out
        for name in os.listdir(self.hb_dir):
            if not name.startswith(_PREFIX):
                continue
            try:
                rank = int(name[len(_PREFIX):])
                out[rank] = os.path.getmtime(os.path.join(self.hb_dir, name))
            except (ValueError, OSError):
                continue  # foreign file, or beat racing the scan
        return out

    def filesystem_now(self) -> float:
        """Touch the monitor's sentinel; return its mtime — a timestamp
        from the same clock that stamps the heartbeat files."""
        os.makedirs(self.hb_dir, exist_ok=True)
        with open(self._sentinel, "w") as f:
            f.write("monitor clock sentinel\n")
        return os.path.getmtime(self._sentinel)

    def dead_ranks(self, now: float | None = None) -> list[int]:
        seen = self.last_seen()
        if not seen:
            return []
        if now is None:
            now = self.filesystem_now()
        return sorted(r for r, t in seen.items() if now - t > self.timeout_s)


# ----------------------------------------------------------------------
# fleet membership: rank-complete eviction + un-evict/rejoin
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Membership:
    """One epoch of the fleet view: who is in, who is out.

    Immutable and totally ordered by ``epoch``; workers compare the
    epoch they trained under against the published one and reshard on
    any change (grow or shrink — both are just "the mesh is different
    now").
    """

    epoch: int
    active: tuple[int, ...]
    evicted: tuple[int, ...]

    @property
    def leader(self) -> int:
        """The supervisor seat: lowest active rank (fails over
        deterministically when the leader itself is evicted)."""
        return min(self.active) if self.active else -1

    def evict(self, ranks: Sequence[int]) -> "Membership":
        gone = [r for r in self.active if r in set(ranks)]
        if not gone:
            return self
        return Membership(
            epoch=self.epoch + 1,
            active=tuple(r for r in self.active if r not in set(gone)),
            evicted=tuple(sorted(set(self.evicted) | set(gone))),
        )

    def unevict(self, ranks: Sequence[int]) -> "Membership":
        back = [r for r in self.evicted if r in set(ranks)]
        if not back:
            return self
        return Membership(
            epoch=self.epoch + 1,
            active=tuple(sorted(set(self.active) | set(back))),
            evicted=tuple(r for r in self.evicted if r not in set(back)),
        )


class MembershipChanged(RuntimeError):
    """Abort signal: the fleet membership epoch moved under this attempt.

    Raised by workers when the published :class:`Membership` epoch
    differs from the one the attempt started on (a rank was evicted, or
    an evicted rank rejoined). :meth:`RestartPolicy.run` treats it like
    an eviction: restart *immediately* (no backoff, no restart-budget
    slot — the fleet changed shape, nothing is broken) so the attempt
    function re-reads the membership and reshards.
    """

    def __init__(self, membership: Membership):
        super().__init__(
            f"membership epoch {membership.epoch}: "
            f"active={list(membership.active)} evicted={list(membership.evicted)}"
        )
        self.membership = membership


class MembershipView:
    """The atomically-published fleet view (``<dir>/membership.json``).

    Readers never block and never observe a torn file (tmp + rename);
    concurrent supervisor writes are last-write-wins, which is safe
    because every would-be writer derives the same decision from the
    same heartbeat files — see :class:`FleetSupervisor`.
    """

    def __init__(self, coord_dir: str, world_size: int):
        self.path = os.path.join(coord_dir, "membership.json")
        self.world_size = world_size

    def initial(self) -> Membership:
        return Membership(0, tuple(range(self.world_size)), ())

    def read(self) -> Membership:
        try:
            with open(self.path) as f:
                obj = json.load(f)
        except (OSError, ValueError):
            return self.initial()  # not yet published (or mid-rename)
        return Membership(
            int(obj["epoch"]),
            tuple(obj["active"]),
            tuple(obj["evicted"]),
        )

    def write(self, m: Membership) -> None:
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(
                {
                    "epoch": m.epoch,
                    "active": list(m.active),
                    "evicted": list(m.evicted),
                    "world_size": self.world_size,
                },
                f,
            )
        os.replace(tmp, self.path)


class FleetSupervisor:
    """Rank-complete fault supervision: every rank beats, the supervisor
    aggregates, eviction AND rejoin decisions cover any rank.

    One ``poll()`` pass:

    1. stale heartbeats among the active set ⇒ evict (epoch bump) —
       unless the rank left a ``<coord>/done/rank_<r>*`` completion
       marker (orderly leave, see :meth:`completed_ranks`);
    2. rejoin requests (``<coord>/rejoin/rank_<r>``) from evicted ranks
       whose heartbeat is *fresh again* ⇒ un-evict (epoch bump) and
       clear the request.

    The supervisor seat is the lowest active rank, but the decision
    procedure is a pure function of the shared files, so when the
    leader itself dies the next rank takes over by simply running
    ``poll()`` — duplicate writers converge on the same content
    (last-write-wins on an atomic rename).
    """

    def __init__(
        self,
        coord_dir: str,
        world_size: int,
        *,
        timeout_s: float = 60.0,
        monitor: HeartbeatMonitor | None = None,
    ):
        self.coord_dir = coord_dir
        self.view = MembershipView(coord_dir, world_size)
        self.monitor = (
            monitor
            if monitor is not None
            else HeartbeatMonitor(os.path.join(coord_dir, "hb"), timeout_s)
        )
        self._rejoin_dir = os.path.join(coord_dir, "rejoin")

    # -- worker-side rejoin request ------------------------------------

    def request_rejoin(self, rank: int) -> None:
        os.makedirs(self._rejoin_dir, exist_ok=True)
        with open(os.path.join(self._rejoin_dir, f"{_PREFIX}{rank:05d}"), "w") as f:
            f.write(str(os.getpid()))

    def _rejoin_requests(self) -> list[int]:
        if not os.path.isdir(self._rejoin_dir):
            return []
        out = []
        for name in os.listdir(self._rejoin_dir):
            if name.startswith(_PREFIX):
                try:
                    out.append(int(name[len(_PREFIX):]))
                except ValueError:
                    continue
        return sorted(out)

    def _clear_rejoin(self, rank: int) -> None:
        try:
            os.remove(os.path.join(self._rejoin_dir, f"{_PREFIX}{rank:05d}"))
        except OSError:
            pass

    # -- worker-side orderly completion --------------------------------

    def completed_ranks(self) -> list[int]:
        """Ranks that finished the job and exited on purpose: a
        ``<coord>/done/rank_<r>*`` marker (written by the driver right
        before exit). Their heartbeats go silent exactly like a dead
        rank's, but completion is an orderly leave, NOT a fault — the
        supervisor exempts them from eviction so ranks that finish
        first don't trigger a reshard storm while stragglers drain."""
        done_dir = os.path.join(self.coord_dir, "done")
        if not os.path.isdir(done_dir):
            return []
        out = set()
        for name in os.listdir(done_dir):
            if name.startswith(_PREFIX):
                try:
                    out.add(int(name[len(_PREFIX):].split(".")[0]))
                except ValueError:
                    continue
        return sorted(out)

    # -- supervisor-side decision pass ---------------------------------

    def poll(self) -> Membership:
        """One supervision pass; returns the (possibly bumped) view."""
        m = self.view.read()
        now = self.monitor.filesystem_now()
        seen = self.monitor.last_seen()
        done = set(self.completed_ranks())

        # 1. eviction: active ranks whose beat is stale — or missing
        # entirely (initialize() guarantees every rank beat once, so a
        # missing file means the rank died before this poll ever saw
        # it). Ranks that COMPLETED are silent too, but on purpose —
        # never evicted.
        dead = [
            r
            for r in m.active
            if r not in done
            and (r not in seen or now - seen[r] > self.monitor.timeout_s)
        ]
        m2 = m.evict(dead)

        # 2. rejoin: an evicted rank asking back in must prove liveness
        # with a *fresh* heartbeat, else a stale request file from a
        # rank that died again would flap the membership.
        back = [
            r
            for r in self._rejoin_requests()
            if r in m2.evicted
            and r in seen
            and now - seen[r] <= self.monitor.timeout_s
        ]
        m3 = m2.unevict(back)
        for r in back:
            self._clear_rejoin(r)

        if m3.epoch != m.epoch:
            self.view.write(m3)
            return m3
        return m

    def should_poll(self, rank: int, m: Membership | None = None) -> bool:
        """Does ``rank`` currently hold (or inherit) the supervisor seat?

        The leader polls; any other active rank takes over only when the
        leader's own heartbeat has gone stale — otherwise exactly one
        writer runs per pass in the steady state.
        """
        m = self.view.read() if m is None else m
        if rank not in m.active:
            return False
        done = set(self.completed_ranks())
        # seat order skips completed ranks: a finished leader has
        # exited, so the lowest still-running active rank inherits
        live = [r for r in m.active if r not in done]
        if not live:
            return False
        lead = min(live)
        if rank == lead:
            return True
        others = [r for r in live if r != lead]
        if not others:
            return False
        seen = self.monitor.last_seen()
        if lead not in seen:
            return rank == min(others)
        now = self.monitor.filesystem_now()
        if now - seen[lead] > self.monitor.timeout_s:
            return rank == min(others)
        return False

    def check_epoch(self, epoch: int) -> Membership:
        """Worker-side guard: raise :class:`MembershipChanged` when the
        published epoch differs from the one this attempt trains on."""
        m = self.view.read()
        if m.epoch != epoch:
            raise MembershipChanged(m)
        return m

    def wait_active(self, rank: int, *, timeout_s: float, poll_s: float = 0.05) -> Membership:
        """Block until ``rank`` is in the active set (rejoin handshake)."""
        deadline = time.monotonic() + timeout_s
        while True:
            m = self.view.read()
            if rank in m.active:
                return m
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"rank {rank} never re-admitted (view: {m})"
                )
            time.sleep(poll_s)


class StragglerTracker:
    """Per-rank step-time EWMA; a rank is a straggler when its EWMA
    exceeds ``slack`` × the median EWMA of the *other* ranks.

    The leave-one-out median keeps a slow rank from shifting the
    baseline it is judged against (decisive at 2-3 ranks, where a
    fleet-wide median would absorb the outlier). Ranks with fewer than
    ``min_records`` observations are not judged (warmup/compile steps).
    """

    def __init__(self, slack: float = 2.0, alpha: float = 0.2, min_records: int = 3):
        self.slack = slack
        self.alpha = alpha
        self.min_records = min_records
        self._ewma: dict[int, float] = {}
        self._n: dict[int, int] = {}

    def record(self, rank: int, step_time_s: float) -> None:
        prev = self._ewma.get(rank)
        self._ewma[rank] = (
            step_time_s
            if prev is None
            else (1.0 - self.alpha) * prev + self.alpha * step_time_s
        )
        self._n[rank] = self._n.get(rank, 0) + 1

    def ewma(self, rank: int) -> float | None:
        return self._ewma.get(rank)

    def forget(self, rank: int) -> None:
        """Drop a rank's history (evicted ranks must not keep inflating
        the leave-one-out baseline the survivors are judged against)."""
        self._ewma.pop(rank, None)
        self._n.pop(rank, None)

    def stragglers(self) -> list[int]:
        judged = {
            r: t
            for r, t in self._ewma.items()
            if self._n.get(r, 0) >= self.min_records
        }
        if len(judged) < 2:
            return []  # a lone rank is its own baseline
        out = []
        for r, t in judged.items():
            # leave-one-out baseline: a slow rank must not shift the
            # median it is judged against (matters most at 2-3 ranks)
            others = [v for q, v in judged.items() if q != r]
            if t > self.slack * statistics.median(others):
                out.append(r)
        return sorted(out)


class StragglerEvicted(RuntimeError):
    """Abort signal: a persistently slow rank must be resharded around.

    Raised from inside a training attempt (by
    :class:`StragglerSupervisor`); :meth:`RestartPolicy.run` catches it,
    records the rank on its excluded-rank list, and restarts the attempt
    immediately — the attempt function re-reads
    ``RestartPolicy.excluded_ranks`` and builds its mesh/data split
    around the survivors.
    """

    def __init__(self, rank: int, ewma_s: float, baseline_s: float):
        super().__init__(
            f"rank {rank} straggling (EWMA {ewma_s:.3f}s vs baseline "
            f"{baseline_s:.3f}s) — evicting for reshard"
        )
        self.rank = rank
        self.ewma_s = ewma_s
        self.baseline_s = baseline_s


class StragglerSupervisor:
    """Detection → response: turns :class:`StragglerTracker` verdicts
    into :class:`StragglerEvicted` aborts.

    A rank is evicted only after it has been flagged on ``patience``
    *consecutive* checks (one transient slow step — GC, checkpoint
    flush, preemption notice — must not shrink the fleet), and never if
    it is already on the caller's excluded list.
    """

    def __init__(
        self, tracker: StragglerTracker | None = None, patience: int = 3
    ):
        self.tracker = tracker if tracker is not None else StragglerTracker()
        self.patience = patience
        self._streak: dict[int, int] = {}

    def record(self, rank: int, step_time_s: float) -> None:
        self.tracker.record(rank, step_time_s)

    def check(self, excluded: Sequence[int] = ()) -> None:
        """Raise :class:`StragglerEvicted` for the worst persistent
        straggler, if any. Call once per step after ``record``."""
        # Excluded ranks must not linger in the tracker: a stale slow
        # EWMA would inflate the median baseline and mask real
        # stragglers among the survivors.
        for r in excluded:
            self.tracker.forget(r)
            self._streak.pop(r, None)
        flagged = self.tracker.stragglers()
        for r in list(self._streak):
            if r not in flagged:
                self._streak.pop(r)
        worst: int | None = None
        for r in flagged:
            self._streak[r] = self._streak.get(r, 0) + 1
            if self._streak[r] >= self.patience:
                if worst is None or self.tracker.ewma(r) > self.tracker.ewma(worst):
                    worst = r
        if worst is not None:
            judged = {
                q: t for q, t in self.tracker._ewma.items() if q != worst
            }
            baseline = statistics.median(judged.values()) if judged else 0.0
            ewma = self.tracker.ewma(worst)
            self._streak.pop(worst, None)
            self.tracker.forget(worst)
            raise StragglerEvicted(worst, ewma, baseline)


@dataclasses.dataclass
class RestartPolicy:
    """Bounded-restart supervisor with exponential backoff.

    ``run(attempt)`` calls ``attempt(attempt_idx)`` until it returns;
    on an exception it backs off and retries up to ``max_restarts``
    times, then re-raises. The driver's attempt function restores from
    the latest committed checkpoint, so each retry resumes rather than
    recomputes.

    Straggler response: a :class:`StragglerEvicted` raised from inside
    the attempt adds its rank to ``excluded_ranks`` and restarts
    *immediately* (no backoff — the fleet just shrank, there is nothing
    to wait out) without consuming a restart budget slot. The attempt
    function reads ``excluded_ranks`` on entry to reshard around the
    evicted ranks. Evictions are bounded by ``max_evictions`` (a fleet
    cannot shrink forever), and a rank that is already excluded cannot
    be evicted twice — either overrun degrades the signal to an
    ordinary bounded restart (backoff included), so ``run`` always
    terminates.

    Membership response: a :class:`MembershipChanged` raised from
    inside the attempt (the supervisor moved the fleet epoch — a rank
    died, or a recovered rank rejoined) also restarts immediately and
    budget-free, bounded by ``max_reshards``. The attempt function
    re-reads the published membership on entry. ``unexclude(rank)``
    re-admits a previously evicted straggler (the un-evict half of the
    rejoin protocol): the next attempt reshards *with* the rank again,
    and the rank becomes evictable afresh.
    """

    max_restarts: int = 3
    backoff_s: float = 1.0
    backoff_mult: float = 2.0
    max_evictions: int = 16
    max_reshards: int = 64
    excluded_ranks: list[int] = dataclasses.field(default_factory=list)

    def unexclude(self, rank: int) -> bool:
        """Re-admit an evicted rank (rejoin). Returns True if it was
        excluded. The rank regains a fresh eviction-budget slot: a
        recovered machine that degrades again must be evictable."""
        if rank in self.excluded_ranks:
            self.excluded_ranks.remove(rank)
            return True
        return False

    def run(
        self,
        attempt: Callable[[int], object],
        *,
        on_restart: Callable[[int, BaseException], None] | None = None,
        on_evict: Callable[[int, "StragglerEvicted"], None] | None = None,
        on_reshard: Callable[[Membership], None] | None = None,
    ):
        delay = self.backoff_s
        restarts = 0
        evictions = 0
        reshards = 0
        i = 0
        while True:
            try:
                return attempt(i)
            except MembershipChanged as e:
                if reshards >= self.max_reshards:
                    # a flapping fleet must not restart forever; degrade
                    # to the bounded-restart budget like eviction storms
                    if restarts >= self.max_restarts:
                        raise
                    if on_restart is not None:
                        on_restart(restarts, e)
                    time.sleep(delay)
                    delay *= self.backoff_mult
                    restarts += 1
                else:
                    reshards += 1
                    if on_reshard is not None:
                        on_reshard(e.membership)
            except StragglerEvicted as e:
                fresh = e.rank not in self.excluded_ranks
                if fresh:
                    self.excluded_ranks.append(e.rank)
                    if on_evict is not None:
                        on_evict(e.rank, e)
                if fresh and evictions < self.max_evictions:
                    evictions += 1
                else:
                    # double eviction (supervisor misuse) or an eviction
                    # storm: degrade to an ordinary bounded restart so
                    # the loop stays finite and backs off.
                    if restarts >= self.max_restarts:
                        raise
                    if on_restart is not None:
                        on_restart(restarts, e)
                    time.sleep(delay)
                    delay *= self.backoff_mult
                    restarts += 1
            except Exception as e:
                if restarts >= self.max_restarts:
                    raise
                if on_restart is not None:
                    on_restart(restarts, e)
                time.sleep(delay)
                delay *= self.backoff_mult
                restarts += 1
            i += 1
