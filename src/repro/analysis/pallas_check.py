"""Static Pallas kernel checks over :mod:`repro.kernels.specs` objects.

The kernels build their ``pl.pallas_call`` grids from the same
:class:`~repro.kernels.specs.KernelSpec` objects this module audits, so
the checks below hold for the launched kernels by construction:

* **in-bounds proof** — every BlockSpec index map is evaluated over the
  *full* grid (with representative scalar-prefetch arrays at their
  extreme legal values: the maps are monotone in the prefetch entries,
  so min/max candidates bound every legal launch) and each returned
  block index must address a real block of the operand.
* **divisibility** — operand shapes must be whole multiples of their
  block shapes, the contract ``docs/kernels.md`` states (wrappers pad
  before launching; a ragged operand would silently read Pallas'
  zero-fill).
* **VMEM footprint** — resident blocks are double-buffered on TPU, so
  the estimate is ``2 * Σ block_bytes + scratch``; it must fit the
  per-platform budget (:data:`VMEM_BUDGETS`).
* **traffic emulation** — the grid is swept sequentially (last axis
  fastest, TPU order) with revisit elision: an operand whose index map
  returns the same block on consecutive steps is fetched once. The
  per-operand totals are cross-checked against the named components of
  :func:`repro.core.flops.conv_backward_bytes_breakdown` — the bytes
  model that *routes* the engine (fused vs canonical) — so the numbers
  that pick the kernel are provably the numbers the kernel moves.
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from repro.analysis.report import ERROR, INFO, Report
from repro.core import flops as ftab
from repro.core.policy import SsPropPolicy
from repro.kernels import specs
from repro.kernels.specs import BlockSpecInfo, KernelSpec

#: double-buffered VMEM budget per platform, bytes.
VMEM_BUDGETS = {"tpu": 16 * 2**20, "interpret": 1 << 62}


def _nblocks(info: BlockSpecInfo) -> tuple:
    return tuple(
        -(-dim // blk)
        for dim, blk in zip(info.array_shape, info.block_shape, strict=True)
    )


def _eval_map(info: BlockSpecInfo, point, prefetch) -> tuple:
    args = point if prefetch is None else (*point, prefetch)
    return tuple(int(v) for v in info.index_map(*args))


# ----------------------------------------------------------------------
# structural checks
# ----------------------------------------------------------------------


def check_divisibility(report: Report, spec: KernelSpec) -> None:
    for info in (*spec.in_specs, *spec.out_specs):
        ragged = [
            (d, blk)
            for d, blk in zip(info.array_shape, info.block_shape, strict=True)
            if d % blk
        ]
        if ragged:
            report.add(
                "pallas",
                ERROR,
                f"{spec.name}/{info.name}",
                f"operand {info.array_shape} not divisible by block "
                f"{info.block_shape} (docs/kernels.md contract: wrappers "
                "pad before launch)",
                array_shape=list(info.array_shape),
                block_shape=list(info.block_shape),
            )


def check_in_bounds(
    report: Report,
    spec: KernelSpec,
    prefetch_candidates=(None,),
) -> None:
    """Prove every index map addresses a real block over the full grid."""
    for info in (*spec.in_specs, *spec.out_specs):
        limit = _nblocks(info)
        bad = None
        for prefetch in prefetch_candidates:
            for point in itertools.product(*(range(g) for g in spec.grid)):
                idx = _eval_map(info, point, prefetch)
                if any(not 0 <= v < lim for v, lim in zip(idx, limit, strict=True)):
                    bad = (point, idx)
                    break
            if bad:
                break
        if bad:
            report.add(
                "pallas",
                ERROR,
                f"{spec.name}/{info.name}",
                f"index map out of bounds at grid {bad[0]}: block index "
                f"{bad[1]}, valid < {limit}",
                grid_point=list(bad[0]),
                block_index=list(bad[1]),
                limit=list(limit),
            )
        else:
            report.add(
                "pallas",
                INFO,
                f"{spec.name}/{info.name}",
                f"in-bounds over {spec.grid_size} grid steps "
                f"x {len(prefetch_candidates)} prefetch candidate(s)",
                grid=list(spec.grid),
            )


def vmem_bytes(spec: KernelSpec) -> int:
    """Double-buffered resident-block + scratch VMEM estimate."""
    blocks = sum(
        i.block_elems * i.itemsize for i in (*spec.in_specs, *spec.out_specs)
    )
    scratch = sum(4 * math.prod(s) for s in spec.scratch)
    return 2 * blocks + scratch


def check_vmem(
    report: Report, spec: KernelSpec, *, platform: str = "tpu"
) -> None:
    budget = VMEM_BUDGETS[platform]
    used = vmem_bytes(spec)
    sev = ERROR if used > budget else INFO
    report.add(
        "pallas",
        sev,
        spec.name,
        f"VMEM estimate {used:,} B vs {platform} budget {budget:,} B",
        vmem_bytes=used,
        budget=budget,
        platform=platform,
    )


# ----------------------------------------------------------------------
# traffic emulation
# ----------------------------------------------------------------------


def emulate_traffic(spec: KernelSpec, prefetch=None) -> dict:
    """Per-operand element traffic under sequential-grid revisit elision.

    Sweeps the grid in TPU order (last axis fastest); each operand is
    (re)fetched — or each output block flushed — whenever its index map
    output differs from the previous step's.
    """
    totals = {}
    for info in (*spec.in_specs, *spec.out_specs):
        prev = None
        fetches = 0
        for point in itertools.product(*(range(g) for g in spec.grid)):
            idx = _eval_map(info, point, prefetch)
            if idx != prev:
                fetches += 1
                prev = idx
        totals[info.name] = fetches * info.block_elems
    return totals


# ----------------------------------------------------------------------
# per-site audits (fused conv + paged attention)
# ----------------------------------------------------------------------


def conv_fused_site_specs(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: SsPropPolicy,
    *,
    groups: int = 1,
):
    """The (dW, dX) fused kernel specs the engine would launch for one
    conv site at the auditor's stride-1 probe geometry, plus the
    balanced kept-block index array (sorted, groups covered evenly —
    what the engine's per-group top-k produces)."""
    bs = policy.block_size
    c_pad = c_out + (-c_out) % bs
    nb = c_pad // bs
    kb = policy.keep_count(c_out)
    bpg = nb // groups
    per_g = max(kb // groups, 1)
    idx = np.concatenate(
        [g * bpg + np.arange(per_g) for g in range(groups)]
    )[:kb].astype(np.int32)
    geom = dict(
        b=bt, h_pad=h_out + k - 1, w_pad=w_out + k - 1, groups=groups,
        cg=c_in // groups, h_out=h_out, w_out=w_out, c_pad=c_pad,
        kh_dim=k, kw_dim=k, stride=(1, 1), dilation=(1, 1), kb=kb,
        block_size=bs,
    )
    return (
        specs.conv_dw_fused_spec(**geom),
        specs.conv_dx_fused_spec(**geom),
        idx,
    )


def check_conv_fused_site(
    report: Report,
    site: str,
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: SsPropPolicy,
    *,
    groups: int = 1,
    platform: str = "tpu",
) -> None:
    """Full kernel audit of one fused conv site: bounds, VMEM, traffic.

    The traffic cross-check pins the fused kernel components of
    ``conv_backward_bytes_breakdown`` to the emulated grid: exact for
    every component except the dX cotangent stream, where the model
    ignores the ``clip``-at-border revisit elision and so upper-bounds
    the emulation by at most ``2*(K-1)^2`` collapsed fetches per
    (image, kept block).
    """
    dw_spec, dx_spec, idx = conv_fused_site_specs(
        bt, h_out, w_out, c_in, c_out, k, policy, groups=groups
    )
    nb = (c_out + (-c_out) % policy.block_size) // policy.block_size
    lo = np.zeros_like(idx)
    hi = np.full_like(idx, nb - 1)
    for spec in (dw_spec, dx_spec):
        check_divisibility(report, spec)
        check_in_bounds(report, spec, prefetch_candidates=(lo, hi, idx))
        check_vmem(report, spec, platform=platform)

    parts = ftab.conv_backward_bytes_breakdown(
        bt, h_out, w_out, c_in, c_out, k, policy, fused=True, groups=groups
    )
    dw_traffic = emulate_traffic(dw_spec, idx)
    dx_traffic = emulate_traffic(dx_spec, idx)
    exact = {
        "dw.xg_rows": dw_traffic["xg"],
        "dw.dy_panels": dw_traffic["dy2r"],
        "dw.out_flush": dw_traffic["dw"],
        "dx.w2k_fetch": dx_traffic["w2k"],
        "dx.out_writes": dx_traffic["dxp"],
    }
    for key, measured in exact.items():
        if measured != parts[key]:
            report.add(
                "pallas",
                ERROR,
                f"{site}:{key}",
                f"traffic model {parts[key]:,} elems != emulated "
                f"{measured:,}",
                model=parts[key],
                emulated=measured,
            )
    dy_model = parts["dx.dy_rows"]
    dy_meas = dx_traffic["dy2r"]
    bs = policy.block_size
    slack = 2 * (k - 1) ** 2 * bt * len(idx) * w_out * bs
    if not (dy_meas <= dy_model <= dy_meas + slack):
        report.add(
            "pallas",
            ERROR,
            f"{site}:dx.dy_rows",
            f"traffic model {dy_model:,} outside [{dy_meas:,}, "
            f"{dy_meas + slack:,}] (emulated + border-clip slack)",
            model=dy_model,
            emulated=dy_meas,
            slack=slack,
        )
    report.add(
        "pallas",
        INFO,
        site,
        "fused kernel traffic cross-checked against bytes model "
        f"({len(exact)} exact components, dy_rows within clip slack)",
        model={k_: int(v) for k_, v in parts.items()},
        emulated_dw={k_: int(v) for k_, v in dw_traffic.items()},
        emulated_dx={k_: int(v) for k_, v in dx_traffic.items()},
    )


def check_paged_attention_site(
    report: Report,
    *,
    b: int,
    s: int,
    h: int,
    d: int,
    n_pages: int,
    bs_pg: int,
    kvh: int,
    nb: int,
    platform: str = "tpu",
) -> None:
    """Audit the paged-attention launch geometry for one serve config."""
    spec = specs.paged_attention_spec(
        b=b, s=s, h=h, d=d, n_pages=n_pages, bs_pg=bs_pg, kvh=kvh, nb=nb
    )
    lo = np.zeros((b * nb,), np.int32)
    hi = np.full((b * nb,), n_pages - 1, np.int32)
    check_divisibility(report, spec)
    check_in_bounds(report, spec, prefetch_candidates=(lo, hi))
    check_vmem(report, spec, platform=platform)
