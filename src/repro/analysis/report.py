"""Findings and reports for the static program auditor.

Every check in :mod:`repro.analysis` emits :class:`Finding`s into a
:class:`Report`; the CLI (``launch/analyze.py``) renders the report and
exits non-zero iff any finding is an error. Severities:

  * ``error`` — a contract is violated (savings mismatch beyond
    tolerance, dtype leak, host callback in a jitted step, OOB index
    map, retrace budget blown). CI fails.
  * ``warn``  — suspicious but not provably wrong (dead contraction
    FLOPs, unbounded loop encountered during counting).
  * ``info``  — audit evidence (per-site counts, traffic totals) kept
    in the report so ``--json`` consumers get the numbers that backed
    the verdict.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

ERROR = "error"
WARN = "warn"
INFO = "info"

_LEVELS = (ERROR, WARN, INFO)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One check outcome at one site.

    ``check`` names the auditor pass (``savings``, ``dtype``,
    ``transfer``, ``dead``, ``retrace``, ``pallas``); ``site`` the
    program point it applies to (a policy site path, kernel name, or
    step name); ``data`` carries the numbers behind the message.
    """

    check: str
    severity: str
    site: str
    message: str
    data: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        assert self.severity in _LEVELS, self.severity


@dataclasses.dataclass
class Report:
    """Accumulated findings for one audited program/config."""

    name: str
    findings: list[Finding] = dataclasses.field(default_factory=list)

    def add(self, check: str, severity: str, site: str, message: str,
            **data: Any) -> Finding:
        f = Finding(check, severity, site, message, data)
        self.findings.append(f)
        return f

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == WARN]

    @property
    def ok(self) -> bool:
        return not self.errors()

    def render(self, *, verbose: bool = False) -> str:
        """Human-readable table: one line per finding."""
        lines = [f"== {self.name} =="]
        shown = 0
        for f in self.findings:
            if f.severity == INFO and not verbose:
                continue
            shown += 1
            lines.append(f"  [{f.severity:5s}] {f.check:8s} {f.site}: {f.message}")
        lines.append(
            f"  {len(self.errors())} error(s), {len(self.warnings())} "
            f"warning(s), {len(self.findings) - shown} finding(s) hidden"
            if not verbose
            else f"  {len(self.errors())} error(s), "
            f"{len(self.warnings())} warning(s)"
        )
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps(
            {
                "name": self.name,
                "ok": self.ok,
                "findings": [dataclasses.asdict(f) for f in self.findings],
            },
            indent=2,
            default=str,
        )
