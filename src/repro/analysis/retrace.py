"""Retrace-budget checker: compiled-executable counts, statically.

Every distinct (shape, static-arg) signature a jitted function sees is
one XLA compile. Two surfaces in this repo are designed around a bounded
jit cache, and this module enumerates their executables *without
running anything*:

* **Train** — a :class:`~repro.core.policy.PolicyProgram`'s per-step
  site tables. ``Schedule.scale`` is bucket-quantized, so the distinct
  tables over any run are a subset of the tables the bucket scales
  produce; :func:`train_tables` enumerates exactly that candidate set
  (``{0} ∪ {bucket/target}``) and deduplicates the resolved
  :class:`SitePolicies`. The documented budget is
  ``len(schedule.rate_buckets)`` (see ``core/schedulers.py``) — one
  compiled train step per bucket, whatever the schedule's shape.
* **Serve** — the engine's jit surface (``serve/engine.py``): the
  target ``_step_fn`` compiles once per width in
  ``ServeConfig.widths`` (the decode-width ladder, prefill chunk
  included); a speculative drafter adds its own step at the catch-up
  width (``prefill_chunk``) and the width-1 propose step; an
  encoder-decoder adds one ``encode`` executable per plane. The
  documented budget is :data:`SERVE_JIT_BUDGET` total executables —
  past that, width-ladder "flexibility" is really a compile-time and
  HBM (executable cache) regression.

Both checks fail (error finding) when the static bound exceeds the
budget; the enumeration itself is attached as an info finding so
``--json`` consumers can see where the executables come from.
"""
from __future__ import annotations

from collections.abc import Sequence

from repro.analysis.report import ERROR, INFO, Report
from repro.core.policy import PolicyProgram, SitePolicies

#: documented ceiling on serve-engine executables (all planes summed).
SERVE_JIT_BUDGET = 12


# ----------------------------------------------------------------------
# train
# ----------------------------------------------------------------------


def train_tables(
    program: PolicyProgram,
    sites: Sequence[str],
    *,
    depth: int | None = None,
) -> list[SitePolicies]:
    """Distinct per-step site tables the program can ever compile.

    Candidate scales are ``{0}`` plus every ``bucket / target`` the
    schedule's quantizer can emit; ``at_scale`` re-buckets per site, so
    deduplicating the resulting tables gives the exact executable set —
    typically far fewer than ``len(rate_buckets)`` for bar-like
    schedules that only ever visit {off, peak}.
    """
    resolved = program.resolve(sites, depth=depth)
    sched = program.schedule
    scales = {0.0}
    if sched.target > 0:
        scales |= {min(b / sched.target, 1.0) for b in sched.rate_buckets}
    seen: list[SitePolicies] = []
    for s in sorted(scales):
        table = resolved.at_scale(s)
        if table not in seen:
            seen.append(table)
    return seen


def check_train_retrace(
    report: Report,
    program: PolicyProgram,
    sites: Sequence[str],
    *,
    depth: int | None = None,
    budget: int | None = None,
) -> int:
    """Bound train-step executables; error when over budget."""
    if budget is None:
        budget = len(program.schedule.rate_buckets)
    tables = train_tables(program, sites, depth=depth)
    n = len(tables)
    sev = ERROR if n > budget else INFO
    report.add(
        "retrace",
        sev,
        "train_step",
        f"{n} distinct compiled step table(s) (budget {budget}: one per "
        "schedule rate bucket)",
        executables=n,
        budget=budget,
        rate_buckets=list(program.schedule.rate_buckets),
    )
    return n


# ----------------------------------------------------------------------
# serve
# ----------------------------------------------------------------------


def serve_executables(cfg, serve_cfg) -> dict[str, int]:
    """Executable count per jitted engine function, from config alone.

    Mirrors ``ServeEngine.__init__`` + ``_pick_width`` +
    ``_draft_propose``: the target step sees every ladder width; the
    drafter sees the catch-up width (``prefill_chunk``) and, for
    ``spec_k > 1``, the width-1 propose step; encdec planes add one
    encode each.
    """
    widths = serve_cfg.widths
    out = {"_step_fn": len(widths)}
    if serve_cfg.spec_k > 0:
        draft_widths = {serve_cfg.prefill_chunk}
        if serve_cfg.spec_k > 1:
            draft_widths.add(1)
        out["_draft_step_fn"] = len(draft_widths)
    if cfg.family == "encdec":
        out["_encode"] = 1
        if serve_cfg.spec_k > 0:
            out["_draft_encode"] = 1
    return out


def check_serve_retrace(
    report: Report,
    cfg,
    serve_cfg,
    *,
    budget: int = SERVE_JIT_BUDGET,
) -> int:
    """Bound serve-engine executables; error when over budget."""
    per_fn = serve_executables(cfg, serve_cfg)
    total = sum(per_fn.values())
    sev = ERROR if total > budget else INFO
    report.add(
        "retrace",
        sev,
        "serve_engine",
        f"{total} executable(s) across {len(per_fn)} jit function(s) "
        f"(budget {budget}); widths {list(serve_cfg.widths)}",
        executables=total,
        budget=budget,
        per_fn=per_fn,
        widths=list(serve_cfg.widths),
    )
    return total
