"""Honest-savings audit: jaxpr-measured backward FLOPs vs analytic tables.

For every sparsifiable site of a model this module traces the *actual*
backward program (``jax.vjp`` of ``sparse_conv2d`` / ``sparse_dense``
under the site's resolved policy, abstract inputs only — nothing runs)
and counts its contractions with :mod:`repro.analysis.jaxpr_walk`. The
measured ``(lo, hi)`` interval must equal
:func:`repro.core.flops.conv_backward_contraction_bounds` /
``dense_backward_contraction_bounds`` **exactly** — those tables model
every route the engine takes, including Pallas tile padding and the
im2col materialization convs, so any daylight between the two means the
paper-facing savings numbers are dishonest and the audit errors.

The legacy Eq.-9 tables (``conv_backward_flops_policy`` et al., what
``benchmarks/roofline.py`` historically multiplied out) are compared as
a *sanity band*: they deliberately omit the im2col materialization and
the fused-dX padded sweep, so the audit only warns when they drift more
than 2x from the measured interval — block-rounding and bookkeeping
tolerance, not a contract.

Probes are geometry-exact: convs are traced at stride 1 with
``(K-1)``-total padding so ``H_in == H_out`` and the padded image height
is the ``H_out + K - 1`` the bounds table assumes. The analytic tables
carry no stride parameter, so a strided site audits through its
stride-1 twin with the same output geometry — same M, N, routing and
padding, hence the same backward contraction cost the tables model.

Traces are cached on ``(geometry, policy)``; per-model audits over
ResNet/DDPM conv walks and the transformer dense walk therefore pay one
trace per distinct site geometry.
"""
from __future__ import annotations

import functools

import jax

from repro.analysis import jaxpr_walk
from repro.analysis.lints import lint_backward_counts
from repro.analysis.report import ERROR, INFO, Report, WARN
from repro.core import flops as ftab
from repro.core import sparse_conv2d, sparse_dense
from repro.core.policy import PolicyLike, SsPropPolicy, policy_for

#: multiplicative sanity band for the legacy Eq.-9 tables (see module
#: docstring) — measured/legacy outside [1/2, 2] is a warning.
LEGACY_BAND = 2.0


# ----------------------------------------------------------------------
# cached probe traces
# ----------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def conv_backward_counts(
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: SsPropPolicy,
    groups: int = 1,
    dtype: str = "float32",
) -> jaxpr_walk.Counts:
    """Walker census of one conv site's backward program (trace only)."""
    pl_, pr = (k - 1) // 2, (k - 1) - (k - 1) // 2
    x = jax.ShapeDtypeStruct((bt, c_in, h_out, w_out), dtype)
    w = jax.ShapeDtypeStruct((c_out, c_in // groups, k, k), dtype)
    b = jax.ShapeDtypeStruct((c_out,), dtype)
    dy = jax.ShapeDtypeStruct((bt, c_out, h_out, w_out), dtype)

    def bwd(x_, w_, b_, dy_):
        _, vjp = jax.vjp(
            lambda xa, wa, ba: sparse_conv2d(
                xa,
                wa,
                ba,
                stride=1,
                padding=((pl_, pr), (pl_, pr)),
                groups=groups,
                policy=policy,
            ),
            x_,
            w_,
            b_,
        )
        return vjp(dy_)

    closed = jax.make_jaxpr(bwd)(x, w, b, dy)
    return jaxpr_walk.count(closed, name=f"conv[{c_in}->{c_out}]k{k}")


@functools.lru_cache(maxsize=None)
def dense_backward_counts(
    m: int,
    d_in: int,
    d_out: int,
    policy: SsPropPolicy,
    dtype: str = "bfloat16",
) -> jaxpr_walk.Counts:
    """Walker census of one dense site's backward program (trace only)."""
    x = jax.ShapeDtypeStruct((m, d_in), dtype)
    w = jax.ShapeDtypeStruct((d_in, d_out), dtype)
    b = jax.ShapeDtypeStruct((d_out,), dtype)
    dy = jax.ShapeDtypeStruct((m, d_out), dtype)

    def bwd(x_, w_, b_, dy_):
        _, vjp = jax.vjp(
            lambda xa, wa, ba: sparse_dense(xa, wa, ba, policy=policy),
            x_,
            w_,
            b_,
        )
        return vjp(dy_)

    closed = jax.make_jaxpr(bwd)(x, w, b, dy)
    return jaxpr_walk.count(closed, name=f"dense[{d_in}->{d_out}]")


def clear_cache() -> None:
    """Drop cached traces (tests that monkeypatch the engine need this)."""
    conv_backward_counts.cache_clear()
    dense_backward_counts.cache_clear()


# ----------------------------------------------------------------------
# per-site audits
# ----------------------------------------------------------------------


def _legacy_band_check(
    report: Report, site: str, lo: int, hi: int, legacy: int, dense_ref: int
) -> None:
    mid = (lo + hi) / 2 or 1
    ratio = legacy / mid
    sev = INFO if 1 / LEGACY_BAND <= ratio <= LEGACY_BAND else WARN
    report.add(
        "savings",
        sev,
        site,
        f"measured backward contraction FLOPs in [{lo:,}, {hi:,}] "
        f"({mid / dense_ref:.3f}x dense); legacy table {legacy:,} "
        f"({ratio:.2f}x measured mid)",
        flops_lo=lo,
        flops_hi=hi,
        legacy=legacy,
        dense_ref=dense_ref,
        ratio_vs_dense=mid / dense_ref,
    )


def audit_conv_site(
    report: Report,
    site: str,
    bt: int,
    h_out: int,
    w_out: int,
    c_in: int,
    c_out: int,
    k: int,
    policy: SsPropPolicy,
    *,
    groups: int = 1,
    dtype: str = "float32",
) -> jaxpr_walk.Counts:
    """Audit one conv site: measured == analytic bounds, lints, band."""
    counts = conv_backward_counts(
        bt, h_out, w_out, c_in, c_out, k, policy, groups, dtype
    )
    lo, hi = ftab.conv_backward_contraction_bounds(
        bt, h_out, w_out, c_in, c_out, k, policy,
        groups=groups, h_pad=h_out + k - 1,
    )
    if (counts.flops_lo, counts.flops_hi) != (lo, hi):
        report.add(
            "savings",
            ERROR,
            site,
            f"jaxpr backward FLOPs ({counts.flops_lo:,}, "
            f"{counts.flops_hi:,}) != analytic bounds ({lo:,}, {hi:,})",
            measured=(counts.flops_lo, counts.flops_hi),
            analytic=(lo, hi),
        )
    if groups == 1:
        legacy = ftab.conv_backward_flops_policy(
            bt, h_out, w_out, c_in, c_out, k, policy
        )
        dense_ref = ftab.conv_backward_flops(bt, h_out, w_out, c_in, c_out, k)
        _legacy_band_check(report, site, lo, hi, legacy, dense_ref)
    lint_backward_counts(report, site, counts, policy)
    return counts


def audit_dense_site(
    report: Report,
    site: str,
    m: int,
    d_in: int,
    d_out: int,
    policy: SsPropPolicy,
    *,
    dtype: str = "bfloat16",
) -> jaxpr_walk.Counts:
    """Audit one dense site: measured == analytic bounds, lints, band."""
    counts = dense_backward_counts(m, d_in, d_out, policy, dtype)
    lo, hi = ftab.dense_backward_contraction_bounds(m, d_in, d_out, policy)
    if (counts.flops_lo, counts.flops_hi) != (lo, hi):
        report.add(
            "savings",
            ERROR,
            site,
            f"jaxpr backward FLOPs ({counts.flops_lo:,}, "
            f"{counts.flops_hi:,}) != analytic bounds ({lo:,}, {hi:,})",
            measured=(counts.flops_lo, counts.flops_hi),
            analytic=(lo, hi),
        )
    legacy = ftab.dense_backward_flops_policy(m, d_in, d_out, policy)
    dense_ref = ftab.dense_backward_flops(m, d_in, d_out)
    _legacy_band_check(report, site, lo, hi, legacy, dense_ref)
    lint_backward_counts(report, site, counts, policy)
    return counts


# ----------------------------------------------------------------------
# per-model audits
# ----------------------------------------------------------------------


def audit_resnet(
    name: str,
    image,
    policy: PolicyLike,
    *,
    batch: int,
) -> Report:
    """Audit every conv site of a ResNet variant at one input shape."""
    from repro.models import resnet

    report = Report(f"savings:{name}")
    for site, c_in, c_out, k, h_out, w_out in resnet.iter_conv_shapes(
        name, image
    ):
        audit_conv_site(
            report, site, batch, h_out, w_out, c_in, c_out, k,
            policy_for(policy, site),
        )
    return report


def audit_ddpm(
    image,
    policy: PolicyLike,
    *,
    batch: int,
    base: int = 64,
) -> Report:
    """Audit every conv site of the DDPM UNet at one input shape."""
    from repro.models import ddpm

    report = Report("savings:ddpm")
    for site, c_in, c_out, k, h_out, w_out in ddpm.iter_conv_shapes(
        image, base
    ):
        audit_conv_site(
            report, site, batch, h_out, w_out, c_in, c_out, k,
            policy_for(policy, site),
        )
    return report


def audit_lm(
    cfg,
    policy: PolicyLike,
    *,
    batch: int,
    seq: int,
) -> Report:
    """Audit every dense projection geometry of a transformer config.

    Sites come from :func:`repro.models.transformer.iter_dense_shapes`
    (depth-aggregated, one audit per distinct geometry); the per-site
    policy is resolved against the representative ``layer_{si}/...``
    path, matching what ``stack_apply`` does at that depth.
    """
    from repro.models import transformer

    report = Report(f"savings:{cfg.name}")
    for site, m, d_in, d_out, count in transformer.iter_dense_shapes(
        cfg, batch, seq
    ):
        counts = audit_dense_site(
            report, site, m, d_in, d_out, policy_for(policy, site),
            dtype=cfg.dtype,
        )
        report.add(
            "savings",
            INFO,
            site,
            f"x{count} layers: per-layer measured "
            f"[{counts.flops_lo:,}, {counts.flops_hi:,}]",
            count=count,
            flops_lo=counts.flops_lo,
            flops_hi=counts.flops_hi,
        )
    return report


def lm_site_flops(cfg, policy: PolicyLike, *, batch: int, seq: int):
    """Jaxpr-derived per-site backward contraction FLOPs for roofline.

    Returns ``[(site, count, fwd_flops, bwd_lo, bwd_hi), ...]`` — the
    measured (not 6ND) per-site numbers ``benchmarks/roofline.py``
    consumes. ``fwd_flops`` is the plain ``2*M*D_in*D_out`` forward
    cost; the backward interval comes from the traced program.
    """
    from repro.models import transformer

    rows = []
    for site, m, d_in, d_out, count in transformer.iter_dense_shapes(
        cfg, batch, seq
    ):
        counts = dense_backward_counts(
            m, d_in, d_out, policy_for(policy, site), cfg.dtype
        )
        rows.append(
            (site, count, 2 * m * d_in * d_out, counts.flops_lo,
             counts.flops_hi)
        )
    return rows
